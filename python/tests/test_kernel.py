"""Kernel-vs-reference correctness: the CORE numeric signal.

Each Pallas kernel (interpret=True) is checked against its pure-jnp oracle
in compile/kernels/ref.py, both on fixed seeds and under hypothesis sweeps
of shapes and value distributions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import cosine_scores, facedetect, sigmatch_counts
from compile.kernels import ref

RNG = np.random.default_rng(0)


def _users_cats(b, k, n, seed=0):
    rng = np.random.default_rng(seed)
    users = rng.normal(size=(b, k)).astype(np.float32)
    cats = rng.normal(size=(k, n)).astype(np.float32)
    return jnp.asarray(users), jnp.asarray(cats)


# ------------------------------------------------------------------- cosine


class TestCosine:
    def test_matches_ref_default_shape(self):
        users, cats = _users_cats(8, 256, 512)
        got = cosine_scores(users, cats)
        want = ref.cosine_scores_ref(users, cats)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_scores_bounded(self):
        users, cats = _users_cats(8, 256, 512, seed=1)
        got = np.asarray(cosine_scores(users, cats))
        assert np.all(got <= 1.0 + 1e-4) and np.all(got >= -1.0 - 1e-4)

    def test_identical_vector_scores_one(self):
        v = np.abs(RNG.normal(size=256)).astype(np.float32) + 0.1
        users = jnp.asarray(np.tile(v, (8, 1)))
        cats = jnp.asarray(np.tile(v[:, None], (1, 128)))
        got = np.asarray(cosine_scores(users, cats, block_n=128))
        np.testing.assert_allclose(got, np.ones((8, 128)), atol=1e-4)

    def test_zero_pad_columns_score_zero(self):
        users, cats = _users_cats(8, 256, 256, seed=2)
        cats = cats.at[:, 128:].set(0.0)
        got = np.asarray(cosine_scores(users, cats, block_n=128))
        np.testing.assert_allclose(got[:, 128:], 0.0, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 16),
        k=st.sampled_from([32, 64, 256]),
        nblocks=st.integers(1, 4),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_shapes(self, b, k, nblocks, seed):
        users, cats = _users_cats(b, k, 128 * nblocks, seed=seed)
        got = cosine_scores(users, cats)
        want = ref.cosine_scores_ref(users, cats)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- sigmatch


def _plant(windows, sigs, wi, si):
    """Plant signature column si into window row wi; return updated windows."""
    return windows.at[wi, :].set(sigs[:, si])


class TestSigmatch:
    def _data(self, w=1024, l=16, s=128, seed=0):
        rng = np.random.default_rng(seed)
        windows = jnp.asarray(
            rng.integers(0, 256, size=(w, l)).astype(np.float32)
        )
        sigs = jnp.asarray(rng.integers(0, 256, size=(l, s)).astype(np.float32))
        return windows, sigs

    def test_matches_ref(self):
        windows, sigs = self._data()
        windows = _plant(windows, sigs, 3, 7)
        windows = _plant(windows, sigs, 900, 7)
        windows = _plant(windows, sigs, 511, 42)
        got = sigmatch_counts(windows, sigs)
        want = ref.sigmatch_counts_ref(windows, sigs)
        np.testing.assert_allclose(got, want, atol=0.01)

    def test_planted_counts_exact(self):
        rng = np.random.default_rng(9)
        # Windows of value 300 can never collide with byte signatures.
        windows = jnp.full((512, 16), 300.0, jnp.float32)
        sigs = jnp.asarray(rng.integers(0, 256, size=(16, 128)).astype(np.float32))
        windows = _plant(windows, sigs, 0, 5)
        windows = _plant(windows, sigs, 100, 5)
        windows = _plant(windows, sigs, 511, 99)
        got = np.asarray(sigmatch_counts(windows, sigs))
        want = np.zeros(128, np.float32)
        want[5], want[99] = 2.0, 1.0
        np.testing.assert_array_equal(got, want)

    def test_pad_rows_never_match(self):
        rng = np.random.default_rng(10)
        windows = jnp.full((512, 16), -1.0, jnp.float32)
        sigs = jnp.asarray(rng.integers(0, 256, size=(16, 128)).astype(np.float32))
        got = np.asarray(sigmatch_counts(windows, sigs))
        np.testing.assert_array_equal(got, np.zeros(128, np.float32))

    @settings(max_examples=15, deadline=None)
    @given(
        wblocks=st.integers(1, 4),
        s=st.sampled_from([32, 128]),
        nplant=st.integers(0, 8),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_planted(self, wblocks, s, nplant, seed):
        rng = np.random.default_rng(seed)
        w = 512 * wblocks
        windows = jnp.asarray(rng.integers(256, 512, size=(w, 16)).astype(np.float32))
        sigs = jnp.asarray(rng.integers(0, 256, size=(16, s)).astype(np.float32))
        expect = np.zeros(s, np.float32)
        for _ in range(nplant):
            wi, si = int(rng.integers(w)), int(rng.integers(s))
            windows = _plant(windows, sigs, wi, si)
        # Recompute expectation from final windows (plants may overwrite).
        expect = np.asarray(ref.sigmatch_counts_ref(windows, sigs))
        got = np.asarray(sigmatch_counts(windows, sigs))
        np.testing.assert_allclose(got, expect, atol=0.01)


# --------------------------------------------------------------- facedetect


class TestFacedetect:
    def _data(self, p=1024, d=64, f=16, seed=0):
        rng = np.random.default_rng(seed)
        patches = jnp.asarray(rng.normal(size=(p, d)).astype(np.float32))
        filters = rng.normal(size=(d, f)).astype(np.float32)
        filters -= filters.mean(axis=0, keepdims=True)  # zero-mean
        return patches, jnp.asarray(filters)

    def test_matches_ref(self):
        patches, filters = self._data()
        t = jnp.float32(2.0)
        gm, gc = facedetect(patches, filters, t)
        wm, wc = ref.facedetect_ref(patches, filters, t)
        np.testing.assert_allclose(gm, wm, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gc, wc, atol=0.01)

    def test_zero_patches_score_zero(self):
        patches = jnp.zeros((512, 64), jnp.float32)
        _, filters = self._data()
        gm, gc = facedetect(patches, filters, jnp.float32(0.5))
        np.testing.assert_allclose(gm, np.zeros(16), atol=1e-6)
        np.testing.assert_allclose(gc, np.zeros(16), atol=1e-6)

    def test_planted_face_detected(self):
        patches, filters = self._data(seed=3)
        f0 = np.asarray(filters)[:, 0]
        strong = 10.0 * f0 / np.linalg.norm(f0)
        patches = patches.at[77, :].set(jnp.asarray(strong))
        t = jnp.float32(float(strong @ f0) - 1e-3)
        _, gc = facedetect(patches, filters, t)
        assert float(gc[0]) >= 1.0

    @settings(max_examples=15, deadline=None)
    @given(
        pblocks=st.integers(1, 4),
        f=st.sampled_from([8, 16]),
        thresh=st.floats(-1.0, 4.0),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_shapes(self, pblocks, f, thresh, seed):
        rng = np.random.default_rng(seed)
        patches = jnp.asarray(rng.normal(size=(256 * pblocks, 64)).astype(np.float32))
        filters = jnp.asarray(rng.normal(size=(64, f)).astype(np.float32))
        t = jnp.float32(thresh)
        gm, gc = facedetect(patches, filters, t)
        wm, wc = ref.facedetect_ref(patches, filters, t)
        np.testing.assert_allclose(gm, wm, rtol=1e-4, atol=1e-4)
        # Responses within 1e-5 of the threshold can legitimately flip.
        resp = np.asarray(patches) @ np.asarray(filters)
        margin = np.min(np.abs(resp - float(t)))
        if margin > 1e-4:
            np.testing.assert_allclose(gc, wc, atol=0.01)
