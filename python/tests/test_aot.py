"""AOT lowering tests: HLO text is produced and the manifest is faithful."""

import json

import jax

from compile import aot, model


class TestLowering:
    def test_every_model_lowers_to_hlo_text(self):
        for name in model.MODELS:
            _, text = aot.lower_model(name)
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_hlo_has_no_custom_calls(self):
        # interpret=True Pallas must lower to plain HLO ops — a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        for name in model.MODELS:
            _, text = aot.lower_model(name)
            assert "custom-call" not in text, f"{name} contains custom-call"

    def test_root_is_tuple(self):
        # return_tuple=True: the Rust side unwraps with Literal::to_tuple().
        for name in model.MODELS:
            _, text = aot.lower_model(name)
            entry = text[text.index("ENTRY") :]
            root = [l for l in entry.splitlines() if "ROOT" in l]
            assert root and "tuple(" in root[0], name

    def test_manifest_shapes_match_eval_shape(self, tmp_path):
        import subprocess, sys, os

        # Run the module the same way the Makefile does.
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
        )
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert set(manifest) == set(model.MODELS)
        for name, entry in manifest.items():
            fn, specs = model.MODELS[name]
            outs = jax.tree_util.tree_leaves(jax.eval_shape(fn, *specs))
            assert [o["shape"] for o in entry["inputs"]] == [
                list(s.shape) for s in specs
            ]
            assert [o["shape"] for o in entry["outputs"]] == [
                list(o.shape) for o in outs
            ]
            assert (tmp_path / entry["file"]).exists()

    def test_op_census_reports_dot(self):
        _, text = aot.lower_model("categorize")
        census = aot.hlo_report(text)
        assert any("dot" in k for k in census), census
