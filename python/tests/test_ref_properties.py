"""Property tests on the oracle semantics themselves (and, transitively,
on the Pallas kernels, which earlier tests pin to the oracles).

These encode the *mathematical* invariants the apps rely on:
cosine scale-invariance, signature-match shift/permutation behavior,
detector linearity — so a kernel change that preserves allclose-to-oracle
but breaks an invariant the apps assume is still caught.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import cosine_scores, ref, sigmatch_counts


class TestCosineInvariants:
    @settings(max_examples=15, deadline=None)
    @given(scale=st.floats(0.1, 50.0), seed=st.integers(0, 2**31))
    def test_scale_invariance(self, scale, seed):
        rng = np.random.default_rng(seed)
        users = rng.normal(size=(4, 64)).astype(np.float32)
        cats = rng.normal(size=(64, 128)).astype(np.float32)
        a = np.asarray(cosine_scores(jnp.asarray(users), jnp.asarray(cats)))
        b = np.asarray(
            cosine_scores(jnp.asarray(scale * users), jnp.asarray(cats))
        )
        np.testing.assert_allclose(a, b, atol=2e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_column_permutation_permutes_scores(self, seed):
        rng = np.random.default_rng(seed)
        users = rng.normal(size=(4, 64)).astype(np.float32)
        cats = rng.normal(size=(64, 128)).astype(np.float32)
        perm = rng.permutation(128)
        a = np.asarray(cosine_scores(jnp.asarray(users), jnp.asarray(cats)))
        b = np.asarray(
            cosine_scores(jnp.asarray(users), jnp.asarray(cats[:, perm]))
        )
        np.testing.assert_allclose(a[:, perm], b, atol=1e-4)


class TestSigmatchInvariants:
    @settings(max_examples=10, deadline=None)
    @given(shift=st.integers(1, 400), seed=st.integers(0, 2**31))
    def test_match_count_invariant_under_row_rotation(self, shift, seed):
        # Rotating the window rows (reordering scan positions) must not
        # change per-signature totals.
        rng = np.random.default_rng(seed)
        windows = rng.integers(0, 256, size=(512, 16)).astype(np.float32)
        sigs = rng.integers(0, 256, size=(16, 32)).astype(np.float32)
        windows[7] = sigs[:, 3]
        a = np.asarray(sigmatch_counts(jnp.asarray(windows), jnp.asarray(sigs)))
        b = np.asarray(
            sigmatch_counts(jnp.asarray(np.roll(windows, shift, axis=0)), jnp.asarray(sigs))
        )
        np.testing.assert_array_equal(a, b)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_counts_are_nonnegative_integers(self, seed):
        rng = np.random.default_rng(seed)
        windows = rng.integers(0, 256, size=(512, 16)).astype(np.float32)
        sigs = rng.integers(0, 256, size=(16, 32)).astype(np.float32)
        c = np.asarray(sigmatch_counts(jnp.asarray(windows), jnp.asarray(sigs)))
        assert np.all(c >= 0)
        np.testing.assert_array_equal(c, np.round(c))

    def test_off_by_one_byte_never_matches(self):
        rng = np.random.default_rng(0)
        sigs = rng.integers(1, 255, size=(16, 32)).astype(np.float32)
        windows = np.tile(sigs[:, 5], (512, 1)).astype(np.float32)
        windows[:, 3] += 1.0  # one byte off
        c = np.asarray(
            ref.sigmatch_counts_ref(jnp.asarray(windows), jnp.asarray(sigs))
        )
        assert c[5] == 0.0


class TestFacedetectInvariants:
    @settings(max_examples=10, deadline=None)
    @given(gain=st.floats(1.5, 10.0), seed=st.integers(0, 2**31))
    def test_response_maxima_scale_linearly(self, gain, seed):
        rng = np.random.default_rng(seed)
        patches = rng.normal(size=(256, 64)).astype(np.float32)
        filters = rng.normal(size=(64, 8)).astype(np.float32)
        filters -= filters.mean(axis=0, keepdims=True)
        t = jnp.float32(1e9)  # count nothing; compare maxima only
        m1, _ = ref.facedetect_ref(jnp.asarray(patches), jnp.asarray(filters), t)
        m2, _ = ref.facedetect_ref(
            jnp.asarray(gain * patches), jnp.asarray(filters), t
        )
        np.testing.assert_allclose(np.asarray(m2), gain * np.asarray(m1), rtol=1e-3)

    def test_counts_monotone_in_threshold(self):
        rng = np.random.default_rng(1)
        patches = rng.normal(size=(256, 64)).astype(np.float32)
        filters = rng.normal(size=(64, 8)).astype(np.float32)
        prev = None
        for t in [-5.0, 0.0, 2.0, 5.0]:
            _, counts = ref.facedetect_ref(
                jnp.asarray(patches), jnp.asarray(filters), jnp.float32(t)
            )
            total = float(np.sum(np.asarray(counts)))
            if prev is not None:
                assert total <= prev
            prev = total
