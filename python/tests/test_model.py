"""L2 model tests: end-to-end app semantics over the Pallas kernels."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model


class TestScanChunk:
    def _sigs(self, seed=0):
        rng = np.random.default_rng(seed)
        return rng.integers(0, 256, size=(model.SIG_LEN, model.N_SIGS)).astype(
            np.float32
        )

    def test_clean_chunk_zero_hits(self):
        rng = np.random.default_rng(1)
        sigs = self._sigs()
        # Byte values 256..511 cannot collide with byte signatures.
        chunk = rng.integers(256, 512, size=model.CHUNK).astype(np.float32)
        counts, total = model.scan_chunk(jnp.asarray(chunk), jnp.asarray(sigs))
        assert float(total) == 0.0
        np.testing.assert_array_equal(np.asarray(counts), np.zeros(model.N_SIGS))

    def test_planted_signature_found_at_every_offset_class(self):
        sigs = self._sigs()
        for off in [0, 1, 1000, model.CHUNK - model.SIG_LEN]:
            chunk = np.full(model.CHUNK, 300.0, np.float32)
            chunk[off : off + model.SIG_LEN] = sigs[:, 17]
            counts, total = model.scan_chunk(jnp.asarray(chunk), jnp.asarray(sigs))
            assert float(counts[17]) == 1.0, f"offset {off}"
            assert float(total) == 1.0, f"offset {off}"

    def test_signature_straddling_end_not_counted(self):
        # A signature whose tail falls off the chunk must not match: the
        # window is padded with -1 which differs from any byte.
        sigs = self._sigs()
        chunk = np.full(model.CHUNK, 300.0, np.float32)
        chunk[model.CHUNK - 8 :] = sigs[:8, 3]
        _, total = model.scan_chunk(jnp.asarray(chunk), jnp.asarray(sigs))
        assert float(total) == 0.0

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(0, 12), seed=st.integers(0, 2**31))
    def test_hypothesis_n_plants(self, n, seed):
        rng = np.random.default_rng(seed)
        sigs = self._sigs(seed)
        chunk = np.full(model.CHUNK, 300.0, np.float32)
        # Non-overlapping plant slots, SIG_LEN apart.
        slots = rng.choice(model.CHUNK // model.SIG_LEN - 1, size=n, replace=False)
        for s in slots:
            si = int(rng.integers(model.N_SIGS))
            chunk[s * model.SIG_LEN : (s + 1) * model.SIG_LEN] = sigs[:, si]
        _, total = model.scan_chunk(jnp.asarray(chunk), jnp.asarray(sigs))
        assert float(total) == float(n)


class TestFaceDetect:
    def test_blank_image_no_faces(self):
        rng = np.random.default_rng(2)
        filters = rng.normal(size=(64, model.N_FILTERS)).astype(np.float32)
        filters -= filters.mean(axis=0, keepdims=True)
        img = jnp.zeros((model.IMG, model.IMG), jnp.float32)
        _, _, faces = model.face_detect(img, jnp.asarray(filters), jnp.float32(1.0))
        assert float(faces) == 0.0

    def test_planted_face_found(self):
        rng = np.random.default_rng(3)
        filters = rng.normal(size=(64, model.N_FILTERS)).astype(np.float32)
        filters -= filters.mean(axis=0, keepdims=True)
        img = np.zeros((model.IMG, model.IMG), np.float32)
        face = filters[:, 4].reshape(model.PATCH, model.PATCH)
        img[20 : 20 + model.PATCH, 30 : 30 + model.PATCH] = 5.0 * face
        t = 0.5 * 5.0 * float(np.sum(face * face))
        maxima, counts, faces = model.face_detect(
            jnp.asarray(img), jnp.asarray(filters), jnp.float32(t)
        )
        assert float(faces) >= 1.0
        assert float(counts[4]) >= 1.0

    def test_output_shapes(self):
        out = jax.eval_shape(
            model.face_detect,
            jax.ShapeDtypeStruct((model.IMG, model.IMG), jnp.float32),
            jax.ShapeDtypeStruct((64, model.N_FILTERS), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        assert out[0].shape == (model.N_FILTERS,)
        assert out[1].shape == (model.N_FILTERS,)
        assert out[2].shape == ()


class TestCategorize:
    def test_best_category_is_argmax(self):
        rng = np.random.default_rng(4)
        users = rng.normal(size=(model.N_USERS, model.KDIM)).astype(np.float32)
        cats = rng.normal(size=(model.KDIM, model.N_CATS)).astype(np.float32)
        scores, best, best_score = model.categorize(
            jnp.asarray(users), jnp.asarray(cats)
        )
        np.testing.assert_array_equal(
            np.asarray(best), np.argmax(np.asarray(scores), axis=1)
        )
        np.testing.assert_allclose(
            np.asarray(best_score), np.max(np.asarray(scores), axis=1), rtol=1e-6
        )

    def test_user_matching_category_wins(self):
        rng = np.random.default_rng(5)
        cats = rng.normal(size=(model.KDIM, model.N_CATS)).astype(np.float32)
        users = np.tile(cats[:, 37], (model.N_USERS, 1)).astype(np.float32)
        _, best, best_score = model.categorize(jnp.asarray(users), jnp.asarray(cats))
        assert list(np.asarray(best)) == [37] * model.N_USERS
        np.testing.assert_allclose(np.asarray(best_score), 1.0, atol=1e-4)
