"""L2: the JAX compute graphs behind the three CloneCloud apps.

Each function here is the *native compute* an app method reaches through
DroidVM's native interface (the analogue of Android's natively-implemented
API routines, §4 of the paper: "native everywhere" operations available on
both the phone and the clone). They are jitted, call the L1 Pallas kernels,
and are lowered ONCE by aot.py to HLO text that the Rust runtime loads via
PJRT. Python never runs on the request path.

AOT shapes are fixed; the Rust callers pad inputs to these shapes:
  scan_chunk   : chunk (4096,) f32 byte values, sigs (16, 128) f32
  face_detect  : img (64, 64) f32, filters (64, 16) f32, thresh () f32
  categorize   : users (8, 256) f32, cats (256, 512) f32
"""

import jax
import jax.numpy as jnp

from .kernels import cosine_scores, facedetect, sigmatch_counts

# ---------------------------------------------------------------- virus scan

CHUNK = 4096  # bytes per scan call
SIG_LEN = 16  # signature length in bytes
N_SIGS = 128  # signatures per artifact (the 1000-sig library is 8 panels)


def scan_chunk(chunk: jnp.ndarray, sigs: jnp.ndarray):
    """Scan one 4 KiB file chunk against a signature panel.

    chunk: (CHUNK,) float32 — byte values 0..255; callers pad short chunks
           with -1 so pad windows can never match.
    sigs:  (SIG_LEN, N_SIGS) float32 — signature byte columns.
    returns: (counts (N_SIGS,), total ()) — per-signature and total hits.
    """
    # Sliding windows, one per byte offset. Offsets within SIG_LEN-1 of the
    # end are padded with -1 (cross-chunk matches are handled by the Rust
    # caller overlapping chunks by SIG_LEN-1 bytes).
    padded = jnp.concatenate([chunk, jnp.full((SIG_LEN - 1,), -1.0, jnp.float32)])
    idx = jnp.arange(CHUNK)[:, None] + jnp.arange(SIG_LEN)[None, :]
    windows = padded[idx]  # (CHUNK, SIG_LEN)
    counts = sigmatch_counts(windows, sigs)
    return counts, jnp.sum(counts)


# --------------------------------------------------------------- face detect

IMG = 64  # image side
PATCH = 8  # detection window side
N_FILTERS = 16
N_PATCHES = (IMG - PATCH + 1) ** 2  # 3249
PAD_PATCHES = 3328  # next multiple of BLOCK_P=256


def face_detect(img: jnp.ndarray, filters: jnp.ndarray, thresh: jnp.ndarray):
    """Detect faces in one image with a zero-mean filter bank.

    img:     (IMG, IMG) float32 grayscale.
    filters: (PATCH*PATCH, N_FILTERS) float32 zero-mean filters.
    thresh:  () float32 detection threshold.
    returns: (maxima (N_FILTERS,), counts (N_FILTERS,), faces ()) where
             faces is the total number of above-threshold responses.
    """
    side = IMG - PATCH + 1
    rc = jnp.arange(side)
    base = (rc[:, None] * IMG + rc[None, :]).reshape(-1)  # (3249,)
    off = (jnp.arange(PATCH)[:, None] * IMG + jnp.arange(PATCH)[None, :]).reshape(-1)
    idx = base[:, None] + off[None, :]  # (3249, 64)
    patches = img.reshape(-1)[idx]
    # Pad the patch axis to the kernel tile multiple; zero patches respond
    # 0 to zero-mean filters and never cross a positive threshold.
    patches = jnp.concatenate(
        [patches, jnp.zeros((PAD_PATCHES - N_PATCHES, PATCH * PATCH), jnp.float32)]
    )
    maxima, counts = facedetect(patches, filters, thresh)
    return maxima, counts, jnp.sum(counts)


# ---------------------------------------------------------------- categorize

N_USERS = 8  # interest vectors scored per call (one page-visit batch)
KDIM = 256  # keyword-vector dimensionality
N_CATS = 512  # category panel width (a DMOZ level is scored in panels)


def categorize(users: jnp.ndarray, cats: jnp.ndarray):
    """Score user interest vectors against one DMOZ category panel.

    users: (N_USERS, KDIM) float32.
    cats:  (KDIM, N_CATS) float32 — zero columns are padding and score ~0.
    returns: (scores (N_USERS, N_CATS), best (N_USERS,) int32,
              best_score (N_USERS,)).
    """
    scores = cosine_scores(users, cats)
    best = jnp.argmax(scores, axis=1).astype(jnp.int32)
    best_score = jnp.max(scores, axis=1)
    return scores, best, best_score


# ------------------------------------------------------------- AOT registry

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# name -> (fn, example arg specs). aot.py lowers each entry to
# artifacts/<name>.hlo.txt and records shapes in artifacts/manifest.json.
MODELS = {
    "scan_chunk": (scan_chunk, (_spec(CHUNK), _spec(SIG_LEN, N_SIGS))),
    "face_detect": (face_detect, (_spec(IMG, IMG), _spec(PATCH * PATCH, N_FILTERS), _spec())),
    "categorize": (categorize, (_spec(N_USERS, KDIM), _spec(KDIM, N_CATS))),
}
