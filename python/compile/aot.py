"""AOT lowering: JAX models -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`). The text
parser reassigns ids, so text round-trips cleanly. Lowering goes through
stablehlo -> XlaComputation with return_tuple=True; the Rust side unwraps
with Literal::to_tuple(). See /opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [--report]
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import MODELS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name):
    fn, specs = MODELS[name]
    lowered = jax.jit(fn).lower(*specs)
    return lowered, to_hlo_text(lowered)


def hlo_report(text: str) -> dict:
    """Crude HLO op census for the L2 perf pass (fusion / redundancy check)."""
    ops = {}
    for line in text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1].strip()
        # "f32[8,512]{1,0} dot(...)" -> "dot"
        for tok in rhs.split():
            if "(" in tok:
                op = tok.split("(", 1)[0]
                ops[op] = ops.get(op, 0) + 1
                break
    return ops


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--report", action="store_true", help="print HLO op census")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name in sorted(MODELS):
        fn, specs = MODELS[name]
        lowered, text = lower_model(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *specs)
        outs = jax.tree_util.tree_leaves(out_tree)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs
            ],
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
        if args.report:
            census = hlo_report(text)
            top = sorted(census.items(), key=lambda kv: -kv[1])[:12]
            print(f"  op census: {top}")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest)} models -> {mpath}")


if __name__ == "__main__":
    main()
