"""L1 Pallas kernel: tiled cosine-similarity scoring.

CloneCloud's behavior-profiling app (Adnostic-style targeted advertising)
computes the cosine similarity between user interest keyword vectors and
the keyword vectors of DMOZ category nodes. This is the app's compute
hot-spot; on the phone it dominates the 315.8 s depth-5 run in Table 1.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the similarity is an
(B, K) x (K, N) matmul over L2-normalized operands. We tile the category
axis N into MXU-aligned blocks of 128 via BlockSpec so each grid step
holds a (K, 128) category panel in VMEM; the user panel (B, K) is small
and mapped whole into every step. Normalization of the category panel is
fused into the kernel so the HBM->VMEM traffic is one pass.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6

# MXU-aligned tile along the category axis.
BLOCK_N = 128


def _cosine_kernel(u_ref, c_ref, o_ref):
    """One grid step: score all users against one category panel.

    u_ref: (B, K) user vectors (whole array each step).
    c_ref: (K, BLOCK_N) category panel for this step.
    o_ref: (B, BLOCK_N) output scores for this panel.
    """
    u = u_ref[...]
    c = c_ref[...]
    un = u / (jnp.sqrt(jnp.sum(u * u, axis=1, keepdims=True)) + EPS)
    cn = c / (jnp.sqrt(jnp.sum(c * c, axis=0, keepdims=True)) + EPS)
    # MXU-shaped inner product; accumulate in f32.
    o_ref[...] = jnp.dot(un, cn, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def cosine_scores(users: jnp.ndarray, cats: jnp.ndarray, block_n: int = BLOCK_N):
    """Tiled cosine similarity: users (B, K) x cats (K, N) -> (B, N).

    N must be a multiple of block_n (the AOT shapes are padded by the
    caller; pad columns are zero vectors and score ~0).
    """
    b, k = users.shape
    k2, n = cats.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert n % block_n == 0, f"N={n} not a multiple of block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _cosine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(users, cats)
