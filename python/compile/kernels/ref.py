"""Pure-jnp oracles for the three CloneCloud app kernels.

These are the correctness references the Pallas kernels (cosine.py,
sigmatch.py, conv2d.py) are tested against in python/tests/. They are
deliberately written in the most direct jnp style — no tiling, no tricks —
so a mismatch always indicts the kernel, not the oracle.
"""

import jax.numpy as jnp

EPS = 1e-6


def cosine_scores_ref(users: jnp.ndarray, cats: jnp.ndarray) -> jnp.ndarray:
    """Cosine similarity between user keyword vectors and category vectors.

    users: (B, K) float32 — one row per user interest vector.
    cats:  (K, N) float32 — one column per DMOZ category keyword vector.
    returns: (B, N) float32 — cosine similarity scores.
    """
    un = users / (jnp.linalg.norm(users, axis=1, keepdims=True) + EPS)
    cn = cats / (jnp.linalg.norm(cats, axis=0, keepdims=True) + EPS)
    return un @ cn


def sigmatch_counts_ref(windows: jnp.ndarray, sigs: jnp.ndarray) -> jnp.ndarray:
    """Count exact window/signature matches.

    A window w matches signature s iff w == s elementwise, which (over
    floats encoding bytes) is equivalent to:
        |w|^2 + |s|^2 - 2 * w.s == 0
    (this is |w - s|^2).

    windows: (W, L) float32 — sliding byte windows (padded rows use -1,
             which can never equal a byte value in [0, 255]).
    sigs:    (L, S) float32 — signature byte columns.
    returns: (S,) float32 — per-signature match counts.
    """
    dots = windows @ sigs  # (W, S)
    wn2 = jnp.sum(windows * windows, axis=1, keepdims=True)  # (W, 1)
    sn2 = jnp.sum(sigs * sigs, axis=0, keepdims=True)  # (1, S)
    d2 = sn2 + wn2 - 2.0 * dots  # squared distance, >= 0 up to fp error
    match = (d2 < 0.5).astype(jnp.float32)
    return jnp.sum(match, axis=0)


def facedetect_ref(patches: jnp.ndarray, filters: jnp.ndarray, thresh: jnp.ndarray):
    """Filter-bank face detector over image patches.

    patches: (P, D) float32 — flattened 8x8 image patches (rows of pad
             patches are 0 and score 0 under the zero-mean filters).
    filters: (D, F) float32 — flattened zero-mean detection filters.
    thresh:  ()     float32 — detection threshold.
    returns: (maxima (F,), counts (F,)) — per-filter max response and the
             number of patches whose response exceeds thresh.
    """
    resp = patches @ filters  # (P, F)
    maxima = jnp.max(resp, axis=0)
    counts = jnp.sum((resp > thresh).astype(jnp.float32), axis=0)
    return maxima, counts
