"""L1 Pallas kernel: filter-bank face detection over image patches.

CloneCloud's image-search app finds faces in the phone's photo corpus via
an Android face-detection library. We build the equivalent substrate: a
bank of zero-mean detection filters correlated against every 8x8 patch of
the image. The patch correlation is an (P, D) x (D, F) matmul — conv as
matmul, the MXU-native formulation (the GPU/CPU library's nested loops
re-thought for the systolic array, DESIGN.md §Hardware-Adaptation).

The patch axis P is tiled into VMEM blocks; two outputs are reduced
across grid steps into fixed blocks: per-filter response maxima (running
max) and per-filter above-threshold counts (running sum).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Patch-axis tile.
BLOCK_P = 256
NEG_INF = -3.0e38


def _facedetect_kernel(p_ref, f_ref, t_ref, max_ref, cnt_ref):
    """One grid step: correlate BLOCK_P patches with the filter bank.

    p_ref:   (BLOCK_P, D) patch panel.
    f_ref:   (D, F) filter bank (VMEM-resident every step).
    t_ref:   (1, 1) detection threshold.
    max_ref: (1, F) running per-filter maxima.
    cnt_ref: (1, F) running per-filter detection counts.
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        max_ref[...] = jnp.full_like(max_ref, NEG_INF)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    resp = jnp.dot(p_ref[...], f_ref[...], preferred_element_type=jnp.float32)
    max_ref[...] = jnp.maximum(max_ref[...], jnp.max(resp, axis=0, keepdims=True))
    hits = (resp > t_ref[0, 0]).astype(jnp.float32)
    cnt_ref[...] += jnp.sum(hits, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_p",))
def facedetect(
    patches: jnp.ndarray,
    filters: jnp.ndarray,
    thresh: jnp.ndarray,
    block_p: int = BLOCK_P,
):
    """Per-filter (maxima, counts): patches (P, D), filters (D, F), thresh ().

    P must be a multiple of block_p; pad patches are all-zero and respond
    0 to every zero-mean filter (never above a positive threshold).
    """
    p, d = patches.shape
    d2, f = filters.shape
    assert d == d2, f"patch dim {d} vs filter dim {d2}"
    assert p % block_p == 0, f"P={p} not a multiple of block_p={block_p}"
    t = jnp.reshape(thresh.astype(jnp.float32), (1, 1))
    grid = (p // block_p,)
    maxima, counts = pl.pallas_call(
        _facedetect_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, f), lambda i: (0, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, f), jnp.float32),
            jax.ShapeDtypeStruct((1, f), jnp.float32),
        ],
        interpret=True,
    )(patches, filters, t)
    return maxima[0], counts[0]
