# L1: Pallas kernels for the CloneCloud app compute hot-spots.
from .cosine import cosine_scores  # noqa: F401
from .sigmatch import sigmatch_counts  # noqa: F401
from .conv2d import facedetect  # noqa: F401
