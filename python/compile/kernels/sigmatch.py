"""L1 Pallas kernel: sliding-window virus-signature matching.

CloneCloud's virus-scanner app matches the phone file system against a
library of byte signatures. The paper's per-byte scan loop is re-stated
for the MXU (DESIGN.md §Hardware-Adaptation): an exact window==signature
match is detected through squared euclidean distance,

    |w - s|^2 = |w|^2 + |s|^2 - 2 w.s,

whose cross term is a (W, L) x (L, S) matmul — the TPU-native form of
string matching. The window axis W is tiled into VMEM-sized blocks; the
signature panel (L, S) is small and resident in VMEM across all steps.
Per-signature match counts are accumulated across grid steps into a
single output block (classic Pallas reduction: all steps map to output
block 0; step 0 zero-initializes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Window-axis tile: 512 windows per grid step.
BLOCK_W = 512


def _sigmatch_kernel(w_ref, s_ref, sn2_ref, o_ref):
    """One grid step: match BLOCK_W windows against all signatures.

    w_ref:   (BLOCK_W, L) window panel.
    s_ref:   (L, S) signature matrix (whole, VMEM-resident).
    sn2_ref: (1, S) precomputed per-signature squared norms.
    o_ref:   (1, S) accumulated match counts (same block every step).
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...]
    s = s_ref[...]
    dots = jnp.dot(w, s, preferred_element_type=jnp.float32)  # (BW, S)
    wn2 = jnp.sum(w * w, axis=1, keepdims=True)  # (BW, 1)
    d2 = sn2_ref[...] + wn2 - 2.0 * dots
    match = (d2 < 0.5).astype(jnp.float32)
    o_ref[...] += jnp.sum(match, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_w",))
def sigmatch_counts(windows: jnp.ndarray, sigs: jnp.ndarray, block_w: int = BLOCK_W):
    """Per-signature exact-match counts: windows (W, L), sigs (L, S) -> (S,).

    W must be a multiple of block_w; pad rows use -1 bytes (never match).
    """
    w, l = windows.shape
    l2, s = sigs.shape
    assert l == l2, f"window length {l} vs signature length {l2}"
    assert w % block_w == 0, f"W={w} not a multiple of block_w={block_w}"
    sn2 = jnp.sum(sigs * sigs, axis=0, keepdims=True)  # (1, S)
    grid = (w // block_w,)
    out = pl.pallas_call(
        _sigmatch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w, l), lambda i: (i, 0)),
            pl.BlockSpec((l, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, s), jnp.float32),
        interpret=True,
    )(windows, sigs, sn2)
    return out[0]
