//! Execution tiers: tier-1 direct-threaded dispatch vs the tier-0
//! interpreter on a hot offloaded span.
//!
//! The same phone workload (the farm's synthetic offload: a byte-sum
//! loop over a 64-byte file, clone-side between `ccstart`/`ccstop`) runs
//! through an `InlineClone` twice — once with the `interp` ablation,
//! once with tier 1 — and the bench demands two things at once:
//!
//!  1. **Bit identity.** Merged result, phone virtual-clock bits, and
//!     executed-instruction counts must match exactly. The tier is only
//!     allowed to change wall time.
//!  2. **Speed.** Tier 1 must run the load in under half the interp's
//!     wall time (>=2x; informational under CC_BENCH_SMOKE, where the
//!     span is too short to amortize translation).
//!
//!     cargo bench --bench exec_tiers

use std::sync::Arc;
use std::time::Instant;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::Program;
use clonecloud::nodemanager::CloneServeStats;
use clonecloud::config::{CostParams, ExecTierKind, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{run_distributed, InlineClone};
use clonecloud::farm::{synthetic_expected, synthetic_offload_src};
use clonecloud::util::bench::{emit_json, smoke_mode, Table};
use clonecloud::util::rng::Rng;
use clonecloud::vfs::SimFs;

struct RunOut {
    wall: f64,
    result: i64,
    clock_bits: u64,
    instrs: u64,
    serve: CloneServeStats,
}

fn load_fs() -> SimFs {
    let mut bytes = vec![0u8; 64];
    Rng::new(0x71E2).fill_bytes(&mut bytes);
    let mut fs = SimFs::new();
    fs.add("data.bin", bytes);
    fs
}

/// One full offload roundtrip under `kind`; `trips` re-runs reuse the
/// channel so tier 1's translation cache persists like a farm slot's.
fn run_once(program: &Arc<Program>, kind: ExecTierKind, trips: usize) -> RunOut {
    let fs = load_fs();
    let clone = Process::new(
        program.clone(),
        DeviceSpec::clone_desktop(),
        Location::Clone,
        NodeEnv::with_rust_compute(fs.synchronize()),
    );
    let mut channel = InlineClone::new(clone, CostParams::default()).with_exec_tier(kind);
    let main = program.entry().unwrap();

    let mut out = RunOut {
        wall: 0.0,
        result: 0,
        clock_bits: 0,
        instrs: 0,
        serve: CloneServeStats::default(),
    };
    for _ in 0..trips {
        let mut phone = Process::new(
            program.clone(),
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(fs.synchronize()),
        );
        let t0 = Instant::now();
        run_distributed(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
        )
        .expect("distributed run");
        out.wall += t0.elapsed().as_secs_f64();
        out.result = phone.statics[main.class.0 as usize][0]
            .as_int()
            .expect("int result");
        out.clock_bits = phone.clock.now_us().to_bits();
        out.instrs = phone.metrics.instrs;
    }
    out.serve = channel.serve_stats.clone();
    out
}

fn best_of(program: &Arc<Program>, kind: ExecTierKind, trips: usize, rounds: usize) -> RunOut {
    let mut best = run_once(program, kind, trips);
    for _ in 1..rounds {
        let next = run_once(program, kind, trips);
        // Identical VM state by construction; keep the quietest wall.
        assert_eq!(next.clock_bits, best.clock_bits, "round-to-round clock");
        assert_eq!(next.instrs, best.instrs, "round-to-round instrs");
        if next.wall < best.wall {
            best = next;
        }
    }
    best
}

fn main() {
    let smoke = smoke_mode();
    let (iters, trips, rounds) = if smoke {
        (30_000i64, 2usize, 2usize)
    } else {
        (400_000i64, 3usize, 3usize)
    };

    let program = Arc::new(assemble(&synthetic_offload_src(iters)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let expected = synthetic_expected(&load_fs(), iters);

    println!(
        "exec_tiers: {iters} clone iters/span, {trips} trips/run, best of {rounds}{}",
        if smoke { "  [smoke]" } else { "" }
    );

    let interp = best_of(&program, ExecTierKind::Interp, trips, rounds);
    let tier1 = best_of(&program, ExecTierKind::Tier1, trips, rounds);

    // Gate 1: bit identity, same contract as tests/exec_parity.rs but on
    // the real offload path at bench scale.
    assert_eq!(interp.result, expected, "interp result");
    assert_eq!(tier1.result, expected, "tier1 result");
    assert_eq!(tier1.clock_bits, interp.clock_bits, "virtual clock bits");
    assert_eq!(tier1.instrs, interp.instrs, "phone instructions");
    assert_eq!(
        tier1.serve.instrs_executed, interp.serve.instrs_executed,
        "clone instructions"
    );
    assert_eq!(interp.serve.tier1_instrs, 0, "ablation ran tier-1 code");
    assert!(tier1.serve.tier_promotions >= 1, "hot span never promoted");
    assert!(
        tier1.serve.tier_cache_hits >= 1,
        "translation cache never hit across trips"
    );

    let mut table = Table::new(
        "Offloaded span: interp vs tier-1 dispatch",
        &["Tier", "Wall(s)", "Minstr/s", "Promoted", "CacheHit", "T1Instr%"],
    );
    for (name, r) in [("interp", &interp), ("tier1", &tier1)] {
        table.row(vec![
            name.to_string(),
            format!("{:.4}", r.wall),
            format!("{:.1}", r.serve.instrs_executed as f64 / r.wall / 1e6),
            r.serve.tier_promotions.to_string(),
            r.serve.tier_cache_hits.to_string(),
            format!(
                "{:.0}",
                100.0 * r.serve.tier1_instrs as f64 / r.serve.instrs_executed.max(1) as f64
            ),
        ]);
    }
    table.print();

    // Gate 2: speed.
    let speedup = interp.wall / tier1.wall;
    emit_json(
        "exec_tiers",
        &[],
        &[
            ("interp_wall_s", interp.wall),
            ("tier1_wall_s", tier1.wall),
            ("speedup", speedup),
            ("tier1_promotions", tier1.serve.tier_promotions as f64),
            ("tier1_cache_hits", tier1.serve.tier_cache_hits as f64),
            (
                "tier1_instr_share",
                tier1.serve.tier1_instrs as f64 / tier1.serve.instrs_executed.max(1) as f64,
            ),
        ],
    );
    println!("\ntier1 speedup over interp: {speedup:.2}x (bit-identical state)");
    if smoke {
        if speedup > 1.1 {
            println!("PASS: tier 1 faster at bit-identical results (smoke threshold 1.1x)");
        } else {
            println!(
                "NOTE: speedup below 1.1x in smoke mode (span too short to \
                 amortize translation on this host)"
            );
        }
    } else if speedup >= 2.0 {
        println!("PASS: tier 1 delivers >=2x dispatch speedup at bit-identical results");
    } else {
        panic!("FAIL: tier-1 speedup {speedup:.2}x below the 2x gate");
    }
}
