//! Delta vs full migration: capsule bytes and latency across repeat
//! offloads with a small mutated working set.
//!
//! One phone runs a 24-round offload loop over a 24 x 8 KiB working set;
//! each round mutates O(1) arrays on each side. The full-capture path
//! re-ships the whole reachable heap every roundtrip; the delta path
//! ships the first roundtrip in full, then only the dirty set. Headline:
//! total capsule bytes (up + down) full/delta ratio — target >= 5x — with
//! bit-identical application results.
//!
//!     cargo bench --bench delta_migration

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::appvm::{Heap, Program};
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{
    delta_workload_expected, delta_workload_src, run_distributed_session, DistOutcome,
    InlineClone,
};
use clonecloud::migration::MobileSession;
use clonecloud::util::bench::Table;
use clonecloud::vfs::SimFs;

const ROUNDS: i64 = 24;
const PAYLOAD: i64 = 8 * 1024;
const ZYGOTE_OBJECTS: usize = 1_000;
const ZYGOTE_SEED: u64 = 0xDE17A;

fn make_proc(program: &Arc<Program>, template: &Heap, loc: Location) -> Process {
    let dev = match loc {
        Location::Mobile => DeviceSpec::phone_g1(),
        Location::Clone => DeviceSpec::clone_desktop(),
    };
    Process::fork_from_zygote(
        program.clone(),
        template,
        dev,
        loc,
        NodeEnv::with_rust_compute(SimFs::new()),
    )
}

/// One measured run; returns the outcome, the final `out` static, and
/// wall seconds.
fn run_mode(program: &Arc<Program>, template: &Heap, delta: bool) -> (DistOutcome, i64, f64) {
    let mut phone = make_proc(program, template, Location::Mobile);
    let clone = make_proc(program, template, Location::Clone);
    let mut channel = InlineClone::new(clone, CostParams::default());
    if delta {
        channel = channel.with_delta();
    }
    let mut session = MobileSession::new(delta);
    let t0 = std::time::Instant::now();
    let out = run_distributed_session(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
    )
    .expect("distributed run");
    let wall = t0.elapsed().as_secs_f64();
    let main = program.entry().unwrap();
    let got = phone.statics[main.class.0 as usize][1]
        .as_int()
        .expect("out static");
    (out, got, wall)
}

fn main() {
    let program = Arc::new(assemble(&delta_workload_src(ROUNDS, PAYLOAD)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let template = build_template(&program, ZYGOTE_OBJECTS, ZYGOTE_SEED);
    let expected = delta_workload_expected(ROUNDS);

    println!(
        "delta_migration: {ROUNDS} repeat offloads over a {ROUNDS} x {PAYLOAD} B working set, \
         O(1) arrays mutated per round"
    );

    let mut table = Table::new(
        "Full vs delta capsule transfer (one phone, repeat offloads)",
        &["Mode", "Trips", "Delta", "Fallback", "Up(KB)", "Down(KB)", "KB/trip", "Wall(ms)"],
    );
    let mut rows: Vec<(&str, DistOutcome, f64)> = Vec::new();
    for (name, delta) in [("full", false), ("delta", true)] {
        let (out, got, wall) = run_mode(&program, &template, delta);
        assert_eq!(got, expected, "{name}: application result");
        let total = out.transfer.up + out.transfer.down;
        table.row(vec![
            name.to_string(),
            out.migrations.to_string(),
            out.delta_roundtrips.to_string(),
            out.delta_fallbacks.to_string(),
            format!("{:.1}", out.transfer.up as f64 / 1024.0),
            format!("{:.1}", out.transfer.down as f64 / 1024.0),
            format!("{:.1}", total as f64 / 1024.0 / out.migrations as f64),
            format!("{:.1}", wall * 1e3),
        ]);
        rows.push((name, out, wall));
    }
    table.print();

    let full = &rows[0].1;
    let delta = &rows[1].1;
    assert_eq!(
        full.result, delta.result,
        "full and delta paths are bit-identical"
    );
    let full_bytes = full.transfer.up + full.transfer.down;
    let delta_bytes = delta.transfer.up + delta.transfer.down;
    let ratio = full_bytes as f64 / delta_bytes as f64;
    // Steady state (excluding the unavoidable first-contact full trip):
    // approximate by subtracting one full-trip average from both sides.
    let full_per_trip = full_bytes / full.migrations as u64;
    let steady_ratio = (full_bytes - full_per_trip) as f64
        / delta_bytes.saturating_sub(full_per_trip).max(1) as f64;
    println!(
        "\nfull {full_bytes} B vs delta {delta_bytes} B => {ratio:.1}x fewer capsule bytes \
         ({steady_ratio:.1}x excluding first contact); virtual time {:.1} ms -> {:.1} ms",
        full.virtual_ms, delta.virtual_ms
    );
    assert!(
        ratio >= 5.0,
        "delta path must ship >=5x fewer bytes (got {ratio:.2}x)"
    );
    println!("PASS: delta migration ships {ratio:.1}x fewer capsule bytes at identical results");
}
