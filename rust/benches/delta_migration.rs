//! Delta vs full migration: capsule bytes and latency across repeat
//! offloads with a small mutated working set and a statics-heavy class.
//!
//! One phone runs a repeat-offload loop over `ROUNDS` x `PAYLOAD` byte
//! arrays plus `STATICS` never-changing static slots; each round mutates
//! O(1) arrays on each side. Four wire shapes are measured:
//!
//! * `full`     — full capture every roundtrip (the paper's pipeline);
//! * `pr2`      — delta capsules, but the statics section re-serialized
//!                every capsule and no frame codec (the PR 2 shape);
//! * `delta`    — incremental statics, no codec;
//! * `delta+lz` — incremental statics + negotiated LZ frame compression.
//!
//! Headlines: full/delta capsule-byte ratio (>= 5x), and the new
//! pr2/(delta+lz) ratio (>= 2x) showing compression + incremental
//! statics buy a further cut below the PR 2 baseline — all four modes
//! bit-identical.
//!
//!     cargo bench --bench delta_migration

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::appvm::{Heap, Program};
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{
    delta_statics_workload_src, delta_workload_expected, run_distributed_session, DistOutcome,
    InlineClone,
};
use clonecloud::migration::MobileSession;
use clonecloud::nodemanager::Codec;
use clonecloud::util::bench::{emit_json, smoke_mode, Table};
use clonecloud::vfs::SimFs;

const ZYGOTE_SEED: u64 = 0xDE17A;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Full,
    Pr2,
    Delta,
    DeltaLz,
}

impl Mode {
    fn name(&self) -> &'static str {
        match self {
            Mode::Full => "full",
            Mode::Pr2 => "pr2",
            Mode::Delta => "delta",
            Mode::DeltaLz => "delta+lz",
        }
    }
}

fn make_proc(program: &Arc<Program>, template: &Heap, loc: Location) -> Process {
    let dev = match loc {
        Location::Mobile => DeviceSpec::phone_g1(),
        Location::Clone => DeviceSpec::clone_desktop(),
    };
    Process::fork_from_zygote(
        program.clone(),
        template,
        dev,
        loc,
        NodeEnv::with_rust_compute(SimFs::new()),
    )
}

/// One measured run; returns the outcome, the final `out` static, and
/// wall seconds.
fn run_mode(program: &Arc<Program>, template: &Heap, mode: Mode) -> (DistOutcome, i64, f64) {
    let mut phone = make_proc(program, template, Location::Mobile);
    let clone = make_proc(program, template, Location::Clone);
    let mut channel = InlineClone::new(clone, CostParams::default());
    match mode {
        Mode::Full => {}
        Mode::Pr2 => channel = channel.with_delta().with_full_statics(),
        Mode::Delta => channel = channel.with_delta(),
        Mode::DeltaLz => channel = channel.with_delta().with_codec(Codec::Lz),
    }
    let mut session = MobileSession::new(mode != Mode::Full);
    if mode == Mode::Pr2 {
        session.ship_full_statics(true);
    }
    let t0 = std::time::Instant::now();
    let out = run_distributed_session(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
    )
    .expect("distributed run");
    let wall = t0.elapsed().as_secs_f64();
    let main = program.entry().unwrap();
    let got = phone.statics[main.class.0 as usize][1]
        .as_int()
        .expect("out static");
    (out, got, wall)
}

fn total_bytes(out: &DistOutcome) -> u64 {
    out.transfer.up + out.transfer.down
}

fn by_mode(outs: &[(Mode, DistOutcome)], m: Mode) -> &DistOutcome {
    &outs.iter().find(|(x, _)| *x == m).unwrap().1
}

fn main() {
    let smoke = smoke_mode();
    let (rounds, payload, statics, zygote): (i64, i64, usize, usize) = if smoke {
        (12, 4 * 1024, 96, 400)
    } else {
        (24, 8 * 1024, 192, 1_000)
    };
    // The steady-state full/delta gate shrinks with the trip count (the
    // unavoidable first-contact full trip amortizes less in smoke mode).
    let full_delta_gate = if smoke { 3.0 } else { 5.0 };

    let program =
        Arc::new(assemble(&delta_statics_workload_src(rounds, payload, statics)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let template = build_template(&program, zygote, ZYGOTE_SEED);
    let expected = delta_workload_expected(rounds);

    println!(
        "delta_migration: {rounds} repeat offloads over a {rounds} x {payload} B working set, \
         {statics} never-changing statics, O(1) arrays mutated per round{}",
        if smoke { "  [smoke]" } else { "" }
    );

    let mut table = Table::new(
        "Full vs delta vs compressed capsule transfer (one phone, repeat offloads)",
        &[
            "Mode", "Trips", "Delta", "Fallback", "Statics", "Raw(KB)", "Wire(KB)", "KB/trip",
            "Wall(ms)",
        ],
    );
    let mut outs: Vec<(Mode, DistOutcome)> = Vec::new();
    for mode in [Mode::Full, Mode::Pr2, Mode::Delta, Mode::DeltaLz] {
        let (out, got, wall) = run_mode(&program, &template, mode);
        assert_eq!(got, expected, "{}: application result", mode.name());
        table.row(vec![
            mode.name().to_string(),
            out.migrations.to_string(),
            out.delta_roundtrips.to_string(),
            out.delta_fallbacks.to_string(),
            out.statics_shipped.to_string(),
            format!("{:.1}", (out.raw_up + out.raw_down) as f64 / 1024.0),
            format!("{:.1}", total_bytes(&out) as f64 / 1024.0),
            format!("{:.1}", total_bytes(&out) as f64 / 1024.0 / out.migrations as f64),
            format!("{:.1}", wall * 1e3),
        ]);
        outs.push((mode, out));
    }
    table.print();

    let full = by_mode(&outs, Mode::Full);
    let pr2 = by_mode(&outs, Mode::Pr2);
    let delta = by_mode(&outs, Mode::Delta);
    let lz = by_mode(&outs, Mode::DeltaLz);

    for (name, out) in [("pr2", pr2), ("delta", delta), ("delta+lz", lz)] {
        assert_eq!(
            full.result, out.result,
            "{name}: bit-identical to the full path"
        );
    }

    let ratio_full_delta = total_bytes(full) as f64 / total_bytes(delta) as f64;
    let ratio_pr2_lz = total_bytes(pr2) as f64 / total_bytes(lz) as f64;
    let compression = (lz.raw_up + lz.raw_down) as f64 / total_bytes(lz) as f64;
    println!(
        "\nfull {} B vs delta {} B => {ratio_full_delta:.1}x fewer capsule bytes; \
         pr2 {} B vs delta+lz {} B => {ratio_pr2_lz:.1}x below the PR 2 delta baseline \
         (frame compression {compression:.1}x); virtual time {:.1} ms -> {:.1} ms",
        total_bytes(full),
        total_bytes(delta),
        total_bytes(pr2),
        total_bytes(lz),
        full.virtual_ms,
        lz.virtual_ms
    );

    emit_json(
        "delta_migration",
        &[("mode_set", "full/pr2/delta/delta+lz")],
        &[
            ("full_bytes", total_bytes(full) as f64),
            ("pr2_bytes", total_bytes(pr2) as f64),
            ("delta_bytes", total_bytes(delta) as f64),
            ("delta_lz_bytes", total_bytes(lz) as f64),
            ("ratio_full_delta", ratio_full_delta),
            ("ratio_pr2_delta_lz", ratio_pr2_lz),
            ("compression_ratio", compression),
            ("statics_shipped_pr2", pr2.statics_shipped as f64),
            ("statics_shipped_delta", delta.statics_shipped as f64),
        ],
    );

    assert!(
        ratio_full_delta >= full_delta_gate,
        "delta path must ship >={full_delta_gate}x fewer bytes (got {ratio_full_delta:.2}x)"
    );
    assert!(
        ratio_pr2_lz >= 2.0,
        "compression + incremental statics must land >=2x below the PR 2 \
         delta baseline (got {ratio_pr2_lz:.2}x)"
    );
    println!(
        "PASS: delta ships {ratio_full_delta:.1}x fewer bytes than full, and \
         compression + incremental statics a further {ratio_pr2_lz:.1}x below PR 2, \
         at identical results"
    );
}
