//! Zygote-scale capture: per-page epochs + session dictionary vs the
//! PR 4 baseline (per-object epoch traversal, per-capsule string table).
//!
//! The phone roots the WHOLE Zygote template graph from an app static —
//! the realistic shape where a framework registry (resource tables,
//! interned strings) keeps ~40k template objects reachable — then runs a
//! repeat-offload loop mutating O(1) objects per round. The per-object
//! baseline traversal walks all of it at every capture (and re-lists
//! every clean template object in `zygote_refs`, re-learning the string
//! table each capsule); the page-epoch scan touches only the dirty
//! pages, and the session dictionary ships each name once.
//!
//! Asserts: results bit-identical across monolithic / PR 4 / paged+dict,
//! capture work (objects scanned, pages scanned) and repeat-offload
//! capsule bytes both strictly below the baseline.
//!
//!     cargo bench --bench zygote_scale

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::appvm::{Heap, Program};
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{
    run_distributed_session, run_monolithic, DistOutcome, InlineClone,
};
use clonecloud::migration::MobileSession;
use clonecloud::util::bench::{emit_json, smoke_mode, Table};
use clonecloud::vfs::SimFs;

const ZYGOTE_SEED: u64 = 0x5CA1E;

/// The delta repeat-offload workload plus an extra `registry` static the
/// code never touches — the bench parks the template-rooting array there.
fn workload_src(rounds: i64, payload: i64) -> String {
    assert!((1..=256).contains(&rounds) && payload >= 2);
    format!(
        r#"
class Zy app
  static data
  static out
  static keep
  static registry
  method main nargs=0 regs=12
    const r0 {rounds}
    newarr r1 val r0
    puts Zy.data r1
    const r2 0
    const r3 {payload}
  mk:
    ifge r2 r0 @mkd
    newarr r4 byte r3
    aput r1 r2 r4
    const r5 1
    add r2 r2 r5
    goto @mk
  mkd:
    const r6 0
    const r10 0
  loop:
    ifge r6 r0 @done
    aget r4 r1 r6
    const r5 0
    aput r4 r5 r6
    invoke r8 Zy.work r4
    add r10 r10 r8
    const r5 1
    add r6 r6 r5
    goto @loop
  done:
    puts Zy.out r10
    retv
  end
  method work nargs=1 regs=8
    ccstart 0
    len r1 r0
    const r2 0
    const r3 0
  sum:
    ifge r2 r1 @sd
    aget r4 r0 r2
    add r3 r3 r4
    const r5 1
    add r2 r2 r5
    goto @sum
  sd:
    const r6 1
    aput r0 r6 r3
    const r7 4
    newarr r2 byte r7
    const r6 0
    aput r2 r6 r3
    puts Zy.keep r2
    ccstop 0
    ret r3
  end
end
"#
    )
}

fn expected(rounds: i64) -> i64 {
    rounds * (rounds - 1) / 2
}

fn make_proc(program: &Arc<Program>, template: &Heap, loc: Location) -> Process {
    let dev = match loc {
        Location::Mobile => DeviceSpec::phone_g1(),
        Location::Clone => DeviceSpec::clone_desktop(),
    };
    Process::fork_from_zygote(
        program.clone(),
        template,
        dev,
        loc,
        NodeEnv::with_rust_compute(SimFs::new()),
    )
}

/// Root the whole template graph from the `registry` static (slot 3,
/// never written by code).
fn root_template(p: &mut Process) {
    let main = p.program.entry().unwrap();
    clonecloud::appvm::zygote::root_template_in_static(p, main.class.0 as usize, 3);
}

fn read_out(p: &Process) -> i64 {
    let main = p.program.entry().unwrap();
    p.statics[main.class.0 as usize][1].as_int().expect("out")
}

/// One measured distributed run. `paged_dict` selects the new path;
/// false = the PR 4 baseline (per-object traversal, per-capsule table).
fn run_mode(
    program: &Arc<Program>,
    template: &Heap,
    paged_dict: bool,
) -> (DistOutcome, i64, f64) {
    let mut phone = make_proc(program, template, Location::Mobile);
    root_template(&mut phone);
    let clone = make_proc(program, template, Location::Clone);
    let mut channel = InlineClone::new(clone, CostParams::default()).with_delta();
    if paged_dict {
        channel = channel.with_dict();
    } else {
        channel = channel.with_per_object_captures();
    }
    let mut session = MobileSession::new(true);
    session.set_paged(paged_dict);
    let t0 = std::time::Instant::now();
    let out = run_distributed_session(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
    )
    .expect("distributed run");
    let wall = t0.elapsed().as_secs_f64();
    (out, read_out(&phone), wall)
}

fn main() {
    let smoke = smoke_mode();
    let (rounds, payload, zygote): (i64, i64, usize) = if smoke {
        (12, 2 * 1024, 4_000)
    } else {
        (24, 2 * 1024, 40_000) // Android's Zygote warms ~40k objects
    };
    // Gates shrink in smoke mode: the unavoidable first-contact full
    // capsule amortizes over fewer trips and a smaller template.
    let (bytes_gate, work_gate) = if smoke { (2.0, 8.0) } else { (4.0, 20.0) };

    let program = Arc::new(assemble(&workload_src(rounds, payload)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let template = build_template(&program, zygote, ZYGOTE_SEED);
    let want = expected(rounds);

    println!(
        "zygote_scale: {zygote}-object template rooted from an app static, {rounds} repeat \
         offloads, O(1) objects mutated per round{}",
        if smoke { "  [smoke]" } else { "" }
    );

    // Monolithic reference (registry injected for symmetry).
    let mut mono = make_proc(&program, &template, Location::Mobile);
    root_template(&mut mono);
    run_monolithic(&mut mono).expect("monolithic run");
    assert_eq!(read_out(&mono), want, "monolithic result");

    let (pr4, got_pr4, wall_pr4) = run_mode(&program, &template, false);
    let (new, got_new, wall_new) = run_mode(&program, &template, true);
    assert_eq!(got_pr4, want, "PR 4 path bit-identical to monolithic");
    assert_eq!(got_new, want, "paged+dict path bit-identical to monolithic");
    assert_eq!(pr4.result, new.result, "both paths return the same value");
    assert_eq!(pr4.migrations, new.migrations);
    assert_eq!(new.delta_fallbacks, 0, "no NeedFull on the happy path");
    assert_eq!(new.dict_fallbacks, 0);

    let mut table = Table::new(
        "Per-object/per-capsule-table baseline vs page epochs + session dictionary",
        &[
            "Mode", "Trips", "Scanned", "Pages", "Raw(KB)", "Wire(KB)", "DictSave(KB)",
            "Wall(ms)",
        ],
    );
    for (name, out, wall) in [("pr4", &pr4, wall_pr4), ("paged+dict", &new, wall_new)] {
        table.row(vec![
            name.to_string(),
            out.migrations.to_string(),
            out.objects_scanned.to_string(),
            format!("{}/{}", out.pages_scanned, out.pages_dirty),
            format!("{:.1}", (out.raw_up + out.raw_down) as f64 / 1024.0),
            format!(
                "{:.1}",
                (out.transfer.up + out.transfer.down) as f64 / 1024.0
            ),
            format!("{:.1}", out.dict_hit_bytes as f64 / 1024.0),
            format!("{:.1}", wall * 1e3),
        ]);
    }
    table.print();

    let bytes_pr4 = (pr4.transfer.up + pr4.transfer.down) as f64;
    let bytes_new = (new.transfer.up + new.transfer.down) as f64;
    let ratio_bytes = bytes_pr4 / bytes_new;
    let ratio_work = pr4.objects_scanned as f64 / new.objects_scanned.max(1) as f64;
    println!(
        "\ncapture work {} -> {} objects scanned ({ratio_work:.1}x less), capsule bytes \
         {:.0} -> {:.0} ({ratio_bytes:.1}x less), {} pages scanned / {} dirty, \
         dictionary saved {} B",
        pr4.objects_scanned,
        new.objects_scanned,
        bytes_pr4,
        bytes_new,
        new.pages_scanned,
        new.pages_dirty,
        new.dict_hit_bytes
    );

    emit_json(
        "zygote_scale",
        &[("mode_set", "pr4/paged+dict")],
        &[
            ("zygote_objects", zygote as f64),
            ("rounds", rounds as f64),
            ("pr4_bytes", bytes_pr4),
            ("paged_dict_bytes", bytes_new),
            ("ratio_bytes", ratio_bytes),
            ("pr4_objects_scanned", pr4.objects_scanned as f64),
            ("paged_objects_scanned", new.objects_scanned as f64),
            ("ratio_scan_work", ratio_work),
            ("pages_scanned", new.pages_scanned as f64),
            ("pages_dirty", new.pages_dirty as f64),
            ("dict_hit_bytes", new.dict_hit_bytes as f64),
        ],
    );

    // Strictly below the baseline on both axes, with real margin.
    assert!(
        ratio_work >= work_gate,
        "paged scan must cut capture work >={work_gate}x (got {ratio_work:.1}x)"
    );
    assert!(
        ratio_bytes >= bytes_gate,
        "paged+dict must cut capsule bytes >={bytes_gate}x (got {ratio_bytes:.1}x)"
    );
    assert!(
        new.pages_scanned <= new.pages_dirty + 4 * new.migrations,
        "pages scanned ({}) bounded by dirty pages ({}) + O(1) per trip",
        new.pages_scanned,
        new.pages_dirty
    );
    assert!(new.dict_hit_bytes > 0, "dictionary hits accumulated");
    println!(
        "PASS: page epochs cut capture work {ratio_work:.1}x and paged+dict cut capsule \
         bytes {ratio_bytes:.1}x below the PR 4 baseline, at identical results"
    );
}
