//! Farm throughput: aggregate sessions/sec vs clone-pool size, plus the
//! async-vs-blocking gateway comparison.
//!
//! Part 1: a fixed 16-phone load is replayed against farms of 1, 2, and
//! 4 workers (6 phones, 1/2 workers in CI smoke mode). Growing the pool
//! helps twice over: clone execution parallelizes across worker threads,
//! and the larger warm pool absorbs more session provisions (the
//! 1-worker farm must cold-fork most of its clone processes inline). The
//! headline number is the largest-pool / 1-worker sessions-per-second
//! ratio (target: >2x; informational in smoke mode, where the workload
//! is too small to saturate the pool).
//!
//! Part 2: the same canned wire conversation (provision → fs sync →
//! migrate → shutdown, no Hello) is replayed by a swarm of concurrent
//! mock phones over real TCP against both gateway shapes — the sharded
//! async readiness loop and the thread-per-connection blocking ablation.
//! Reported: sessions/sec, client-observed migrate p99, and an
//! order-independent digest of every reply (both gateways must produce
//! bit-identical bytes). A follow-on soak replays many more sessions
//! through the async gateway and checks the process's fd and thread
//! counts stay flat (no per-connection resource leak).
//!
//!     cargo bench --bench farm_throughput

use std::sync::{Arc, Mutex};

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::config::{CostParams, ExecTierKind, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::run_distributed;
use clonecloud::farm::{
    synthetic_expected, synthetic_offload_src, CloneFarm, FarmConfig, FarmStats,
    PlacementPolicy,
};
use clonecloud::migration::Migrator;
use clonecloud::nodemanager::{
    serve_farm, serve_farm_async, AsyncGatewayConfig, NodeManager, TcpEndpoint, TcpTransport,
};
use clonecloud::util::bench::{emit_json, smoke_mode, Table};
use clonecloud::util::rng::Rng;
use clonecloud::util::stats::LogHistogram;
use clonecloud::vfs::SimFs;

const ZYGOTE_SEED: u64 = 0xBE9C;

/// The load's knobs, scaled down in smoke mode.
struct Load {
    phones: u64,
    /// Clone-side interpreted work per session.
    iters: i64,
    /// Zygote template size: makes a cold fork a real, measurable cost.
    zygote_objects: usize,
    /// Pre-forked processes per worker.
    warm_per_worker: usize,
    worker_set: &'static [usize],
}

fn phone_fs(phone: u64) -> SimFs {
    let mut bytes = vec![0u8; 64];
    Rng::new(0xBE ^ phone).fill_bytes(&mut bytes);
    let mut fs = SimFs::new();
    fs.add("data.bin", bytes);
    fs
}

/// Run the phone load once; returns (wall seconds, farm stats).
fn run_load(
    program: &Arc<clonecloud::appvm::Program>,
    template: &Arc<clonecloud::appvm::Heap>,
    load: &Load,
    workers: usize,
) -> (f64, FarmStats) {
    let farm = CloneFarm::start(
        program.clone(),
        FarmConfig {
            workers,
            warm_per_worker: load.warm_per_worker,
            queue_depth: 64,
            policy: PlacementPolicy::LeastLoaded,
            zygote_objects: load.zygote_objects,
            zygote_seed: ZYGOTE_SEED,
            fuel: 2_000_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::Tier1,
        },
        CostParams::default(),
        Arc::new(NodeEnv::with_rust_compute),
    )
    .expect("farm start");
    let handle = farm.handle();

    // Measurement starts as soon as the farm is up. Warm pools fill on
    // the worker threads; whatever provisioning the smaller pool cannot
    // absorb lands inline in the measured window — that is exactly the
    // cost the larger pool amortizes.
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for phone in 0..load.phones {
        let program = program.clone();
        let template = template.clone();
        let fs = phone_fs(phone);
        let expected = synthetic_expected(&fs, load.iters);
        let mut session = handle.session(phone, fs.synchronize());
        joins.push(std::thread::spawn(move || {
            let mut p = Process::fork_from_zygote(
                program.clone(),
                &template,
                DeviceSpec::phone_g1(),
                Location::Mobile,
                NodeEnv::with_rust_compute(fs),
            );
            run_distributed(
                &mut p,
                &mut session,
                &NetworkProfile::wifi(),
                &CostParams::default(),
            )
            .expect("distributed run");
            let main = program.entry().unwrap();
            assert_eq!(
                p.statics[main.class.0 as usize][0].as_int(),
                Some(expected),
                "phone {phone} result"
            );
            session.close();
        }));
    }
    for j in joins {
        j.join().expect("phone thread");
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = farm.shutdown();
    assert_eq!(stats.migrations, load.phones);
    assert_eq!(stats.errors, 0);
    (wall, stats)
}

// ------------------------------------------------------------- gateways

/// Gateway-comparison knobs, scaled down in smoke mode.
struct GatewayLoad {
    /// Concurrent mock phones in the async-vs-blocking comparison.
    conns: usize,
    /// Clone-side work per canned capsule (small: the comparison
    /// measures serve-path overhead, not clone execution).
    iters: i64,
    /// Total sessions in the fd/thread soak.
    soak_sessions: usize,
    /// Concurrent connections per soak wave.
    soak_window: usize,
}

/// FNV-1a, the digest folded over every reply so the two gateways can
/// be compared for bit-identical output without storing the bytes.
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One canned phone conversation: provision → fs sync → migrate the
/// pre-captured capsule → shutdown. Returns the reply digest and the
/// migrate roundtrip latency in ms.
fn canned_session(
    addr: &str,
    program: &Arc<clonecloud::appvm::Program>,
    zygote_objects: usize,
    fs: &SimFs,
    capsule: &[u8],
) -> (u64, f64) {
    let mut nm = NodeManager::new(TcpTransport::connect(addr).expect("connect"));
    nm.provision(program, zygote_objects, ZYGOTE_SEED)
        .expect("provision");
    nm.sync_fs(fs).expect("sync_fs");
    let t0 = std::time::Instant::now();
    let (reply, _) = nm.migrate(capsule.to_vec()).expect("migrate");
    let lat_ms = t0.elapsed().as_secs_f64() * 1e3;
    nm.shutdown().expect("shutdown");
    (fnv64(&reply), lat_ms)
}

struct GatewayRun {
    wall: f64,
    p99_ms: f64,
    /// Wrapping sum of per-reply FNV digests: order-independent (the
    /// swarm finishes in arbitrary order) without the self-cancellation
    /// an XOR fold would suffer when every reply is identical.
    digest: u64,
}

/// Replay `conns` concurrent canned sessions against whatever gateway
/// is listening at `addr`.
fn run_swarm(
    addr: &str,
    program: &Arc<clonecloud::appvm::Program>,
    zygote_objects: usize,
    capsule: &Arc<Vec<u8>>,
    conns: usize,
) -> GatewayRun {
    let hist = Arc::new(Mutex::new(LogHistogram::new()));
    let digest = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let t0 = std::time::Instant::now();
    let joins: Vec<_> = (0..conns)
        .map(|_| {
            let addr = addr.to_string();
            let program = program.clone();
            let capsule = capsule.clone();
            let hist = hist.clone();
            let digest = digest.clone();
            std::thread::spawn(move || {
                let fs = phone_fs(0);
                let (d, lat_ms) =
                    canned_session(&addr, &program, zygote_objects, &fs, &capsule);
                hist.lock().unwrap().record(lat_ms);
                digest.fetch_add(d, std::sync::atomic::Ordering::Relaxed);
            })
        })
        .collect();
    for j in joins {
        j.join().expect("mock phone");
    }
    let wall = t0.elapsed().as_secs_f64();
    let p99_ms = hist.lock().unwrap().p99();
    GatewayRun {
        wall,
        p99_ms,
        digest: digest.load(std::sync::atomic::Ordering::Relaxed),
    }
}

fn gateway_farm(
    program: &Arc<clonecloud::appvm::Program>,
    zygote_objects: usize,
) -> CloneFarm {
    CloneFarm::start(
        program.clone(),
        FarmConfig {
            workers: 2,
            warm_per_worker: 2,
            queue_depth: 64,
            policy: PlacementPolicy::LeastLoaded,
            zygote_objects,
            zygote_seed: ZYGOTE_SEED,
            fuel: 2_000_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::Tier1,
        },
        CostParams::default(),
        Arc::new(NodeEnv::with_rust_compute),
    )
    .expect("farm start")
}

/// Capture one real forward capsule to replay from every mock phone.
fn canned_capsule(
    program: &Arc<clonecloud::appvm::Program>,
    zygote_objects: usize,
) -> Vec<u8> {
    use clonecloud::appvm::interp::{run_thread, NoHooks, RunExit};
    let template = build_template(program, zygote_objects, ZYGOTE_SEED);
    let mut p = Process::fork_from_zygote(
        program.clone(),
        &template,
        DeviceSpec::phone_g1(),
        Location::Mobile,
        NodeEnv::with_rust_compute(phone_fs(0)),
    );
    let main = program.entry().expect("entry");
    let tid = p.spawn_thread(main, &[]).expect("spawn");
    let exit = run_thread(&mut p, tid, &mut NoHooks, 2_000_000_000).expect("run");
    assert!(matches!(exit, RunExit::MigrationPoint { .. }), "{exit:?}");
    let (packet, _) = Migrator::new(CostParams::default())
        .migrate_out(&mut p, tid)
        .expect("capture");
    packet.encode().expect("encode")
}

fn fd_count() -> Option<usize> {
    std::fs::read_dir("/proc/self/fd").ok().map(|d| d.count())
}

/// Soft RLIMIT_NOFILE from /proc (client + gateway share one process in
/// this bench, so every mock phone costs two fds).
fn max_open_files() -> Option<usize> {
    std::fs::read_to_string("/proc/self/limits")
        .ok()?
        .lines()
        .find(|l| l.starts_with("Max open files"))?
        .split_whitespace()
        .nth(3)?
        .parse()
        .ok()
}

fn os_thread_count() -> Option<usize> {
    std::fs::read_to_string("/proc/self/status")
        .ok()?
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

fn main() {
    let smoke = smoke_mode();
    let load = if smoke {
        Load {
            phones: 6,
            iters: 10_000,
            zygote_objects: 2_000,
            warm_per_worker: 2,
            worker_set: &[1, 2],
        }
    } else {
        Load {
            phones: 16,
            iters: 80_000,
            zygote_objects: 24_000,
            warm_per_worker: 4,
            worker_set: &[1, 2, 4],
        }
    };

    let program = Arc::new(assemble(&synthetic_offload_src(load.iters)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let template = Arc::new(build_template(&program, load.zygote_objects, ZYGOTE_SEED));

    println!(
        "farm_throughput: {}-phone load, {} clone iters/session, zygote {} objects, \
         warm {}/worker{}",
        load.phones,
        load.iters,
        load.zygote_objects,
        load.warm_per_worker,
        if smoke { "  [smoke]" } else { "" }
    );

    let mut table = Table::new(
        "Farm throughput vs pool size",
        &["Workers", "Wall(s)", "Sessions/s", "PoolHit%", "QueueWait(ms)", "AdmWait(ms)"],
    );
    let mut per_workers = Vec::new();
    let mut json_fields: Vec<(String, f64)> = Vec::new();
    for &workers in load.worker_set {
        // Best of 2 rounds: the second round benefits from OS warmup.
        let mut best_wall = f64::INFINITY;
        let mut best_stats = FarmStats::default();
        for _ in 0..2 {
            let (wall, stats) = run_load(&program, &template, &load, workers);
            if wall < best_wall {
                best_wall = wall;
                best_stats = stats;
            }
        }
        let rate = load.phones as f64 / best_wall;
        table.row(vec![
            workers.to_string(),
            format!("{best_wall:.3}"),
            format!("{rate:.1}"),
            format!("{:.0}", best_stats.pool_hit_rate() * 100.0),
            format!("{:.1}", best_stats.queue_wait_ms),
            format!("{:.1}", best_stats.admission_wait_ms),
        ]);
        json_fields.push((format!("sessions_per_sec_{workers}w"), rate));
        per_workers.push((workers, rate));
    }
    table.print();

    let rate1 = per_workers[0].1;
    let rate_max = per_workers[per_workers.len() - 1].1;
    let ratio = rate_max / rate1;
    json_fields.push(("scaling_ratio".to_string(), ratio));

    println!(
        "\n1 -> {} workers: {ratio:.2}x aggregate sessions/sec",
        per_workers[per_workers.len() - 1].0
    );
    if ratio > 2.0 {
        println!("PASS: pool growth delivers >2x aggregate throughput");
    } else {
        println!(
            "NOTE: ratio below 2x on this host (parallel speedup is bounded \
             by available cores; {} detected)",
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        );
    }

    // ------------------------------------------------ gateway comparison

    let mut gw = if smoke {
        GatewayLoad {
            conns: 64,
            iters: 1_000,
            soak_sessions: 512,
            soak_window: 32,
        }
    } else {
        GatewayLoad {
            conns: 1_000,
            iters: 2_000,
            soak_sessions: 10_000,
            soak_window: 64,
        }
    };
    if let Some(limit) = max_open_files() {
        // Each mock phone holds two fds here (client socket + accepted
        // socket); leave headroom for the process's own files.
        let cap = (limit.saturating_sub(128) / 2).max(16);
        if cap < gw.conns {
            println!("NOTE: clamping swarm to {cap} conns (RLIMIT_NOFILE {limit})");
            gw.conns = cap;
        }
    }
    const GW_ZY: usize = 500;
    let gw_program =
        Arc::new(assemble(&synthetic_offload_src(gw.iters)).expect("assemble gw"));
    clonecloud::appvm::verifier::verify_program(&gw_program).expect("verify gw");
    let capsule = Arc::new(canned_capsule(&gw_program, GW_ZY));

    println!(
        "\ngateway comparison: {} concurrent mock phones, {} clone iters, \
         capsule {} bytes{}",
        gw.conns,
        gw.iters,
        capsule.len(),
        if smoke { "  [smoke]" } else { "" }
    );

    // Async (sharded readiness loop).
    let farm = gateway_farm(&gw_program, GW_ZY);
    let ep = TcpEndpoint::bind("127.0.0.1:0").expect("bind");
    let addr = ep.local_addr().expect("addr");
    let handle = farm.handle();
    let conns = gw.conns;
    let server = std::thread::spawn(move || {
        let cfg = AsyncGatewayConfig {
            shards: 4,
            max_sessions: Some(conns),
            ..AsyncGatewayConfig::default()
        };
        serve_farm_async(&ep, &handle, &cfg).expect("async gateway")
    });
    let async_run = run_swarm(&addr, &gw_program, GW_ZY, &capsule, gw.conns);
    let gw_stats = server.join().expect("async gateway thread");
    assert_eq!(gw_stats.migrations, gw.conns as u64);
    assert_eq!(gw_stats.protocol_errors, 0);
    farm.shutdown();

    // Blocking (thread-per-connection ablation). serve_farm returns
    // after the last accept while session threads still run; the farm
    // stats poll below waits for every session to retire before
    // shutdown.
    let farm = gateway_farm(&gw_program, GW_ZY);
    let ep = TcpEndpoint::bind("127.0.0.1:0").expect("bind");
    let addr = ep.local_addr().expect("addr");
    let handle = farm.handle();
    let server = std::thread::spawn(move || {
        serve_farm(&ep, &handle, None, Some(conns)).expect("blocking gateway")
    });
    let blocking_run = run_swarm(&addr, &gw_program, GW_ZY, &capsule, gw.conns);
    server.join().expect("blocking gateway thread");
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while farm.stats().sessions_closed < gw.conns as u64 {
        assert!(
            std::time::Instant::now() < deadline,
            "blocking gateway sessions failed to retire"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    farm.shutdown();

    assert_eq!(
        async_run.digest, blocking_run.digest,
        "async and blocking gateways must produce bit-identical replies"
    );

    let async_rate = gw.conns as f64 / async_run.wall;
    let blocking_rate = gw.conns as f64 / blocking_run.wall;
    let speedup = async_rate / blocking_rate;
    let mut gw_table = Table::new(
        "Gateway serve-path comparison",
        &["Gateway", "Wall(s)", "Sessions/s", "Migrate p99(ms)"],
    );
    gw_table.row(vec![
        "async".into(),
        format!("{:.3}", async_run.wall),
        format!("{async_rate:.1}"),
        format!("{:.2}", async_run.p99_ms),
    ]);
    gw_table.row(vec![
        "blocking".into(),
        format!("{:.3}", blocking_run.wall),
        format!("{blocking_rate:.1}"),
        format!("{:.2}", blocking_run.p99_ms),
    ]);
    gw_table.print();
    println!("replies bit-identical across gateways (digest {:#018x})", async_run.digest);
    if speedup >= 1.0 && async_run.p99_ms <= blocking_run.p99_ms {
        println!("PASS: async gateway wins on sessions/sec and p99");
    } else {
        println!(
            "NOTE: async/blocking = {speedup:.2}x sessions/sec, p99 {:.2}ms vs {:.2}ms \
             (thread-per-conn can keep up at this scale on an unloaded host)",
            async_run.p99_ms, blocking_run.p99_ms
        );
    }
    json_fields.push(("gateway_sessions_per_sec_async".into(), async_rate));
    json_fields.push(("gateway_sessions_per_sec_blocking".into(), blocking_rate));
    json_fields.push(("gateway_speedup".into(), speedup));
    json_fields.push(("gateway_p99_ms_async".into(), async_run.p99_ms));
    json_fields.push(("gateway_p99_ms_blocking".into(), blocking_run.p99_ms));

    // ------------------------------------------------- fd/thread soak

    println!(
        "\nsoak: {} sessions through the async gateway in waves of {}",
        gw.soak_sessions, gw.soak_window
    );
    let farm = gateway_farm(&gw_program, GW_ZY);
    let ep = TcpEndpoint::bind("127.0.0.1:0").expect("bind");
    let addr = ep.local_addr().expect("addr");
    let handle = farm.handle();
    let soak_total = gw.soak_sessions;
    let server = std::thread::spawn(move || {
        let cfg = AsyncGatewayConfig {
            shards: 2,
            max_sessions: Some(soak_total),
            ..AsyncGatewayConfig::default()
        };
        serve_farm_async(&ep, &handle, &cfg).expect("soak gateway")
    });
    let mut done = 0usize;
    let mut baseline: Option<(usize, usize)> = None;
    while done < soak_total {
        let wave = gw.soak_window.min(soak_total - done);
        run_swarm(&addr, &gw_program, GW_ZY, &capsule, wave);
        done += wave;
        if baseline.is_none() {
            // Measured after the first wave so shard threads and the
            // farm's steady-state fds are all in the baseline.
            baseline = fd_count().zip(os_thread_count());
        }
    }
    let final_counts = fd_count().zip(os_thread_count());
    let soak_stats = server.join().expect("soak gateway thread");
    assert_eq!(soak_stats.migrations, soak_total as u64);
    assert_eq!(soak_stats.protocol_errors, 0);
    let fstats = farm.shutdown();
    assert_eq!(fstats.sessions_closed, soak_total as u64, "sessions retired");

    match (baseline, final_counts) {
        (Some((fd0, th0)), Some((fd1, th1))) => {
            let fd_delta = fd1 as i64 - fd0 as i64;
            let th_delta = th1 as i64 - th0 as i64;
            println!(
                "soak resources: fds {fd0} -> {fd1} ({fd_delta:+}), \
                 threads {th0} -> {th1} ({th_delta:+})"
            );
            // A handful of transient fds (sockets in TIME_WAIT teardown)
            // is noise; growth proportional to sessions is a leak.
            assert!(
                fd_delta.unsigned_abs() < 16 + gw.soak_window as u64,
                "fd count grew across the soak: {fd0} -> {fd1}"
            );
            assert!(
                th_delta.unsigned_abs() < 8,
                "thread count grew across the soak: {th0} -> {th1}"
            );
            println!("PASS: fd/thread counts flat across {soak_total} sessions");
            json_fields.push(("soak_fd_delta".into(), fd_delta as f64));
            json_fields.push(("soak_thread_delta".into(), th_delta as f64));
        }
        _ => println!("NOTE: /proc not available; fd/thread soak check skipped"),
    }
    json_fields.push(("soak_sessions".into(), soak_total as f64));

    let fields: Vec<(&str, f64)> = json_fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    emit_json("farm_throughput", &[], &fields);
}
