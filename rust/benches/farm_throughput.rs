//! Farm throughput: aggregate sessions/sec vs clone-pool size.
//!
//! A fixed 16-phone load is replayed against farms of 1, 2, and 4
//! workers (6 phones, 1/2 workers in CI smoke mode). Growing the pool
//! helps twice over: clone execution parallelizes across worker threads,
//! and the larger warm pool absorbs more session provisions (the
//! 1-worker farm must cold-fork most of its clone processes inline). The
//! headline number is the largest-pool / 1-worker sessions-per-second
//! ratio (target: >2x; informational in smoke mode, where the workload
//! is too small to saturate the pool).
//!
//!     cargo bench --bench farm_throughput

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::config::{CostParams, ExecTierKind, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::run_distributed;
use clonecloud::farm::{
    synthetic_expected, synthetic_offload_src, CloneFarm, FarmConfig, FarmStats,
    PlacementPolicy,
};
use clonecloud::util::bench::{emit_json, smoke_mode, Table};
use clonecloud::util::rng::Rng;
use clonecloud::vfs::SimFs;

const ZYGOTE_SEED: u64 = 0xBE9C;

/// The load's knobs, scaled down in smoke mode.
struct Load {
    phones: u64,
    /// Clone-side interpreted work per session.
    iters: i64,
    /// Zygote template size: makes a cold fork a real, measurable cost.
    zygote_objects: usize,
    /// Pre-forked processes per worker.
    warm_per_worker: usize,
    worker_set: &'static [usize],
}

fn phone_fs(phone: u64) -> SimFs {
    let mut bytes = vec![0u8; 64];
    Rng::new(0xBE ^ phone).fill_bytes(&mut bytes);
    let mut fs = SimFs::new();
    fs.add("data.bin", bytes);
    fs
}

/// Run the phone load once; returns (wall seconds, farm stats).
fn run_load(
    program: &Arc<clonecloud::appvm::Program>,
    template: &Arc<clonecloud::appvm::Heap>,
    load: &Load,
    workers: usize,
) -> (f64, FarmStats) {
    let farm = CloneFarm::start(
        program.clone(),
        FarmConfig {
            workers,
            warm_per_worker: load.warm_per_worker,
            queue_depth: 64,
            policy: PlacementPolicy::LeastLoaded,
            zygote_objects: load.zygote_objects,
            zygote_seed: ZYGOTE_SEED,
            fuel: 2_000_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::Tier1,
        },
        CostParams::default(),
        Arc::new(NodeEnv::with_rust_compute),
    )
    .expect("farm start");
    let handle = farm.handle();

    // Measurement starts as soon as the farm is up. Warm pools fill on
    // the worker threads; whatever provisioning the smaller pool cannot
    // absorb lands inline in the measured window — that is exactly the
    // cost the larger pool amortizes.
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for phone in 0..load.phones {
        let program = program.clone();
        let template = template.clone();
        let fs = phone_fs(phone);
        let expected = synthetic_expected(&fs, load.iters);
        let mut session = handle.session(phone, fs.synchronize());
        joins.push(std::thread::spawn(move || {
            let mut p = Process::fork_from_zygote(
                program.clone(),
                &template,
                DeviceSpec::phone_g1(),
                Location::Mobile,
                NodeEnv::with_rust_compute(fs),
            );
            run_distributed(
                &mut p,
                &mut session,
                &NetworkProfile::wifi(),
                &CostParams::default(),
            )
            .expect("distributed run");
            let main = program.entry().unwrap();
            assert_eq!(
                p.statics[main.class.0 as usize][0].as_int(),
                Some(expected),
                "phone {phone} result"
            );
            session.close();
        }));
    }
    for j in joins {
        j.join().expect("phone thread");
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = farm.shutdown();
    assert_eq!(stats.migrations, load.phones);
    assert_eq!(stats.errors, 0);
    (wall, stats)
}

fn main() {
    let smoke = smoke_mode();
    let load = if smoke {
        Load {
            phones: 6,
            iters: 10_000,
            zygote_objects: 2_000,
            warm_per_worker: 2,
            worker_set: &[1, 2],
        }
    } else {
        Load {
            phones: 16,
            iters: 80_000,
            zygote_objects: 24_000,
            warm_per_worker: 4,
            worker_set: &[1, 2, 4],
        }
    };

    let program = Arc::new(assemble(&synthetic_offload_src(load.iters)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let template = Arc::new(build_template(&program, load.zygote_objects, ZYGOTE_SEED));

    println!(
        "farm_throughput: {}-phone load, {} clone iters/session, zygote {} objects, \
         warm {}/worker{}",
        load.phones,
        load.iters,
        load.zygote_objects,
        load.warm_per_worker,
        if smoke { "  [smoke]" } else { "" }
    );

    let mut table = Table::new(
        "Farm throughput vs pool size",
        &["Workers", "Wall(s)", "Sessions/s", "PoolHit%", "QueueWait(ms)", "AdmWait(ms)"],
    );
    let mut per_workers = Vec::new();
    let mut json_fields: Vec<(String, f64)> = Vec::new();
    for &workers in load.worker_set {
        // Best of 2 rounds: the second round benefits from OS warmup.
        let mut best_wall = f64::INFINITY;
        let mut best_stats = FarmStats::default();
        for _ in 0..2 {
            let (wall, stats) = run_load(&program, &template, &load, workers);
            if wall < best_wall {
                best_wall = wall;
                best_stats = stats;
            }
        }
        let rate = load.phones as f64 / best_wall;
        table.row(vec![
            workers.to_string(),
            format!("{best_wall:.3}"),
            format!("{rate:.1}"),
            format!("{:.0}", best_stats.pool_hit_rate() * 100.0),
            format!("{:.1}", best_stats.queue_wait_ms),
            format!("{:.1}", best_stats.admission_wait_ms),
        ]);
        json_fields.push((format!("sessions_per_sec_{workers}w"), rate));
        per_workers.push((workers, rate));
    }
    table.print();

    let rate1 = per_workers[0].1;
    let rate_max = per_workers[per_workers.len() - 1].1;
    let ratio = rate_max / rate1;
    json_fields.push(("scaling_ratio".to_string(), ratio));
    let fields: Vec<(&str, f64)> = json_fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    emit_json("farm_throughput", &[], &fields);

    println!(
        "\n1 -> {} workers: {ratio:.2}x aggregate sessions/sec",
        per_workers[per_workers.len() - 1].0
    );
    if ratio > 2.0 {
        println!("PASS: pool growth delivers >2x aggregate throughput");
    } else {
        println!(
            "NOTE: ratio below 2x on this host (parallel speedup is bounded \
             by available cores; {} detected)",
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        );
    }
}
