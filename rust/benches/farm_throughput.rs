//! Farm throughput: aggregate sessions/sec vs clone-pool size.
//!
//! A fixed 16-phone load is replayed against farms of 1, 2, and 4
//! workers. Growing the pool helps twice over: clone execution
//! parallelizes across worker threads, and the larger warm pool absorbs
//! more session provisions (the 1-worker farm must cold-fork most of its
//! clone processes inline). The headline number is the 4-worker /
//! 1-worker sessions-per-second ratio (target: >2x).
//!
//!     cargo bench --bench farm_throughput

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::run_distributed;
use clonecloud::farm::{
    synthetic_expected, synthetic_offload_src, CloneFarm, FarmConfig, FarmStats,
    PlacementPolicy,
};
use clonecloud::util::bench::Table;
use clonecloud::util::rng::Rng;
use clonecloud::vfs::SimFs;

const PHONES: u64 = 16;
/// Clone-side interpreted work per session.
const ITERS: i64 = 80_000;
/// Zygote template size: makes a cold fork a real, measurable cost.
const ZYGOTE_OBJECTS: usize = 24_000;
const ZYGOTE_SEED: u64 = 0xBE9C;
/// Pre-forked processes per worker: a 4-worker farm starts with 16 warm
/// processes (the whole load), a 1-worker farm with 4.
const WARM_PER_WORKER: usize = 4;

fn phone_fs(phone: u64) -> SimFs {
    let mut bytes = vec![0u8; 64];
    Rng::new(0xBE ^ phone).fill_bytes(&mut bytes);
    let mut fs = SimFs::new();
    fs.add("data.bin", bytes);
    fs
}

/// Run the 16-phone load once; returns (wall seconds, farm stats).
fn run_load(
    program: &Arc<clonecloud::appvm::Program>,
    template: &Arc<clonecloud::appvm::Heap>,
    workers: usize,
) -> (f64, FarmStats) {
    let farm = CloneFarm::start(
        program.clone(),
        FarmConfig {
            workers,
            warm_per_worker: WARM_PER_WORKER,
            queue_depth: 64,
            policy: PlacementPolicy::LeastLoaded,
            zygote_objects: ZYGOTE_OBJECTS,
            zygote_seed: ZYGOTE_SEED,
            fuel: 2_000_000_000,
        },
        CostParams::default(),
        Arc::new(NodeEnv::with_rust_compute),
    )
    .expect("farm start");
    let handle = farm.handle();

    // Measurement starts as soon as the farm is up. Warm pools fill on
    // the worker threads; whatever provisioning the smaller pool cannot
    // absorb lands inline in the measured window — that is exactly the
    // cost the larger pool amortizes.
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for phone in 0..PHONES {
        let program = program.clone();
        let template = template.clone();
        let fs = phone_fs(phone);
        let expected = synthetic_expected(&fs, ITERS);
        let mut session = handle.session(phone, fs.synchronize());
        joins.push(std::thread::spawn(move || {
            let mut p = Process::fork_from_zygote(
                program.clone(),
                &template,
                DeviceSpec::phone_g1(),
                Location::Mobile,
                NodeEnv::with_rust_compute(fs),
            );
            run_distributed(
                &mut p,
                &mut session,
                &NetworkProfile::wifi(),
                &CostParams::default(),
            )
            .expect("distributed run");
            let main = program.entry().unwrap();
            assert_eq!(
                p.statics[main.class.0 as usize][0].as_int(),
                Some(expected),
                "phone {phone} result"
            );
            session.close();
        }));
    }
    for j in joins {
        j.join().expect("phone thread");
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = farm.shutdown();
    assert_eq!(stats.migrations, PHONES);
    assert_eq!(stats.errors, 0);
    (wall, stats)
}

fn main() {
    let program = Arc::new(assemble(&synthetic_offload_src(ITERS)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let template = Arc::new(build_template(&program, ZYGOTE_OBJECTS, ZYGOTE_SEED));

    println!(
        "farm_throughput: {PHONES}-phone load, {ITERS} clone iters/session, \
         zygote {ZYGOTE_OBJECTS} objects, warm {WARM_PER_WORKER}/worker"
    );

    let mut table = Table::new(
        "Farm throughput vs pool size (16-phone load)",
        &["Workers", "Wall(s)", "Sessions/s", "PoolHit%", "QueueWait(ms)", "AdmWait(ms)"],
    );
    let mut per_workers = Vec::new();
    for &workers in &[1usize, 2, 4] {
        // Best of 2 rounds: the second round benefits from OS warmup.
        let mut best_wall = f64::INFINITY;
        let mut best_stats = FarmStats::default();
        for _ in 0..2 {
            let (wall, stats) = run_load(&program, &template, workers);
            if wall < best_wall {
                best_wall = wall;
                best_stats = stats;
            }
        }
        let rate = PHONES as f64 / best_wall;
        table.row(vec![
            workers.to_string(),
            format!("{best_wall:.3}"),
            format!("{rate:.1}"),
            format!("{:.0}", best_stats.pool_hit_rate() * 100.0),
            format!("{:.1}", best_stats.queue_wait_ms),
            format!("{:.1}", best_stats.admission_wait_ms),
        ]);
        per_workers.push((workers, rate));
    }
    table.print();

    let rate1 = per_workers[0].1;
    let rate4 = per_workers[per_workers.len() - 1].1;
    let ratio = rate4 / rate1;
    println!("\n1 -> 4 workers: {ratio:.2}x aggregate sessions/sec");
    if ratio > 2.0 {
        println!("PASS: pool growth delivers >2x aggregate throughput");
    } else {
        println!(
            "NOTE: ratio below 2x on this host (parallel speedup is bounded \
             by available cores; {} detected)",
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        );
    }
}
