//! Adaptive runtime partition policy over a fast→slow→fast network
//! trace (the PR 4 tentpole's acceptance bench).
//!
//! One phone runs a repeat-offload workload while the link sweeps
//! WiFi → EDGE → WiFi. Three strategies are measured on identical
//! inputs:
//!
//! * `all-local`   — `policy.force = local`: the paper's Local column;
//! * `all-offload` — `policy.force = offload`: the seed's hardwired
//!                   always-migrate behavior;
//! * `adaptive`    — cost-model decisions from the live estimator
//!                   (EWMA per-byte link times fed by the measured
//!                   transfers, span priced from a calibration run).
//!
//! Gates: the engine offloads on the fast segments and runs locally on
//! the slow one; end results are bit-identical across all three
//! strategies; and the adaptive run's total virtual time is strictly
//! better than either fixed strategy on the mixed trace.
//!
//!     cargo bench --bench adaptive_policy

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::appvm::{Heap, Program};
use clonecloud::config::{CostParams, NetworkProfile, PolicyParams};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{
    delta_statics_workload_src, delta_workload_expected, run_distributed_with, Decision,
    DistOutcome, InlineClone, PolicyEngine, SpanCost,
};
use clonecloud::migration::MobileSession;
use clonecloud::util::bench::{emit_json, smoke_mode, Table};
use clonecloud::vfs::SimFs;

const ZYGOTE_SEED: u64 = 0xADA9;
const PAYLOAD: i64 = 12 * 1024;
const STATICS: usize = 16;

/// Per-round working-set bytes are large enough that the span's phone
/// cost dominates; the calibrated instruction cost makes the contrast
/// sharp while keeping wall time tiny (virtual time only).
fn costs() -> CostParams {
    CostParams {
        instr_us: 0.6,
        suspend_resume_us: 2_000.0,
        ..CostParams::default()
    }
}

struct Trace {
    /// Segment lengths in migration trips: fast, slow, fast.
    fast1: usize,
    slow: usize,
    fast2: usize,
}

impl Trace {
    fn rounds(&self) -> i64 {
        (self.fast1 + self.slow + self.fast2) as i64
    }

    fn net_at(&self, trip: usize) -> NetworkProfile {
        if trip >= self.fast1 && trip < self.fast1 + self.slow {
            NetworkProfile::edge()
        } else {
            NetworkProfile::wifi()
        }
    }

    fn is_slow(&self, trip: usize) -> bool {
        trip >= self.fast1 && trip < self.fast1 + self.slow
    }
}

fn make_proc(program: &Arc<Program>, template: &Heap, loc: Location) -> Process {
    let dev = match loc {
        Location::Mobile => DeviceSpec::phone_g1(),
        Location::Clone => DeviceSpec::clone_desktop(),
    };
    Process::fork_from_zygote(
        program.clone(),
        template,
        dev,
        loc,
        NodeEnv::with_rust_compute(SimFs::new()),
    )
}

/// One full run under `engine`; returns the outcome, the final `out`
/// static, and the engine (for its decision log).
fn run(
    program: &Arc<Program>,
    template: &Heap,
    trace: &Trace,
    mut engine: PolicyEngine,
) -> (DistOutcome, i64, PolicyEngine) {
    let mut phone = make_proc(program, template, Location::Mobile);
    let clone = make_proc(program, template, Location::Clone);
    let mut channel = InlineClone::new(clone, costs()).with_delta();
    let mut session = MobileSession::new(true);
    let out = run_distributed_with(
        &mut phone,
        &mut channel,
        |trip| trace.net_at(trip),
        &costs(),
        &mut session,
        &mut engine,
    )
    .expect("distributed run");
    let main = program.entry().unwrap();
    let got = phone.statics[main.class.0 as usize][1]
        .as_int()
        .expect("out static");
    (out, got, engine)
}

fn main() {
    let smoke = smoke_mode();
    let trace = if smoke {
        Trace { fast1: 6, slow: 4, fast2: 8 }
    } else {
        Trace { fast1: 8, slow: 6, fast2: 10 }
    };
    let rounds = trace.rounds();
    let zygote = if smoke { 300 } else { 600 };
    let expected = delta_workload_expected(rounds);

    let program = Arc::new(
        assemble(&delta_statics_workload_src(rounds, PAYLOAD, STATICS)).expect("assemble"),
    );
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let template = build_template(&program, zygote, ZYGOTE_SEED);

    println!(
        "adaptive_policy: {rounds} offload rounds x {PAYLOAD} B spans over a \
         wifi[{}] -> edge[{}] -> wifi[{}] trace{}",
        trace.fast1,
        trace.slow,
        trace.fast2,
        if smoke { "  [smoke]" } else { "" }
    );

    // Fixed strategies first; the forced-local run doubles as the span
    // calibration for the adaptive engine.
    let (local, got_local, _) = run(&program, &template, &trace, PolicyEngine::force_local());
    let (offload, got_offload, _) =
        run(&program, &template, &trace, PolicyEngine::force_offload());

    let span_local_ms = local.virtual_ms / rounds as f64;
    let phone_factor = DeviceSpec::phone_g1().cpu_factor;
    let clone_factor = DeviceSpec::clone_desktop().cpu_factor;
    let span_clone_ms = span_local_ms * clone_factor / phone_factor;

    let params = PolicyParams {
        // Trust the most recent trips: the trace shifts by 10x+, and
        // detection speed matters more than smoothing here.
        half_life_trips: 0.3,
        probe_trips: 6,
        ..PolicyParams::default()
    };
    let mut engine = PolicyEngine::from_params(&params).expect("params");
    engine.set_span(
        0,
        SpanCost {
            local_ms: span_local_ms,
            clone_ms: span_clone_ms,
        },
    );
    let (adaptive, got_adaptive, engine) = run(&program, &template, &trace, engine);

    let mut table = Table::new(
        "Fixed vs adaptive strategy over the mixed trace (virtual time)",
        &[
            "Strategy", "Virtual(s)", "Offloads", "Local", "Mispred", "Delta", "Wire(KB)",
        ],
    );
    for (name, out) in [
        ("all-local", &local),
        ("all-offload", &offload),
        ("adaptive", &adaptive),
    ] {
        table.row(vec![
            name.to_string(),
            format!("{:.2}", out.virtual_ms / 1e3),
            out.offloads.to_string(),
            out.local_fallbacks.to_string(),
            out.mispredictions.to_string(),
            out.delta_roundtrips.to_string(),
            format!("{:.1}", (out.transfer.up + out.transfer.down) as f64 / 1024.0),
        ]);
    }
    table.print();

    println!("\ndecision log (span local {span_local_ms:.0} ms / clone {span_clone_ms:.0} ms):");
    for d in &engine.log {
        println!(
            "  trip {:>2} on {:<4}: {:<7}{} offload_est={}  [{}]",
            d.trip,
            if trace.is_slow(d.trip) { "edge" } else { "wifi" },
            match d.decision {
                Decision::Offload => "OFFLOAD",
                Decision::Local => "local",
            },
            if d.probe { " (probe)" } else { "" },
            d.offload_est_ms
                .map_or_else(|| "?".to_string(), |x| format!("{x:.0}ms")),
            d.estimator,
        );
    }

    // --- gates ----------------------------------------------------------
    assert_eq!(got_local, expected, "all-local result");
    assert_eq!(got_offload, expected, "all-offload result");
    assert_eq!(got_adaptive, expected, "adaptive result");
    assert_eq!(local.result, adaptive.result, "bit-identical to all-local");
    assert_eq!(offload.result, adaptive.result, "bit-identical to all-offload");

    let decisions: Vec<(usize, Decision)> =
        engine.log.iter().map(|d| (d.trip, d.decision)).collect();
    assert_eq!(decisions.len(), rounds as usize, "one decision per span");
    let fast1_offloads = decisions
        .iter()
        .filter(|(t, d)| *t < trace.fast1 && *d == Decision::Offload)
        .count();
    assert_eq!(
        fast1_offloads, trace.fast1,
        "every first-fast-segment trip offloads"
    );
    let slow_offloads = decisions
        .iter()
        .filter(|(t, d)| trace.is_slow(*t) && *d == Decision::Offload)
        .count();
    assert!(
        slow_offloads <= 2,
        "slow segment runs locally after at most the boundary trip + one \
         probe (got {slow_offloads} offloads)"
    );
    assert!(
        slow_offloads < trace.slow,
        "the slow segment has real local decisions"
    );
    let tail: Vec<Decision> = decisions
        .iter()
        .rev()
        .take(2)
        .map(|&(_, d)| d)
        .collect();
    assert!(
        tail.iter().all(|&d| d == Decision::Offload),
        "the engine recovers to offloading by the end of the second fast \
         segment (tail {tail:?})"
    );
    assert!(adaptive.mispredictions >= 1, "boundary trips score as wrong");

    let vs_local = local.virtual_ms / adaptive.virtual_ms;
    let vs_offload = offload.virtual_ms / adaptive.virtual_ms;
    emit_json(
        "adaptive_policy",
        &[("trace", "wifi/edge/wifi")],
        &[
            ("local_virtual_ms", local.virtual_ms),
            ("offload_virtual_ms", offload.virtual_ms),
            ("adaptive_virtual_ms", adaptive.virtual_ms),
            ("speedup_vs_local", vs_local),
            ("speedup_vs_offload", vs_offload),
            ("adaptive_offloads", adaptive.offloads as f64),
            ("adaptive_local", adaptive.local_fallbacks as f64),
            ("adaptive_mispredictions", adaptive.mispredictions as f64),
        ],
    );

    assert!(
        adaptive.virtual_ms < local.virtual_ms,
        "adaptive ({:.0} ms) must beat all-local ({:.0} ms)",
        adaptive.virtual_ms,
        local.virtual_ms
    );
    assert!(
        adaptive.virtual_ms < offload.virtual_ms,
        "adaptive ({:.0} ms) must beat all-offload ({:.0} ms)",
        adaptive.virtual_ms,
        offload.virtual_ms
    );
    println!(
        "\nPASS: adaptive {:.2}s vs all-local {:.2}s ({vs_local:.2}x) and \
         all-offload {:.2}s ({vs_offload:.2}x), identical results",
        adaptive.virtual_ms / 1e3,
        local.virtual_ms / 1e3,
        offload.virtual_ms / 1e3
    );
}
