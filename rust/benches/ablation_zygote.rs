//! E4 — the Zygote transfer optimization (paper §4.3): "This typically
//! saves about 40,000 object transmissions with every migration
//! operation."
//!
//! Migrate each app's worker thread out of a full-Zygote phone process
//! (40k template objects, with a realistic fraction dirtied and a
//! static rooting the template graph) with the optimization ON and OFF,
//! and report objects shipped, bytes, and capture wall time.
//!
//!     cargo bench --bench ablation_zygote

use std::path::Path;
use std::sync::Arc;

use clonecloud::apps::{all_apps, build_process, Size};
use clonecloud::appvm::interp::{run_thread, NoHooks, RunExit};
use clonecloud::appvm::value::Value;
use clonecloud::config::NetworkProfile;
use clonecloud::device::Location;
use clonecloud::migration::Migrator;
use clonecloud::partitioner::rewrite_with_partition;
use clonecloud::pipeline::{partition_from_trees, profile_pair};
use clonecloud::runtime::default_backend;
use clonecloud::util::bench::Table;
use clonecloud::util::stats::fmt_bytes;
use clonecloud::Config;

fn main() {
    let cfg = Config::default(); // 40,000 Zygote objects, as on Android
    let backend = default_backend(Path::new(&cfg.artifacts_dir));

    let mut t = Table::new(
        "Zygote-diff ablation: objects/bytes shipped per migration",
        &[
            "App",
            "ZygoteDiff",
            "Objects shipped",
            "Zygote skipped",
            "Bytes",
            "Capture wall (ms)",
            "3G transfer (s)",
        ],
    );

    for app in all_apps() {
        let size = Size::Medium;
        let program = app.program();
        let (tm, tc, _) =
            profile_pair(app.as_ref(), &program, size, &cfg, &backend).expect("profiling");
        let (partition, _, _) =
            partition_from_trees(app.as_ref(), &(tm, tc), &cfg, &NetworkProfile::wifi())
                .expect("solve");
        if !partition.is_offload() {
            eprintln!("[zygote] {} chose Local on wifi; skipping", app.name());
            continue;
        }
        let (rewritten, _) = rewrite_with_partition(&program, &partition).expect("rewrite");
        let rewritten = Arc::new(rewritten);

        for diff in [true, false] {
            let mut phone = build_process(
                app.as_ref(), rewritten.clone(), size, &cfg,
                Location::Mobile, backend.clone(), false,
            )
            .expect("phone");
            // Root the WHOLE template graph from app state, as a real
            // app roots framework objects (resource tables, interned
            // strings): a registry array referencing every Zygote
            // object. With diff ON these are named; OFF they all ship.
            let zy_ids: Vec<Value> = phone
                .heap
                .iter()
                .filter(|(_, o)| o.zygote_seq.is_some())
                .map(|(id, _)| Value::Ref(id))
                .collect();
            let arr_class = phone.array_class;
            let root_arr = phone.heap.alloc_ref_array(arr_class, zy_ids.len());
            if let clonecloud::appvm::ObjBody::RefArray(v) =
                &mut phone.heap.get_mut(root_arr).unwrap().body
            {
                v.copy_from_slice(&zy_ids);
            }
            // Park the root array in a static of the entry class if one
            // exists; otherwise in the Scanner-like class slot 0 (all
            // apps have statics).
            'root: for (ci, st) in phone.statics.iter_mut().enumerate() {
                if phone.program.classes[ci].system {
                    continue;
                }
                for slot in st.iter_mut() {
                    if matches!(slot, Value::Null) {
                        *slot = Value::Ref(root_arr);
                        break 'root;
                    }
                }
            }

            let entry = phone.program.entry().unwrap();
            let tid = phone.spawn_thread(entry, &[]).unwrap();
            loop {
                match run_thread(&mut phone, tid, &mut NoHooks, u64::MAX).unwrap() {
                    RunExit::MigrationPoint { .. } => break,
                    RunExit::ReintegrationPoint { .. } => continue,
                    other => panic!("{} never migrated: {other:?}", app.name()),
                }
            }
            let mut m = Migrator::new(cfg.costs.clone());
            m.opts.zygote_diff = diff;
            let wall0 = std::time::Instant::now();
            let (_packet, phases) = m.migrate_out(&mut phone, tid).unwrap();
            let wall_ms = wall0.elapsed().as_secs_f64() * 1e3;
            let threeg = NetworkProfile::threeg();
            t.row(vec![
                app.name().into(),
                if diff { "on".into() } else { "off".into() },
                format!("{}", phases.objects_shipped),
                format!("{}", phases.zygote_skipped),
                fmt_bytes(phases.bytes_out),
                format!("{wall_ms:.1}"),
                format!("{:.1}", threeg.transfer_ms(phases.bytes_out, true) / 1e3),
            ]);
        }
    }
    t.print();
    println!(
        "\nshape to check: diff=on skips ~40,000 template objects per \
         migration (paper §4.3) and cuts shipped bytes accordingly."
    );
}
