//! E8 — related-work baselines (paper §7): what CloneCloud's method
//! granularity, native-everywhere operation, and one-shot thread
//! migration buy over
//!   (a) class-granularity MINCUT partitioning with per-call RPC
//!       (the Java-partitioning line: Gu/Messer/Ou et al.), and
//!   (b) thread migration restricted to pure virtualized computation
//!       (the DJVM line: cJVM, Jessica2 — natives pinned).
//!
//! All three are priced on the same profile trees + cost model.
//!
//!     cargo bench --bench ablation_baselines

use std::path::Path;

use clonecloud::apps::{all_apps, Size};
use clonecloud::baselines::{solve_class_partition, solve_no_native_everywhere};
use clonecloud::config::NetworkProfile;
use clonecloud::partitioner::{solve_partition, Cfg, CostModel};
use clonecloud::pipeline::profile_pair;
use clonecloud::runtime::default_backend;
use clonecloud::util::bench::Table;
use clonecloud::Config;

fn main() {
    let cfg = Config::default();
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let net = NetworkProfile::wifi();

    let mut t = Table::new(
        "Baselines on WiFi (modeled execution time, s)",
        &[
            "App",
            "Input",
            "All-local",
            "CloneCloud",
            "Class-MINCUT+RPC",
            "No-native-everywhere",
            "CC wins",
        ],
    );

    for app in all_apps() {
        for size in [Size::Medium, Size::Large] {
            let program = app.program();
            let (tm, tc, _) =
                profile_pair(app.as_ref(), &program, size, &cfg, &backend).expect("profiling");
            let cm = CostModel::build_scaled(
                &[(&tm, &tc)],
                &cfg.costs,
                &net,
                cfg.phone.cpu_factor,
                cfg.clone.cpu_factor,
            );
            let cfg_graph = Cfg::build(&program);
            let (cc, _) = solve_partition(&program, &cfg_graph, &cm).expect("cc solve");
            let class = solve_class_partition(&program, &cfg_graph, &cm, &net)
                .expect("class solve");
            let (nn, _) = solve_no_native_everywhere(&program, &cm).expect("nn solve");
            let wins = cc.expected_us <= class.expected_us + 1e-6
                && cc.expected_us <= nn.expected_us + 1e-6;
            t.row(vec![
                app.name().into(),
                app.input_label(size),
                format!("{:.1}", cc.local_us / 1e6),
                format!("{:.1} ({})", cc.expected_us / 1e6, cc.label()),
                format!(
                    "{:.1} (remote: {})",
                    class.expected_us / 1e6,
                    if class.remote_classes.is_empty() {
                        "none".to_string()
                    } else {
                        class.remote_classes.join(",")
                    }
                ),
                format!("{:.1} ({})", nn.expected_us / 1e6, nn.label()),
                format!("{wins}"),
            ]);
        }
    }
    t.print();
    println!(
        "\nshape to check: CloneCloud <= both baselines everywhere; the \
         no-native-everywhere baseline collapses to Local wherever the \
         hot loop touches fs/compute natives (paper §7's contrast)."
    );
}
