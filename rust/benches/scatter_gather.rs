//! Scatter/gather clone parallelism (the PR's headline number): fan one
//! data-parallel `CcStart` span across N clone lanes and merge the N
//! disjoint reverse deltas against one baseline. Sweeps the fan width
//! over the same workload and reports virtual-time speedup vs the
//! single-clone offload, plus the bit-identity check across widths.
//!
//!     cargo bench --bench scatter_gather
//!
//! Runs on a LAN-ish profile: scatter pays N serial uplinks of the same
//! full capture, so it targets the regime where clone compute dominates
//! transfer (on wifi's 66 ms latency the fan would lose on uplink).

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::appvm::{Heap, Program};
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{
    run_distributed_policy, scatter_workload_expected, scatter_workload_src, DistOutcome,
    InlineClone, PolicyEngine,
};
use clonecloud::migration::MobileSession;
use clonecloud::util::bench::{emit_json, smoke_mode};
use clonecloud::vfs::SimFs;

fn lan() -> NetworkProfile {
    NetworkProfile {
        name: "lan".into(),
        latency_ms: 0.2,
        down_mbps: 400.0,
        up_mbps: 400.0,
    }
}

fn make_proc(program: &Arc<Program>, template: &Heap, loc: Location) -> Process {
    let dev = match loc {
        Location::Mobile => DeviceSpec::phone_g1(),
        Location::Clone => DeviceSpec::clone_desktop(),
    };
    Process::fork_from_zygote(
        program.clone(),
        template,
        dev,
        loc,
        NodeEnv::with_rust_compute(SimFs::new()),
    )
}

/// One delta session over an inline clone with span 0 annotated at
/// `width` lanes (0 = monolithic single-clone offload).
fn run_width(program: &Arc<Program>, template: &Heap, width: u16) -> (DistOutcome, i64) {
    let mut phone = make_proc(program, template, Location::Mobile);
    let clone = make_proc(program, template, Location::Clone);
    let mut channel = InlineClone::new(clone, CostParams::default()).with_delta();
    let mut session = MobileSession::new(true);
    let mut engine = PolicyEngine::force_offload();
    engine.set_span_shards(0, width);
    let out = run_distributed_policy(
        &mut phone,
        &mut channel,
        &lan(),
        &CostParams::default(),
        &mut session,
        &mut engine,
    )
    .unwrap();
    let main = program.entry().unwrap();
    let got = phone.statics[main.class.0 as usize][1].as_int().unwrap();
    (out, got)
}

fn main() {
    let (slots, cells, spin) = if smoke_mode() {
        (8i64, 128i64, 16i64)
    } else {
        (16i64, 512i64, 32i64)
    };
    let program = Arc::new(assemble(&scatter_workload_src(slots, cells, spin)).unwrap());
    let template = build_template(&program, 200, 11);
    let expected = scatter_workload_expected(slots, cells);
    println!("scatter/gather: {slots} slots x {cells} cells, spin {spin}, lan profile");

    let (single, got_single) = run_width(&program, &template, 0);
    assert_eq!(got_single, expected, "single-clone result");
    println!(
        "  width 1 (single clone): {:8.3} virtual ms  ({} B up, {} B down)",
        single.virtual_ms, single.transfer.up, single.transfer.down
    );

    for width in [2u16, 4] {
        let (fan, got) = run_width(&program, &template, width);
        assert_eq!(got, expected, "width {width} result is bit-identical");
        assert_eq!(fan.scatter_offloads, 1, "width {width} gather committed");
        assert_eq!(fan.scatter_shards as u64, u64::from(width));
        let speedup = single.virtual_ms / fan.virtual_ms;
        println!(
            "  width {width} (scatter):      {:8.3} virtual ms  ({} B up, {} B down)  speedup {speedup:.2}x",
            fan.virtual_ms, fan.transfer.up, fan.transfer.down
        );
        emit_json(
            "scatter_gather",
            &[("case", &format!("width{width}"))],
            &[
                ("single_virtual_ms", single.virtual_ms),
                ("scatter_virtual_ms", fan.virtual_ms),
                ("speedup", speedup),
                ("bytes_up", fan.transfer.up as f64),
                ("bytes_down", fan.transfer.down as f64),
                ("bit_identical", f64::from(u8::from(got == got_single))),
            ],
        );
        // The PR's acceptance criterion: the 4-lane fan must beat the
        // single clone on virtual time with an identical result.
        if width == 4 {
            assert!(
                fan.virtual_ms < single.virtual_ms,
                "4-lane scatter ({:.3} ms) must beat the single clone ({:.3} ms)",
                fan.virtual_ms,
                single.virtual_ms
            );
        }
    }
}
