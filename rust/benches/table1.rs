//! E1 — regenerate the paper's Table 1.
//!
//! For each of the three applications at each of the three input sizes:
//! phone-monolithic execution, clone-monolithic execution, and the
//! CloneCloud execution under 3G and WiFi (full pipeline: profile both
//! platforms, solve the ILP, rewrite the binary, run distributed).
//!
//! Expected shape (paper §6): clone 18-26x faster; 3G keeps ~5/9
//! workloads local vs ~2/9 on WiFi; speedups grow with workload size;
//! largest-workload WiFi speedups ≈ 14x / 21x / 12x.
//!
//!     cargo bench --bench table1

use std::path::Path;

use clonecloud::apps::{all_apps, Size};
use clonecloud::pipeline::table1_row;
use clonecloud::runtime::default_backend;
use clonecloud::util::bench::Table;
use clonecloud::Config;

fn main() {
    let cfg = Config::default();
    let backend = default_backend(Path::new(&cfg.artifacts_dir));

    let mut table = Table::new(
        "Table 1: execution times of virus scanning, image search, and behavior profiling",
        &[
            "Application",
            "Input",
            "Phone(s)",
            "Clone(s)",
            "MaxSpd",
            "CC-3G(s)",
            "Part-3G",
            "Spd-3G",
            "CC-WiFi(s)",
            "Part-WiFi",
            "Spd-WiFi",
        ],
    );

    let mut local_3g = 0;
    let mut local_wifi = 0;
    let mut rows = 0;
    for app in all_apps() {
        for size in Size::all() {
            let t0 = std::time::Instant::now();
            let row = table1_row(app.as_ref(), size, &cfg, &backend)
                .unwrap_or_else(|e| panic!("{} {:?}: {e}", app.name(), size));
            eprintln!(
                "[table1] {} {} done in {:.1}s wall ({})",
                app.name(),
                row.input,
                t0.elapsed().as_secs_f64(),
                row.result
            );
            rows += 1;
            if row.threeg.label == "Local" {
                local_3g += 1;
            }
            if row.wifi.label == "Local" {
                local_wifi += 1;
            }
            table.row(vec![
                row.app.to_string(),
                row.input.clone(),
                format!("{:.2}", row.phone_ms / 1e3),
                format!("{:.2}", row.clone_ms / 1e3),
                format!("{:.2}", row.max_speedup),
                format!("{:.2}", row.threeg.exec_ms / 1e3),
                row.threeg.label.to_string(),
                format!("{:.2}", row.threeg.speedup),
                format!("{:.2}", row.wifi.exec_ms / 1e3),
                row.wifi.label.to_string(),
                format!("{:.2}", row.wifi.speedup),
            ]);
        }
    }
    table.print();
    println!(
        "\nshape: {local_3g}/{rows} workloads Local on 3G (paper: 5/9), \
         {local_wifi}/{rows} Local on WiFi (paper: 2/9)"
    );
}
