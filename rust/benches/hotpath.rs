//! L3 hot-path microbenchmarks (the §Perf working set): interpreter
//! dispatch rate, capture/serialize/merge throughput, wire codec, and
//! ILP solve latency. These are the knobs the perf pass iterates on;
//! EXPERIMENTS.md §Perf records before/after from this bench's output.
//!
//!     cargo bench --bench hotpath

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::interp::{run_thread, NoHooks, RunExit};
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::value::Value;
use clonecloud::appvm::zygote::build_template;
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{
    delta_statics_workload_src, delta_workload_expected, run_distributed_traced, InlineClone,
    PolicyEngine,
};
use clonecloud::migration::{
    capture_thread, Capsule, CaptureOptions, CapturePacket, DictMode, Direction, Migrator,
    MobileSession,
};
use clonecloud::partitioner::lp::{solve_ilp, Constraint, Sense};
use clonecloud::trace::{chrome_trace_string, Endpoint, Event, Tracer};
use clonecloud::util::bench::{bench, black_box, emit_json, smoke_mode};
use clonecloud::vfs::SimFs;

const LOOP: &str = r#"
class L app
  method main nargs=0 regs=8
    const r0 0
    const r1 1000000
    const r2 1
    constf r3 0.0
  loop:
    ifge r0 r1 @done
    add r0 r0 r2
    i2f r4 r0
    fadd r3 r3 r4
    goto @loop
  done:
    retv
  end
end
"#;

fn interp_rate() {
    let program = Arc::new(assemble(LOOP).unwrap());
    let main = program.entry().unwrap();
    let r = bench("interp: 5M-instr arithmetic loop", 1, 5, || {
        let mut p = Process::new(
            program.clone(),
            DeviceSpec::clone_desktop(),
            Location::Mobile,
            NodeEnv::with_rust_compute(SimFs::new()),
        );
        let tid = p.spawn_thread(main, &[]).unwrap();
        match run_thread(&mut p, tid, &mut NoHooks, u64::MAX).unwrap() {
            RunExit::Completed(_) => black_box(p.metrics.instrs),
            other => panic!("{other:?}"),
        };
    });
    // ~1M iterations x 5 instrs per iteration.
    let mips = 5.0e6 / (r.summary.p50 / 1e3) / 1e6;
    println!("  -> {mips:.1} M instrs/s");
}

fn capture_throughput() {
    let program = Arc::new(assemble(LOOP).unwrap());
    let main = program.entry().unwrap();
    for (label, zygote, ballast) in [
        ("capture: 40k-obj zygote heap (diff on)", 40_000usize, 0usize),
        ("capture: 1MB app state + 40k zygote", 40_000, 1 << 20),
    ] {
        let template = build_template(&program, zygote, 1);
        let mut p = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(SimFs::new()),
        );
        let tid = p.spawn_thread(main, &[]).unwrap();
        // Root the whole template graph from a register (a framework
        // registry array, as real apps have): the capture traversal must
        // walk all `zygote` objects — and, with diff on, name instead of
        // ship them.
        let zy_ids: Vec<Value> = p.heap.iter().map(|(id, _)| Value::Ref(id)).collect();
        let registry = p.heap.alloc_ref_array(p.array_class, zy_ids.len());
        if let clonecloud::appvm::ObjBody::RefArray(v) =
            &mut p.heap.get_mut(registry).unwrap().body
        {
            v.copy_from_slice(&zy_ids);
        }
        // The registry array itself is dirty app state; the template
        // objects it references stay clean.
        for val in &zy_ids {
            if let Some(id) = val.as_ref() {
                if let Some(obj) = p.heap.peek_mut(id) {
                    obj.dirty = false;
                }
            }
        }
        p.thread_mut(tid).unwrap().current_frame_mut().unwrap().regs[6] =
            Value::Ref(registry);
        if ballast > 0 {
            let arr = p.heap.alloc_byte_array(p.array_class, vec![7u8; ballast]);
            p.thread_mut(tid).unwrap().current_frame_mut().unwrap().regs[7] =
                Value::Ref(arr);
        }
        let mut bytes = 0u64;
        let r = bench(label, 2, 10, || {
            let (packet, stats) =
                capture_thread(&p, 0, Direction::Forward, None, CaptureOptions::default())
                    .unwrap();
            bytes = stats.bytes as u64;
            black_box(packet.objects.len());
        });
        let mbps = bytes as f64 / 1e6 / (r.summary.p50 / 1e3);
        println!("  -> {bytes} bytes captured, {mbps:.0} MB/s");
    }
}

fn codec_throughput() {
    let program = Arc::new(assemble(LOOP).unwrap());
    let main = program.entry().unwrap();
    let template = build_template(&program, 5_000, 1);
    let mut p = Process::fork_from_zygote(
        program.clone(),
        &template,
        DeviceSpec::phone_g1(),
        Location::Mobile,
        NodeEnv::with_rust_compute(SimFs::new()),
    );
    let arr = p.heap.alloc_byte_array(p.array_class, vec![9u8; 1 << 20]);
    let tid = p.spawn_thread(main, &[]).unwrap();
    p.thread_mut(tid).unwrap().current_frame_mut().unwrap().regs[7] = Value::Ref(arr);
    // Root the template graph so the packet carries a realistic object
    // population (diff is off below: everything ships).
    let zy_ids: Vec<Value> = p.heap.iter().map(|(id, _)| Value::Ref(id)).collect();
    let registry = p.heap.alloc_ref_array(p.array_class, zy_ids.len());
    if let clonecloud::appvm::ObjBody::RefArray(v) =
        &mut p.heap.get_mut(registry).unwrap().body
    {
        v.copy_from_slice(&zy_ids);
    }
    p.thread_mut(tid).unwrap().current_frame_mut().unwrap().regs[6] = Value::Ref(registry);
    let mut m = Migrator::new(CostParams::default());
    m.opts.zygote_diff = false; // big packet
    let (packet, _) = m.migrate_out(&mut p, tid).unwrap();
    let encoded = packet.encode().unwrap();
    println!("  packet: {} objects, {} bytes", packet.objects.len(), encoded.len());
    let r = bench("wire: encode capture packet", 2, 20, || {
        black_box(packet.encode().unwrap().len());
    });
    let mbps = encoded.len() as f64 / 1e6 / (r.summary.p50 / 1e3);
    println!("  -> encode {mbps:.0} MB/s");
    let r = bench("wire: decode capture packet", 2, 20, || {
        black_box(CapturePacket::decode(&encoded).unwrap().objects.len());
    });
    let mbps = encoded.len() as f64 / 1e6 / (r.summary.p50 / 1e3);
    println!("  -> decode {mbps:.0} MB/s");
}

/// The session-lifetime encode scratch on the offload hot path: the
/// driver's `stamp_and_encode` streams every forward capsule into one
/// reused buffer, so the encoder's doubling reallocations are paid once
/// per session instead of once per trip. Measured head-to-head on the
/// same capsule: fresh buffer per encode vs `WireWriter::from_vec`
/// scratch reuse (the exact take/encode/split_off/put cycle the driver
/// runs).
fn encode_scratch_reuse() {
    let program = Arc::new(assemble(LOOP).unwrap());
    let main = program.entry().unwrap();
    let template = build_template(&program, 5_000, 1);
    let mut p = Process::fork_from_zygote(
        program.clone(),
        &template,
        DeviceSpec::phone_g1(),
        Location::Mobile,
        NodeEnv::with_rust_compute(SimFs::new()),
    );
    let arr = p.heap.alloc_byte_array(p.array_class, vec![9u8; 1 << 20]);
    let tid = p.spawn_thread(main, &[]).unwrap();
    p.thread_mut(tid).unwrap().current_frame_mut().unwrap().regs[7] = Value::Ref(arr);
    let zy_ids: Vec<Value> = p.heap.iter().map(|(id, _)| Value::Ref(id)).collect();
    let registry = p.heap.alloc_ref_array(p.array_class, zy_ids.len());
    if let clonecloud::appvm::ObjBody::RefArray(v) =
        &mut p.heap.get_mut(registry).unwrap().body
    {
        v.copy_from_slice(&zy_ids);
    }
    p.thread_mut(tid).unwrap().current_frame_mut().unwrap().regs[6] = Value::Ref(registry);
    let mut m = Migrator::new(CostParams::default());
    m.opts.zygote_diff = false;
    let (packet, _) = m.migrate_out(&mut p, tid).unwrap();
    let capsule = Capsule::Full(packet);
    let bytes = capsule.encode().unwrap().len();
    println!("  capsule: {bytes} bytes");

    let fresh = bench("wire: encode capsule, fresh buffer per trip", 2, 20, || {
        black_box(capsule.encode().unwrap().len());
    });
    let mut scratch: Vec<u8> = Vec::new();
    let reused = bench("wire: encode capsule, session scratch reuse", 2, 20, || {
        let mut w = clonecloud::util::bytes::WireWriter::from_vec(std::mem::take(&mut scratch));
        capsule.encode_into_with(&mut w, DictMode::Off).unwrap();
        let mut store = w.into_vec();
        let raw = store.split_off(0);
        scratch = store;
        black_box(raw.len());
    });
    let ratio = fresh.summary.p50 / reused.summary.p50;
    println!("  -> scratch reuse speedup {ratio:.2}x over fresh-buffer encode");
    emit_json(
        "hotpath",
        &[("case", "encode_scratch_reuse")],
        &[
            ("fresh_p50_ms", fresh.summary.p50),
            ("scratch_p50_ms", reused.summary.p50),
            ("speedup", ratio),
            ("capsule_bytes", bytes as f64),
        ],
    );
}

fn ilp_latency() {
    // A partitioner-shaped ILP: 16 methods, XOR chains + TC rows.
    let n = 16usize;
    let l = |i: usize| i;
    let r = |i: usize| n + i;
    let mut cons = vec![Constraint {
        coeffs: vec![(l(0), 1.0)],
        sense: Sense::Eq,
        rhs: 0.0,
    }];
    for i in 1..n {
        let parent = (i - 1) / 2;
        let (l1, l2, r2) = (l(parent), l(i), r(i));
        cons.push(Constraint { coeffs: vec![(l2, 1.0), (l1, -1.0), (r2, 1.0)], sense: Sense::Ge, rhs: 0.0 });
        cons.push(Constraint { coeffs: vec![(l2, 1.0), (l1, -1.0), (r2, -1.0)], sense: Sense::Le, rhs: 0.0 });
        cons.push(Constraint { coeffs: vec![(l2, 1.0), (r2, -1.0), (l1, 1.0)], sense: Sense::Ge, rhs: 0.0 });
        cons.push(Constraint { coeffs: vec![(l2, 1.0), (r2, 1.0), (l1, 1.0)], sense: Sense::Le, rhs: 2.0 });
        // TC with ancestors.
        let mut a = parent;
        loop {
            cons.push(Constraint {
                coeffs: vec![(r(a), 1.0), (r(i), 1.0)],
                sense: Sense::Le,
                rhs: 1.0,
            });
            if a == 0 {
                break;
            }
            a = (a - 1) / 2;
        }
    }
    let mut c = vec![0.0; 2 * n];
    for i in 0..n {
        c[l(i)] = if i % 3 == 0 { 50.0 } else { -80.0 };
        c[r(i)] = 20.0;
    }
    bench("ilp: 32-var partitioner-shaped solve", 2, 20, || {
        black_box(solve_ilp(2 * n, &c, &cons));
    });
}

/// Flight-recorder overhead on the offload hot path: the same traced
/// driver runs a delta session once with `Tracer::disabled()` (the
/// zero-cost path — every record degenerates to an enabled-flag check)
/// and once with a live ring buffer + wire context + piggybacked clone
/// events. The bound is the PR's acceptance criterion: tracing-on must
/// stay within 5% of tracing-off. `CC_TRACE_OUT=<path>` additionally
/// exports one traced session as Chrome trace-event JSON (the CI
/// artifact next to BENCH_PR.json).
fn tracing_overhead() {
    let rounds: i64 = if smoke_mode() { 8 } else { 16 };
    let iters = if smoke_mode() { 15 } else { 30 };
    let program = Arc::new(assemble(&delta_statics_workload_src(rounds, 4096, 8)).unwrap());
    let template = build_template(&program, 2_000, 1);
    let expected = delta_workload_expected(rounds);
    let main = program.entry().unwrap();

    let run = |label: &str, traced: bool| {
        bench(label, 2, iters, || {
            let mut phone = Process::fork_from_zygote(
                program.clone(),
                &template,
                DeviceSpec::phone_g1(),
                Location::Mobile,
                NodeEnv::with_rust_compute(SimFs::new()),
            );
            let clone = Process::fork_from_zygote(
                program.clone(),
                &template,
                DeviceSpec::clone_desktop(),
                Location::Clone,
                NodeEnv::with_rust_compute(SimFs::new()),
            );
            let mut channel = InlineClone::new(clone, CostParams::default())
                .with_delta()
                .with_trace();
            let mut session = MobileSession::new(true);
            let mut engine = PolicyEngine::force_offload().without_degrade();
            let mut tracer = if traced {
                Tracer::new(0xBE7C, Endpoint::Phone, 8192)
            } else {
                Tracer::disabled()
            };
            run_distributed_traced(
                &mut phone,
                &mut channel,
                &NetworkProfile::wifi(),
                &CostParams::default(),
                &mut session,
                &mut engine,
                &mut tracer,
            )
            .unwrap();
            assert_eq!(phone.statics[main.class.0 as usize][1].as_int(), Some(expected));
            black_box(tracer.report().events);
        })
    };

    let off = run("trace: delta session, recorder off", false);
    let on = run("trace: delta session, recorder on", true);
    let ratio = on.summary.p50 / off.summary.p50;
    println!("  -> tracing overhead {:.1}% (bound 5%)", (ratio - 1.0) * 100.0);
    emit_json(
        "hotpath",
        &[("case", "tracing_overhead")],
        &[
            ("untraced_p50_ms", off.summary.p50),
            ("traced_p50_ms", on.summary.p50),
            ("overhead_ratio", ratio),
        ],
    );
    assert!(
        ratio <= 1.05,
        "tracing overhead {:.1}% exceeds the 5% bound",
        (ratio - 1.0) * 100.0
    );

    if let Some(path) = std::env::var_os("CC_TRACE_OUT") {
        let mut phone = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(SimFs::new()),
        );
        let clone = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::clone_desktop(),
            Location::Clone,
            NodeEnv::with_rust_compute(SimFs::new()),
        );
        let mut channel = InlineClone::new(clone, CostParams::default())
            .with_delta()
            .with_trace();
        let mut tracer = Tracer::new(0xBE7C, Endpoint::Phone, 8192);
        run_distributed_traced(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut MobileSession::new(true),
            &mut PolicyEngine::force_offload().without_degrade(),
            &mut tracer,
        )
        .unwrap();
        let events: Vec<Event> = tracer.events().cloned().collect();
        std::fs::write(&path, chrome_trace_string(tracer.session_id(), &events)).unwrap();
        println!("  -> sample chrome trace written to {path:?}");
    }
}

fn main() {
    interp_rate();
    capture_throughput();
    codec_throughput();
    encode_scratch_reuse();
    ilp_latency();
    tracing_overhead();
}
