//! E3 — migration-cost analysis (paper §6): "Migration costs about
//! 10-15 seconds in the WiFi case, but shoots up to 60 seconds in the 3G
//! case... migration costs include a network-unspecific thread-merge
//! cost and the network-specific transmission of the thread state. The
//! former dominates the latter for WiFi, but is dominated by the latter
//! for 3G."
//!
//! For each app's offload-chosen workload, run the distributed execution
//! and break one migration round trip into suspend+capture / uplink /
//! downlink / merge phases (virtual time), per network.
//!
//!     cargo bench --bench migration_cost

use std::path::Path;
use std::sync::Arc;

use clonecloud::apps::{all_apps, Size};
use clonecloud::apps::build_process;
use clonecloud::config::NetworkProfile;
use clonecloud::device::Location;
use clonecloud::exec::{run_distributed, InlineClone};
use clonecloud::partitioner::rewrite_with_partition;
use clonecloud::pipeline::{partition_from_trees, profile_pair};
use clonecloud::runtime::default_backend;
use clonecloud::util::bench::Table;
use clonecloud::Config;

fn main() {
    let cfg = Config::default();
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    // CI smoke mode: one app is enough to record the trajectory point.
    let smoke = clonecloud::util::bench::smoke_mode();

    let mut t = Table::new(
        "Migration cost breakdown per round trip (virtual time)",
        &[
            "App",
            "Net",
            "Migr",
            "Susp+Capt(s)",
            "Uplink(s)",
            "Downlink(s)",
            "Merge(s)",
            "Total(s)",
            "Dominant",
            "Bytes up/down",
        ],
    );

    // Use the Medium workloads (offload-chosen on WiFi for all three).
    let apps = all_apps();
    let napps = if smoke { 1 } else { apps.len() };
    for app in apps.into_iter().take(napps) {
        let size = Size::Medium;
        let program = app.program();
        let (tm, tc, _) =
            profile_pair(app.as_ref(), &program, size, &cfg, &backend).expect("profiling");
        let trees = (tm, tc);
        for net in [NetworkProfile::wifi(), NetworkProfile::threeg()] {
            // Force-offload with the WiFi partition so the breakdown is
            // comparable across networks even where 3G would stay local
            // (the paper's 60 s number is for the same migration priced
            // on 3G).
            let (partition, _, _) =
                partition_from_trees(app.as_ref(), &trees, &cfg, &NetworkProfile::wifi())
                    .expect("solve");
            if !partition.is_offload() {
                continue;
            }
            let (rewritten, _) =
                rewrite_with_partition(&program, &partition).expect("rewrite");
            let rewritten = Arc::new(rewritten);
            let mut phone = build_process(
                app.as_ref(), rewritten.clone(), size, &cfg,
                Location::Mobile, backend.clone(), false,
            )
            .expect("phone");
            let clone_proc = build_process(
                app.as_ref(), rewritten.clone(), size, &cfg,
                Location::Clone, backend.clone(), false,
            )
            .expect("clone");
            let mut channel = InlineClone::new(clone_proc, cfg.costs.clone());
            let out = run_distributed(&mut phone, &mut channel, &net, &cfg.costs)
                .expect("distributed run");
            let n = out.migrations.max(1) as f64;
            let (sc, up, down, merge) = (
                out.suspend_capture_ms / n / 1e3,
                out.uplink_ms / n / 1e3,
                out.downlink_ms / n / 1e3,
                out.merge_ms / n / 1e3,
            );
            let total = sc + up + down + merge;
            let dominant = if up + down > merge { "transfer" } else { "merge" };
            t.row(vec![
                app.name().into(),
                net.name.clone(),
                format!("{}", out.migrations),
                format!("{sc:.2}"),
                format!("{up:.2}"),
                format!("{down:.2}"),
                format!("{merge:.2}"),
                format!("{total:.2}"),
                dominant.into(),
                format!(
                    "{}/{}",
                    clonecloud::util::stats::fmt_bytes(out.transfer.up / out.migrations.max(1) as u64),
                    clonecloud::util::stats::fmt_bytes(out.transfer.down / out.migrations.max(1) as u64)
                ),
            ]);
            clonecloud::util::bench::emit_json(
                "migration_cost",
                &[("app", app.name()), ("net", net.name.as_str())],
                &[
                    ("migrations", out.migrations as f64),
                    ("total_s", total),
                    ("bytes_up", out.transfer.up as f64),
                    ("bytes_down", out.transfer.down as f64),
                ],
            );
        }
    }
    t.print();
    println!(
        "\nshape to check: WiFi totals ~10-15s dominated by merge; \
         3G totals ~40-70s dominated by transfer (paper §6)."
    );
}
