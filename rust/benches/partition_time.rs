//! E2 — the partitioning-framework timing analysis (paper §6, last
//! paragraph): for the image-search application, the paper reports
//! profiling execution 29.4 s (phone) / 1.2 s (clone), migration-cost
//! profiling 98.4 s (phone), static analysis (jchord) 19.4 s, and ILP
//! generation + solve < 1 s.
//!
//! The *shape* to reproduce: phone-profiling >> clone-profiling (the
//! device speed ratio), migration-cost profiling >> plain profiling
//! (captures at every method entry/exit), and solving ~ negligible.
//! Wall-clock absolute values differ (our "phone" is a simulated device
//! on a desktop); the virtual profile-run times carry the device ratio.
//!
//!     cargo bench --bench partition_time

use std::path::Path;

use clonecloud::apps::{App, ImageSearch, Size};
use clonecloud::config::NetworkProfile;
use clonecloud::pipeline::{partition_from_trees, profile_pair};
use clonecloud::runtime::default_backend;
use clonecloud::util::bench::Table;
use clonecloud::Config;

fn main() {
    let cfg = Config::default();
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let app = ImageSearch;
    let program = app.program();

    // The paper profiles the image search app (35 methods in their Java
    // build; our DroidVM build has fewer, all instrumented).
    let size = Size::Large;
    let (tm, tc, report) =
        profile_pair(&app, &program, size, &cfg, &backend).expect("profiling");
    let trees = (tm, tc);

    let mut solve_s = 0.0;
    let mut static_s = 0.0;
    for net in [NetworkProfile::threeg(), NetworkProfile::wifi()] {
        let (p, st, sv) =
            partition_from_trees(&app, &trees, &cfg, &net).expect("solve");
        static_s = st.max(static_s);
        solve_s = sv.max(solve_s);
        eprintln!("[partition_time] {} -> {}", net.name, p.label());
    }

    let mut t = Table::new(
        "Partitioning-framework timing (image search, 100 images)",
        &["Phase", "This repro", "Paper (G1 + desktop)"],
    );
    t.row(vec![
        "Methods profiled".into(),
        format!("{}", report.methods_profiled),
        "35".into(),
    ]);
    t.row(vec![
        "Profiling execution, phone (virtual)".into(),
        format!("{:.1}s", report.profile_phone_virtual_ms / 1e3),
        "29.4s (wall)".into(),
    ]);
    t.row(vec![
        "Profiling execution, clone (virtual)".into(),
        format!("{:.1}s", report.profile_clone_virtual_ms / 1e3),
        "1.2s (wall)".into(),
    ]);
    t.row(vec![
        "Profiling execution, phone (wall)".into(),
        format!("{:.2}s", report.profile_phone_s),
        "29.4s".into(),
    ]);
    t.row(vec![
        "Profiling execution, clone (wall)".into(),
        format!("{:.2}s", report.profile_clone_s),
        "1.2s".into(),
    ]);
    t.row(vec![
        "Migration-cost profiling (wall)".into(),
        format!("{:.2}s", report.profile_migration_s),
        "98.4s".into(),
    ]);
    t.row(vec![
        "Static analysis (wall)".into(),
        format!("{:.4}s", static_s),
        "19.4s (jchord)".into(),
    ]);
    t.row(vec![
        "ILP generate + solve (wall)".into(),
        format!("{:.4}s", solve_s),
        "<1s (Mosek)".into(),
    ]);
    t.print();

    let ratio = report.profile_phone_virtual_ms / report.profile_clone_virtual_ms;
    println!(
        "\nshape: phone/clone profiling ratio {ratio:.1}x (paper: 24.5x); \
         migration-cost profiling {:.1}x plain profiling wall (paper: 3.3x); \
         solve sub-second: {}",
        report.profile_migration_s / report.profile_phone_s.max(1e-9),
        solve_s < 1.0
    );
}
