//! Adversarial wire-robustness harness: every decoder in the protocol
//! stack is driven with structured mutants ([`clonecloud::util::fuzz`])
//! of its own valid encodings, plus pure garbage, under a counting
//! global allocator. Three laws are asserted for every input:
//!
//! 1. **No panic** — decode returns `Ok` or a typed error, period.
//! 2. **No state corruption** — a rejected capsule leaves the session
//!    dictionary replica bit-identical, cleanly reset (the `NeedFull`
//!    path), or exactly in the sender's post-encode state (the
//!    trailing-garbage-after-a-valid-capsule case, where both replicas
//!    agree by construction). Never a silently forked replica.
//! 3. **Bounded allocation** — no decode path reserves more than
//!    `MAX_PREVALIDATION_ALLOC` ahead of validation; peak allocation
//!    may exceed it only in proportion to input bytes actually present
//!    (decompression expands at most ~44x per input byte; 64x is the
//!    asserted ceiling, plus fixed slack for error strings).
//!
//! Budgets are fixed-seed and small enough for the CI `fuzz-smoke` job
//! (a few seconds total); any failure reproduces from (seed, iteration).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use clonecloud::migration::format::{
    WireBody, WireFrame, WireObject, WireStatic, WireValue,
};
use clonecloud::migration::{
    Capsule, CapturePacket, DeltaPacket, DictMode, DictRead, Direction, SessionDict,
};
use clonecloud::nodemanager::{
    decode_sub_job, decode_sub_result, encode_sub_result, open_frame, seal_frame, Codec,
    FrameDecoder, Msg, SubJobFrame, MAX_PREVALIDATION_ALLOC,
};
use clonecloud::trace::wire::{decode_events, encode_events};
use clonecloud::trace::{
    prepend_ctx, prepend_events, split_ctx, split_events, Endpoint, Event, EventKind, Mark,
    Phase, TraceCtx, FLAG_WANT_CLONE_EVENTS,
};
use clonecloud::util::compress::{compress, decompress};
use clonecloud::util::fuzz::WireFuzzer;
use clonecloud::util::rng::Rng;
use clonecloud::vfs::SimFs;

// ---- counting allocator (law 3) ------------------------------------------

/// Wraps the system allocator and tracks, per thread, the live byte
/// count and the high-water mark since the last reset. Thread-local
/// const-init `Cell`s avoid both locks and allocation recursion.
struct CountingAlloc;

thread_local! {
    static LIVE: Cell<usize> = const { Cell::new(0) };
    static PEAK: Cell<usize> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            LIVE.with(|l| {
                let v = l.get() + layout.size();
                l.set(v);
                PEAK.with(|pk| {
                    if v > pk.get() {
                        pk.set(v);
                    }
                });
            });
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        // saturating: a buffer may be freed on a different thread than
        // the one that allocated it.
        LIVE.with(|l| l.set(l.get().saturating_sub(layout.size())));
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Run `f` and return (peak allocation delta over the call, result).
fn peak_during<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let base = LIVE.with(|l| l.get());
    PEAK.with(|p| p.set(base));
    let r = f();
    let peak = PEAK.with(|p| p.get());
    (peak.saturating_sub(base), r)
}

/// Law 3: pre-validation reservations are capped by the one protocol
/// constant; anything beyond must be paid for by real input bytes.
/// 64x covers the worst decompression expansion (~44x) with margin;
/// the fixed slack covers error-string formatting and Vec rounding.
fn assert_alloc_law(what: &str, input_len: usize, peak: usize) {
    let bound = MAX_PREVALIDATION_ALLOC + 64 * input_len + 4096;
    assert!(
        peak <= bound,
        "{what}: peak allocation {peak} exceeds {bound} for a {input_len}-byte input"
    );
}

// ---- generators of valid base encodings ----------------------------------

fn gen_blob(rng: &mut Rng, max: usize) -> Vec<u8> {
    let mut b = vec![0u8; rng.index(max)];
    rng.fill_bytes(&mut b);
    b
}

fn gen_msg(rng: &mut Rng) -> Msg {
    match rng.index(10) {
        0 => Msg::Provision {
            zygote_objects: rng.next_u64() as u32,
            zygote_seed: rng.next_u64(),
            program_hash: rng.next_u64(),
        },
        1 => {
            let mut fs = SimFs::new();
            for i in 0..rng.index(4) {
                fs.add(&format!("f{i}"), gen_blob(rng, 256));
            }
            Msg::SyncFs(fs)
        }
        2 => Msg::Migrate(gen_blob(rng, 512)),
        3 => Msg::Reintegrate(gen_blob(rng, 512)),
        4 => Msg::Ack,
        5 => Msg::Error(format!("err {}", rng.next_u64())),
        6 => Msg::Shutdown,
        7 => Msg::Hello {
            proto: (rng.next_u64() % 6) as u16,
            delta: rng.chance(0.5),
            caps: rng.next_u64() as u32,
        },
        8 => Msg::NeedFull(format!("nf {}", rng.next_u64())),
        _ => Msg::Heartbeat {
            base_epoch: rng.next_u64(),
            digest: rng.next_u64(),
            assignments: (0..rng.index(6))
                .map(|_| (rng.next_u64(), rng.next_u64()))
                .collect(),
        },
    }
}

fn gen_name(rng: &mut Rng) -> String {
    const POOL: &[&str] = &["App", "sys.String", "[arr]", "x.y.Z", "Работа"];
    if rng.chance(0.8) {
        POOL[rng.index(POOL.len())].to_string()
    } else {
        format!("C{}", rng.next_u64())
    }
}

fn gen_value(rng: &mut Rng) -> WireValue {
    match rng.index(6) {
        0 => WireValue::Null,
        1 => WireValue::Int(rng.next_u64() as i64),
        2 => WireValue::Float(rng.range_i64(-1_000_000, 1_000_000) as f64 / 64.0),
        3 => WireValue::Slot(rng.next_u64() as u32),
        4 => WireValue::Zygote(rng.next_u64() as u32),
        _ => WireValue::Base(rng.next_u64()),
    }
}

fn gen_body(rng: &mut Rng) -> WireBody {
    match rng.index(4) {
        0 => WireBody::Fields((0..rng.index(6)).map(|_| gen_value(rng)).collect()),
        1 => WireBody::ByteArray(gen_blob(rng, 128)),
        2 => WireBody::FloatArray((0..rng.index(16)).map(|_| rng.range_f32(-1e6, 1e6)).collect()),
        _ => WireBody::RefArray((0..rng.index(6)).map(|_| gen_value(rng)).collect()),
    }
}

fn gen_packet(rng: &mut Rng) -> CapturePacket {
    CapturePacket {
        direction: if rng.chance(0.5) {
            Direction::Forward
        } else {
            Direction::Reverse
        },
        thread_id: rng.next_u64() as u32,
        clock_us: rng.range_i64(0, 1 << 40) as f64 / 16.0,
        frames: (0..rng.index(3))
            .map(|_| WireFrame {
                class_name: gen_name(rng),
                method_name: gen_name(rng),
                pc: rng.next_u64() as u32,
                ret_reg_plus1: rng.byte(),
                regs: (0..rng.index(6)).map(|_| gen_value(rng)).collect(),
            })
            .collect(),
        objects: (0..rng.index(6))
            .map(|_| WireObject {
                origin_id: rng.next_u64(),
                mapped_id: rng.next_u64(),
                class_name: gen_name(rng),
                zygote_seq: rng.chance(0.3).then(|| rng.next_u64() as u32),
                body: gen_body(rng),
            })
            .collect(),
        zygote_refs: (0..rng.index(3))
            .map(|_| (gen_name(rng), rng.next_u64() as u32))
            .collect(),
        statics: (0..rng.index(3))
            .map(|_| WireStatic {
                class_name: gen_name(rng),
                idx: rng.next_u64() as u16,
                value: gen_value(rng),
            })
            .collect(),
    }
}

fn gen_capsule(rng: &mut Rng) -> Capsule {
    if rng.chance(0.5) {
        Capsule::Full(gen_packet(rng))
    } else {
        let p = gen_packet(rng);
        Capsule::Delta(DeltaPacket {
            direction: p.direction,
            thread_id: p.thread_id,
            clock_us: p.clock_us,
            base_epoch: rng.next_u64(),
            base_digest: rng.next_u64(),
            assignments: (0..rng.index(5))
                .map(|_| (rng.next_u64(), rng.next_u64()))
                .collect(),
            deleted: (0..rng.index(5)).map(|_| rng.next_u64()).collect(),
            sections: clonecloud::migration::format::WireSections {
                frames: p.frames,
                objects: p.objects,
                zygote_refs: p.zygote_refs,
                statics: p.statics,
            },
        })
    }
}

fn gen_event(rng: &mut Rng) -> Event {
    let kind = match rng.index(3) {
        0 => EventKind::Begin(Phase::Capture),
        1 => EventKind::End(Phase::Encode),
        _ => EventKind::Instant(Mark::NeedFull),
    };
    Event {
        seq: rng.next_u64(),
        endpoint: if rng.chance(0.5) {
            Endpoint::Phone
        } else {
            Endpoint::Clone
        },
        trip: rng.next_u64() as u32,
        virt_us: rng.range_i64(0, 1 << 40) as f64 / 16.0,
        wall_us: rng.next_u64() >> 16,
        kind,
    }
}

// ---- the harness ----------------------------------------------------------

/// Drive one decoder closure with mutants, garbage tails, and pure
/// garbage derived from `base`, asserting the allocation law each time.
/// The closure must already swallow its decoder's `Result`.
fn pound(what: &str, fz: &mut WireFuzzer, base: &[u8], budget: usize, decode: &dyn Fn(&[u8])) {
    for i in 0..budget {
        let input = match i % 3 {
            0 => fz.mutate(base),
            1 => fz.garbage_tail(base),
            _ => fz.garbage(base.len() + 64),
        };
        let (peak, ()) = peak_during(|| decode(&input));
        assert_alloc_law(what, input.len(), peak);
    }
    // The unmutated base must of course also obey the law.
    let (peak, ()) = peak_during(|| decode(base));
    assert_alloc_law(what, base.len(), peak);
}

#[test]
fn fuzz_msg_decoder() {
    let mut fz = WireFuzzer::new(0xF022_0001);
    let mut rng = Rng::new(0xF022_0002);
    for _ in 0..60 {
        let base = gen_msg(&mut rng).encode().unwrap();
        pound("Msg::decode", &mut fz, &base, 12, &|input| {
            let _ = Msg::decode(input);
        });
    }
}

#[test]
fn fuzz_frame_container() {
    let mut fz = WireFuzzer::new(0xF022_0003);
    let mut rng = Rng::new(0xF022_0004);
    for _ in 0..40 {
        // Compressible payloads so the LZ path really engages.
        let mut payload = gen_blob(&mut rng, 2048);
        let run = rng.index(2048);
        payload.resize(payload.len() + run, 0xAB);
        for codec in [Codec::None, Codec::Lz] {
            let base = seal_frame(codec, payload.clone());
            pound("open_frame", &mut fz, &base, 8, &|input| {
                let _ = open_frame(input);
            });
        }
    }
}

#[test]
fn fuzz_incremental_frame_decoder_any_chunking() {
    let mut fz = WireFuzzer::new(0xF022_0005);
    let mut rng = Rng::new(0xF022_0006);

    // A valid multi-frame stream must decode identically however the
    // bytes are fragmented.
    for _ in 0..30 {
        let frames: Vec<Vec<u8>> = (0..1 + rng.index(4))
            .map(|_| gen_blob(&mut rng, 600))
            .collect();
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&(f.len() as u32).to_be_bytes());
            stream.extend_from_slice(f);
        }
        let points = fz.chunk_points(stream.len());
        let mut dec = FrameDecoder::new();
        let mut got: Vec<Vec<u8>> = Vec::new();
        for w in points.windows(2) {
            dec.feed(&stream[w[0]..w[1]]);
            while let Some(f) = dec.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, frames, "chunking changed the decoded frames");
    }

    // Mutated / hostile streams: no panic, allocation stays bounded by
    // bytes actually fed (a lying length prefix must not pre-allocate).
    for _ in 0..60 {
        let mut stream = Vec::new();
        for _ in 0..1 + rng.index(3) {
            let f = gen_blob(&mut rng, 300);
            stream.extend_from_slice(&(f.len() as u32).to_be_bytes());
            stream.extend_from_slice(&f);
        }
        let hostile = fz.mutate(&stream);
        let points = fz.chunk_points(hostile.len());
        let (peak, ()) = peak_during(|| {
            let mut dec = FrameDecoder::new();
            for w in points.windows(2) {
                dec.feed(&hostile[w[0]..w[1]]);
                loop {
                    match dec.next_frame() {
                        Ok(Some(_)) => {}
                        Ok(None) => break,
                        Err(_) => return, // typed error; connection would drop
                    }
                }
            }
        });
        assert_alloc_law("FrameDecoder", hostile.len(), peak);
    }
}

#[test]
fn fuzz_capsule_decoders_all_dict_modes() {
    let mut fz = WireFuzzer::new(0xF022_0007);
    let mut rng = Rng::new(0xF022_0008);

    for round in 0..40 {
        let capsule = gen_capsule(&mut rng);

        // Mode Off: the pre-dict layout through both entry points.
        let base = capsule.encode().unwrap();
        pound("Capsule::decode", &mut fz, &base, 6, &|input| {
            let _ = Capsule::decode(input);
            let _ = CapturePacket::decode(input);
        });

        // Mode Inline under a negotiated channel: replica must stay
        // untouched whatever happens.
        let base = capsule.encode_with(DictMode::Inline).unwrap();
        for i in 0..6 {
            let input = if i % 2 == 0 {
                fz.mutate(&base)
            } else {
                fz.garbage_tail(&base)
            };
            let mut rx = SessionDict::new();
            let (peak, _) =
                peak_during(|| Capsule::decode_with(&input, DictRead::Negotiated(&mut rx)));
            assert_alloc_law("Capsule::decode_with(Inline)", input.len(), peak);
        }

        // Mode Shared: the replica-coherence law (law 2). Warm a
        // sender/receiver pair, encode against the warm dict, then
        // mutate. On ANY decode error the receiver replica must be
        // bit-identical, cleanly reset, or exactly the sender's
        // post-encode state — never silently forked.
        let mut tx = SessionDict::new();
        let mut rx_master = SessionDict::new();
        let warm = gen_capsule(&mut rng);
        let warm_bytes = warm.encode_with(DictMode::Shared(&mut tx)).unwrap();
        Capsule::decode_with(&warm_bytes, DictRead::Negotiated(&mut rx_master))
            .expect("warm capsule decodes");
        let base = capsule.encode_with(DictMode::Shared(&mut tx)).unwrap();
        // The sender's post-encode digest: what a receiver that absorbs
        // this capsule's additions lands on.
        let absorbed_digest = tx.digest();
        let before_digest = rx_master.digest();
        for i in 0..8 {
            let input = match i % 3 {
                0 => fz.mutate(&base),
                1 => fz.garbage_tail(&base),
                _ => fz.garbage(base.len() + 32),
            };
            let mut rx = rx_master.clone();
            let (peak, res) =
                peak_during(|| Capsule::decode_with(&input, DictRead::Negotiated(&mut rx)));
            assert_alloc_law("Capsule::decode_with(Shared)", input.len(), peak);
            if res.is_err() {
                let d = rx.digest();
                assert!(
                    d == before_digest || rx.is_empty() || d == absorbed_digest,
                    "round {round}.{i}: rejected capsule forked the replica \
                     (digest {d:#x}, expected untouched {before_digest:#x}, \
                     reset, or absorbed {absorbed_digest:#x})"
                );
            }
        }
        // And the unmutated capsule still decodes against the master.
        let mut rx = rx_master.clone();
        let (got, used) = Capsule::decode_with(&base, DictRead::Negotiated(&mut rx))
            .expect("valid shared capsule decodes");
        assert!(used);
        assert_eq!(rx.digest(), absorbed_digest);
        match (&got, &capsule) {
            (Capsule::Full(a), Capsule::Full(b)) => assert_eq!(a, b),
            (Capsule::Delta(a), Capsule::Delta(b)) => assert_eq!(a, b),
            _ => panic!("capsule flavor flipped"),
        }
    }
}

#[test]
fn fuzz_sub_job_frames() {
    let mut fz = WireFuzzer::new(0xF022_0009);
    let mut rng = Rng::new(0xF022_000A);
    for _ in 0..50 {
        let shards = 1 + rng.index(8) as u16;
        let frame = SubJobFrame {
            shard: rng.index(shards as usize) as u16,
            shards,
            payload: gen_blob(&mut rng, 400),
        };
        let base = frame.encode();
        pound("decode_sub_job", &mut fz, &base, 8, &|input| {
            let _ = decode_sub_job(input);
        });

        let base = encode_sub_result(frame.shard, &frame.payload);
        pound("decode_sub_result", &mut fz, &base, 8, &|input| {
            let _ = decode_sub_result(input);
        });
    }
}

#[test]
fn fuzz_trace_envelopes() {
    let mut fz = WireFuzzer::new(0xF022_000B);
    let mut rng = Rng::new(0xF022_000C);
    for _ in 0..40 {
        let events: Vec<Event> = (0..rng.index(12)).map(|_| gen_event(&mut rng)).collect();
        let capsule = gen_blob(&mut rng, 400);

        let base = encode_events(&events).unwrap();
        pound("decode_events", &mut fz, &base, 6, &|input| {
            let _ = decode_events(input);
        });

        let base = prepend_events(&events, &capsule).unwrap();
        pound("split_events", &mut fz, &base, 6, &|input| {
            let _ = split_events(input);
        });

        let ctx = TraceCtx {
            session_id: rng.next_u64(),
            trip: rng.next_u64() as u32,
            parent_span: rng.next_u64() as u32,
            flags: if rng.chance(0.5) { FLAG_WANT_CLONE_EVENTS } else { 0 },
        };
        let base = prepend_ctx(&ctx, &capsule);
        pound("split_ctx", &mut fz, &base, 6, &|input| {
            let _ = split_ctx(input);
        });
    }
}

#[test]
fn fuzz_decompress() {
    let mut fz = WireFuzzer::new(0xF022_000D);
    let mut rng = Rng::new(0xF022_000E);
    for _ in 0..60 {
        let mut data = gen_blob(&mut rng, 2048);
        let run = rng.index(2048);
        data.resize(data.len() + run, 0x5A);
        let base = compress(&data);
        for i in 0..8 {
            let input = if i % 2 == 0 {
                fz.mutate(&base)
            } else {
                fz.garbage(base.len() + 64)
            };
            // Both the true length and hostile claims, including claims
            // far past the pre-validation cap.
            for claimed in [
                data.len(),
                fz.rng().index(4 * MAX_PREVALIDATION_ALLOC),
                usize::from(u16::MAX) * 70_000, // ~4.5 GiB claim
            ] {
                let (peak, _) = peak_during(|| decompress(&input, claimed));
                assert_alloc_law("decompress", input.len(), peak);
            }
        }
    }
}
