//! End-to-end over the REAL PJRT artifacts (skipped gracefully when
//! `artifacts/` is absent): the full Table-1 cell path executing the AOT
//! Pallas kernels from the Rust hot path, plus PJRT/reference
//! equivalence at the app level.

use std::path::Path;
use std::sync::Arc;

use clonecloud::apps::{build_process, App, Size, VirusScan};
use clonecloud::appvm::natives::{ComputeBackend, RustCompute};
use clonecloud::config::Config;
use clonecloud::device::Location;
use clonecloud::exec::run_monolithic;
use clonecloud::runtime::{PjrtCompute, PjrtRuntime};

fn pjrt() -> Option<Arc<dyn ComputeBackend>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(PjrtCompute::new(Arc::new(
        PjrtRuntime::load(&dir).expect("load artifacts"),
    ))))
}

fn cfg() -> Config {
    Config {
        zygote_objects: 200,
        ..Config::default()
    }
}

/// The same app run must produce identical results (and identical
/// virtual time) under the PJRT artifacts and the Rust reference — the
/// kernels are semantically interchangeable.
#[test]
fn pjrt_and_reference_agree_at_app_level() {
    let Some(pjrt) = pjrt() else { return };
    let app = VirusScan;
    let cfg = cfg();
    let run = |backend: Arc<dyn ComputeBackend>| {
        let mut p = build_process(
            &app, app.program(), Size::Small, &cfg,
            Location::Mobile, backend, false,
        )
        .unwrap();
        let out = run_monolithic(&mut p).unwrap();
        let msg = app.check(&p, Size::Small).unwrap();
        (msg, out.virtual_ms)
    };
    let (pjrt_msg, pjrt_ms) = run(pjrt);
    let (ref_msg, ref_ms) = run(Arc::new(RustCompute));
    assert_eq!(pjrt_msg, ref_msg);
    assert!((pjrt_ms - ref_ms).abs() < 1e-6, "virtual time is backend-independent");
}

/// The PJRT runtime reports per-artifact call counts — the scanner's
/// chunk count must match the corpus size.
#[test]
fn pjrt_call_counts_match_workload() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let rt = Arc::new(PjrtRuntime::load(&dir).unwrap());
    let backend: Arc<dyn ComputeBackend> = Arc::new(PjrtCompute::new(rt.clone()));
    let app = VirusScan;
    let cfg = cfg();
    let mut p = build_process(
        &app, app.program(), Size::Small, &cfg, Location::Mobile, backend, false,
    )
    .unwrap();
    run_monolithic(&mut p).unwrap();
    let calls = rt.call_counts();
    // 100 KB = 3 x 32 KiB files (9 chunk offsets each at stride 4081)
    // + 1 x 4 KiB file (2 offsets: 0 and 4081 < 4096).
    assert_eq!(calls.get("scan_chunk"), Some(&29));
}
