//! Hostile-peer matrix: full offload sessions against a scripted
//! malicious endpoint ([`HostilePeerChannel`]). The clone executes
//! honestly but its replies come back truncated, bit-flipped, replayed,
//! garbage, oversize-claiming, trailing-garbage, or as an endless
//! stream of `NeedFull` lies. The driver contract under every behavior:
//!
//! * no panic, ever;
//! * no half-applied merge — a rejected reply leaves the phone exactly
//!   as the capture left it;
//! * under a degrading policy engine, deterministically-rejected
//!   tampering finishes the run locally with a bit-identical result and
//!   the error surfaced in `DistOutcome::channel_errors`.

use std::sync::Arc;

use clonecloud::appvm::zygote::build_template;
use clonecloud::appvm::{Heap, Process, Program};
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{
    delta_statics_workload_src, delta_workload_expected, run_distributed_policy, HostileBehavior,
    HostilePeerChannel, InlineClone, PolicyEngine,
};
use clonecloud::migration::MobileSession;

const ROUNDS: i64 = 6;

struct Rig {
    program: Arc<Program>,
    template: Heap,
    expected: i64,
    main_class: usize,
}

fn rig() -> Rig {
    let program = Arc::new(
        clonecloud::appvm::assembler::assemble(&delta_statics_workload_src(ROUNDS, 512, 8))
            .unwrap(),
    );
    clonecloud::appvm::verifier::verify_program(&program).unwrap();
    let template = build_template(&program, 100, 11);
    let main = program.entry().unwrap();
    Rig {
        main_class: main.class.0 as usize,
        template,
        expected: delta_workload_expected(ROUNDS),
        program,
    }
}

impl Rig {
    fn fork(&self, loc: Location) -> Process {
        Process::fork_from_zygote(
            self.program.clone(),
            &self.template,
            match loc {
                Location::Mobile => DeviceSpec::phone_g1(),
                Location::Clone => DeviceSpec::clone_desktop(),
            },
            loc,
            clonecloud::appvm::NodeEnv::with_rust_compute(clonecloud::vfs::SimFs::new()),
        )
    }

    fn result(&self, phone: &Process) -> Option<i64> {
        phone.statics[self.main_class][1].as_int()
    }

    fn run(
        &self,
        behavior: HostileBehavior,
        seed: u64,
    ) -> clonecloud::error::Result<(Process, clonecloud::exec::DistOutcome)> {
        let inner = InlineClone::new(self.fork(Location::Clone), CostParams::default())
            .with_delta()
            .with_dict();
        let mut channel = HostilePeerChannel::new(inner, behavior, seed);
        let mut phone = self.fork(Location::Mobile);
        let mut session = MobileSession::new(true);
        let mut engine = PolicyEngine::force_offload();
        let out = run_distributed_policy(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
            &mut engine,
        )?;
        Ok((phone, out))
    }
}

/// The control row: an untampering wrapper is invisible — every round
/// migrates, nothing degrades, and the result is the workload's answer.
#[test]
fn honest_control_row_is_transparent() {
    let rig = rig();
    let (phone, out) = rig.run(HostileBehavior::Honest, 0x4057_1E00).unwrap();
    assert_eq!(rig.result(&phone), Some(rig.expected));
    assert_eq!(out.channel_errors, 0);
    assert_eq!(out.migrations, ROUNDS as usize);
}

/// Behaviors whose tampering is deterministically rejected by the
/// decoders (truncation, pure garbage, trailing garbage, a replayed
/// capsule against an advanced dictionary, an endless `NeedFull` lie):
/// every such session must complete locally with a bit-identical
/// result, the hostile replies surfaced as channel errors, and every
/// span decided exactly once. No panic, no half-applied merge.
#[test]
fn deterministic_tampering_degrades_to_a_bit_identical_local_run() {
    let rig = rig();
    for (behavior, seed) in [
        (HostileBehavior::TruncateReply, 0x4057_1E01),
        (HostileBehavior::GarbageReply, 0x4057_1E02),
        (HostileBehavior::AppendGarbage, 0x4057_1E03),
        (HostileBehavior::ReplayPreviousReply, 0x4057_1E04),
        (HostileBehavior::AlwaysNeedFull, 0x4057_1E05),
    ] {
        let (phone, out) = rig
            .run(behavior, seed)
            .unwrap_or_else(|e| panic!("{behavior:?}: run must degrade, got {e}"));
        assert_eq!(
            rig.result(&phone),
            Some(rig.expected),
            "{behavior:?}: result must stay bit-identical"
        );
        assert!(
            out.channel_errors >= 1,
            "{behavior:?}: tampering must surface in channel_errors"
        );
        assert!(out.local_fallbacks >= 1, "{behavior:?}");
        assert_eq!(
            out.offloads + out.local_fallbacks,
            ROUNDS as usize,
            "{behavior:?}: every span decided exactly once"
        );
        assert!(
            out.last_channel_error.is_some(),
            "{behavior:?}: the last hostile error is reported"
        );
    }
}

/// Chaos behaviors (a single bit flip, an oversize word overwrite) can
/// land anywhere — sometimes the reply still decodes and merges,
/// sometimes it dies in any decoder layer. The harness sweeps seeds and
/// holds the unconditional laws: no panic, and every failure is a typed
/// error, never a corrupted driver state (a subsequent clean run on the
/// same rig still produces the exact workload answer).
#[test]
fn chaos_tampering_never_panics_and_always_fails_typed() {
    let rig = rig();
    for behavior in [HostileBehavior::BitFlipReply, HostileBehavior::OversizeClaim] {
        for seed in 0..12u64 {
            match rig.run(behavior, 0x4057_1E10 + seed) {
                Ok((_, out)) => {
                    assert_eq!(
                        out.offloads + out.local_fallbacks,
                        ROUNDS as usize,
                        "{behavior:?}/{seed}: every span decided exactly once"
                    );
                }
                Err(e) => {
                    // Typed, printable, and categorized — the shape a
                    // caller can act on.
                    let msg = e.to_string();
                    assert!(!msg.is_empty(), "{behavior:?}/{seed}");
                }
            }
        }
    }
    // The rig itself is untouched by the chaos sweeps: an honest run
    // still lands on the exact answer.
    let (phone, _) = rig.run(HostileBehavior::Honest, 0x4057_1EFF).unwrap();
    assert_eq!(rig.result(&phone), Some(rig.expected));
}

/// Tampered replies must never leak half-applied state into the phone:
/// after a fully hostile session (every reply truncated), the SAME
/// mobile session recovers over an honest channel — full re-seed, all
/// rounds migrate, bit-identical result.
#[test]
fn session_recovers_over_an_honest_channel_after_a_hostile_one() {
    let rig = rig();
    let mut session = MobileSession::new(true);

    let inner = InlineClone::new(rig.fork(Location::Clone), CostParams::default())
        .with_delta()
        .with_dict();
    let mut channel =
        HostilePeerChannel::new(inner, HostileBehavior::TruncateReply, 0x4057_1E20);
    let mut phone = rig.fork(Location::Mobile);
    let mut engine = PolicyEngine::force_offload();
    let out = run_distributed_policy(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
        &mut engine,
    )
    .unwrap();
    assert_eq!(rig.result(&phone), Some(rig.expected));
    assert!(out.channel_errors >= 1);

    // Same session object, fresh honest channel: nothing stale leaks.
    let inner = InlineClone::new(rig.fork(Location::Clone), CostParams::default())
        .with_delta()
        .with_dict();
    let mut channel = HostilePeerChannel::new(inner, HostileBehavior::Honest, 0x4057_1E21);
    let mut phone = rig.fork(Location::Mobile);
    let mut engine = PolicyEngine::force_offload();
    let out = run_distributed_policy(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
        &mut engine,
    )
    .unwrap();
    assert_eq!(out.channel_errors, 0, "honest channel, clean session");
    assert_eq!(out.migrations, ROUNDS as usize);
    assert_eq!(rig.result(&phone), Some(rig.expected));
}
