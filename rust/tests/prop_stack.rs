//! Property tests over the coordinator's core invariants (our prop
//! harness standing in for proptest — DESIGN.md §2).

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::interp::{run_thread, NoHooks, RunExit};
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::zygote::build_template;
use clonecloud::appvm::{ObjBody, Process, Program, Value};
use clonecloud::config::CostParams;
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::migration::{
    capture_thread, CaptureOptions, CapturePacket, Direction, Migrator,
};
use clonecloud::util::prop::{ensure, ensure_eq, forall, PropConfig};
use clonecloud::util::rng::Rng;
use clonecloud::vfs::SimFs;

/// Random-heap capture → wire → decode is lossless, regardless of graph
/// shape (chains, cycles, shared structure, arrays).
#[test]
fn prop_capture_roundtrips_random_heaps() {
    const SRC: &str = "class H app\n  method main nargs=0 regs=8\n    ccstart 0\n    ccstop 0\n    retv\n  end\nend\n";
    let program: Arc<Program> = Arc::new(assemble(SRC).unwrap());
    let main = program.entry().unwrap();

    forall(
        PropConfig { seed: 0xCAFE, cases: 40 },
        |rng: &mut Rng| {
            let n_objs = 1 + rng.index(30);
            let edges: Vec<(usize, usize)> = (0..n_objs * 2)
                .map(|_| (rng.index(n_objs), rng.index(n_objs)))
                .collect();
            let bytes = rng.index(500);
            (n_objs, edges, bytes, rng.next_u64())
        },
        |(n_objs, edges, nbytes, seed)| {
            let mut p = Process::new(
                program.clone(),
                DeviceSpec::phone_g1(),
                Location::Mobile,
                NodeEnv::with_rust_compute(SimFs::new()),
            );
            let mut rng = Rng::new(*seed);
            // Build a random object graph.
            let ids: Vec<_> = (0..*n_objs)
                .map(|_| p.heap.alloc_ref_array(p.array_class, 4))
                .collect();
            for (a, b) in edges {
                let target = ids[*b];
                if let ObjBody::RefArray(v) = &mut p.heap.get_mut(ids[*a]).unwrap().body {
                    let slot = rng.index(4);
                    v[slot] = Value::Ref(target);
                }
            }
            let ballast = p
                .heap
                .alloc_byte_array(p.array_class, (0..*nbytes).map(|i| i as u8).collect());
            let tid = p.spawn_thread(main, &[]).unwrap();
            {
                let f = p.thread_mut(tid).unwrap().current_frame_mut().unwrap();
                f.regs[0] = Value::Ref(ids[0]);
                f.regs[1] = Value::Ref(ballast);
                f.regs[2] = Value::Int(-7);
                f.regs[3] = Value::Float(2.5);
            }
            let (packet, stats) =
                capture_thread(&p, tid, Direction::Forward, None, CaptureOptions::default())
                    .map_err(|e| e.to_string())?;
            let bytes = packet.encode().map_err(|e| e.to_string())?;
            let decoded = CapturePacket::decode(&bytes).map_err(|e| e.to_string())?;
            ensure_eq(decoded, packet.clone(), "wire roundtrip")?;
            ensure(
                stats.objects <= n_objs + 1,
                "capture bounded by live objects",
            )?;
            clonecloud::migration::validate_packet(&packet).map_err(|e| e.to_string())
        },
    );
}

/// Migration round trips preserve program semantics for random loop
/// bounds: distributed result == local result, always.
#[test]
fn prop_migration_preserves_semantics_random_inputs() {
    const SRC: &str = r#"
class W app
  static n
  static out
  method main nargs=0 regs=4
    invoke r0 W.work
    puts W.out r0
    retv
  end
  method work nargs=0 regs=8
    ccstart 0
    gets r0 W.n
    const r1 0
    const r2 0
  loop:
    ifge r2 r0 @done
    add r1 r1 r2
    const r3 1
    add r2 r2 r3
    goto @loop
  done:
    ccstop 0
    ret r1
  end
end
"#;
    let program: Arc<Program> = Arc::new(assemble(SRC).unwrap());
    let main = program.entry().unwrap();
    let template = build_template(&program, 100, 3);
    let n_class = program.class_id("W").unwrap();

    forall(
        PropConfig { seed: 0xD15C0, cases: 30 },
        |rng: &mut Rng| rng.range_i64(0, 2000),
        |&n| {
            let make = |loc: Location| {
                let dev = match loc {
                    Location::Mobile => DeviceSpec::phone_g1(),
                    Location::Clone => DeviceSpec::clone_desktop(),
                };
                let mut p = Process::fork_from_zygote(
                    program.clone(),
                    &template,
                    dev,
                    loc,
                    NodeEnv::with_rust_compute(SimFs::new()),
                );
                p.statics[n_class.0 as usize][0] = Value::Int(n);
                p
            };
            // Local reference.
            let mut local = make(Location::Mobile);
            let tid = local.spawn_thread(main, &[]).unwrap();
            loop {
                match run_thread(&mut local, tid, &mut NoHooks, u64::MAX).unwrap() {
                    RunExit::Completed(_) => break,
                    RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. } => {}
                    other => return Err(format!("{other:?}")),
                }
            }
            let want = local.statics[n_class.0 as usize][1];

            // Migrated run.
            let mut phone = make(Location::Mobile);
            let mut clone = make(Location::Clone);
            let tid = phone.spawn_thread(main, &[]).unwrap();
            let m = Migrator::new(CostParams::default());
            loop {
                match run_thread(&mut phone, tid, &mut NoHooks, u64::MAX).unwrap() {
                    RunExit::Completed(_) => break,
                    RunExit::ReintegrationPoint { .. } => {}
                    RunExit::MigrationPoint { .. } => {
                        let (pkt, _) = m.migrate_out(&mut phone, tid).map_err(|e| e.to_string())?;
                        let (ctid, table, _) =
                            m.receive_at_clone(&mut clone, &pkt).map_err(|e| e.to_string())?;
                        loop {
                            match run_thread(&mut clone, ctid, &mut NoHooks, u64::MAX).unwrap() {
                                RunExit::ReintegrationPoint { .. } => break,
                                RunExit::MigrationPoint { .. } => {}
                                other => return Err(format!("clone: {other:?}")),
                            }
                        }
                        let (rp, _, _) = m
                            .return_from_clone(&mut clone, ctid, table)
                            .map_err(|e| e.to_string())?;
                        m.merge_back(&mut phone, tid, &rp).map_err(|e| e.to_string())?;
                    }
                    other => return Err(format!("{other:?}")),
                }
            }
            let got = phone.statics[n_class.0 as usize][1];
            ensure_eq(got, want, "sum 0..n")
        },
    );
}

/// The interpreter is deterministic: same program + same seed => same
/// metrics, clock, and heap size, across repeated runs.
#[test]
fn prop_vm_determinism() {
    const SRC: &str = r#"
class D app
  static acc
  method main nargs=0 regs=8
    const r0 0
    const r1 500
    constf r2 0.0
  loop:
    ifge r0 r1 @done
    i2f r3 r0
    fmul r4 r3 r3
    fadd r2 r2 r4
    const r5 1
    add r0 r0 r5
    goto @loop
  done:
    puts D.acc r2
    retv
  end
end
"#;
    let program: Arc<Program> = Arc::new(assemble(SRC).unwrap());
    forall(
        PropConfig { seed: 0xDE7, cases: 10 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let run = || {
                let template = build_template(&program, 200, seed);
                let mut p = Process::fork_from_zygote(
                    program.clone(),
                    &template,
                    DeviceSpec::phone_g1(),
                    Location::Mobile,
                    NodeEnv::with_rust_compute(SimFs::new()),
                );
                let tid = p.spawn_thread(program.entry().unwrap(), &[]).unwrap();
                match run_thread(&mut p, tid, &mut NoHooks, u64::MAX).unwrap() {
                    RunExit::Completed(_) => {}
                    other => panic!("{other:?}"),
                }
                (p.metrics.instrs, p.clock.now_us().to_bits(), p.heap.len())
            };
            ensure_eq(run(), run(), "deterministic execution")
        },
    );
}
