//! Cross-module integration tests: the full stack composed the way the
//! benches and examples use it, on small workloads so `cargo test` stays
//! fast. Uses the Rust-reference compute backend (hermetic); PJRT-vs-
//! reference equivalence is covered in `runtime::pjrt` unit tests and
//! `tests/pjrt_e2e.rs`.

use std::sync::Arc;

use clonecloud::apps::{
    all_apps, build_process, read_static_int, App, BehaviorProfile, ImageSearch, Size, VirusScan,
};
use clonecloud::appvm::natives::{ComputeBackend, RustCompute};
use clonecloud::config::{Config, NetworkProfile};
use clonecloud::device::Location;
use clonecloud::exec::{
    run_distributed, run_distributed_policy, run_monolithic, InlineClone, PolicyEngine,
};
use clonecloud::migration::MobileSession;
use clonecloud::nodemanager::{CloneServer, NodeManager, TcpEndpoint, TcpTransport};
use clonecloud::partitioner::{
    candidate_points, rewrite_with_candidates, rewrite_with_partition, solver::Partition, Cfg,
};
use clonecloud::pipeline::{partition_from_trees, profile_pair, table1_row};
use clonecloud::util::rng::Rng;

fn cfg() -> Config {
    Config {
        zygote_objects: 300,
        ..Config::default()
    }
}

fn backend() -> Arc<dyn ComputeBackend> {
    Arc::new(RustCompute)
}

/// Force a partition with the given migratory methods.
fn forced_partition(program: &clonecloud::appvm::Program, names: &[(&str, &str)]) -> Partition {
    let mut migrate = std::collections::BTreeSet::new();
    for (c, m) in names {
        migrate.insert(program.resolve(c, m).unwrap());
    }
    Partition {
        migrate,
        locations: Default::default(),
        expected_us: 0.0,
        local_us: 0.0,
        span_costs: Default::default(),
    }
}

/// Every app: a forced-offload distributed run returns exactly the
/// monolithic result (the core semantic-preservation guarantee).
#[test]
fn distributed_equals_monolithic_for_all_apps() {
    let cfg = cfg();
    let cases: Vec<(Box<dyn App>, (&str, &str))> = vec![
        (Box::new(VirusScan), ("Scanner", "scan_all")),
        (Box::new(ImageSearch), ("Finder", "find_all")),
        (Box::new(BehaviorProfile), ("Tracker", "profile")),
    ];
    for (app, point) in cases {
        let program = app.program();
        // Monolithic reference.
        let mut mono = build_process(
            app.as_ref(), program.clone(), Size::Small, &cfg,
            Location::Mobile, backend(), false,
        )
        .unwrap();
        run_monolithic(&mut mono).unwrap();
        let mono_result = app.check(&mono, Size::Small).unwrap();

        // Forced-offload distributed run.
        let partition = forced_partition(&program, &[point]);
        let (rewritten, _) = rewrite_with_partition(&program, &partition).unwrap();
        let rewritten = Arc::new(rewritten);
        let mut phone = build_process(
            app.as_ref(), rewritten.clone(), Size::Small, &cfg,
            Location::Mobile, backend(), false,
        )
        .unwrap();
        let clone = build_process(
            app.as_ref(), rewritten, Size::Small, &cfg,
            Location::Clone, backend(), false,
        )
        .unwrap();
        let mut channel = InlineClone::new(clone, cfg.costs.clone());
        let out =
            run_distributed(&mut phone, &mut channel, &NetworkProfile::wifi(), &cfg.costs)
                .unwrap();
        assert!(out.migrations >= 1, "{} actually migrated", app.name());
        let dist_result = app.check(&phone, Size::Small).unwrap();
        assert_eq!(mono_result, dist_result, "{}", app.name());
    }
}

/// The conditional binary: ONE rewritten executable carries every
/// candidate migration point, and the runtime policy engine answers
/// migrate/local per invocation — offload-everything and local-everything
/// both reproduce the monolithic result from the same binary (nested
/// candidate points included).
#[test]
fn conditional_binary_serves_both_policies() {
    let cfg = cfg();
    let app = VirusScan;
    let program = app.program();

    let mut mono = build_process(
        &app, program.clone(), Size::Small, &cfg, Location::Mobile, backend(), false,
    )
    .unwrap();
    run_monolithic(&mut mono).unwrap();
    let mono_result = app.check(&mono, Size::Small).unwrap();

    let cfg_graph = Cfg::build(&program);
    let candidates = candidate_points(&program, &cfg_graph);
    assert!(
        candidates.len() >= 2,
        "virus scanner has nested candidates (scan_all -> scan_file)"
    );
    let (rewritten, points) = rewrite_with_candidates(&program, &candidates).unwrap();
    assert_eq!(
        rewritten.migration_points().len(),
        points.len(),
        "the binary itself carries the pid map"
    );
    let rewritten = Arc::new(rewritten);

    // Cold auto engine: static choice offloads at the outermost point.
    let mut phone = build_process(
        &app, rewritten.clone(), Size::Small, &cfg, Location::Mobile, backend(), false,
    )
    .unwrap();
    let clone = build_process(
        &app, rewritten.clone(), Size::Small, &cfg, Location::Clone, backend(), false,
    )
    .unwrap();
    let mut channel = InlineClone::new(clone, cfg.costs.clone());
    let mut engine = PolicyEngine::auto();
    let out = run_distributed_policy(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &cfg.costs,
        &mut MobileSession::disabled(),
        &mut engine,
    )
    .unwrap();
    assert!(out.offloads >= 1 && out.migrations >= 1);
    assert_eq!(app.check(&phone, Size::Small).unwrap(), mono_result);

    // Forced local on the SAME binary: every point (nested ones too)
    // continues in place; nothing is captured or sent.
    let mut phone = build_process(
        &app, rewritten.clone(), Size::Small, &cfg, Location::Mobile, backend(), false,
    )
    .unwrap();
    let clone2 = build_process(
        &app, rewritten, Size::Small, &cfg, Location::Clone, backend(), false,
    )
    .unwrap();
    let mut channel = InlineClone::new(clone2, cfg.costs.clone());
    let mut engine = PolicyEngine::force_local();
    let out = run_distributed_policy(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &cfg.costs,
        &mut MobileSession::disabled(),
        &mut engine,
    )
    .unwrap();
    assert_eq!(out.migrations, 0);
    assert!(out.local_fallbacks >= candidates.len());
    assert_eq!(out.transfer.up + out.transfer.down, 0);
    assert_eq!(app.check(&phone, Size::Small).unwrap(), mono_result);
}

/// The partitioner's choices are stable and legal across all apps/sizes/
/// networks, and the local/offload decision is monotone in network
/// quality (WiFi never keeps local what 3G offloads).
#[test]
fn partition_choices_monotone_in_network_quality() {
    let cfg = cfg();
    for app in all_apps() {
        for size in [Size::Small, Size::Medium] {
            let program = app.program();
            let (tm, tc, _) =
                profile_pair(app.as_ref(), &program, size, &cfg, &backend()).unwrap();
            let trees = (tm, tc);
            let (p3g, _, _) =
                partition_from_trees(app.as_ref(), &trees, &cfg, &NetworkProfile::threeg())
                    .unwrap();
            let (pwifi, _, _) =
                partition_from_trees(app.as_ref(), &trees, &cfg, &NetworkProfile::wifi())
                    .unwrap();
            assert!(
                !(p3g.is_offload() && !pwifi.is_offload()),
                "{} {:?}: 3G offloads but WiFi doesn't",
                app.name(),
                size
            );
        }
    }
}

/// Table 1 row invariants on the Small workloads.
#[test]
fn table1_row_invariants() {
    let cfg = cfg();
    for app in all_apps() {
        let row = table1_row(app.as_ref(), Size::Small, &cfg, &backend()).unwrap();
        assert!(row.phone_ms > row.clone_ms, "{}", app.name());
        assert!(
            row.max_speedup > 15.0 && row.max_speedup < 30.0,
            "{}: {}",
            app.name(),
            row.max_speedup
        );
        for cell in [&row.threeg, &row.wifi] {
            if cell.label == "Local" {
                assert!((cell.exec_ms - row.phone_ms).abs() < 1e-9);
            } else {
                assert!(cell.exec_ms < row.phone_ms, "offload must win");
            }
        }
    }
}

/// Distributed execution over a REAL TCP clone node with fs sync, for
/// the virus scanner (forced offload so the test is size-independent).
#[test]
fn tcp_clone_node_end_to_end() {
    let cfg = cfg();
    let app = VirusScan;
    let program = app.program();
    let partition = forced_partition(&program, &[("Scanner", "scan_all")]);
    let (rewritten, _) = rewrite_with_partition(&program, &partition).unwrap();
    let rewritten = Arc::new(rewritten);

    let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap();
    let srv_prog = rewritten.clone();
    let costs = cfg.costs.clone();
    let server = std::thread::spawn(move || {
        let t = ep.accept().unwrap();
        CloneServer::new(
            t,
            srv_prog,
            costs,
            Box::new(clonecloud::appvm::NodeEnv::with_rust_compute),
        )
        .serve()
        .unwrap()
    });

    let mut nm = NodeManager::new(TcpTransport::connect(&addr).unwrap());
    nm.provision(&rewritten, cfg.zygote_objects, cfg.seed ^ 0x2760)
        .unwrap();
    let mut rng = Rng::new(cfg.seed);
    nm.sync_fs(&app.make_fs(Size::Small, &mut rng)).unwrap();

    let mut phone = build_process(
        &app, rewritten.clone(), Size::Small, &cfg, Location::Mobile, backend(), false,
    )
    .unwrap();
    let out =
        run_distributed(&mut phone, &mut nm, &NetworkProfile::wifi(), &cfg.costs).unwrap();
    assert_eq!(out.migrations, 1);
    assert_eq!(read_static_int(&phone, "Scanner", "total"), Some(3));
    nm.shutdown().unwrap();
    let stats = server.join().unwrap();
    assert_eq!(stats.migrations, 1);
}

/// Failure injection: a clone serving the WRONG executable is rejected
/// at provision; migrating without provisioning errors cleanly.
#[test]
fn failure_injection_wrong_binary_and_no_provision() {
    let cfg = cfg();
    let app = VirusScan;
    let program = app.program();
    let other = ImageSearch.program();

    let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap();
    let costs = cfg.costs.clone();
    let server = std::thread::spawn(move || {
        let t = ep.accept().unwrap();
        // Clone has the image-search binary.
        let _ = CloneServer::new(
            t,
            other,
            costs,
            Box::new(clonecloud::appvm::NodeEnv::with_rust_compute),
        )
        .serve();
    });
    let mut nm = NodeManager::new(TcpTransport::connect(&addr).unwrap());
    let err = nm
        .provision(&program, cfg.zygote_objects, 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("hash mismatch"), "{err}");
    // Migrating without a provisioned process errors, not hangs.
    let err2 = nm.migrate(vec![1, 2, 3]).unwrap_err().to_string();
    assert!(err2.contains("provision"), "{err2}");
    nm.shutdown().unwrap();
    server.join().unwrap();
}

/// GC interacts correctly with migration: objects that die at the clone
/// are collected on the phone after the merge (paper Fig. 8 orphans).
#[test]
fn orphans_collected_after_merge() {
    use clonecloud::appvm::assembler::assemble;
    use clonecloud::appvm::interp::{run_thread, NoHooks, RunExit};
    use clonecloud::appvm::zygote::build_template;
    use clonecloud::migration::Migrator;

    const SRC: &str = r#"
class G app
  static keep
  method main nargs=0 regs=4
    const r0 4096
    newarr r1 byte r0
    puts G.keep r1
    const r1 0
    invokev G.work
    retv
  end
  method work nargs=0 regs=4
    ccstart 0
    # drop the big array at the clone
    const r0 0
    newarr r1 byte r0
    puts G.keep r1
    ccstop 0
    retv
  end
end
"#;
    let program = Arc::new(assemble(SRC).unwrap());
    let template = build_template(&program, 50, 1);
    let make = |loc| {
        clonecloud::appvm::Process::fork_from_zygote(
            program.clone(),
            &template,
            match loc {
                Location::Mobile => clonecloud::device::DeviceSpec::phone_g1(),
                Location::Clone => clonecloud::device::DeviceSpec::clone_desktop(),
            },
            loc,
            clonecloud::appvm::NodeEnv::with_rust_compute(clonecloud::vfs::SimFs::new()),
        )
    };
    let mut phone = make(Location::Mobile);
    let mut clone = make(Location::Clone);
    let main = program.entry().unwrap();
    let tid = phone.spawn_thread(main, &[]).unwrap();
    let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000).unwrap();
    assert!(matches!(exit, RunExit::MigrationPoint { .. }));
    let heap_before = phone.heap.len();

    let m = Migrator::new(cfg().costs);
    let (pkt, _) = m.migrate_out(&mut phone, tid).unwrap();
    let (ctid, table, _) = m.receive_at_clone(&mut clone, &pkt).unwrap();
    let exit = run_thread(&mut clone, ctid, &mut NoHooks, 100_000).unwrap();
    assert!(matches!(exit, RunExit::ReintegrationPoint { .. }));
    let (rp, _, dropped) = m.return_from_clone(&mut clone, ctid, table).unwrap();
    assert!(dropped >= 1, "the 4 KiB array died at the clone");
    m.merge_back(&mut phone, tid, &rp).unwrap();
    let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000).unwrap();
    assert!(matches!(exit, RunExit::Completed(_)));
    let collected = phone.gc();
    assert!(collected >= 1, "orphan collected");
    assert!(phone.heap.len() <= heap_before);
}
