//! Cross-module integration tests: the full stack composed the way the
//! benches and examples use it, on small workloads so `cargo test` stays
//! fast. Uses the Rust-reference compute backend (hermetic); PJRT-vs-
//! reference equivalence is covered in `runtime::pjrt` unit tests and
//! `tests/pjrt_e2e.rs`.

use std::sync::Arc;

use clonecloud::apps::{
    all_apps, build_process, read_static_int, App, BehaviorProfile, ImageSearch, Size, VirusScan,
};
use clonecloud::appvm::natives::{ComputeBackend, RustCompute};
use clonecloud::config::{Config, NetworkProfile};
use clonecloud::device::Location;
use clonecloud::exec::{
    run_distributed, run_distributed_policy, run_monolithic, InlineClone, PolicyEngine,
};
use clonecloud::migration::MobileSession;
use clonecloud::nodemanager::{CloneServer, NodeManager, TcpEndpoint, TcpTransport};
use clonecloud::partitioner::{
    candidate_points, rewrite_with_candidates, rewrite_with_partition, solver::Partition, Cfg,
};
use clonecloud::pipeline::{partition_from_trees, profile_pair, table1_row};
use clonecloud::util::rng::Rng;

fn cfg() -> Config {
    Config {
        zygote_objects: 300,
        ..Config::default()
    }
}

fn backend() -> Arc<dyn ComputeBackend> {
    Arc::new(RustCompute)
}

/// Force a partition with the given migratory methods.
fn forced_partition(program: &clonecloud::appvm::Program, names: &[(&str, &str)]) -> Partition {
    let mut migrate = std::collections::BTreeSet::new();
    for (c, m) in names {
        migrate.insert(program.resolve(c, m).unwrap());
    }
    Partition {
        migrate,
        locations: Default::default(),
        expected_us: 0.0,
        local_us: 0.0,
        span_costs: Default::default(),
    }
}

/// Every app: a forced-offload distributed run returns exactly the
/// monolithic result (the core semantic-preservation guarantee).
#[test]
fn distributed_equals_monolithic_for_all_apps() {
    let cfg = cfg();
    let cases: Vec<(Box<dyn App>, (&str, &str))> = vec![
        (Box::new(VirusScan), ("Scanner", "scan_all")),
        (Box::new(ImageSearch), ("Finder", "find_all")),
        (Box::new(BehaviorProfile), ("Tracker", "profile")),
    ];
    for (app, point) in cases {
        let program = app.program();
        // Monolithic reference.
        let mut mono = build_process(
            app.as_ref(), program.clone(), Size::Small, &cfg,
            Location::Mobile, backend(), false,
        )
        .unwrap();
        run_monolithic(&mut mono).unwrap();
        let mono_result = app.check(&mono, Size::Small).unwrap();

        // Forced-offload distributed run.
        let partition = forced_partition(&program, &[point]);
        let (rewritten, _) = rewrite_with_partition(&program, &partition).unwrap();
        let rewritten = Arc::new(rewritten);
        let mut phone = build_process(
            app.as_ref(), rewritten.clone(), Size::Small, &cfg,
            Location::Mobile, backend(), false,
        )
        .unwrap();
        let clone = build_process(
            app.as_ref(), rewritten, Size::Small, &cfg,
            Location::Clone, backend(), false,
        )
        .unwrap();
        let mut channel = InlineClone::new(clone, cfg.costs.clone());
        let out =
            run_distributed(&mut phone, &mut channel, &NetworkProfile::wifi(), &cfg.costs)
                .unwrap();
        assert!(out.migrations >= 1, "{} actually migrated", app.name());
        let dist_result = app.check(&phone, Size::Small).unwrap();
        assert_eq!(mono_result, dist_result, "{}", app.name());
    }
}

/// The conditional binary: ONE rewritten executable carries every
/// candidate migration point, and the runtime policy engine answers
/// migrate/local per invocation — offload-everything and local-everything
/// both reproduce the monolithic result from the same binary (nested
/// candidate points included).
#[test]
fn conditional_binary_serves_both_policies() {
    let cfg = cfg();
    let app = VirusScan;
    let program = app.program();

    let mut mono = build_process(
        &app, program.clone(), Size::Small, &cfg, Location::Mobile, backend(), false,
    )
    .unwrap();
    run_monolithic(&mut mono).unwrap();
    let mono_result = app.check(&mono, Size::Small).unwrap();

    let cfg_graph = Cfg::build(&program);
    let candidates = candidate_points(&program, &cfg_graph);
    assert!(
        candidates.len() >= 2,
        "virus scanner has nested candidates (scan_all -> scan_file)"
    );
    let (rewritten, points) = rewrite_with_candidates(&program, &candidates).unwrap();
    assert_eq!(
        rewritten.migration_points().len(),
        points.len(),
        "the binary itself carries the pid map"
    );
    let rewritten = Arc::new(rewritten);

    // Cold auto engine: static choice offloads at the outermost point.
    let mut phone = build_process(
        &app, rewritten.clone(), Size::Small, &cfg, Location::Mobile, backend(), false,
    )
    .unwrap();
    let clone = build_process(
        &app, rewritten.clone(), Size::Small, &cfg, Location::Clone, backend(), false,
    )
    .unwrap();
    let mut channel = InlineClone::new(clone, cfg.costs.clone());
    let mut engine = PolicyEngine::auto();
    let out = run_distributed_policy(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &cfg.costs,
        &mut MobileSession::disabled(),
        &mut engine,
    )
    .unwrap();
    assert!(out.offloads >= 1 && out.migrations >= 1);
    assert_eq!(app.check(&phone, Size::Small).unwrap(), mono_result);

    // Forced local on the SAME binary: every point (nested ones too)
    // continues in place; nothing is captured or sent.
    let mut phone = build_process(
        &app, rewritten.clone(), Size::Small, &cfg, Location::Mobile, backend(), false,
    )
    .unwrap();
    let clone2 = build_process(
        &app, rewritten, Size::Small, &cfg, Location::Clone, backend(), false,
    )
    .unwrap();
    let mut channel = InlineClone::new(clone2, cfg.costs.clone());
    let mut engine = PolicyEngine::force_local();
    let out = run_distributed_policy(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &cfg.costs,
        &mut MobileSession::disabled(),
        &mut engine,
    )
    .unwrap();
    assert_eq!(out.migrations, 0);
    assert!(out.local_fallbacks >= candidates.len());
    assert_eq!(out.transfer.up + out.transfer.down, 0);
    assert_eq!(app.check(&phone, Size::Small).unwrap(), mono_result);
}

/// The partitioner's choices are stable and legal across all apps/sizes/
/// networks, and the local/offload decision is monotone in network
/// quality (WiFi never keeps local what 3G offloads).
#[test]
fn partition_choices_monotone_in_network_quality() {
    let cfg = cfg();
    for app in all_apps() {
        for size in [Size::Small, Size::Medium] {
            let program = app.program();
            let (tm, tc, _) =
                profile_pair(app.as_ref(), &program, size, &cfg, &backend()).unwrap();
            let trees = (tm, tc);
            let (p3g, _, _) =
                partition_from_trees(app.as_ref(), &trees, &cfg, &NetworkProfile::threeg())
                    .unwrap();
            let (pwifi, _, _) =
                partition_from_trees(app.as_ref(), &trees, &cfg, &NetworkProfile::wifi())
                    .unwrap();
            assert!(
                !(p3g.is_offload() && !pwifi.is_offload()),
                "{} {:?}: 3G offloads but WiFi doesn't",
                app.name(),
                size
            );
        }
    }
}

/// Table 1 row invariants on the Small workloads.
#[test]
fn table1_row_invariants() {
    let cfg = cfg();
    for app in all_apps() {
        let row = table1_row(app.as_ref(), Size::Small, &cfg, &backend()).unwrap();
        assert!(row.phone_ms > row.clone_ms, "{}", app.name());
        assert!(
            row.max_speedup > 15.0 && row.max_speedup < 30.0,
            "{}: {}",
            app.name(),
            row.max_speedup
        );
        for cell in [&row.threeg, &row.wifi] {
            if cell.label == "Local" {
                assert!((cell.exec_ms - row.phone_ms).abs() < 1e-9);
            } else {
                assert!(cell.exec_ms < row.phone_ms, "offload must win");
            }
        }
    }
}

/// Distributed execution over a REAL TCP clone node with fs sync, for
/// the virus scanner (forced offload so the test is size-independent).
#[test]
fn tcp_clone_node_end_to_end() {
    let cfg = cfg();
    let app = VirusScan;
    let program = app.program();
    let partition = forced_partition(&program, &[("Scanner", "scan_all")]);
    let (rewritten, _) = rewrite_with_partition(&program, &partition).unwrap();
    let rewritten = Arc::new(rewritten);

    let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap();
    let srv_prog = rewritten.clone();
    let costs = cfg.costs.clone();
    let server = std::thread::spawn(move || {
        let t = ep.accept().unwrap();
        CloneServer::new(
            t,
            srv_prog,
            costs,
            Box::new(clonecloud::appvm::NodeEnv::with_rust_compute),
        )
        .serve()
        .unwrap()
    });

    let mut nm = NodeManager::new(TcpTransport::connect(&addr).unwrap());
    nm.provision(&rewritten, cfg.zygote_objects, cfg.seed ^ 0x2760)
        .unwrap();
    let mut rng = Rng::new(cfg.seed);
    nm.sync_fs(&app.make_fs(Size::Small, &mut rng)).unwrap();

    let mut phone = build_process(
        &app, rewritten.clone(), Size::Small, &cfg, Location::Mobile, backend(), false,
    )
    .unwrap();
    let out =
        run_distributed(&mut phone, &mut nm, &NetworkProfile::wifi(), &cfg.costs).unwrap();
    assert_eq!(out.migrations, 1);
    assert_eq!(read_static_int(&phone, "Scanner", "total"), Some(3));
    nm.shutdown().unwrap();
    let stats = server.join().unwrap();
    assert_eq!(stats.migrations, 1);
}

/// Failure injection: a clone serving the WRONG executable is rejected
/// at provision; migrating without provisioning errors cleanly.
#[test]
fn failure_injection_wrong_binary_and_no_provision() {
    let cfg = cfg();
    let app = VirusScan;
    let program = app.program();
    let other = ImageSearch.program();

    let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap();
    let costs = cfg.costs.clone();
    let server = std::thread::spawn(move || {
        let t = ep.accept().unwrap();
        // Clone has the image-search binary.
        let _ = CloneServer::new(
            t,
            other,
            costs,
            Box::new(clonecloud::appvm::NodeEnv::with_rust_compute),
        )
        .serve();
    });
    let mut nm = NodeManager::new(TcpTransport::connect(&addr).unwrap());
    let err = nm
        .provision(&program, cfg.zygote_objects, 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("hash mismatch"), "{err}");
    // Migrating without a provisioned process errors, not hangs.
    let err2 = nm.migrate(vec![1, 2, 3]).unwrap_err().to_string();
    assert!(err2.contains("provision"), "{err2}");
    nm.shutdown().unwrap();
    server.join().unwrap();
}

/// Protocol interop matrix: every (v3,v4) x (initiator,responder) x
/// (LZ, dictionary, delta on/off) pairing negotiates the COMMON SUBSET —
/// unknown bits ignored, min revision echoed, never a rejection — and a
/// two-round session completes bit-identical to monolithic.
#[test]
fn interop_matrix_lands_on_common_subset_bit_identical() {
    use clonecloud::appvm::zygote::build_template;
    use clonecloud::config::CostParams;
    use clonecloud::exec::{delta_statics_workload_src, delta_workload_expected,
        run_distributed_session};
    use clonecloud::migration::MobileSession;
    use clonecloud::nodemanager::{
        Codec, InProcTransport, CAP_CODEC_LZ, CAP_SESSION_DICT,
    };

    const ROUNDS: i64 = 2;
    const ZY: usize = 120;
    let program = Arc::new(
        clonecloud::appvm::assembler::assemble(&delta_statics_workload_src(ROUNDS, 256, 4))
            .unwrap(),
    );
    clonecloud::appvm::verifier::verify_program(&program).unwrap();
    let template = build_template(&program, ZY, 5);
    let main = program.entry().unwrap();
    let expected = delta_workload_expected(ROUNDS);
    let fork = |loc: Location| {
        clonecloud::appvm::Process::fork_from_zygote(
            program.clone(),
            &template,
            match loc {
                Location::Mobile => clonecloud::device::DeviceSpec::phone_g1(),
                Location::Clone => clonecloud::device::DeviceSpec::clone_desktop(),
            },
            loc,
            clonecloud::appvm::NodeEnv::with_rust_compute(clonecloud::vfs::SimFs::new()),
        )
    };

    for init_proto in [3u16, 4] {
        for resp_proto in [3u16, 4] {
            for lz in [false, true] {
                for dict in [false, true] {
                    for delta in [false, true] {
                        let label = format!(
                            "init v{init_proto} vs resp v{resp_proto}, \
                             lz={lz} dict={dict} delta={delta}"
                        );
                        let (phone_t, clone_t) = InProcTransport::pair();
                        let mut server = CloneServer::new(
                            clone_t,
                            program.clone(),
                            CostParams::default(),
                            Box::new(clonecloud::appvm::NodeEnv::with_rust_compute),
                        );
                        server.proto_cap = resp_proto;
                        let srv = std::thread::spawn(move || server.serve().unwrap());

                        let mut nm = NodeManager::new(phone_t);
                        nm.pretend_proto(init_proto);
                        let mut caps = 0u32;
                        if lz {
                            caps |= CAP_CODEC_LZ;
                        }
                        if dict {
                            caps |= CAP_SESSION_DICT;
                        }
                        // Advertise an unknown future bit too: it must
                        // be ignored, never rejected.
                        nm.advertise_caps(caps | 0x8000_0000);
                        nm.advertise_delta(delta);
                        nm.negotiate().unwrap();

                        // The negotiated set is exactly the common
                        // subset of what both ends speak.
                        let min = init_proto.min(resp_proto);
                        assert_eq!(
                            nm.delta_negotiated(),
                            delta && min >= 4,
                            "{label}: delta"
                        );
                        assert_eq!(
                            nm.negotiated_codec() == Codec::Lz,
                            lz && min >= 4,
                            "{label}: codec"
                        );
                        assert_eq!(
                            nm.dict_negotiated(),
                            dict && min >= 4,
                            "{label}: dict"
                        );
                        assert_eq!(nm.negotiated_proto(), min, "{label}: revision echo");

                        nm.provision(&program, ZY, 5).unwrap();
                        let mut phone = fork(Location::Mobile);
                        let mut session = MobileSession::new(true);
                        let out = run_distributed_session(
                            &mut phone,
                            &mut nm,
                            &NetworkProfile::wifi(),
                            &clonecloud::config::CostParams::default(),
                            &mut session,
                        )
                        .unwrap();
                        assert_eq!(out.migrations, ROUNDS as usize, "{label}");
                        assert_eq!(out.delta_fallbacks, 0, "{label}");
                        assert_eq!(out.dict_fallbacks, 0, "{label}");
                        if nm.delta_negotiated() {
                            assert_eq!(out.delta_roundtrips, 1, "{label}: repeat delta");
                        } else {
                            assert_eq!(out.delta_roundtrips, 0, "{label}: full-only");
                        }
                        assert_eq!(
                            phone.statics[main.class.0 as usize][1].as_int(),
                            Some(expected),
                            "{label}: bit-identical to monolithic"
                        );
                        nm.shutdown().unwrap();
                        srv.join().unwrap();
                    }
                }
            }
        }
    }
}

/// Capability-flapping rows of the interop matrix: a peer that
/// advertises `CAP_SESSION_DICT`/`CAP_SCATTER` on one Hello and drops
/// them on the next must renegotiate cleanly — and when the bits come
/// BACK a round later, neither end may decode against the dictionary
/// replica left over from the first negotiation. The clone's per-Hello
/// `set_dict_enabled` toggle resets its replica, so a fresh phone and
/// the long-lived clone both re-seed from the empty prefix: round 3
/// completes with zero dictionary fallbacks instead of a digest
/// mismatch against stale state.
#[test]
fn interop_capability_flapping_renegotiates_without_stale_dict_state() {
    use clonecloud::appvm::zygote::build_template;
    use clonecloud::config::CostParams;
    use clonecloud::exec::{
        delta_statics_workload_src, delta_workload_expected, run_distributed_session,
    };
    use clonecloud::nodemanager::{InProcTransport, CAP_SCATTER, CAP_SESSION_DICT};

    const ROUNDS: i64 = 2;
    const ZY: usize = 120;
    let program = Arc::new(
        clonecloud::appvm::assembler::assemble(&delta_statics_workload_src(ROUNDS, 256, 4))
            .unwrap(),
    );
    clonecloud::appvm::verifier::verify_program(&program).unwrap();
    let template = build_template(&program, ZY, 5);
    let main = program.entry().unwrap();
    let expected = delta_workload_expected(ROUNDS);
    let fork = || {
        clonecloud::appvm::Process::fork_from_zygote(
            program.clone(),
            &template,
            clonecloud::device::DeviceSpec::phone_g1(),
            Location::Mobile,
            clonecloud::appvm::NodeEnv::with_rust_compute(clonecloud::vfs::SimFs::new()),
        )
    };

    let (phone_t, clone_t) = InProcTransport::pair();
    let mut server = CloneServer::new(
        clone_t,
        program.clone(),
        CostParams::default(),
        Box::new(clonecloud::appvm::NodeEnv::with_rust_compute),
    );
    server.proto_cap = 4;
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let mut nm = NodeManager::new(phone_t);
    nm.pretend_proto(4);
    // Delta stays off throughout: baselines would add their own
    // (legitimate) fallbacks across phone restarts and mask the
    // dictionary behavior under test.
    nm.advertise_delta(false);

    // One session per negotiation round; each uses a fresh phone (the
    // app restarted) against the SAME long-lived clone connection.
    let mut provisioned = false;
    let mut run_round = |nm: &mut NodeManager<InProcTransport>, label: &str| {
        if !provisioned {
            nm.provision(&program, ZY, 5).unwrap();
            provisioned = true;
        }
        let mut phone = fork();
        let mut session = MobileSession::new(true);
        let out = run_distributed_session(
            &mut phone,
            nm,
            &NetworkProfile::wifi(),
            &clonecloud::config::CostParams::default(),
            &mut session,
        )
        .unwrap();
        assert_eq!(out.migrations, ROUNDS as usize, "{label}: migrations");
        assert_eq!(out.delta_fallbacks, 0, "{label}: delta fallbacks");
        assert_eq!(out.dict_fallbacks, 0, "{label}: dict fallbacks");
        assert_eq!(
            phone.statics[main.class.0 as usize][1].as_int(),
            Some(expected),
            "{label}: bit-identical to monolithic"
        );
    };

    // Round 1: both capabilities advertised and agreed; the clone's
    // dictionary replica warms up over the session.
    nm.advertise_caps(CAP_SESSION_DICT | CAP_SCATTER);
    nm.negotiate().unwrap();
    assert!(nm.dict_negotiated(), "round 1: dict agreed");
    assert!(nm.scatter_negotiated(), "round 1: scatter agreed");
    run_round(&mut nm, "round 1 (caps on)");

    // Round 2: the peer flaps both bits off. Negotiation must land on
    // the plain subset and the session must run dict-free.
    nm.advertise_caps(0);
    nm.negotiate().unwrap();
    assert!(!nm.dict_negotiated(), "round 2: dict off after flap");
    assert!(!nm.scatter_negotiated(), "round 2: scatter off after flap");
    run_round(&mut nm, "round 2 (caps flapped off)");

    // Round 3: the bits come back. The fresh phone starts from the
    // empty dictionary; the clone must too (its replica was reset by
    // the capability toggle), or the very first shared-mode capsule
    // would be answered with a digest-mismatch NeedFull.
    nm.advertise_caps(CAP_SESSION_DICT | CAP_SCATTER);
    nm.negotiate().unwrap();
    assert!(nm.dict_negotiated(), "round 3: dict re-agreed");
    assert!(nm.scatter_negotiated(), "round 3: scatter re-agreed");
    run_round(&mut nm, "round 3 (caps back on)");

    nm.shutdown().unwrap();
    srv.join().unwrap();
}

/// `CAP_TRACE_CTX` rows of the interop matrix: every (v3,v4) initiator/
/// responder pairing with the trace envelope advertised or withheld
/// negotiates the common subset — context only when both ends speak v4
/// AND both carried the bit — and the session stays bit-identical to
/// every other pairing. Observe-only invariant: the recorder's
/// presence/absence never changes execution results, it only decides
/// whether clone-side spans come home merged into the phone timeline.
#[test]
fn trace_interop_pairings_negotiate_common_subset_bit_identical() {
    use clonecloud::appvm::zygote::build_template;
    use clonecloud::config::CostParams;
    use clonecloud::exec::{
        delta_statics_workload_src, delta_workload_expected, run_distributed_traced,
    };
    use clonecloud::nodemanager::{InProcTransport, CAP_CODEC_LZ, CAP_TRACE_CTX};
    use clonecloud::trace::{Endpoint, Event, Tracer};

    const ROUNDS: i64 = 2;
    const ZY: usize = 120;
    let program = Arc::new(
        clonecloud::appvm::assembler::assemble(&delta_statics_workload_src(ROUNDS, 256, 4))
            .unwrap(),
    );
    clonecloud::appvm::verifier::verify_program(&program).unwrap();
    let template = build_template(&program, ZY, 5);
    let main = program.entry().unwrap();
    let expected = delta_workload_expected(ROUNDS);

    // (migrations, result) must agree across ALL pairings — trace
    // on/off and v3/v4 alike. (delta_roundtrips legitimately varies
    // with the protocol floor, so it is checked per-pairing instead.)
    let mut fingerprint: Option<(usize, Option<i64>)> = None;
    for init_proto in [3u16, 4] {
        for resp_proto in [3u16, 4] {
            for trace in [false, true] {
                let label = format!("init v{init_proto} vs resp v{resp_proto}, trace={trace}");
                let (phone_t, clone_t) = InProcTransport::pair();
                let mut server = CloneServer::new(
                    clone_t,
                    program.clone(),
                    CostParams::default(),
                    Box::new(clonecloud::appvm::NodeEnv::with_rust_compute),
                );
                server.proto_cap = resp_proto;
                let srv = std::thread::spawn(move || server.serve().unwrap());

                let mut nm = NodeManager::new(phone_t);
                nm.pretend_proto(init_proto);
                let mut caps = CAP_CODEC_LZ;
                if trace {
                    caps |= CAP_TRACE_CTX;
                }
                nm.advertise_caps(caps);
                nm.advertise_delta(true);
                nm.negotiate().unwrap();

                let min = init_proto.min(resp_proto);
                assert_eq!(
                    nm.trace_negotiated(),
                    trace && min >= 4,
                    "{label}: trace ctx is the intersection at proto >= 4"
                );

                nm.provision(&program, ZY, 5).unwrap();
                let mut phone = clonecloud::appvm::Process::fork_from_zygote(
                    program.clone(),
                    &template,
                    clonecloud::device::DeviceSpec::phone_g1(),
                    Location::Mobile,
                    clonecloud::appvm::NodeEnv::with_rust_compute(clonecloud::vfs::SimFs::new()),
                );
                let mut session = MobileSession::new(true);
                let mut engine = PolicyEngine::force_offload().without_degrade();
                let mut tracer = Tracer::new(0x1A7E, Endpoint::Phone, 4096);
                let out = run_distributed_traced(
                    &mut phone,
                    &mut nm,
                    &NetworkProfile::wifi(),
                    &CostParams::default(),
                    &mut session,
                    &mut engine,
                    &mut tracer,
                )
                .unwrap();

                let got = phone.statics[main.class.0 as usize][1].as_int();
                assert_eq!(got, Some(expected), "{label}: result");
                assert_eq!(
                    out.delta_roundtrips,
                    if nm.delta_negotiated() { 1 } else { 0 },
                    "{label}: delta follows its own negotiation, not trace's"
                );
                let fp = (out.migrations, got);
                if let Some(base) = &fingerprint {
                    assert_eq!(*base, fp, "{label}: bit-identical across pairings");
                } else {
                    fingerprint = Some(fp);
                }

                // Clone-side spans come home exactly when negotiated;
                // the phone records its own spans either way.
                let events: Vec<Event> = tracer.events().cloned().collect();
                let clone_events = events.iter().filter(|e| e.endpoint == Endpoint::Clone).count();
                assert!(!events.is_empty(), "{label}: phone spans recorded");
                assert_eq!(
                    clone_events > 0,
                    nm.trace_negotiated(),
                    "{label}: piggybacked events iff negotiated"
                );
                nm.shutdown().unwrap();
                srv.join().unwrap();
            }
        }
    }
}

/// Fault-injection matrix: the link dies at every possible frame
/// boundary of a six-round session. Under a degrading engine every cut
/// point still completes the run locally (bit-identical result, error
/// surfaced in `channel_errors`, no panic, no half-applied merge), and
/// the legacy session wrapper still fails fast.
#[test]
fn fault_matrix_every_cut_degrades_to_local() {
    use clonecloud::appvm::zygote::build_template;
    use clonecloud::config::CostParams;
    use clonecloud::exec::{
        delta_statics_workload_src, delta_workload_expected, run_distributed_session,
        FaultInjectChannel,
    };
    use clonecloud::migration::MobileSession;

    const ROUNDS: i64 = 6;
    let program = Arc::new(
        clonecloud::appvm::assembler::assemble(&delta_statics_workload_src(ROUNDS, 512, 8))
            .unwrap(),
    );
    clonecloud::appvm::verifier::verify_program(&program).unwrap();
    let template = build_template(&program, 100, 11);
    let main = program.entry().unwrap();
    let expected = delta_workload_expected(ROUNDS);
    let fork = |loc: Location| {
        clonecloud::appvm::Process::fork_from_zygote(
            program.clone(),
            &template,
            match loc {
                Location::Mobile => clonecloud::device::DeviceSpec::phone_g1(),
                Location::Clone => clonecloud::device::DeviceSpec::clone_desktop(),
            },
            loc,
            clonecloud::appvm::NodeEnv::with_rust_compute(clonecloud::vfs::SimFs::new()),
        )
    };

    // A clean session moves 2 frames per roundtrip.
    let total_frames = 2 * ROUNDS as u64;
    for kill_after in 0..=total_frames + 1 {
        let inner = InlineClone::new(fork(Location::Clone), CostParams::default())
            .with_delta()
            .with_dict();
        let mut channel = FaultInjectChannel::new(inner, kill_after);
        let mut phone = fork(Location::Mobile);
        let mut session = MobileSession::new(true);
        let mut engine = PolicyEngine::force_offload();
        let out = run_distributed_policy(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
            &mut engine,
        )
        .unwrap_or_else(|e| panic!("cut at frame {kill_after}: run must degrade, got {e}"));

        assert_eq!(
            phone.statics[main.class.0 as usize][1].as_int(),
            Some(expected),
            "cut at frame {kill_after}: result must stay bit-identical"
        );
        assert_eq!(
            out.offloads + out.local_fallbacks,
            ROUNDS as usize,
            "cut at frame {kill_after}: every span decided exactly once"
        );
        if kill_after < total_frames {
            assert!(
                out.channel_errors >= 1,
                "cut at frame {kill_after}: error must surface in channel_errors"
            );
            assert!(
                out.last_channel_error.as_deref().unwrap().contains("injected fault"),
                "cut at frame {kill_after}"
            );
            assert!(out.local_fallbacks >= 1, "cut at frame {kill_after}");
        } else {
            assert_eq!(out.channel_errors, 0, "no cut reached: {kill_after}");
            assert_eq!(out.migrations, ROUNDS as usize);
        }
    }

    // The legacy wrapper keeps its contract: a dead link is an error,
    // fast and clean (no panic, no partial merge into the phone).
    let inner = InlineClone::new(fork(Location::Clone), CostParams::default()).with_delta();
    let mut channel = FaultInjectChannel::new(inner, 3);
    let mut phone = fork(Location::Mobile);
    let err = run_distributed_session(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut MobileSession::new(true),
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("injected fault"),
        "legacy wrapper fails fast: {err}"
    );

    // Recovery: the same clone (which executed a roundtrip whose reverse
    // frame was cut) serves a fresh run cleanly — the session re-arms
    // from a full capture, no stale state leaks.
    let inner = InlineClone::new(fork(Location::Clone), CostParams::default())
        .with_delta()
        .with_dict();
    let mut channel = FaultInjectChannel::new(inner, total_frames - 1);
    let mut phone = fork(Location::Mobile);
    let mut session = MobileSession::new(true);
    let mut engine = PolicyEngine::force_offload();
    let out = run_distributed_policy(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
        &mut engine,
    )
    .unwrap();
    assert_eq!(out.channel_errors, 1, "exactly the last reverse frame was cut");
    let mut inner = channel.into_inner();
    let mut phone2 = fork(Location::Mobile);
    let out2 = run_distributed_session(
        &mut phone2,
        &mut inner,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
    )
    .unwrap();
    assert_eq!(out2.migrations, ROUNDS as usize);
    assert_eq!(
        phone2.statics[main.class.0 as usize][1].as_int(),
        Some(expected),
        "recovery session over the half-advanced clone is bit-identical"
    );
}

/// Page-epoch soak: 110 one-offload rounds over a 4000-object template
/// rooted from an app static, with a skewed O(1) mutation set. Pages
/// scanned stay bounded by dirty pages + a constant — never O(heap) —
/// and every mutation path (interp stores, merge apply, put_static) is
/// covered by the barrier: 110 coherent deltas, zero fallbacks. A
/// deliberately missed stamp (peek_mut on a baseline member) surfaces
/// as a digest divergence error BEFORE any state is merged — never as
/// wrong bytes — and the session recovers with a full capture.
#[test]
fn page_epoch_soak_bounds_scan_work_and_catches_missed_stamps() {
    use clonecloud::appvm::zygote::build_template;
    use clonecloud::appvm::ObjBody;
    use clonecloud::config::CostParams;
    use clonecloud::exec::run_distributed_session;
    use clonecloud::migration::MobileSession;

    const SRC: &str = r#"
class Soak app
  static out
  static keep
  static registry
  method main nargs=0 regs=8
    const r0 1024
    newarr r1 byte r0
    const r2 0
    const r3 7
    aput r1 r2 r3
    invoke r4 Soak.work r1
    puts Soak.out r4
    retv
  end
  method work nargs=1 regs=8
    ccstart 0
    len r1 r0
    const r2 0
    const r3 0
  sum:
    ifge r2 r1 @sd
    aget r4 r0 r2
    add r3 r3 r4
    const r5 1
    add r2 r2 r5
    goto @sum
  sd:
    const r7 4
    newarr r2 byte r7
    const r6 0
    aput r2 r6 r3
    puts Soak.keep r2
    ccstop 0
    ret r3
  end
end
"#;
    const ZY: usize = 4_000;
    let program = Arc::new(clonecloud::appvm::assembler::assemble(SRC).unwrap());
    clonecloud::appvm::verifier::verify_program(&program).unwrap();
    let template = build_template(&program, ZY, 17);
    let main = program.entry().unwrap();
    let fork = |loc: Location| {
        clonecloud::appvm::Process::fork_from_zygote(
            program.clone(),
            &template,
            match loc {
                Location::Mobile => clonecloud::device::DeviceSpec::phone_g1(),
                Location::Clone => clonecloud::device::DeviceSpec::clone_desktop(),
            },
            loc,
            clonecloud::appvm::NodeEnv::with_rust_compute(clonecloud::vfs::SimFs::new()),
        )
    };

    // Root the WHOLE template graph from the `registry` static (slot
    // 2), as a real app roots framework state — the Zygote-scale shape
    // where a per-object traversal would visit ~4000 objects every
    // capture.
    let mut phone = fork(Location::Mobile);
    clonecloud::appvm::zygote::root_template_in_static(&mut phone, main.class.0 as usize, 2);

    let mut channel = InlineClone::new(fork(Location::Clone), CostParams::default())
        .with_delta()
        .with_dict();
    let mut session = MobileSession::new(true);

    const ROUNDS: usize = 110;
    for round in 0..ROUNDS {
        let out = run_distributed_session(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
        )
        .unwrap();
        assert_eq!(
            phone.statics[main.class.0 as usize][0].as_int(),
            Some(7),
            "round {round}"
        );
        assert_eq!(out.delta_fallbacks, 0, "round {round}: barrier covered");
        assert_eq!(out.dict_fallbacks, 0, "round {round}");
        if round == 0 {
            assert_eq!(out.full_roundtrips, 1, "first contact is full");
        } else {
            assert_eq!(out.delta_roundtrips, 1, "round {round} rode a delta");
            // The satellite's core claim: scan work is bounded by the
            // dirty set, never the heap. A per-object traversal would
            // have scanned ~4000 objects here.
            assert!(
                out.pages_scanned <= out.pages_dirty + 8,
                "round {round}: {} pages scanned vs {} dirty",
                out.pages_scanned,
                out.pages_dirty
            );
            assert!(
                out.objects_scanned <= 400,
                "round {round}: scan work {} is not O(dirty)",
                out.objects_scanned
            );
        }
    }

    // Negative control: a mutation that BYPASSES the write barrier
    // (peek_mut on a baseline member) must surface as a digest
    // divergence before any merge applies — not as wrong bytes.
    let keep = phone.statics[main.class.0 as usize][1].as_ref().unwrap();
    if let ObjBody::ByteArray(b) = &mut phone.heap.peek_mut(keep).unwrap().body {
        b[0] ^= 0xFF;
    }
    let out_before = phone.statics[main.class.0 as usize][0];
    let err = run_distributed_session(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("diverged"),
        "missed stamp surfaced as a digest divergence: {err}"
    );
    assert_eq!(
        phone.statics[main.class.0 as usize][0], out_before,
        "no half-applied merge: phone state untouched by the rejected round"
    );

    // The divergence cleared the baseline; the next round recovers from
    // a full capture with correct results.
    let out = run_distributed_session(
        &mut phone,
        &mut channel,
        &NetworkProfile::wifi(),
        &CostParams::default(),
        &mut session,
    )
    .unwrap();
    assert_eq!(out.full_roundtrips, 1, "recovery rode a full capture");
    assert_eq!(phone.statics[main.class.0 as usize][0].as_int(), Some(7));
}

/// GC interacts correctly with migration: objects that die at the clone
/// are collected on the phone after the merge (paper Fig. 8 orphans).
#[test]
fn orphans_collected_after_merge() {
    use clonecloud::appvm::assembler::assemble;
    use clonecloud::appvm::interp::{run_thread, NoHooks, RunExit};
    use clonecloud::appvm::zygote::build_template;
    use clonecloud::migration::Migrator;

    const SRC: &str = r#"
class G app
  static keep
  method main nargs=0 regs=4
    const r0 4096
    newarr r1 byte r0
    puts G.keep r1
    const r1 0
    invokev G.work
    retv
  end
  method work nargs=0 regs=4
    ccstart 0
    # drop the big array at the clone
    const r0 0
    newarr r1 byte r0
    puts G.keep r1
    ccstop 0
    retv
  end
end
"#;
    let program = Arc::new(assemble(SRC).unwrap());
    let template = build_template(&program, 50, 1);
    let make = |loc| {
        clonecloud::appvm::Process::fork_from_zygote(
            program.clone(),
            &template,
            match loc {
                Location::Mobile => clonecloud::device::DeviceSpec::phone_g1(),
                Location::Clone => clonecloud::device::DeviceSpec::clone_desktop(),
            },
            loc,
            clonecloud::appvm::NodeEnv::with_rust_compute(clonecloud::vfs::SimFs::new()),
        )
    };
    let mut phone = make(Location::Mobile);
    let mut clone = make(Location::Clone);
    let main = program.entry().unwrap();
    let tid = phone.spawn_thread(main, &[]).unwrap();
    let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000).unwrap();
    assert!(matches!(exit, RunExit::MigrationPoint { .. }));
    let heap_before = phone.heap.len();

    let m = Migrator::new(cfg().costs);
    let (pkt, _) = m.migrate_out(&mut phone, tid).unwrap();
    let (ctid, table, _) = m.receive_at_clone(&mut clone, &pkt).unwrap();
    let exit = run_thread(&mut clone, ctid, &mut NoHooks, 100_000).unwrap();
    assert!(matches!(exit, RunExit::ReintegrationPoint { .. }));
    let (rp, _, dropped) = m.return_from_clone(&mut clone, ctid, table).unwrap();
    assert!(dropped >= 1, "the 4 KiB array died at the clone");
    m.merge_back(&mut phone, tid, &rp).unwrap();
    let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000).unwrap();
    assert!(matches!(exit, RunExit::Completed(_)));
    let collected = phone.gc();
    assert!(collected >= 1, "orphan collected");
    assert!(phone.heap.len() <= heap_before);
}
