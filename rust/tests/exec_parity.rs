//! Differential parity suite: tier-1 direct-threaded execution vs the
//! tier-0 interpreter (the bit-identity contract in `appvm::tier1`).
//!
//! Every test runs the same program under both engines and compares the
//! *complete* observable machine state — exit condition or error string,
//! per-instruction virtual-clock bits, `VmMetrics::instrs`, thread
//! `cpu_us` bits, the full frame stack (pc + registers), statics, and
//! every heap object including its write-barrier epoch. The tier may
//! only change wall time, never a single bit of VM state.

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::bytecode::{ArrKind, CmpOp, FloatOp, IntOp};
use clonecloud::appvm::interp::{run_thread, NoHooks, RunExit};
use clonecloud::appvm::{
    ClassDef, ExecTier, Instr, MethodDef, NodeEnv, Process, Program, Tier1Engine,
};
use clonecloud::config::{CostParams, ExecTierKind, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{run_distributed, InlineClone};
use clonecloud::farm::{synthetic_expected, synthetic_offload_src};
use clonecloud::util::prop::{forall, PropConfig};
use clonecloud::util::rng::Rng;
use clonecloud::vfs::SimFs;

const REGS: usize = 6;
const FUEL: u64 = 4_000;

fn method(name: &str, nregs: usize, code: Vec<Instr>) -> MethodDef {
    MethodDef {
        name: name.into(),
        nargs: 0,
        nregs,
        code,
        native: None,
        pinned: name == "main",
        native_state: false,
        migration_point: None,
    }
}

/// App class with two statics, `main` = the generated code, plus a small
/// loop helper that random `Invoke`s call (exercises call/return bails
/// and helper promotion).
fn program_with(code: Vec<Instr>) -> Arc<Program> {
    let mut p = Program::new();
    let mut c = ClassDef::new("App", false);
    c.add_static("s0");
    c.add_static("s1");
    c.add_method(method("main", REGS, code));
    c.add_method(method(
        "helper",
        4,
        vec![
            Instr::Const(0, 0),
            Instr::Const(1, 0),
            Instr::Const(2, 5),
            Instr::Const(3, 1),
            Instr::IntBin(IntOp::Add, 1, 1, 3),
            Instr::IntBin(IntOp::Add, 0, 0, 1),
            Instr::IfCmp(CmpOp::Lt, 1, 2, 4),
            Instr::Return(Some(0)),
        ],
    ));
    p.add_class(c);
    p.into_shared()
}

fn process(program: &Arc<Program>) -> Process {
    let mut p = Process::new(
        program.clone(),
        DeviceSpec::clone_desktop(),
        Location::Clone,
        NodeEnv::with_rust_compute(SimFs::new()),
    );
    let main = program.entry().unwrap();
    p.spawn_thread(main, &[]).unwrap();
    p
}

/// The complete observable state, rendered so NaN payloads and f64 bit
/// patterns compare exactly (`Debug` of identical NaNs is equal where
/// `PartialEq` is not).
fn fingerprint(p: &Process) -> String {
    let heap: Vec<String> = p
        .heap
        .iter()
        .map(|(id, o)| format!("{}:{o:?}", id.0))
        .collect();
    let t = p.thread(0).unwrap();
    format!(
        "instrs={} clock={:#x} cpu={:#x} status={:?}\nframes={:?}\nstatics={:?}\nheap={heap:?}",
        p.metrics.instrs,
        p.clock.now_us().to_bits(),
        t.cpu_us.to_bits(),
        t.status,
        t.frames,
        p.statics,
    )
}

/// Drive one engine across partition-point exits until the thread
/// completes, faults, or runs dry. Both engines hit the same points in
/// the same order, so the re-entry cap compares equal too.
fn drive(
    p: &mut Process,
    mut step: impl FnMut(&mut Process) -> clonecloud::error::Result<RunExit>,
) -> String {
    for _ in 0..64 {
        match step(p) {
            Ok(RunExit::MigrationPoint { .. }) | Ok(RunExit::ReintegrationPoint { .. }) => {
                continue
            }
            Ok(exit) => return format!("{exit:?}"),
            Err(e) => return format!("err: {e}"),
        }
    }
    "partition-point limit".into()
}

/// Run `code` under both tiers and demand bit-identical everything.
fn assert_parity(code: &[Instr], fuel: u64, threshold: u32) -> Result<(), String> {
    let prog = program_with(code.to_vec());
    let mut base = process(&prog);
    let r0 = drive(&mut base, |p| run_thread(p, 0, &mut NoHooks, fuel));

    let mut tiered = process(&prog);
    let mut tier = ExecTier::Tier1(Box::new(Tier1Engine::new().with_threshold(threshold)));
    let r1 = drive(&mut tiered, |p| tier.run_thread(p, 0, fuel));

    if r0 != r1 {
        return Err(format!("exit diverged: interp {r0} vs tier1 {r1}"));
    }
    let (f0, f1) = (fingerprint(&base), fingerprint(&tiered));
    if f0 != f1 {
        return Err(format!("state diverged after {r0}:\n--- interp\n{f0}\n--- tier1\n{f1}"));
    }
    Ok(())
}

/// Random program: a seeded prologue (ints + one array), a body drawn
/// from the full light-op set plus heavy ops (alloc, statics stores,
/// invoke, partition points), and random forward/backward branches.
/// Ill-typed and out-of-range combinations are left in on purpose —
/// fault parity (error string + pc + charged work) is half the contract.
fn random_code(rng: &mut Rng) -> Vec<Instr> {
    let body = rng.range_i64(6, 30) as usize;
    let len = 6 + body + 1; // prologue + body + final Return
    let mut code = vec![
        Instr::Const(0, rng.range_i64(-4, 9)),
        Instr::Const(1, rng.range_i64(0, 3)),
        Instr::Const(2, rng.range_i64(1, 6)),
        Instr::Const(3, rng.range_i64(-2, 5)),
        Instr::Const(5, rng.range_i64(1, 8)),
        Instr::NewArray(
            4,
            match rng.range_i64(0, 2) {
                0 => ArrKind::Byte,
                1 => ArrKind::Float,
                _ => ArrKind::Val,
            },
            5,
        ),
    ];
    let reg = |rng: &mut Rng| rng.range_i64(0, (REGS - 1) as i64) as u8;
    // Branches stay past the prologue so loops re-run real work, but a
    // rare wild target (== len, or past it) checks the lazy-fault and
    // end-slot paths.
    let target = |rng: &mut Rng| {
        if rng.chance(0.06) {
            len as u32 + rng.range_i64(0, 2) as u32
        } else {
            rng.range_i64(6, (len - 1) as i64) as u32
        }
    };
    let int_op = |rng: &mut Rng| {
        [
            IntOp::Add,
            IntOp::Sub,
            IntOp::Mul,
            IntOp::Div,
            IntOp::Rem,
            IntOp::And,
            IntOp::Or,
            IntOp::Xor,
            IntOp::Shl,
            IntOp::Shr,
        ][rng.range_i64(0, 9) as usize]
    };
    let cmp_op = |rng: &mut Rng| {
        [
            CmpOp::Eq,
            CmpOp::Ne,
            CmpOp::Lt,
            CmpOp::Le,
            CmpOp::Gt,
            CmpOp::Ge,
        ][rng.range_i64(0, 5) as usize]
    };
    for _ in 0..body {
        let ins = match rng.range_i64(0, 21) {
            0 => Instr::Nop,
            1 => Instr::Const(reg(rng), rng.range_i64(-8, 8)),
            2 => Instr::ConstF(reg(rng), rng.range_i64(-40, 40) as f64 / 8.0),
            3 => Instr::Move(reg(rng), reg(rng)),
            4 | 5 => Instr::IntBin(int_op(rng), reg(rng), reg(rng), reg(rng)),
            6 => Instr::FloatBin(
                [FloatOp::Add, FloatOp::Sub, FloatOp::Mul, FloatOp::Div]
                    [rng.range_i64(0, 3) as usize],
                reg(rng),
                reg(rng),
                reg(rng),
            ),
            7 => Instr::Cmp(cmp_op(rng), reg(rng), reg(rng), reg(rng)),
            8 => Instr::IfZ(reg(rng), target(rng)),
            9 => Instr::IfNZ(reg(rng), target(rng)),
            10 => Instr::IfCmp(cmp_op(rng), reg(rng), reg(rng), target(rng)),
            11 => Instr::Goto(target(rng)),
            12 => Instr::ArrGet(reg(rng), 4, reg(rng)),
            13 => Instr::ArrPut(4, reg(rng), reg(rng)),
            14 => Instr::ArrLen(reg(rng), reg(rng)),
            15 => Instr::IntToFloat(reg(rng), reg(rng)),
            16 => Instr::FloatToInt(reg(rng), reg(rng)),
            17 => Instr::GetStatic(reg(rng), clonecloud::appvm::ClassId(0), rng.range_i64(0, 2) as u16),
            18 => Instr::PutStatic(clonecloud::appvm::ClassId(0), rng.range_i64(0, 1) as u16, reg(rng)),
            19 => Instr::Invoke {
                mref: clonecloud::appvm::MRef {
                    class: clonecloud::appvm::ClassId(0),
                    method: clonecloud::appvm::MethodId(1),
                },
                ret: Some(reg(rng)),
                args: vec![],
            },
            20 => Instr::CcStart(0),
            _ => Instr::CcStop(0),
        };
        code.push(ins);
    }
    code.push(Instr::Return(Some(0)));
    code
}

#[test]
fn random_programs_are_bit_identical_across_tiers() {
    forall(
        PropConfig {
            seed: 0x7EE2_1CED,
            cases: 200,
        },
        random_code,
        |code| assert_parity(code, FUEL, 1),
    );
}

#[test]
fn random_programs_match_under_tight_fuel() {
    // Small fuel values land the budget on every segment phase,
    // including fused-superinstruction interiors.
    forall(
        PropConfig {
            seed: 0xF0E1,
            cases: 60,
        },
        random_code,
        |code| {
            for fuel in [1, 2, 3, 5, 9, 17, 33, 65] {
                assert_parity(code, fuel, 1)?;
            }
            Ok(())
        },
    );
}

#[test]
fn parity_holds_across_promotion_boundaries() {
    // Threshold sweep: the same program is interpreted for 0, 1, 2, or 3
    // activations before tier-1 takes over mid-run. The switch point
    // must not be observable in VM state.
    forall(
        PropConfig {
            seed: 0xB0DA_12,
            cases: 40,
        },
        random_code,
        |code| {
            for threshold in 1..=4u32 {
                assert_parity(code, FUEL, threshold)?;
            }
            Ok(())
        },
    );
}

#[test]
fn offload_roundtrip_is_bit_identical_across_tiers() {
    // End-to-end: the same offload workload through `InlineClone` under
    // the interp ablation and under tier 1 — merged statics and the
    // phone's virtual clock must agree to the bit, and match the
    // monolithic expectation.
    let iters = 3_000;
    let program = Arc::new(assemble(&synthetic_offload_src(iters)).unwrap());
    clonecloud::appvm::verifier::verify_program(&program).unwrap();
    let mut fs = SimFs::new();
    let mut bytes = vec![0u8; 64];
    Rng::new(0xD1FF).fill_bytes(&mut bytes);
    fs.add("data.bin", bytes);
    let expected = synthetic_expected(&fs, iters);

    let run = |kind: ExecTierKind| {
        let phone_env = NodeEnv::with_rust_compute(fs.synchronize());
        let clone_env = NodeEnv::with_rust_compute(fs.synchronize());
        let mut phone = Process::new(
            program.clone(),
            DeviceSpec::phone_g1(),
            Location::Mobile,
            phone_env,
        );
        let clone = Process::new(
            program.clone(),
            DeviceSpec::clone_desktop(),
            Location::Clone,
            clone_env,
        );
        let mut channel = InlineClone::new(clone, CostParams::default()).with_exec_tier(kind);
        run_distributed(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
        )
        .unwrap();
        let main = program.entry().unwrap();
        (
            phone.statics[main.class.0 as usize][0]
                .as_int()
                .unwrap(),
            phone.clock.now_us().to_bits(),
            phone.metrics.instrs,
        )
    };

    let interp = run(ExecTierKind::Interp);
    let tier1 = run(ExecTierKind::Tier1);
    assert_eq!(interp.0, expected, "interp result");
    assert_eq!(tier1.0, expected, "tier1 result");
    assert_eq!(interp, tier1, "merged state and clock bits");
}
