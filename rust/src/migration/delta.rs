//! Incremental migration capsules (epoch-based delta transfer).
//!
//! The paper's runtime re-serializes the entire reachable heap at every
//! `ccstart`/`ccstop`, even when the peer already holds last round's
//! merged state (a farm worker's affinity-pinned clone slot, or the phone
//! itself on reintegration). This module replaces that with **delta
//! capsules**: after any successful sync, both endpoints record a
//! *session baseline* — the set of shared objects (named by their
//! mobile-side id, the session-stable MID), the heap's mutation epoch at
//! the sync, and a canonical digest of the shared state. A later capture
//! then ships only
//!
//! * objects **created** since the baseline,
//! * baseline members **mutated** since the baseline epoch (the
//!   `Heap::get_mut` write barrier stamps every store), and
//! * the ids of members that **died**,
//!
//! while unchanged members ride as [`WireValue::Base`] references. The
//! digest travels with every delta; a receiver whose own digest disagrees
//! (first contact, recycled worker, divergence) answers with the typed
//! [`CloneCloudError::NeedFull`] signal and the sender falls back to a
//! full [`CapturePacket`] — correctness never depends on the cache.
//!
//! New objects created at the clone get their MIDs assigned by the mobile
//! merge; the pairs are piggybacked on the *next* forward capsule
//! (`assignments`), which is exactly when the clone needs them.
//!
//! Epoch-coherence invariant (the codebase's first cross-cutting one):
//! at every sync point both endpoints record baselines describing the
//! same logical state, and each endpoint advances its heap epoch
//! immediately after recording, so "changed since the sync" is the single
//! comparison `obj.epoch > baseline.epoch` on either side.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::appvm::process::Process;
use crate::appvm::thread::{ThreadStatus, VmThread};
use crate::appvm::value::{ObjBody, ObjId, Value};
use crate::error::{CloneCloudError, Result};
use crate::util::bytes::{WireReader, WireWriter};

use super::capture::{
    capture_core, capture_core_paged, capture_thread, BaseView, CaptureOptions, CaptureStats,
    DeltaBase,
};
use super::format::{
    decode_direction, encode_direction, CapturePacket, DictMode, DictRead, Direction,
    SessionDict, WireBody, WireObject, WireSections, WireValue, MAGIC as FULL_MAGIC,
};
use super::mapping::MappingTable;
use super::merge::{
    apply_sections, merge_at_mobile, placeholder, resolve_zygote_locals, BaseResolve,
    MergeStats,
};
use super::zygote_diff::ZygoteIndex;

/// Magic + version for the delta capsule ("CCDP" = CloneCloud delta
/// packet). Shares the section encoding with the full format.
pub(crate) const DELTA_MAGIC: u32 = 0x4343_4450;
const DELTA_VERSION: u16 = 1;

/// An incremental capture: everything that changed since the negotiated
/// session baseline, plus the bookkeeping to keep both ends coherent.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaPacket {
    pub direction: Direction,
    pub thread_id: u32,
    pub clock_us: f64,
    /// The sender's heap epoch at the baseline sync (diagnostic; the
    /// digest is the authoritative coherence check).
    pub base_epoch: u64,
    /// Canonical digest of the shared baseline state. The receiver
    /// recomputes its own and answers `NeedFull` on mismatch.
    pub base_digest: u64,
    /// Forward only: (clone-side id, assigned mobile id) pairs for
    /// objects created at the clone last visit, merged at the mobile.
    pub assignments: Vec<(u64, u64)>,
    /// Baseline members (by MID) no longer reachable at the sender.
    pub deleted: Vec<u64>,
    pub sections: WireSections,
}

impl DeltaPacket {
    /// Serialize to bytes. Fails only when a collection count cannot be
    /// represented on the wire (see [`WireWriter::put_count`]).
    pub fn encode(&self) -> Result<Vec<u8>> {
        self.encode_with(DictMode::Off)
    }

    /// Encode under an explicit session-dictionary mode.
    pub fn encode_with(&self, dict: DictMode<'_>) -> Result<Vec<u8>> {
        let mut w = WireWriter::with_capacity(1024);
        self.encode_into_with(&mut w, dict)?;
        Ok(w.into_vec())
    }

    /// Encode into an existing writer (scratch-buffer reuse; see
    /// [`CapturePacket::encode_into_with`]).
    pub fn encode_into_with(&self, w: &mut WireWriter, dict: DictMode<'_>) -> Result<()> {
        w.put_u32(DELTA_MAGIC);
        w.put_u16(DELTA_VERSION);
        encode_direction(w, self.direction);
        w.put_u32(self.thread_id);
        w.put_f64(self.clock_us);
        w.put_u64(self.base_epoch);
        w.put_u64(self.base_digest);
        w.put_count(self.assignments.len())?;
        for (cid, mid) in &self.assignments {
            w.put_u64(*cid);
            w.put_u64(*mid);
        }
        w.put_count(self.deleted.len())?;
        for mid in &self.deleted {
            w.put_u64(*mid);
        }
        self.sections.encode_into_with(w, dict)
    }

    pub fn decode(buf: &[u8]) -> Result<DeltaPacket> {
        Ok(Self::decode_with(buf, DictRead::Off)?.0)
    }

    /// Decode under an explicit session-dictionary mode; the flag says
    /// whether the capsule rode the shared dictionary.
    pub fn decode_with(buf: &[u8], dict: DictRead<'_>) -> Result<(DeltaPacket, bool)> {
        let mut r = WireReader::new(buf);
        let magic = r.get_u32()?;
        if magic != DELTA_MAGIC {
            return Err(CloneCloudError::Wire(format!("bad delta magic {magic:#x}")));
        }
        let version = r.get_u16()?;
        if version != DELTA_VERSION {
            return Err(CloneCloudError::Wire(format!(
                "unsupported delta version {version}"
            )));
        }
        let direction = decode_direction(&mut r)?;
        let thread_id = r.get_u32()?;
        let clock_us = r.get_f64()?;
        let base_epoch = r.get_u64()?;
        let base_digest = r.get_u64()?;
        let na = r.get_u32()? as usize;
        let na = r.checked_count(na, 16)?;
        let mut assignments = Vec::with_capacity(na);
        for _ in 0..na {
            let cid = r.get_u64()?;
            let mid = r.get_u64()?;
            assignments.push((cid, mid));
        }
        let nd = r.get_u32()? as usize;
        let nd = r.checked_count(nd, 8)?;
        let mut deleted = Vec::with_capacity(nd);
        for _ in 0..nd {
            deleted.push(r.get_u64()?);
        }
        let (sections, used_dict) = WireSections::decode_from_with(&mut r, dict)?;
        if !r.is_done() {
            return Err(CloneCloudError::Wire(format!(
                "{} trailing bytes in delta capsule",
                r.remaining()
            )));
        }
        Ok((
            DeltaPacket {
                direction,
                thread_id,
                clock_us,
                base_epoch,
                base_digest,
                assignments,
                deleted,
                sections,
            },
            used_dict,
        ))
    }
}

/// Byte offset of the `clock_us` field in any encoded capsule: both
/// flavors lead with magic (u32) + version (u16) + direction (u8) +
/// thread id (u32), then the f64 clock. The exec driver patches the
/// post-transfer timestamp at this offset into the (sealed) wire frame
/// instead of re-encoding and re-compressing the whole capsule.
pub const CAPSULE_CLOCK_OFFSET: usize = 11;

/// What actually rides the wire in a `Migrate`/`Reintegrate` frame: a
/// full capture or a delta, distinguished by magic.
#[derive(Debug, Clone, PartialEq)]
pub enum Capsule {
    Full(CapturePacket),
    Delta(DeltaPacket),
}

impl Capsule {
    /// Serialize to bytes. Fails only when a collection count cannot be
    /// represented on the wire (see [`WireWriter::put_count`]).
    pub fn encode(&self) -> Result<Vec<u8>> {
        self.encode_with(DictMode::Off)
    }

    /// Encode under an explicit session-dictionary mode.
    pub fn encode_with(&self, dict: DictMode<'_>) -> Result<Vec<u8>> {
        match self {
            Capsule::Full(p) => p.encode_with(dict),
            Capsule::Delta(d) => d.encode_with(dict),
        }
    }

    /// Encode into an existing writer (scratch-buffer reuse; see
    /// [`CapturePacket::encode_into_with`]).
    pub fn encode_into_with(&self, w: &mut WireWriter, dict: DictMode<'_>) -> Result<()> {
        match self {
            Capsule::Full(p) => p.encode_into_with(w, dict),
            Capsule::Delta(d) => d.encode_into_with(w, dict),
        }
    }

    /// Decode either capsule flavor, dispatching on the leading magic.
    pub fn decode(buf: &[u8]) -> Result<Capsule> {
        Ok(Self::decode_with(buf, DictRead::Off)?.0)
    }

    /// Decode either flavor under an explicit session-dictionary mode;
    /// the flag says whether the capsule rode the shared dictionary (so
    /// receivers can answer in the same mode).
    pub fn decode_with(buf: &[u8], dict: DictRead<'_>) -> Result<(Capsule, bool)> {
        let mut r = WireReader::new(buf);
        match r.get_u32()? {
            FULL_MAGIC => {
                let (p, used) = CapturePacket::decode_with(buf, dict)?;
                Ok((Capsule::Full(p), used))
            }
            DELTA_MAGIC => {
                let (d, used) = DeltaPacket::decode_with(buf, dict)?;
                Ok((Capsule::Delta(d), used))
            }
            magic => Err(CloneCloudError::Wire(format!(
                "unknown capsule magic {magic:#x}"
            ))),
        }
    }

    pub fn is_delta(&self) -> bool {
        matches!(self, Capsule::Delta(_))
    }

    pub fn direction(&self) -> Direction {
        match self {
            Capsule::Full(p) => p.direction,
            Capsule::Delta(d) => d.direction,
        }
    }

    pub fn clock_us(&self) -> f64 {
        match self {
            Capsule::Full(p) => p.clock_us,
            Capsule::Delta(d) => d.clock_us,
        }
    }

    pub fn set_clock_us(&mut self, t: f64) {
        match self {
            Capsule::Full(p) => p.clock_us = t,
            Capsule::Delta(d) => d.clock_us = t,
        }
    }

    /// The objects serialized in this capsule (cost model input).
    pub fn objects(&self) -> &[WireObject] {
        match self {
            Capsule::Full(p) => &p.objects,
            Capsule::Delta(d) => &d.sections.objects,
        }
    }
}

// ---------------------------------------------------------------------------
// Canonical state digest
// ---------------------------------------------------------------------------

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn eat(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x1000_0000_01b3);
        }
    }
    fn eat_u64(&mut self, v: u64) {
        self.eat(&v.to_be_bytes());
    }
}

/// Canonical digest of the shared session state: the baseline members
/// (`(mid, local id)` pairs) hashed in MID order, followed by every
/// app-class static slot (in class order, nulls included), with every
/// reference canonicalized to a MID or a Zygote (class, seq) name. Both
/// endpoints compute this over their own heaps at each sync point;
/// equality means the baselines describe the same logical state —
/// statics included, now that deltas ship them incrementally — so a
/// delta against it is safe to apply.
pub(crate) fn state_digest(p: &Process, members: &[(u64, ObjId)]) -> u64 {
    let by_local: HashMap<u64, u64> = members.iter().map(|&(m, l)| (l.0, m)).collect();
    let mut sorted: Vec<(u64, ObjId)> = members.to_vec();
    sorted.sort_unstable();

    let mut h = Fnv::new();
    let eat_value = |h: &mut Fnv, v: &Value| match v {
        Value::Null => h.eat(&[0]),
        Value::Int(x) => {
            h.eat(&[1]);
            h.eat_u64(*x as u64);
        }
        Value::Float(x) => {
            h.eat(&[2]);
            h.eat_u64(x.to_bits());
        }
        Value::Ref(t) => {
            if let Some(&mid) = by_local.get(&t.0) {
                h.eat(&[3]);
                h.eat_u64(mid);
            } else if let Ok(obj) = p.heap.get(*t) {
                match obj.zygote_seq {
                    Some(seq) => {
                        h.eat(&[4]);
                        h.eat(p.program.class(obj.class).name.as_bytes());
                        h.eat_u64(seq as u64);
                    }
                    // A member referencing a non-member app object cannot
                    // occur at a sync point; poison the digest so any
                    // asymmetry degrades to a full capture.
                    None => h.eat(&[5]),
                }
            } else {
                h.eat(&[6]);
            }
        }
    };

    for (mid, local) in sorted {
        h.eat_u64(mid);
        let obj = match p.heap.get(local) {
            Ok(o) => o,
            Err(_) => {
                h.eat(b"!dead");
                continue;
            }
        };
        h.eat(p.program.class(obj.class).name.as_bytes());
        match &obj.body {
            ObjBody::Fields(vs) => {
                h.eat(&[10]);
                h.eat_u64(vs.len() as u64);
                for v in vs {
                    eat_value(&mut h, v);
                }
            }
            ObjBody::ByteArray(b) => {
                h.eat(&[11]);
                h.eat_u64(b.len() as u64);
                h.eat(b);
            }
            ObjBody::FloatArray(f) => {
                h.eat(&[12]);
                h.eat_u64(f.len() as u64);
                for x in f {
                    h.eat(&x.to_bits().to_be_bytes());
                }
            }
            ObjBody::RefArray(vs) => {
                h.eat(&[13]);
                h.eat_u64(vs.len() as u64);
                for v in vs {
                    eat_value(&mut h, v);
                }
            }
        }
    }

    // App-class statics are session-shared state too (they ride deltas
    // incrementally), so a divergent static must poison the digest just
    // like a divergent member body.
    for (ci, class_statics) in p.statics.iter().enumerate() {
        if p.program.classes[ci].system {
            continue;
        }
        h.eat(&[20]);
        h.eat(p.program.classes[ci].name.as_bytes());
        h.eat_u64(class_statics.len() as u64);
        for v in class_statics {
            eat_value(&mut h, v);
        }
    }
    h.0
}

// ---------------------------------------------------------------------------
// Session state (one per endpoint per phone/clone pairing)
// ---------------------------------------------------------------------------

struct MobileBaseline {
    /// Heap epoch at the sync (objects stamped later are dirty).
    epoch: u64,
    /// Canonical digest of the shared state at the sync.
    digest: u64,
    /// Mobile-side ids of every shared object.
    mids: HashSet<u64>,
}

/// The mobile endpoint's per-session baseline cache. One per
/// (phone process, clone channel) pairing; survives across roundtrips so
/// repeat offloads pay O(dirty set) instead of O(heap).
pub struct MobileSession {
    enabled: bool,
    baseline: Option<MobileBaseline>,
    /// (clone id, assigned mobile id) pairs from the last reverse merge,
    /// shipped with the next forward capsule.
    pending: Vec<(u64, u64)>,
    /// Ship the full statics section in delta capsules (the PR 2 wire
    /// shape; bench ablation only).
    full_statics: bool,
    /// Send a digest heartbeat when the baseline has idled this long
    /// (`None` = never).
    heartbeat_after: Option<Duration>,
    /// Wall time of the last sync point (baseline record or coherent
    /// heartbeat).
    last_sync: Instant,
    /// Use the page-epoch dirty scan for delta captures (off = the
    /// per-object baseline traversal, kept for the bench ablation).
    paged: bool,
    /// Run a mobile-side heap GC every this many delta captures
    /// (0 = never). GC is what turns unreachable baseline members into
    /// the capsule's `deleted` list on the paged path — capture itself
    /// never traverses the heap.
    gc_every: u64,
    delta_captures: u64,
    /// Also collect once the heap has grown by this many objects since
    /// the last collection (0 = count-based cadence only). A
    /// fast-allocating trace collects on growth, before the fixed
    /// capture count comes due.
    gc_growth_objects: u64,
    /// Heap id watermark (`next_id_hint`) at the last collection — the
    /// growth trigger's reference point. 0 = unarmed; armed (without
    /// collecting) on the first delta capture so template allocations
    /// never count as growth.
    gc_watermark: u64,
    /// Mobile GCs this session actually ran (tests + diagnostics).
    gc_runs: u64,
    /// Session-lifetime string dictionary replica (used only when the
    /// channel negotiated `CAP_SESSION_DICT`).
    dict: SessionDict,
    /// Encode capsules against the dictionary when the channel supports
    /// it (off = per-capsule tables even on a negotiated channel).
    dict_enabled: bool,
    /// Session-lifetime encode scratch: the driver reuses this buffer's
    /// capacity across trips instead of growing a fresh Vec per capsule.
    scratch: Vec<u8>,
}

impl MobileSession {
    pub fn new(enabled: bool) -> MobileSession {
        MobileSession {
            enabled,
            baseline: None,
            pending: Vec::new(),
            full_statics: false,
            heartbeat_after: None,
            last_sync: Instant::now(),
            paged: true,
            gc_every: 8,
            delta_captures: 0,
            gc_growth_objects: 0,
            gc_watermark: 0,
            gc_runs: 0,
            dict: SessionDict::new(),
            dict_enabled: true,
            scratch: Vec::new(),
        }
    }

    /// A session that always captures in full (the seed behavior).
    pub fn disabled() -> MobileSession {
        MobileSession::new(false)
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Turn delta capture off (e.g. the channel did not negotiate it).
    pub fn disable(&mut self) {
        self.enabled = false;
        self.baseline = None;
        self.pending.clear();
    }

    pub fn has_baseline(&self) -> bool {
        self.baseline.is_some()
    }

    /// Re-send the full statics section in every delta (PR 2 shape;
    /// bench ablation only — receivers stay compatible either way).
    pub fn ship_full_statics(&mut self, on: bool) {
        self.full_statics = on;
    }

    /// Select the capture strategy: page-epoch dirty scan (default) or
    /// the per-object baseline traversal (bench ablation / the PR 4
    /// shape).
    pub fn set_paged(&mut self, on: bool) {
        self.paged = on;
    }

    /// Mobile-side GC cadence in delta captures (0 = never). See the
    /// `gc_every` field.
    pub fn set_gc_interval(&mut self, every: u64) {
        self.gc_every = every;
    }

    /// Heap-growth GC trigger: also collect once `next_id_hint` has
    /// advanced this many objects past the last collection (0 = off).
    /// Ids are monotonic, so the allocation-rate check is a subtraction
    /// — never a heap walk.
    pub fn set_gc_growth(&mut self, objects: u64) {
        self.gc_growth_objects = objects;
    }

    /// Mobile-side GCs this session has run (either trigger).
    pub fn gc_runs(&self) -> u64 {
        self.gc_runs
    }

    /// The session dictionary replica (driver encode/decode side).
    pub fn dict(&mut self) -> &mut SessionDict {
        &mut self.dict
    }

    /// Whether capsules should be encoded against the session
    /// dictionary when the channel negotiated it.
    pub fn dict_enabled(&self) -> bool {
        self.dict_enabled
    }

    /// (Re)arm or disarm the shared dictionary. Any toggle resets the
    /// replica: a peer that drops `CAP_SESSION_DICT` across Hellos and
    /// later re-advertises it must re-seed from the empty prefix, never
    /// decode against state the other end no longer holds.
    pub fn set_dict_enabled(&mut self, on: bool) {
        if self.dict_enabled != on {
            self.dict.reset();
        }
        self.dict_enabled = on;
    }

    /// Drop the dictionary back to empty. Called whenever a `NeedFull`
    /// crosses the session in either direction, so both replicas land
    /// on the empty prefix together and the resend re-seeds.
    pub fn reset_dict(&mut self) {
        self.dict.reset();
    }

    /// (hit_bytes, additions) counters for metrics deltas.
    pub fn dict_stats(&self) -> (u64, u64) {
        (self.dict.hit_bytes, self.dict.additions)
    }

    /// Probe the peer with a digest heartbeat once the baseline has been
    /// idle this long (`Duration::ZERO` = before every migration).
    pub fn heartbeat_every(&mut self, interval: Duration) {
        self.heartbeat_after = Some(interval);
    }

    /// Whether a heartbeat should precede the next delta capture.
    pub fn heartbeat_due(&self) -> bool {
        match self.heartbeat_after {
            Some(d) if self.enabled && self.baseline.is_some() => {
                self.last_sync.elapsed() >= d
            }
            _ => false,
        }
    }

    /// The recorded baseline's (epoch, canonical digest), if any.
    pub fn baseline_info(&self) -> Option<(u64, u64)> {
        self.baseline.as_ref().map(|b| (b.epoch, b.digest))
    }

    /// MID assignments from the last reverse merge, not yet delivered to
    /// the clone (a heartbeat piggybacks these exactly like a forward
    /// delta would).
    pub fn pending_assignments(&self) -> &[(u64, u64)] {
        &self.pending
    }

    /// The peer confirmed the baseline (heartbeat `Ack`): the delivered
    /// assignments are cleared and the idle clock restarts.
    pub fn mark_coherent(&mut self) {
        self.pending.clear();
        self.last_sync = Instant::now();
    }

    /// Drop the baseline cache (heartbeat `NeedFull`, or any out-of-band
    /// divergence signal): the next capture is full.
    pub fn drop_baseline(&mut self) {
        self.baseline = None;
        self.pending.clear();
    }

    /// Take the session-lifetime encode scratch (empty, but with the
    /// capacity of every prior trip). Pair with [`put_scratch`]: encode
    /// into it, `split_off(0)` the frame, hand the allocation back.
    pub fn take_scratch(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.scratch)
    }

    /// Return the scratch allocation after a trip; contents are cleared,
    /// capacity is kept for the next encode.
    pub fn put_scratch(&mut self, mut buf: Vec<u8>) {
        buf.clear();
        self.scratch = buf;
    }
}

/// A memoized clone-side [`ZygoteIndex`], tagged with the heap template
/// generation it was built at and whether it passed the strict
/// duplicate-name check (the delta path demands strictness; the full
/// path resolves twins leniently).
struct ZidxCache {
    gen: u64,
    strict: bool,
    idx: Arc<ZygoteIndex>,
}

struct CloneBaseline {
    /// Persistent MID <-> CID mapping — the paper's Fig. 8 table promoted
    /// to session lifetime.
    table: MappingTable,
    /// Clone heap epoch right after the last forward apply.
    fwd_epoch: u64,
    /// Digest of the state right after the last forward apply (the
    /// baseline the reverse delta is built against).
    fwd_digest: u64,
}

/// The clone endpoint's per-session baseline cache. Lives in the clone
/// slot (farm worker) or the per-connection server state; evicted when
/// the slot is recycled.
pub struct CloneSession {
    enabled: bool,
    base: Option<CloneBaseline>,
    /// Re-send the full statics section in reverse deltas (PR 2 shape;
    /// bench ablation only).
    full_statics: bool,
    /// Use the page-epoch dirty scan for reverse captures.
    paged: bool,
    /// Session dictionary replica; consulted only when `dict_enabled`
    /// (the channel negotiated `CAP_SESSION_DICT`).
    dict: SessionDict,
    dict_enabled: bool,
    /// Memoized Zygote name index: the full-heap scan is paid once per
    /// template generation on a warm slot, not once per migration.
    zidx: Option<ZidxCache>,
}

impl CloneSession {
    pub fn new(enabled: bool) -> CloneSession {
        CloneSession {
            enabled,
            base: None,
            full_statics: false,
            paged: true,
            dict: SessionDict::new(),
            dict_enabled: false,
            zidx: None,
        }
    }

    /// The clone's (class name, construction seq) -> local-object index,
    /// cached across migrations. A cached index built at template
    /// generation G stays valid while `Heap::zygote_gen() == G`: template
    /// *bodies* may mutate freely, only adding or removing a template
    /// member moves the generation. Lenient twin resolution (duplicate
    /// names keep the last-seen object) — the full-capture path.
    pub(crate) fn zygote_index(&mut self, p: &Process) -> Arc<ZygoteIndex> {
        let gen = p.heap.zygote_gen();
        if let Some(c) = &self.zidx {
            if c.gen == gen {
                return c.idx.clone();
            }
        }
        let idx = Arc::new(ZygoteIndex::build(&p.program, &p.heap));
        self.zidx = Some(ZidxCache {
            gen,
            strict: false,
            idx: idx.clone(),
        });
        idx
    }

    /// Strict variant for the delta path: duplicate template names are a
    /// typed error (the caller degrades it to `NeedFull`). An index
    /// cached by the lenient path is re-verified once and upgraded; a
    /// strict hit is returned as-is, since the template member set cannot
    /// change without moving the generation.
    pub(crate) fn try_zygote_index(&mut self, p: &Process) -> Result<Arc<ZygoteIndex>> {
        let gen = p.heap.zygote_gen();
        if let Some(c) = &self.zidx {
            if c.gen == gen && c.strict {
                return Ok(c.idx.clone());
            }
        }
        let idx = Arc::new(ZygoteIndex::try_build(&p.program, &p.heap)?);
        self.zidx = Some(ZidxCache {
            gen,
            strict: true,
            idx: idx.clone(),
        });
        Ok(idx)
    }

    /// Select the reverse-capture strategy (see
    /// [`MobileSession::set_paged`]).
    pub fn set_paged(&mut self, on: bool) {
        self.paged = on;
    }

    /// The session dictionary replica (decode forward / encode reverse).
    pub fn dict(&mut self) -> &mut SessionDict {
        &mut self.dict
    }

    /// Whether this session negotiated the shared dictionary.
    pub fn dict_enabled(&self) -> bool {
        self.dict_enabled
    }

    /// (Re)arm or disarm the shared dictionary. A toggle resets the
    /// replica (see [`MobileSession::set_dict_enabled`]): a
    /// capability-flapping peer re-seeds, it never decodes against a
    /// stale prefix.
    pub fn set_dict_enabled(&mut self, on: bool) {
        if self.dict_enabled != on {
            self.dict.reset();
        }
        self.dict_enabled = on;
    }

    /// Reset the replica to empty (every `NeedFull` this endpoint emits
    /// resets it, mirroring the mobile side).
    pub fn reset_dict(&mut self) {
        self.dict.reset();
    }

    /// (hit_bytes, additions) counters for metrics deltas.
    pub fn dict_stats(&self) -> (u64, u64) {
        (self.dict.hit_bytes, self.dict.additions)
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// (Re)arm or disarm delta emission/acceptance for this session.
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    /// Re-send the full statics section in every reverse delta (PR 2
    /// shape; bench ablation only).
    pub fn ship_full_statics(&mut self, on: bool) {
        self.full_statics = on;
    }

    /// Drop the baseline (worker recycle / tests): the next delta from
    /// the phone is answered with `NeedFull`.
    pub fn evict(&mut self) {
        self.base = None;
    }

    pub fn has_baseline(&self) -> bool {
        self.base.is_some()
    }

    /// Verify a digest heartbeat against the session baseline: apply the
    /// piggybacked MID assignments (idempotent — a later delta may carry
    /// them again), recompute the canonical digest, and answer
    /// `NeedFull` on any mismatch, evicting the poisoned baseline so the
    /// next delta cannot ride on it either.
    pub fn check_heartbeat(
        &mut self,
        p: &Process,
        digest: u64,
        assignments: &[(u64, u64)],
    ) -> Result<()> {
        // Every `NeedFull` this side emits also resets the session
        // dictionary: the mobile endpoint resets on receiving one, so
        // both replicas land on the empty prefix together.
        if !self.enabled {
            self.dict.reset();
            return Err(CloneCloudError::need_full(
                "heartbeat on a session that did not negotiate delta",
            ));
        }
        let b = match self.base.as_mut() {
            Some(b) => b,
            None => {
                self.dict.reset();
                return Err(CloneCloudError::need_full(
                    "no session baseline at the clone",
                ));
            }
        };
        if let Err(e) = apply_assignments(&mut b.table, assignments) {
            // A replayed assignment poisons the table: evict the
            // baseline and re-seed rather than answer from it.
            self.base = None;
            self.dict.reset();
            return Err(e);
        }
        let have = state_digest(p, &table_members(&b.table));
        if have != digest {
            self.base = None;
            self.dict.reset();
            return Err(CloneCloudError::need_full(format!(
                "heartbeat digest mismatch (clone {have:#x} != mobile {digest:#x})"
            )));
        }
        Ok(())
    }
}

/// What one clone-slot garbage collection reclaimed.
#[derive(Debug, Clone, Copy, Default)]
pub struct SlotGcStats {
    /// Tombstone threads (Migrated/Finished) dropped from the slot.
    pub threads_reclaimed: usize,
    /// Unreachable heap objects swept.
    pub objects_reclaimed: usize,
}

/// Periodic clone-slot garbage collection, keyed on the session mapping
/// table. A retained slot leaks one tombstone thread per roundtrip (its
/// frames pin every object graph it ever touched) plus, on the full
/// path, one obsolete object-graph copy per visit. This reclaims both
/// **without evicting the live baseline**: the GC roots are the slot's
/// statics and live threads, every CID in the session mapping table
/// (future `Base` references resolve through it), and every
/// Zygote-named object (future capsules may address templates by
/// (class, seq) name). Everything reclaimed is unreachable from all
/// three, so no future delta, digest, or merge can observe it — the
/// epoch-coherence invariant is untouched.
pub fn collect_slot_garbage(p: &mut Process, sess: &CloneSession) -> SlotGcStats {
    let mut stats = SlotGcStats::default();
    // Between roundtrips every slot thread is a tombstone; clear them
    // all so their frames stop pinning dead graphs. If anything is
    // still live (mid-roundtrip misuse), keep thread ids stable by
    // skipping thread reclamation entirely.
    let all_tombstones = p
        .threads
        .iter()
        .all(|t| matches!(t.status, ThreadStatus::Migrated | ThreadStatus::Finished));
    if all_tombstones {
        stats.threads_reclaimed = p.threads.len();
        p.threads.clear();
    }
    let mut roots = p.gc_roots();
    if let Some(b) = sess.base.as_ref() {
        roots.extend(b.table.cids().map(ObjId));
    }
    roots.extend(p.heap.zygote_ids());
    stats.objects_reclaimed = p.heap.gc(&roots);
    stats
}

/// Apply piggybacked `(cid, mid)` assignment pairs to a session mapping
/// table. An exact pair already present is skipped (a later capsule may
/// legitimately re-carry assignments the peer has not acknowledged); a
/// pair that is fresh on both axes is recorded. Anything else — the same
/// CID or MID mapped a second time to a *different* partner — is a
/// replayed or forged assignment: applying it would silently rebind an
/// id and corrupt every future `Base` resolution, so it degrades with
/// the typed `NeedFull` instead (callers evict the baseline and reset
/// the dictionary so the session re-seeds).
fn apply_assignments(table: &mut MappingTable, assignments: &[(u64, u64)]) -> Result<()> {
    for &(cid, mid) in assignments {
        let known_cid = table.contains_cid(cid);
        let known_mid = table.contains_mid(mid);
        if !known_cid && !known_mid {
            table.insert(Some(mid), Some(cid));
        } else if !(table.mid_for_cid(cid) == Some(mid) && table.cid_for_mid(mid) == Some(cid)) {
            return Err(CloneCloudError::need_full(format!(
                "assignment ({cid} -> {mid}) rebinds an already-mapped id \
                 (duplicate or replayed assignment)"
            )));
        }
    }
    Ok(())
}

fn table_members(table: &MappingTable) -> Vec<(u64, ObjId)> {
    table
        .entries()
        .iter()
        .filter_map(|e| match (e.mid, e.cid) {
            (Some(m), Some(c)) => Some((m, ObjId(c))),
            _ => None,
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Mobile side: capture forward / merge reverse
// ---------------------------------------------------------------------------

/// Capture thread `tid` for migration, as a delta against the session
/// baseline when one exists, else in full. Records the new baseline and
/// advances the mutation epoch (when the session is enabled).
pub(crate) fn capture_forward(
    p: &mut Process,
    tid: u32,
    mut opts: CaptureOptions,
    sess: &mut MobileSession,
) -> Result<(Capsule, CaptureStats)> {
    if sess.full_statics {
        opts.incremental_statics = false;
    }
    if sess.enabled && sess.baseline.is_some() {
        // Periodic mobile-side GC: liveness is the collector's job, not
        // the capture's. Collected members surface as stamped pages, so
        // this same capture reports them in its `deleted` list. Zygote
        // template objects are rooted — they must stay resolvable by
        // their (class, seq) names however unreachable they look.
        sess.delta_captures += 1;
        let next_id = p.heap.next_id_hint();
        if sess.gc_watermark == 0 {
            sess.gc_watermark = next_id;
        }
        let count_due = sess.gc_every > 0 && sess.delta_captures % sess.gc_every == 0;
        let growth_due = sess.gc_growth_objects > 0
            && next_id - sess.gc_watermark >= sess.gc_growth_objects;
        if sess.paged && (count_due || growth_due) {
            let mut roots = p.gc_roots();
            roots.extend(p.heap.zygote_ids());
            p.heap.gc(&roots);
            sess.gc_runs += 1;
            sess.gc_watermark = next_id;
        }
        let b = sess.baseline.as_ref().expect("checked");
        let base = DeltaBase {
            epoch: b.epoch,
            view: BaseView::Mobile(&b.mids),
        };
        let raw = if sess.paged && opts.zygote_diff {
            // The paged scan bails on any reference its invariants say
            // cannot exist (a barrier edge case, a malformed heap);
            // degrade to the per-object traversal — always sound, its
            // own errors are real — rather than failing the run.
            match capture_core_paged(p, tid, Direction::Forward, None, opts, &base) {
                Ok(raw) => raw,
                Err(_) => capture_core(p, tid, Direction::Forward, None, opts, Some(&base))?,
            }
        } else {
            capture_core(p, tid, Direction::Forward, None, opts, Some(&base))?
        };

        let mut deleted: Vec<u64> = b
            .mids
            .difference(&raw.reached_members)
            .copied()
            .collect();
        deleted.sort_unstable();

        let packet = DeltaPacket {
            direction: Direction::Forward,
            thread_id: tid,
            clock_us: p.clock.now_us(),
            base_epoch: b.epoch,
            base_digest: b.digest,
            assignments: std::mem::take(&mut sess.pending),
            deleted,
            sections: WireSections {
                frames: raw.frames,
                objects: raw.objects,
                zygote_refs: raw.zygote_refs,
                statics: raw.statics,
            },
        };

        // New baseline: surviving members plus everything shipped (dirty
        // members and fresh objects — phone ids are the MIDs).
        let mids: HashSet<u64> = raw
            .reached_members
            .iter()
            .copied()
            .chain(raw.shipped.iter().map(|id| id.0))
            .collect();
        let members: Vec<(u64, ObjId)> = mids.iter().map(|&m| (m, ObjId(m))).collect();
        let digest = state_digest(p, &members);
        sess.baseline = Some(MobileBaseline {
            epoch: p.heap.epoch(),
            digest,
            mids,
        });
        sess.last_sync = Instant::now();
        p.advance_epoch();

        let mut stats = raw.stats;
        stats.bytes = packet.encode()?.len();
        Ok((Capsule::Delta(packet), stats))
    } else {
        let (capsule, stats) = full_forward(p, tid, opts, sess)?;
        Ok((capsule, stats))
    }
}

/// Full forward capture + (if the session is enabled) baseline record.
fn full_forward(
    p: &mut Process,
    tid: u32,
    opts: CaptureOptions,
    sess: &mut MobileSession,
) -> Result<(Capsule, CaptureStats)> {
    let (packet, stats) = capture_thread(p, tid, Direction::Forward, None, opts)?;
    if sess.enabled {
        let mids: HashSet<u64> = packet.objects.iter().map(|o| o.origin_id).collect();
        let members: Vec<(u64, ObjId)> = mids.iter().map(|&m| (m, ObjId(m))).collect();
        let digest = state_digest(p, &members);
        sess.baseline = Some(MobileBaseline {
            epoch: p.heap.epoch(),
            digest,
            mids,
        });
        sess.pending.clear();
        sess.last_sync = Instant::now();
        p.advance_epoch();
    }
    Ok((Capsule::Full(packet), stats))
}

/// Re-capture in full after the peer rejected a delta (`NeedFull`). The
/// thread is still suspended at the same point, so the baseline recorded
/// by the failed delta attempt already describes this exact state — it is
/// kept, and the epoch is NOT advanced again (post-resume writes must
/// stamp past it exactly once).
pub(crate) fn recapture_forward_full(
    p: &Process,
    tid: u32,
    opts: CaptureOptions,
    sess: &mut MobileSession,
) -> Result<(Capsule, CaptureStats)> {
    let (packet, stats) = capture_thread(p, tid, Direction::Forward, None, opts)?;
    Ok((Capsule::Full(packet), stats))
}

/// Merge a reverse capsule into the original process (thread `tid`).
pub(crate) fn merge_at_mobile_capsule(
    p: &mut Process,
    tid: u32,
    capsule: &Capsule,
    sess: &mut MobileSession,
) -> Result<MergeStats> {
    match capsule {
        Capsule::Full(pkt) => {
            let zidx = ZygoteIndex::build(&p.program, &p.heap);
            let stats = merge_at_mobile(p, tid, pkt, &zidx)?;
            if sess.enabled {
                // The clone answered in full, so no coherent shared
                // baseline survives this visit; re-establish on the next
                // forward capture.
                sess.baseline = None;
                sess.pending.clear();
            }
            Ok(stats)
        }
        Capsule::Delta(d) => merge_reverse_delta(p, tid, d, sess),
    }
}

fn merge_reverse_delta(
    p: &mut Process,
    tid: u32,
    d: &DeltaPacket,
    sess: &mut MobileSession,
) -> Result<MergeStats> {
    if d.direction != Direction::Reverse {
        return Err(CloneCloudError::migration("expected a reverse capsule"));
    }
    // Both precondition failures below are typed `NeedFull` and fire
    // BEFORE any process state is touched: a reverse delta that does not
    // match our baseline (a replayed capsule, a stale worker, a peer
    // from another session) is survivable — the caller may degrade the
    // span and the next forward capture re-seeds in full.
    let mut b = sess.baseline.take().ok_or_else(|| {
        CloneCloudError::need_full("reverse delta without a mobile baseline")
    })?;
    if d.base_digest != b.digest {
        // Leave the baseline cleared: the next forward capture is full.
        return Err(CloneCloudError::need_full(
            "reverse delta baseline digest mismatch — endpoints diverged",
        ));
    }

    // Baseline references must land on live local objects before any
    // state is touched.
    let chk = |v: &WireValue| -> Result<()> {
        if let WireValue::Base(mid) = v {
            if !p.heap.contains(ObjId(*mid)) {
                return Err(CloneCloudError::migration(format!(
                    "reverse delta references dead baseline object {mid}"
                )));
            }
        }
        Ok(())
    };
    for f in &d.sections.frames {
        for v in &f.regs {
            chk(v)?;
        }
    }
    for o in &d.sections.objects {
        if let WireBody::Fields(vs) | WireBody::RefArray(vs) = &o.body {
            for v in vs {
                chk(v)?;
            }
        }
    }
    for s in &d.sections.statics {
        chk(&s.value)?;
    }

    // Members that died at the clone become orphans here (left to GC).
    for mid in &d.deleted {
        b.mids.remove(mid);
    }

    // Placement: overwrite mapped members in place, overwrite Zygote
    // twins by name, create the rest — recording (cid, mid) assignments
    // to piggyback on the next forward capsule.
    let zidx = ZygoteIndex::build(&p.program, &p.heap);
    let zlocal = resolve_zygote_locals(&d.sections.zygote_refs, &zidx)?;
    let mut stats = MergeStats::default();
    let mut assignments: Vec<(u64, u64)> = Vec::new();
    let mut locals = Vec::with_capacity(d.sections.objects.len());
    for wo in &d.sections.objects {
        let local = if wo.mapped_id != 0 {
            let id = ObjId(wo.mapped_id);
            if !p.heap.contains(id) {
                return Err(CloneCloudError::migration(format!(
                    "returned object maps to dead local id {}",
                    wo.mapped_id
                )));
            }
            stats.overwritten += 1;
            id
        } else if let Some(seq) = wo.zygote_seq {
            let twin = zidx.lookup(&wo.class_name, seq)?;
            stats.overwritten += 1;
            assignments.push((wo.origin_id, twin.0));
            b.mids.insert(twin.0);
            twin
        } else {
            let class = p.program.class_id(&wo.class_name).ok_or_else(|| {
                CloneCloudError::migration(format!("unknown class '{}'", wo.class_name))
            })?;
            let id = p.heap.alloc(placeholder(class));
            stats.created += 1;
            assignments.push((wo.origin_id, id.0));
            b.mids.insert(id.0);
            id
        };
        locals.push(local);
    }

    let frames = apply_sections(
        p,
        &d.sections.frames,
        &d.sections.objects,
        &d.sections.statics,
        &locals,
        &zlocal,
        BaseResolve::Local,
    )?;

    let t = p.thread_mut(tid)?;
    t.frames = frames;
    t.status = ThreadStatus::Runnable;
    t.suspend_count = 0;
    p.clock.advance_to_us(d.clock_us);

    // Record the new baseline (state after this merge == the clone's
    // state at its reverse capture) and advance the epoch.
    let members: Vec<(u64, ObjId)> = b.mids.iter().map(|&m| (m, ObjId(m))).collect();
    let digest = state_digest(p, &members);
    sess.baseline = Some(MobileBaseline {
        epoch: p.heap.epoch(),
        digest,
        mids: b.mids,
    });
    sess.pending = assignments;
    sess.last_sync = Instant::now();
    p.advance_epoch();
    Ok(stats)
}

// ---------------------------------------------------------------------------
// Scatter/gather: one baseline, N shard capsules
// ---------------------------------------------------------------------------

/// Specialize a monolithic forward capsule for one scatter shard by
/// patching the callee convention arguments `m(begin, end, shards)` in
/// the top (innermost) frame: `regs[0] = begin`, `regs[1] = end`. The
/// capture is taken once — registers are not covered by the canonical
/// state digest, so every patched copy still names the same baseline and
/// all N reverse deltas gather against it.
pub fn shard_capsule(capsule: &Capsule, begin: i64, end: i64) -> Result<Capsule> {
    let base = match capsule {
        Capsule::Full(p) => p,
        Capsule::Delta(_) => {
            return Err(CloneCloudError::migration(
                "scatter requires a full forward capture",
            ))
        }
    };
    let mut p = base.clone();
    let top = p.frames.last_mut().ok_or_else(|| {
        CloneCloudError::migration("scatter capsule has no frames")
    })?;
    if top.regs.len() < 3 {
        return Err(CloneCloudError::migration(
            "scatter span is not shard-shaped: top frame needs (begin, end, shards) args",
        ));
    }
    for r in &top.regs[..3] {
        if !matches!(r, WireValue::Int(_)) {
            return Err(CloneCloudError::migration(
                "scatter span is not shard-shaped: (begin, end, shards) must be ints",
            ));
        }
    }
    top.regs[0] = WireValue::Int(begin);
    top.regs[1] = WireValue::Int(end);
    Ok(Capsule::Full(p))
}

/// Read the shard convention `(begin, end, shards)` off a full forward
/// capsule's top frame. `None` means the captured span is not
/// shard-shaped (delta capsule, missing frame, wrong arity or types,
/// empty range, or fewer than 2 shards): the caller falls back to the
/// ordinary single-clone offload.
pub fn scatter_range(capsule: &Capsule) -> Option<(i64, i64, u16)> {
    let p = match capsule {
        Capsule::Full(p) => p,
        Capsule::Delta(_) => return None,
    };
    let top = p.frames.last()?;
    if top.regs.len() < 3 {
        return None;
    }
    match (&top.regs[0], &top.regs[1], &top.regs[2]) {
        (WireValue::Int(b), WireValue::Int(e), WireValue::Int(s))
            if *e > *b && *s >= 2 && *s <= i64::from(u16::MAX) =>
        {
            // Never more shards than indices: a 2-element range scatters
            // at most 2 ways regardless of the annotation.
            Some((*b, *e, (*s).min(*e - *b) as u16))
        }
        _ => None,
    }
}

/// Gather N concurrent reverse deltas — one per scatter shard — against
/// the single mobile baseline they were all built from.
///
/// The merge is **validate-then-apply**: every check (direction, digest,
/// baseline-reference liveness, frame agreement, write-set disjointness)
/// runs read-only before any heap state is touched, so a conflicting
/// shard set leaves the process *and* the baseline exactly as they were
/// and the caller can degrade to a single-clone offload. A shard's write
/// set is its overwritten baseline members, its dirtied Zygote twins,
/// and its static stores; sets must be pairwise disjoint and must not
/// touch members another shard deleted (concurrent deletes of the same
/// member are fine — deletion is idempotent). Conflicts surface as the
/// typed [`CloneCloudError::ScatterConflict`]; merged results are
/// bit-identical to running the shards back-to-back on one clone.
///
/// The virtual clock advances to the *maximum* shard clock (shards run
/// in parallel), and the gather ends the delta session: the merged union
/// is a state no single clone slot holds, and cross-shard CID
/// assignments could collide, so the next forward capture is full.
pub(crate) fn merge_scatter_at_mobile(
    p: &mut Process,
    tid: u32,
    deltas: &[DeltaPacket],
    sess: &mut MobileSession,
) -> Result<MergeStats> {
    if deltas.is_empty() {
        return Err(CloneCloudError::migration("scatter gather of zero shards"));
    }
    if deltas.len() == 1 {
        // One shard is just a roundtrip; keep the session alive.
        return merge_reverse_delta(p, tid, &deltas[0], sess);
    }
    let digest = match sess.baseline.as_ref() {
        Some(b) => b.digest,
        None => {
            return Err(CloneCloudError::migration(
                "scatter gather without a mobile baseline",
            ))
        }
    };

    // ---- validation: everything below is read-only ----
    for d in deltas {
        if d.direction != Direction::Reverse {
            return Err(CloneCloudError::migration("expected reverse capsules"));
        }
        if d.thread_id != deltas[0].thread_id {
            return Err(CloneCloudError::migration(
                "scatter shards answered for different threads",
            ));
        }
    }
    for d in deltas {
        if d.base_digest != digest {
            // Same contract as the single-delta path: divergence poisons
            // the baseline so the next forward capture is full.
            sess.baseline = None;
            sess.pending.clear();
            return Err(CloneCloudError::migration(
                "scatter shard baseline digest mismatch — endpoints diverged",
            ));
        }
    }
    // Every shard must stop at the same reintegration point with the
    // same call structure; register *contents* are exempt (the shard
    // loop's convention args and scratch counters legitimately differ
    // per shard, and post-reintegration code must not read them — the
    // rewriter validates that). Shard 0's registers are the ones
    // reintegrated.
    let same_shape = |a: &crate::migration::format::WireFrame,
                      b: &crate::migration::format::WireFrame|
     -> bool {
        a.class_name == b.class_name
            && a.method_name == b.method_name
            && a.pc == b.pc
            && a.ret_reg_plus1 == b.ret_reg_plus1
            && a.regs.len() == b.regs.len()
    };
    for d in &deltas[1..] {
        let f0 = &deltas[0].sections.frames;
        if d.sections.frames.len() != f0.len()
            || !d.sections.frames.iter().zip(f0).all(|(a, b)| same_shape(a, b))
        {
            return Err(CloneCloudError::scatter_conflict(
                "shards stopped at divergent thread frames",
            ));
        }
    }
    let chk = |v: &WireValue| -> Result<()> {
        if let WireValue::Base(mid) = v {
            if !p.heap.contains(ObjId(*mid)) {
                return Err(CloneCloudError::migration(format!(
                    "reverse delta references dead baseline object {mid}"
                )));
            }
        }
        Ok(())
    };
    for d in deltas {
        for f in &d.sections.frames {
            for v in &f.regs {
                chk(v)?;
            }
        }
        for o in &d.sections.objects {
            if let WireBody::Fields(vs) | WireBody::RefArray(vs) = &o.body {
                for v in vs {
                    chk(v)?;
                }
            }
        }
        for s in &d.sections.statics {
            chk(&s.value)?;
        }
    }

    // Placement plans + write-set disjointness. Conflict keys: baseline
    // member (tag 0, MID), Zygote twin (tag 1, seq + class), static slot
    // (tag 2, idx + class). Fresh allocations cannot conflict — each
    // shard's new objects get their own local ids at apply time.
    enum Plan {
        Mapped(ObjId),
        Twin(ObjId),
        Fresh(crate::appvm::bytecode::ClassId),
    }
    let deleted_union: HashSet<u64> = deltas
        .iter()
        .flat_map(|d| d.deleted.iter().copied())
        .collect();
    let zidx = ZygoteIndex::build(&p.program, &p.heap);
    let mut seen: HashSet<(u8, u64, &str)> = HashSet::new();
    let mut plans: Vec<Vec<Plan>> = Vec::with_capacity(deltas.len());
    let mut zlocals = Vec::with_capacity(deltas.len());
    for (si, d) in deltas.iter().enumerate() {
        zlocals.push(resolve_zygote_locals(&d.sections.zygote_refs, &zidx)?);
        let mut plan = Vec::with_capacity(d.sections.objects.len());
        for wo in &d.sections.objects {
            let (key, pl) = if wo.mapped_id != 0 {
                let id = ObjId(wo.mapped_id);
                if !p.heap.contains(id) {
                    return Err(CloneCloudError::migration(format!(
                        "returned object maps to dead local id {}",
                        wo.mapped_id
                    )));
                }
                if deleted_union.contains(&wo.mapped_id) {
                    return Err(CloneCloudError::scatter_conflict(format!(
                        "shard {si} rewrote baseline object {} that another \
                         shard deleted",
                        wo.mapped_id
                    )));
                }
                ((0u8, wo.mapped_id, ""), Plan::Mapped(id))
            } else if let Some(seq) = wo.zygote_seq {
                let twin = zidx.lookup(&wo.class_name, seq)?;
                ((1u8, seq as u64, wo.class_name.as_str()), Plan::Twin(twin))
            } else {
                let class = p.program.class_id(&wo.class_name).ok_or_else(|| {
                    CloneCloudError::migration(format!(
                        "unknown class '{}'",
                        wo.class_name
                    ))
                })?;
                // Fresh objects conflict with nothing; skip the key.
                plan.push(Plan::Fresh(class));
                continue;
            };
            if !seen.insert(key) {
                return Err(CloneCloudError::scatter_conflict(format!(
                    "shard {si} and an earlier shard both dirtied {}",
                    match key.0 {
                        0 => format!("baseline object {}", key.1),
                        _ => format!("zygote twin {}#{}", key.2, key.1),
                    }
                )));
            }
            plan.push(pl);
        }
        for s in &d.sections.statics {
            if !seen.insert((2u8, s.idx as u64, s.class_name.as_str())) {
                return Err(CloneCloudError::scatter_conflict(format!(
                    "shard {si} and an earlier shard both stored static {}.{}",
                    s.class_name, s.idx
                )));
            }
        }
        plans.push(plan);
    }

    // ---- apply: conflict-free by construction ----
    let mut stats = MergeStats::default();
    let mut merged_frames = None;
    for ((d, plan), zlocal) in deltas.iter().zip(&plans).zip(&zlocals) {
        let mut locals = Vec::with_capacity(plan.len());
        for pl in plan {
            locals.push(match pl {
                Plan::Mapped(id) | Plan::Twin(id) => {
                    stats.overwritten += 1;
                    *id
                }
                Plan::Fresh(class) => {
                    stats.created += 1;
                    p.heap.alloc(placeholder(*class))
                }
            });
        }
        let frames = apply_sections(
            p,
            &d.sections.frames,
            &d.sections.objects,
            &d.sections.statics,
            &locals,
            zlocal,
            BaseResolve::Local,
        )?;
        // All shards carry identical frames (validated above); resolve
        // them once, from the first shard.
        if merged_frames.is_none() {
            merged_frames = Some(frames);
        }
    }

    let t = p.thread_mut(tid)?;
    t.frames = merged_frames.expect("at least one shard applied");
    t.status = ThreadStatus::Runnable;
    t.suspend_count = 0;
    let clock = deltas.iter().fold(f64::MIN, |a, d| a.max(d.clock_us));
    p.clock.advance_to_us(clock);

    // The gather ends the delta session (see the doc comment): next
    // forward capture is full and re-seeds a fresh baseline.
    sess.baseline = None;
    sess.pending.clear();
    sess.last_sync = Instant::now();
    p.advance_epoch();
    Ok(stats)
}

// ---------------------------------------------------------------------------
// Clone side: receive forward / capture reverse
// ---------------------------------------------------------------------------

/// Apply a forward capsule at the clone: full captures re-instantiate
/// from scratch (and reset the session baseline), deltas patch the
/// retained slot state. Returns the new thread id.
pub(crate) fn receive_at_clone_capsule(
    clone: &mut Process,
    capsule: &Capsule,
    sess: &mut CloneSession,
) -> Result<(u32, MergeStats)> {
    match capsule {
        Capsule::Full(pkt) => {
            let zidx = sess.zygote_index(clone);
            let (tid, table, stats) = super::merge::instantiate_at_clone(clone, pkt, &zidx)?;
            // The digest only matters when deltas may follow.
            let fwd_digest = if sess.enabled {
                state_digest(clone, &table_members(&table))
            } else {
                0
            };
            sess.base = Some(CloneBaseline {
                table,
                fwd_epoch: clone.heap.epoch(),
                fwd_digest,
            });
            clone.advance_epoch();
            Ok((tid, stats))
        }
        Capsule::Delta(d) => receive_forward_delta(clone, d, sess),
    }
}

fn receive_forward_delta(
    clone: &mut Process,
    d: &DeltaPacket,
    sess: &mut CloneSession,
) -> Result<(u32, MergeStats)> {
    if d.direction != Direction::Forward {
        return Err(CloneCloudError::migration("expected a forward capsule"));
    }
    if !sess.enabled {
        return Err(CloneCloudError::migration(
            "delta capsule on a session that did not negotiate delta",
        ));
    }
    let mut b = match sess.base.take() {
        Some(b) => b,
        None => {
            // Emitting NeedFull resets the dictionary replica too (the
            // mobile side resets on receiving it).
            sess.dict.reset();
            return Err(CloneCloudError::need_full(
                "no session baseline at the clone",
            ));
        }
    };

    // Complete the table with the MIDs the mobile merge assigned to the
    // objects this slot created last visit. A conflicting pair degrades
    // to `NeedFull` — the baseline was already taken, so it stays
    // evicted and the retry takes the full path.
    if let Err(e) = apply_assignments(&mut b.table, &d.assignments) {
        sess.dict.reset();
        return Err(e);
    }

    // Verify coherence. The slot heap has not run since the last reverse
    // capture, so the digest is computed lazily, here.
    let members = table_members(&b.table);
    let have = state_digest(clone, &members);
    if have != d.base_digest {
        // Baseline poisoned — stay evicted so the retry takes the full
        // path and re-establishes the session (dictionary included).
        sess.dict.reset();
        return Err(CloneCloudError::need_full(format!(
            "baseline digest mismatch (clone {have:#x} != mobile {:#x})",
            d.base_digest
        )));
    }

    // Members the phone deleted since the sync: drop only the mapping.
    // The local objects become GC orphans (§4.2) — they are NOT removed
    // from the heap, because "deleted" is judged by a traversal that does
    // not descend into clean Zygote objects, so an object still reachable
    // through template-internal references (or re-shipped later by its
    // Zygote name) must stay resolvable.
    b.table.remove_mids(&d.deleted);

    // A malformed template degrades to `NeedFull`: the retried full
    // capture resolves twins leniently instead of aborting the session.
    let zidx = match sess.try_zygote_index(clone) {
        Ok(z) => z,
        Err(e) => {
            sess.dict.reset();
            return Err(CloneCloudError::need_full(e.to_string()));
        }
    };
    let zlocal = resolve_zygote_locals(&d.sections.zygote_refs, &zidx)?;

    // Placement: known members overwrite in place through the session
    // table; dirty Zygote newcomers overwrite their twins; the rest are
    // allocated fresh — all recorded in the table for future rounds.
    let mut stats = MergeStats::default();
    let mut locals = Vec::with_capacity(d.sections.objects.len());
    for wo in &d.sections.objects {
        let local = if let Some(cid) = b.table.cid_for_mid(wo.origin_id) {
            stats.overwritten += 1;
            ObjId(cid)
        } else if let Some(seq) = wo.zygote_seq {
            let twin = zidx.lookup(&wo.class_name, seq)?;
            stats.overwritten += 1;
            b.table.insert(Some(wo.origin_id), Some(twin.0));
            twin
        } else {
            let class = clone.program.class_id(&wo.class_name).ok_or_else(|| {
                CloneCloudError::migration(format!("unknown class '{}'", wo.class_name))
            })?;
            let id = clone.heap.alloc(placeholder(class));
            stats.created += 1;
            b.table.insert(Some(wo.origin_id), Some(id.0));
            id
        };
        locals.push(local);
    }

    let frames = apply_sections(
        clone,
        &d.sections.frames,
        &d.sections.objects,
        &d.sections.statics,
        &locals,
        &zlocal,
        BaseResolve::Table(&b.table),
    )?;

    let tid = clone.threads.len() as u32;
    let mut t = VmThread::new(tid);
    t.frames = frames;
    t.status = ThreadStatus::Runnable;
    clone.threads.push(t);
    clone.clock.advance_to_us(d.clock_us);

    // Re-baseline for the reverse direction and advance the epoch.
    let members = table_members(&b.table);
    b.fwd_digest = state_digest(clone, &members);
    b.fwd_epoch = clone.heap.epoch();
    sess.base = Some(b);
    clone.advance_epoch();
    Ok((tid, stats))
}

/// Capture the migrant thread back for reintegration, as a delta against
/// the forward baseline when the session negotiated it, else in full.
/// Returns the capsule and the number of mapping entries dropped (objects
/// that died at the clone).
pub(crate) fn return_from_clone_capsule(
    clone: &mut Process,
    tid: u32,
    mut opts: CaptureOptions,
    sess: &mut CloneSession,
) -> Result<(Capsule, CaptureStats, usize)> {
    if sess.full_statics {
        opts.incremental_statics = false;
    }
    let base = sess.base.as_mut().ok_or_else(|| {
        CloneCloudError::migration("reverse capture without a clone session")
    })?;

    if sess.enabled {
        let raw = {
            let db = DeltaBase {
                epoch: base.fwd_epoch,
                view: BaseView::CloneTable(&base.table),
            };
            if sess.paged && opts.zygote_diff {
                // Same degrade as the forward side: a paged-scan bail
                // falls back to the always-sound traversal.
                match capture_core_paged(
                    clone,
                    tid,
                    Direction::Reverse,
                    Some(&base.table),
                    opts,
                    &db,
                ) {
                    Ok(raw) => raw,
                    Err(_) => capture_core(
                        clone,
                        tid,
                        Direction::Reverse,
                        Some(&base.table),
                        opts,
                        Some(&db),
                    )?,
                }
            } else {
                capture_core(clone, tid, Direction::Reverse, Some(&base.table), opts, Some(&db))?
            }
        };

        let mut deleted: Vec<u64> = table_members(&base.table)
            .iter()
            .map(|&(mid, _)| mid)
            .filter(|mid| !raw.reached_members.contains(mid))
            .collect();
        deleted.sort_unstable();
        let dropped = base.table.remove_mids(&deleted);

        let packet = DeltaPacket {
            direction: Direction::Reverse,
            thread_id: tid,
            clock_us: clone.clock.now_us(),
            base_epoch: base.fwd_epoch,
            base_digest: base.fwd_digest,
            assignments: Vec::new(),
            deleted,
            sections: WireSections {
                frames: raw.frames,
                objects: raw.objects,
                zygote_refs: raw.zygote_refs,
                statics: raw.statics,
            },
        };
        let mut stats = raw.stats;
        stats.bytes = packet.encode()?.len();
        Ok((Capsule::Delta(packet), stats, dropped))
    } else {
        let (packet, stats) =
            capture_thread(clone, tid, Direction::Reverse, Some(&base.table), opts)?;
        let returning: HashMap<u64, ()> =
            packet.objects.iter().map(|o| (o.origin_id, ())).collect();
        let dropped = base.table.retain_cids(&returning);
        Ok((Capsule::Full(packet), stats, dropped))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::bytecode::ClassId;
    use crate::appvm::value::Object;
    use crate::appvm::zygote::install_system_classes;
    use crate::appvm::Program;
    use crate::util::rng::Rng;

    fn proc_with(program: std::sync::Arc<Program>) -> Process {
        use crate::appvm::natives::NodeEnv;
        use crate::device::{DeviceSpec, Location};
        use crate::vfs::SimFs;
        Process::new(
            program,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(SimFs::new()),
        )
    }

    fn program() -> std::sync::Arc<Program> {
        let mut p = Program::new();
        install_system_classes(&mut p);
        p.into_shared()
    }

    /// Regression: a replayed or forged heartbeat assignment used to be
    /// applied last-write-wins, silently rebinding an already-mapped id
    /// and poisoning every later `Base` resolution. The contract now:
    /// an exact duplicate is idempotent, any rebinding of a known CID or
    /// MID is a conflict — `NeedFull`, baseline evicted, dictionary
    /// reset — so the session re-seeds instead of answering from a
    /// corrupted table.
    #[test]
    fn heartbeat_assignment_replay_is_a_conflict_not_last_write_wins() {
        let prog = program();
        let mut c = proc_with(prog);
        let class = ClassId(0);
        let l1 = c.heap.alloc(Object::new_fields(class, 0));
        let l2 = c.heap.alloc(Object::new_fields(class, 0));

        // A session whose baseline knows one pair (mobile 501 <-> local
        // l1) and whose dictionary replica holds a warm entry.
        let seed_session = |c: &Process| -> CloneSession {
            let mut table = MappingTable::new();
            table.insert(Some(501), Some(l1.0));
            let mut sess = CloneSession::new(true);
            sess.base = Some(CloneBaseline {
                table,
                fwd_epoch: c.heap.epoch(),
                fwd_digest: 0,
            });
            sess.set_dict_enabled(true);
            let warm = CapturePacket {
                direction: Direction::Forward,
                thread_id: 0,
                clock_us: 0.0,
                frames: vec![],
                objects: vec![],
                zygote_refs: vec![("Warm".into(), 1)],
                statics: vec![],
            };
            warm.encode_with(DictMode::Shared(&mut sess.dict)).unwrap();
            assert!(!sess.dict.is_empty(), "replica warmed");
            sess
        };

        // An exact duplicate pair is idempotent: both copies of
        // (l2 -> 502) land as ONE entry and the heartbeat verifies.
        let mut sess = seed_session(&c);
        let mut expected = MappingTable::new();
        expected.insert(Some(501), Some(l1.0));
        expected.insert(Some(502), Some(l2.0));
        let digest = state_digest(&c, &table_members(&expected));
        sess.check_heartbeat(&c, digest, &[(l2.0, 502), (l2.0, 502)])
            .expect("exact duplicate assignment is idempotent");
        assert!(sess.has_baseline());
        assert!(!sess.dict.is_empty(), "replica untouched on success");

        // Replaying the CID with a DIFFERENT mid is a conflict: typed
        // NeedFull, baseline evicted, dictionary reset. Under the old
        // last-write-wins apply this silently rebound l2.
        let mut sess = seed_session(&c);
        let err = sess
            .check_heartbeat(&c, digest, &[(l2.0, 502), (l2.0, 503)])
            .unwrap_err();
        assert!(err.is_need_full(), "typed degradation: {err}");
        assert!(!sess.has_baseline(), "poisoned baseline evicted");
        assert!(sess.dict.is_empty(), "replica reset with the NeedFull");

        // Claiming an already-bound MID for a fresh CID is the same
        // conflict (the forged-assignment shape).
        let mut sess = seed_session(&c);
        let err = sess
            .check_heartbeat(&c, digest, &[(l2.0, 501)])
            .unwrap_err();
        assert!(err.is_need_full(), "typed degradation: {err}");
        assert!(!sess.has_baseline());
        assert!(sess.dict.is_empty());
    }

    #[test]
    fn clone_session_caches_zygote_index_per_template_generation() {
        let p = program();
        let mut c = proc_with(p);
        let class = ClassId(0);
        let mut o = Object::new_fields(class, 0);
        o.zygote_seq = Some(1);
        o.dirty = false;
        c.heap.alloc(o);

        let mut sess = CloneSession::new(true);
        let a = sess.zygote_index(&c);
        let b = sess.zygote_index(&c);
        assert!(Arc::ptr_eq(&a, &b), "warm hit reuses the built index");
        assert_eq!(a.len(), 1);

        // The strict path re-verifies the lenient entry once, then hits.
        let s1 = sess.try_zygote_index(&c).unwrap();
        let s2 = sess.try_zygote_index(&c).unwrap();
        assert!(Arc::ptr_eq(&s1, &s2), "strict hit after one re-verify");

        // Adding a template member moves the generation: rebuild.
        let mut o2 = Object::new_fields(class, 0);
        o2.zygote_seq = Some(2);
        o2.dirty = false;
        c.heap.alloc(o2);
        let d = sess.zygote_index(&c);
        assert!(!Arc::ptr_eq(&s2, &d), "template change invalidates");
        assert_eq!(d.len(), 2);

        // App allocations leave the generation (and the cache) alone.
        c.heap.alloc(Object::new_fields(class, 0));
        assert!(Arc::ptr_eq(&d, &sess.zygote_index(&c)));
    }

    #[test]
    fn digest_tracks_content_not_ids() {
        let p = program();
        let mut a = proc_with(p.clone());
        let mut c = proc_with(p);
        let class = ClassId(0);

        // Same logical state under different local ids: phone objects
        // 1,2; clone twins 11,12 mapped by the session table.
        let a1 = a.heap.alloc(Object::new_fields(class, 1));
        let a2 = a.heap.alloc_byte_array(class, vec![1, 2, 3]);
        a.heap.get_mut(a1).unwrap().body = ObjBody::Fields(vec![Value::Ref(a2)]);

        for _ in 0..9 {
            c.heap.alloc(Object::new_fields(class, 0)); // shift the ids
        }
        let c1 = c.heap.alloc(Object::new_fields(class, 1));
        let c2 = c.heap.alloc_byte_array(class, vec![1, 2, 3]);
        c.heap.get_mut(c1).unwrap().body = ObjBody::Fields(vec![Value::Ref(c2)]);

        let phone_members = vec![(a1.0, a1), (a2.0, a2)];
        let clone_members = vec![(a1.0, c1), (a2.0, c2)];
        assert_eq!(
            state_digest(&a, &phone_members),
            state_digest(&c, &clone_members),
            "same logical state digests equal across id spaces"
        );

        // Mutating one byte diverges the digest.
        if let ObjBody::ByteArray(b) = &mut c.heap.get_mut(c2).unwrap().body {
            b[0] ^= 0xFF;
        }
        assert_ne!(
            state_digest(&a, &phone_members),
            state_digest(&c, &clone_members)
        );
    }

    #[test]
    fn digest_covers_app_statics() {
        let mut prog = Program::new();
        install_system_classes(&mut prog);
        let mut c = crate::appvm::class::ClassDef::new("App", false);
        c.add_static("s");
        prog.add_class(c);
        let prog = prog.into_shared();
        let app = prog.class_id("App").unwrap().0 as usize;

        let mut a = proc_with(prog.clone());
        let b = proc_with(prog);
        let members: Vec<(u64, ObjId)> = Vec::new();
        assert_eq!(state_digest(&a, &members), state_digest(&b, &members));

        a.put_static(app, 0, Value::Int(7)).unwrap();
        assert_ne!(
            state_digest(&a, &members),
            state_digest(&b, &members),
            "a divergent static poisons the digest"
        );
    }

    #[test]
    fn digest_is_member_order_independent() {
        let p = program();
        let mut a = proc_with(p);
        let class = ClassId(0);
        let x = a.heap.alloc_byte_array(class, vec![7]);
        let y = a.heap.alloc_byte_array(class, vec![9]);
        let fwd = vec![(x.0, x), (y.0, y)];
        let rev = vec![(y.0, y), (x.0, x)];
        assert_eq!(state_digest(&a, &fwd), state_digest(&a, &rev));
    }

    fn gen_delta(rng: &mut Rng) -> DeltaPacket {
        DeltaPacket {
            direction: if rng.chance(0.5) {
                Direction::Forward
            } else {
                Direction::Reverse
            },
            thread_id: rng.next_u64() as u32,
            clock_us: rng.range_i64(0, 1 << 40) as f64 / 8.0,
            base_epoch: rng.next_u64(),
            base_digest: rng.next_u64(),
            assignments: (0..rng.index(5))
                .map(|_| (rng.next_u64(), rng.next_u64()))
                .collect(),
            deleted: (0..rng.index(6)).map(|_| rng.next_u64()).collect(),
            sections: WireSections {
                frames: Vec::new(),
                objects: (0..rng.index(4))
                    .map(|_| WireObject {
                        origin_id: rng.next_u64(),
                        mapped_id: rng.next_u64(),
                        class_name: "App".into(),
                        zygote_seq: rng.chance(0.3).then(|| rng.next_u64() as u32),
                        body: WireBody::Fields(vec![
                            WireValue::Base(rng.next_u64()),
                            WireValue::Int(rng.next_u64() as i64),
                        ]),
                    })
                    .collect(),
                zygote_refs: Vec::new(),
                statics: Vec::new(),
            },
        }
    }

    #[test]
    fn prop_delta_capsules_roundtrip_and_dispatch() {
        use crate::util::prop::{ensure, ensure_eq, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xDE17A_01,
                cases: 120,
            },
            gen_delta,
            |d| {
                let bytes = d.encode().map_err(|e| format!("encode: {e}"))?;
                let decoded =
                    DeltaPacket::decode(&bytes).map_err(|e| format!("decode: {e}"))?;
                ensure_eq(decoded, d.clone(), "decode(encode(d))")?;
                // Capsule dispatch picks the delta flavor by magic.
                match Capsule::decode(&bytes).map_err(|e| format!("capsule: {e}"))? {
                    Capsule::Delta(q) => ensure_eq(q, d.clone(), "capsule dispatch"),
                    Capsule::Full(_) => ensure(false, "delta decoded as full"),
                }
            },
        );
    }

    #[test]
    fn prop_delta_strict_prefixes_never_decode() {
        use crate::util::prop::{ensure, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xDE17A_02,
                cases: 120,
            },
            |rng| {
                let bytes = gen_delta(rng).encode().unwrap();
                let cut = rng.index(bytes.len());
                (bytes, cut)
            },
            |(bytes, cut)| {
                ensure(DeltaPacket::decode(&bytes[..*cut]).is_err(), "prefix decoded")
            },
        );
    }

    #[test]
    fn capsule_decode_rejects_unknown_magic() {
        assert!(Capsule::decode(&[0, 1, 2, 3, 4, 5]).is_err());
        assert!(Capsule::decode(&[]).is_err());
    }

    /// The clock field sits at a fixed offset in BOTH capsule flavors —
    /// the invariant the driver's in-place wire stamping relies on.
    #[test]
    fn clock_offset_is_stable_across_flavors() {
        let mut rng = Rng::new(7);
        let mut d = gen_delta(&mut rng);
        d.clock_us = 1.5;
        let mut bytes = d.encode().unwrap();
        bytes[CAPSULE_CLOCK_OFFSET..CAPSULE_CLOCK_OFFSET + 8]
            .copy_from_slice(&42.25f64.to_bits().to_be_bytes());
        let back = DeltaPacket::decode(&bytes).unwrap();
        assert_eq!(back.clock_us, 42.25);
        assert_eq!(
            DeltaPacket { clock_us: 1.5, ..back },
            d,
            "only the clock changed"
        );

        let full = CapturePacket {
            direction: Direction::Forward,
            thread_id: 3,
            clock_us: 9.0,
            frames: Vec::new(),
            objects: Vec::new(),
            zygote_refs: Vec::new(),
            statics: Vec::new(),
        };
        let mut bytes = full.encode().unwrap();
        bytes[CAPSULE_CLOCK_OFFSET..CAPSULE_CLOCK_OFFSET + 8]
            .copy_from_slice(&8.125f64.to_bits().to_be_bytes());
        assert_eq!(CapturePacket::decode(&bytes).unwrap().clock_us, 8.125);
    }
}
