//! The per-process migrator (paper §4, Figure 7).
//!
//! Orchestrates one migration round trip and charges its virtual-time
//! costs: suspend all threads at safe points, capture the migrant, hand
//! the packet to the node manager (the caller), and — on the way back —
//! merge the returned state and resume. The mapping table lives only for
//! the duration of the thread's stay at the clone (§4.2).

use std::collections::HashMap;

use crate::appvm::process::Process;
use crate::appvm::thread::ThreadStatus;
use crate::config::CostParams;
use crate::error::Result;

use super::capture::{capture_thread, CaptureOptions, CaptureStats};
use super::delta::{self, Capsule, CloneSession, DeltaPacket, MobileSession};
use super::format::{CapturePacket, Direction, WireBody, WireObject};
use super::mapping::MappingTable;
use super::merge::{instantiate_at_clone, merge_at_mobile, MergeStats};
use super::zygote_diff::ZygoteIndex;

/// Timing breakdown of one migration phase set (virtual ms). Feeds the E3
/// migration-cost bench.
#[derive(Debug, Clone, Default)]
pub struct MigrationPhases {
    pub suspend_ms: f64,
    pub capture_ms: f64,
    pub merge_ms: f64,
    /// Transfer time is charged by the node manager (network model), not
    /// here; recorded by the exec driver.
    pub bytes_out: u64,
    pub objects_shipped: usize,
    pub zygote_skipped: usize,
    /// Session-baseline objects referenced instead of shipped (delta).
    pub base_skipped: usize,
    /// Static slots serialized into the capsule's statics section.
    pub statics_shipped: usize,
    /// Capture work: objects examined (traversal visits or dirty-page
    /// entries) and, on the paged path, pages opened / found dirty.
    pub objects_scanned: usize,
    pub pages_scanned: usize,
    pub pages_dirty: usize,
}

/// The migrator: per-process component, configured with cost calibration
/// and the Zygote-diff switch.
pub struct Migrator {
    pub costs: CostParams,
    pub opts: CaptureOptions,
}

impl Migrator {
    pub fn new(costs: CostParams) -> Migrator {
        Migrator {
            costs,
            opts: CaptureOptions::default(),
        }
    }

    pub fn without_zygote_diff(mut self) -> Migrator {
        self.opts.zygote_diff = false;
        self
    }

    /// Ship the full statics section in every delta capsule (the PR 2
    /// wire shape; bench ablation only).
    pub fn without_incremental_statics(mut self) -> Migrator {
        self.opts.incremental_statics = false;
        self
    }

    /// Suspend + capture thread `tid` for migration. Charges suspend and
    /// capture costs to the process clock. The thread is marked Migrated.
    pub fn migrate_out(
        &self,
        p: &mut Process,
        tid: u32,
    ) -> Result<(CapturePacket, MigrationPhases)> {
        let mut phases = MigrationPhases::default();

        // Suspend all other threads at safe points (§5: the migrator
        // waits on a condvar until every thread parks).
        p.suspend_others(tid);
        let suspend_us = p.device.scale_us(self.costs.suspend_resume_us / 2.0);
        p.clock.charge_us(suspend_us);
        phases.suspend_ms = suspend_us / 1e3;

        let (packet, stats) = capture_thread(p, tid, Direction::Forward, None, self.opts)?;
        let capture_us = self.capture_cost_us(p, &stats);
        p.clock.charge_us(capture_us);
        phases.capture_ms = capture_us / 1e3;
        phases.bytes_out = stats.bytes as u64;
        phases.objects_shipped = stats.objects;
        phases.zygote_skipped = stats.zygote_skipped;

        p.thread_mut(tid)?.status = ThreadStatus::Migrated;
        Ok((packet, phases))
    }

    /// Clone side: instantiate the migrant thread. Returns the thread id
    /// and the mapping table to retain while the thread executes here.
    pub fn receive_at_clone(
        &self,
        clone: &mut Process,
        packet: &CapturePacket,
    ) -> Result<(u32, MappingTable, MergeStats)> {
        let zidx = ZygoteIndex::build(&clone.program, &clone.heap);
        let (tid, table, stats) = instantiate_at_clone(clone, packet, &zidx)?;
        // Re-instantiation cost mirrors merge cost on the clone's CPU.
        let us = clone.device.scale_us(self.merge_cost_base_us(packet));
        clone.clock.charge_us(us);
        Ok((tid, table, stats))
    }

    /// Clone side: capture the thread for reintegration, consuming the
    /// mapping table (dead entries dropped, new objects added — Fig. 8).
    pub fn return_from_clone(
        &self,
        clone: &mut Process,
        tid: u32,
        mut table: MappingTable,
    ) -> Result<(CapturePacket, MigrationPhases, usize)> {
        let mut phases = MigrationPhases::default();
        let suspend_us = clone.device.scale_us(self.costs.suspend_resume_us / 2.0);
        clone.clock.charge_us(suspend_us);
        phases.suspend_ms = suspend_us / 1e3;

        let (packet, stats) =
            capture_thread(clone, tid, Direction::Reverse, Some(&table), self.opts)?;
        let capture_us = self.capture_cost_us(clone, &stats);
        clone.clock.charge_us(capture_us);
        phases.capture_ms = capture_us / 1e3;
        phases.bytes_out = stats.bytes as u64;
        phases.objects_shipped = stats.objects;
        phases.zygote_skipped = stats.zygote_skipped;

        // Update the table per Fig. 8: drop entries whose CID did not
        // return; report how many died at the clone.
        let returning: HashMap<u64, ()> =
            packet.objects.iter().map(|o| (o.origin_id, ())).collect();
        let dropped = table.retain_cids(&returning);

        clone.thread_mut(tid)?.status = ThreadStatus::Migrated;
        Ok((packet, phases, dropped))
    }

    /// Mobile side: merge the returned state into the original process
    /// and resume. The merge cost (patching references in the running
    /// address space) dominates WiFi-case migration in the paper (§6).
    pub fn merge_back(
        &self,
        p: &mut Process,
        tid: u32,
        packet: &CapturePacket,
    ) -> Result<(MergeStats, MigrationPhases)> {
        let mut phases = MigrationPhases::default();
        let zidx = ZygoteIndex::build(&p.program, &p.heap);
        let stats = merge_at_mobile(p, tid, packet, &zidx)?;
        let merge_us = p
            .device
            .scale_us(self.merge_cost_base_us(packet) + self.costs.suspend_resume_us / 2.0);
        p.clock.charge_us(merge_us);
        phases.merge_ms = merge_us / 1e3;
        p.resume_others(tid);
        Ok((stats, phases))
    }

    /// Baseline merge cost: reference patching per object + per byte of
    /// payload state (the network-unspecific cost that dominates WiFi
    /// migrations in the paper's §6).
    fn merge_cost_base_us(&self, packet: &CapturePacket) -> f64 {
        self.merge_cost_objs_us(&packet.objects)
    }

    fn merge_cost_objs_us(&self, objects: &[WireObject]) -> f64 {
        let bytes: u64 = objects
            .iter()
            .map(|o| match &o.body {
                WireBody::ByteArray(b) => b.len() as u64,
                WireBody::FloatArray(f) => 4 * f.len() as u64,
                WireBody::Fields(v) | WireBody::RefArray(v) => 9 * v.len() as u64,
            })
            .sum();
        self.costs.merge_per_obj_us * objects.len() as f64
            + self.costs.merge_per_byte_us * bytes as f64
    }

    fn capture_cost_us(&self, p: &Process, stats: &CaptureStats) -> f64 {
        p.device.scale_us(
            self.costs.capture_per_obj_us * stats.objects as f64
                + self.costs.per_byte_us * stats.bytes as f64,
        )
    }

    fn phases_from_stats(stats: &CaptureStats, phases: &mut MigrationPhases) {
        phases.bytes_out = stats.bytes as u64;
        phases.objects_shipped = stats.objects;
        phases.zygote_skipped = stats.zygote_skipped;
        phases.base_skipped = stats.base_skipped;
        phases.statics_shipped = stats.statics_shipped;
        phases.objects_scanned = stats.objects_scanned;
        phases.pages_scanned = stats.pages_scanned;
        phases.pages_dirty = stats.pages_dirty;
    }
}

/// Session-aware capsule API: the delta-migration pipeline. Each endpoint
/// keeps a per-session baseline cache ([`MobileSession`] at the phone,
/// [`CloneSession`] in the clone slot); captures degrade to full packets
/// whenever the baseline is missing or incoherent (`NeedFull`).
impl Migrator {
    /// Suspend + capture thread `tid` as a capsule (delta when the
    /// session holds a baseline). Charges suspend and capture costs; the
    /// thread is marked Migrated.
    pub fn migrate_out_capsule(
        &self,
        p: &mut Process,
        tid: u32,
        sess: &mut MobileSession,
    ) -> Result<(Capsule, MigrationPhases)> {
        let mut phases = MigrationPhases::default();
        p.suspend_others(tid);
        let suspend_us = p.device.scale_us(self.costs.suspend_resume_us / 2.0);
        p.clock.charge_us(suspend_us);
        phases.suspend_ms = suspend_us / 1e3;

        let (capsule, stats) = delta::capture_forward(p, tid, self.opts, sess)?;
        let capture_us = self.capture_cost_us(p, &stats);
        p.clock.charge_us(capture_us);
        phases.capture_ms = capture_us / 1e3;
        Self::phases_from_stats(&stats, &mut phases);

        p.thread_mut(tid)?.status = ThreadStatus::Migrated;
        Ok((capsule, phases))
    }

    /// Re-capture in full after the clone rejected a delta (`NeedFull`).
    /// The thread is still suspended at the same point; only the capture
    /// cost is charged (suspension already happened).
    pub fn recapture_full(
        &self,
        p: &mut Process,
        tid: u32,
        sess: &mut MobileSession,
    ) -> Result<(Capsule, MigrationPhases)> {
        let mut phases = MigrationPhases::default();
        let (capsule, stats) = delta::recapture_forward_full(p, tid, self.opts, sess)?;
        let capture_us = self.capture_cost_us(p, &stats);
        p.clock.charge_us(capture_us);
        phases.capture_ms = capture_us / 1e3;
        Self::phases_from_stats(&stats, &mut phases);
        Ok((capsule, phases))
    }

    /// Clone side: apply a forward capsule onto the (possibly retained)
    /// slot process. Full capsules reset the session baseline; deltas
    /// verify it and patch in place. Returns the new thread id.
    pub fn receive_capsule_at_clone(
        &self,
        clone: &mut Process,
        capsule: &Capsule,
        sess: &mut CloneSession,
    ) -> Result<(u32, MergeStats)> {
        let (tid, stats) = delta::receive_at_clone_capsule(clone, capsule, sess)?;
        let us = clone
            .device
            .scale_us(self.merge_cost_objs_us(capsule.objects()));
        clone.clock.charge_us(us);
        Ok((tid, stats))
    }

    /// Clone side: capture the thread back for reintegration (delta when
    /// the session negotiated it). Returns the capsule and the number of
    /// mapping entries dropped (objects that died at the clone).
    pub fn return_capsule_from_clone(
        &self,
        clone: &mut Process,
        tid: u32,
        sess: &mut CloneSession,
    ) -> Result<(Capsule, MigrationPhases, usize)> {
        let mut phases = MigrationPhases::default();
        let suspend_us = clone.device.scale_us(self.costs.suspend_resume_us / 2.0);
        clone.clock.charge_us(suspend_us);
        phases.suspend_ms = suspend_us / 1e3;

        let (capsule, stats, dropped) =
            delta::return_from_clone_capsule(clone, tid, self.opts, sess)?;
        let capture_us = self.capture_cost_us(clone, &stats);
        clone.clock.charge_us(capture_us);
        phases.capture_ms = capture_us / 1e3;
        Self::phases_from_stats(&stats, &mut phases);

        clone.thread_mut(tid)?.status = ThreadStatus::Migrated;
        Ok((capsule, phases, dropped))
    }

    /// Mobile side: merge a reverse capsule and resume. Updates the
    /// session baseline (or clears it on a full reply).
    pub fn merge_back_capsule(
        &self,
        p: &mut Process,
        tid: u32,
        capsule: &Capsule,
        sess: &mut MobileSession,
    ) -> Result<(MergeStats, MigrationPhases)> {
        let mut phases = MigrationPhases::default();
        let stats = delta::merge_at_mobile_capsule(p, tid, capsule, sess)?;
        let merge_us = p.device.scale_us(
            self.merge_cost_objs_us(capsule.objects()) + self.costs.suspend_resume_us / 2.0,
        );
        p.clock.charge_us(merge_us);
        phases.merge_ms = merge_us / 1e3;
        p.resume_others(tid);
        Ok((stats, phases))
    }

    /// Mobile side: gather N scatter-shard reverse deltas against the
    /// single forward baseline, merge them disjointly, and resume. The
    /// merge cost covers every shard's shipped objects (the gather
    /// patches them all). A [`CloneCloudError::ScatterConflict`] from the
    /// merge leaves the process *and* the baseline untouched, so the
    /// caller can degrade to a single-clone offload without corruption.
    ///
    /// [`CloneCloudError::ScatterConflict`]: crate::error::CloneCloudError
    pub fn gather_scatter_capsules(
        &self,
        p: &mut Process,
        tid: u32,
        deltas: &[DeltaPacket],
        sess: &mut MobileSession,
    ) -> Result<(MergeStats, MigrationPhases)> {
        let mut phases = MigrationPhases::default();
        let stats = delta::merge_scatter_at_mobile(p, tid, deltas, sess)?;
        let objs_us: f64 = deltas
            .iter()
            .map(|d| self.merge_cost_objs_us(&d.sections.objects))
            .sum();
        let merge_us = p
            .device
            .scale_us(objs_us + self.costs.suspend_resume_us / 2.0);
        p.clock.charge_us(merge_us);
        phases.merge_ms = merge_us / 1e3;
        p.resume_others(tid);
        Ok((stats, phases))
    }
}
