//! The per-process migrator (paper §4, Figure 7).
//!
//! Orchestrates one migration round trip and charges its virtual-time
//! costs: suspend all threads at safe points, capture the migrant, hand
//! the packet to the node manager (the caller), and — on the way back —
//! merge the returned state and resume. The mapping table lives only for
//! the duration of the thread's stay at the clone (§4.2).

use std::collections::HashMap;

use crate::appvm::process::Process;
use crate::appvm::thread::ThreadStatus;
use crate::config::CostParams;
use crate::error::Result;

use super::capture::{capture_thread, CaptureOptions, CaptureStats};
use super::format::{CapturePacket, Direction};
use super::mapping::MappingTable;
use super::merge::{instantiate_at_clone, merge_at_mobile, MergeStats};
use super::zygote_diff::ZygoteIndex;

/// Timing breakdown of one migration phase set (virtual ms). Feeds the E3
/// migration-cost bench.
#[derive(Debug, Clone, Default)]
pub struct MigrationPhases {
    pub suspend_ms: f64,
    pub capture_ms: f64,
    pub merge_ms: f64,
    /// Transfer time is charged by the node manager (network model), not
    /// here; recorded by the exec driver.
    pub bytes_out: u64,
    pub objects_shipped: usize,
    pub zygote_skipped: usize,
}

/// The migrator: per-process component, configured with cost calibration
/// and the Zygote-diff switch.
pub struct Migrator {
    pub costs: CostParams,
    pub opts: CaptureOptions,
}

impl Migrator {
    pub fn new(costs: CostParams) -> Migrator {
        Migrator {
            costs,
            opts: CaptureOptions::default(),
        }
    }

    pub fn without_zygote_diff(mut self) -> Migrator {
        self.opts.zygote_diff = false;
        self
    }

    /// Suspend + capture thread `tid` for migration. Charges suspend and
    /// capture costs to the process clock. The thread is marked Migrated.
    pub fn migrate_out(
        &self,
        p: &mut Process,
        tid: u32,
    ) -> Result<(CapturePacket, MigrationPhases)> {
        let mut phases = MigrationPhases::default();

        // Suspend all other threads at safe points (§5: the migrator
        // waits on a condvar until every thread parks).
        p.suspend_others(tid);
        let suspend_us = p.device.scale_us(self.costs.suspend_resume_us / 2.0);
        p.clock.charge_us(suspend_us);
        phases.suspend_ms = suspend_us / 1e3;

        let (packet, stats) = capture_thread(p, tid, Direction::Forward, None, self.opts)?;
        let capture_us = self.capture_cost_us(p, &stats);
        p.clock.charge_us(capture_us);
        phases.capture_ms = capture_us / 1e3;
        phases.bytes_out = stats.bytes as u64;
        phases.objects_shipped = stats.objects;
        phases.zygote_skipped = stats.zygote_skipped;

        p.thread_mut(tid)?.status = ThreadStatus::Migrated;
        Ok((packet, phases))
    }

    /// Clone side: instantiate the migrant thread. Returns the thread id
    /// and the mapping table to retain while the thread executes here.
    pub fn receive_at_clone(
        &self,
        clone: &mut Process,
        packet: &CapturePacket,
    ) -> Result<(u32, MappingTable, MergeStats)> {
        let zidx = ZygoteIndex::build(&clone.program, &clone.heap);
        let (tid, table, stats) = instantiate_at_clone(clone, packet, &zidx)?;
        // Re-instantiation cost mirrors merge cost on the clone's CPU.
        let us = clone.device.scale_us(self.merge_cost_base_us(packet));
        clone.clock.charge_us(us);
        Ok((tid, table, stats))
    }

    /// Clone side: capture the thread for reintegration, consuming the
    /// mapping table (dead entries dropped, new objects added — Fig. 8).
    pub fn return_from_clone(
        &self,
        clone: &mut Process,
        tid: u32,
        mut table: MappingTable,
    ) -> Result<(CapturePacket, MigrationPhases, usize)> {
        let mut phases = MigrationPhases::default();
        let suspend_us = clone.device.scale_us(self.costs.suspend_resume_us / 2.0);
        clone.clock.charge_us(suspend_us);
        phases.suspend_ms = suspend_us / 1e3;

        let (packet, stats) =
            capture_thread(clone, tid, Direction::Reverse, Some(&table), self.opts)?;
        let capture_us = self.capture_cost_us(clone, &stats);
        clone.clock.charge_us(capture_us);
        phases.capture_ms = capture_us / 1e3;
        phases.bytes_out = stats.bytes as u64;
        phases.objects_shipped = stats.objects;
        phases.zygote_skipped = stats.zygote_skipped;

        // Update the table per Fig. 8: drop entries whose CID did not
        // return; report how many died at the clone.
        let returning: HashMap<u64, ()> =
            packet.objects.iter().map(|o| (o.origin_id, ())).collect();
        let dropped = table.retain_cids(&returning);

        clone.thread_mut(tid)?.status = ThreadStatus::Migrated;
        Ok((packet, phases, dropped))
    }

    /// Mobile side: merge the returned state into the original process
    /// and resume. The merge cost (patching references in the running
    /// address space) dominates WiFi-case migration in the paper (§6).
    pub fn merge_back(
        &self,
        p: &mut Process,
        tid: u32,
        packet: &CapturePacket,
    ) -> Result<(MergeStats, MigrationPhases)> {
        let mut phases = MigrationPhases::default();
        let zidx = ZygoteIndex::build(&p.program, &p.heap);
        let stats = merge_at_mobile(p, tid, packet, &zidx)?;
        let merge_us = p
            .device
            .scale_us(self.merge_cost_base_us(packet) + self.costs.suspend_resume_us / 2.0);
        p.clock.charge_us(merge_us);
        phases.merge_ms = merge_us / 1e3;
        p.resume_others(tid);
        Ok((stats, phases))
    }

    /// Baseline merge cost: reference patching per object + per byte of
    /// payload state (the network-unspecific cost that dominates WiFi
    /// migrations in the paper's §6).
    fn merge_cost_base_us(&self, packet: &CapturePacket) -> f64 {
        use super::format::WireBody;
        let bytes: u64 = packet
            .objects
            .iter()
            .map(|o| match &o.body {
                WireBody::ByteArray(b) => b.len() as u64,
                WireBody::FloatArray(f) => 4 * f.len() as u64,
                WireBody::Fields(v) | WireBody::RefArray(v) => 9 * v.len() as u64,
            })
            .sum();
        self.costs.merge_per_obj_us * packet.objects.len() as f64
            + self.costs.merge_per_byte_us * bytes as f64
    }

    fn capture_cost_us(&self, p: &Process, stats: &CaptureStats) -> f64 {
        p.device.scale_us(
            self.costs.capture_per_obj_us * stats.objects as f64
                + self.costs.per_byte_us * stats.bytes as f64,
        )
    }
}
