//! Suspend and capture (paper §4.1).
//!
//! Collects a suspended thread's execution state for transfer: virtual
//! stack frames (register contents, pc — stored by method *name* for
//! portability), all heap objects reachable from the frames and from the
//! app-class static fields (a mark-and-sweep-style traversal), and the
//! statics themselves. Clean Zygote objects are referenced by
//! (class, seq) name instead of being shipped when the §4.3 optimization
//! is enabled.
//!
//! The same traversal also powers **delta captures**: given a session
//! baseline (the set of objects the receiver already holds, plus the
//! epoch of the last sync), objects that are members of the baseline and
//! whose mutation epoch is not newer than it are emitted as
//! [`WireValue::Base`] references instead of being serialized. Their
//! children are still traversed — an unchanged object may point at a
//! changed one — whereas clean Zygote objects remain name-addressed and
//! untraversed exactly as in a full capture.

use std::collections::{HashMap, HashSet};

use crate::appvm::process::Process;
use crate::appvm::value::{ObjBody, ObjId, Value};
use crate::error::{CloneCloudError, Result};

use super::format::{
    CapturePacket, Direction, WireBody, WireFrame, WireObject, WireStatic, WireValue,
};
use super::mapping::MappingTable;

/// Capture options.
#[derive(Debug, Clone, Copy)]
pub struct CaptureOptions {
    /// Enable the Zygote-diff optimization (§4.3). Off = ship everything
    /// reachable, including clean template objects (the E4 ablation).
    pub zygote_diff: bool,
    /// Delta captures ship only statics written since the baseline
    /// epoch (unchanged slots are implied by the baseline). Off = every
    /// delta re-sends the full non-null statics section — the PR 2 wire
    /// shape, kept for the bench ablation. Full captures are unaffected.
    pub incremental_statics: bool,
}

impl Default for CaptureOptions {
    fn default() -> Self {
        CaptureOptions {
            zygote_diff: true,
            incremental_statics: true,
        }
    }
}

/// Capture statistics (feeds metrics and the E4 ablation bench).
#[derive(Debug, Clone, Default)]
pub struct CaptureStats {
    /// Objects serialized in full.
    pub objects: usize,
    /// Clean Zygote objects referenced by name instead of shipped.
    pub zygote_skipped: usize,
    /// Baseline objects referenced by id instead of shipped (delta).
    pub base_skipped: usize,
    /// Static slots serialized into the statics section.
    pub statics_shipped: usize,
    /// Encoded packet size.
    pub bytes: usize,
}

/// The sender's view of the session baseline during a delta capture: who
/// is a member of the shared state, and what its **mobile-side** id is.
/// `Base` references always carry the MID — the session-stable object
/// name — so the phone resolves them directly and the clone goes through
/// its persistent mapping table.
pub(crate) enum BaseView<'a> {
    /// Phone side: members are the phone's own ids.
    Mobile(&'a HashSet<u64>),
    /// Clone side: members are the CIDs in the session mapping table.
    CloneTable(&'a MappingTable),
}

impl BaseView<'_> {
    pub(crate) fn mid_of(&self, local: u64) -> Option<u64> {
        match self {
            BaseView::Mobile(mids) => mids.contains(&local).then_some(local),
            BaseView::CloneTable(t) => t.mid_for_cid(local),
        }
    }
}

/// Baseline parameters for a delta capture.
pub(crate) struct DeltaBase<'a> {
    /// Objects with `epoch <= base_epoch` are unchanged since the sync.
    pub epoch: u64,
    pub view: BaseView<'a>,
}

/// The raw output of a capture traversal, before packet framing.
pub(crate) struct RawCapture {
    pub frames: Vec<WireFrame>,
    pub objects: Vec<WireObject>,
    pub zygote_refs: Vec<(String, u32)>,
    pub statics: Vec<WireStatic>,
    /// Every baseline member reached (by MID), whether shipped dirty or
    /// referenced via `Base`. Members NOT in this set died locally — the
    /// delta's `deleted` list.
    pub reached_members: HashSet<u64>,
    /// Local ids of every shipped object, in slot order.
    pub shipped: Vec<ObjId>,
    pub stats: CaptureStats,
}

/// Capture thread `tid` of `p`. For reverse captures pass the clone-side
/// mapping table so each object carries its mobile-side MID. With `base`,
/// performs a delta capture against the session baseline.
pub(crate) fn capture_core(
    p: &Process,
    tid: u32,
    direction: Direction,
    mapping: Option<&MappingTable>,
    opts: CaptureOptions,
    base: Option<&DeltaBase>,
) -> Result<RawCapture> {
    let thread = p.thread(tid)?;
    if thread.frames.is_empty() {
        return Err(CloneCloudError::migration("capture of a frame-less thread"));
    }

    // ---- traversal: assign slots to shipped objects, names to skipped
    // Zygote objects, MIDs to unchanged baseline members -------------------
    let mut slot_of: HashMap<u64, u32> = HashMap::new();
    let mut order: Vec<ObjId> = Vec::new();
    let mut zygote_of: HashMap<u64, u32> = HashMap::new();
    let mut zygote_refs: Vec<(String, u32)> = Vec::new();
    let mut base_of: HashMap<u64, u64> = HashMap::new();
    let mut reached_members: HashSet<u64> = HashSet::new();
    let mut stats = CaptureStats::default();

    // Roots: every register of every frame + app-class statics.
    let mut stack: Vec<ObjId> = thread.roots();
    for (ci, class_statics) in p.statics.iter().enumerate() {
        if p.program.classes[ci].system {
            continue;
        }
        stack.extend(class_statics.iter().filter_map(|v| v.as_ref()));
    }

    while let Some(id) = stack.pop() {
        if slot_of.contains_key(&id.0)
            || zygote_of.contains_key(&id.0)
            || base_of.contains_key(&id.0)
        {
            continue;
        }
        let obj = p.heap.get(id)?;

        // Delta: a baseline member the receiver already holds. Unchanged
        // since the sync epoch => reference by id; changed => ship below
        // (the receiver overwrites in place). Either way its children are
        // traversed — an unchanged parent can reach a changed child.
        let member_mid = base.and_then(|b| b.view.mid_of(id.0));
        if let (Some(b), Some(mid)) = (base, member_mid) {
            reached_members.insert(mid);
            if obj.epoch <= b.epoch {
                base_of.insert(id.0, mid);
                stats.base_skipped += 1;
                stack.extend(obj.body.refs());
                continue;
            }
        }

        // Clean Zygote template object (never a baseline member — members
        // were shipped once, which dirties the receiving twin): reference
        // by (class, seq) name; children are template-internal and
        // identical on the receiving side — not traversed. A template
        // object missing its sequence name (malformed heap) degrades to
        // being shipped like an app object instead of aborting.
        if member_mid.is_none() && opts.zygote_diff && !obj.dirty {
            if let Some(seq) = obj.zygote_seq {
                let zi = zygote_refs.len() as u32;
                zygote_refs.push((p.program.class(obj.class).name.clone(), seq));
                zygote_of.insert(id.0, zi);
                stats.zygote_skipped += 1;
                continue;
            }
        }

        slot_of.insert(id.0, order.len() as u32);
        order.push(id);
        stack.extend(obj.body.refs());
    }
    stats.objects = order.len();

    let conv = |v: &Value| -> Result<WireValue> {
        Ok(match v {
            Value::Null => WireValue::Null,
            Value::Int(x) => WireValue::Int(*x),
            Value::Float(x) => WireValue::Float(*x),
            Value::Ref(r) => {
                if let Some(&s) = slot_of.get(&r.0) {
                    WireValue::Slot(s)
                } else if let Some(&z) = zygote_of.get(&r.0) {
                    WireValue::Zygote(z)
                } else if let Some(&m) = base_of.get(&r.0) {
                    WireValue::Base(m)
                } else {
                    return Err(CloneCloudError::migration(format!(
                        "reference to untraversed object {}",
                        r.0
                    )));
                }
            }
        })
    };

    // ---- objects ---------------------------------------------------------
    let mut objects = Vec::with_capacity(order.len());
    for &id in &order {
        let obj = p.heap.get(id)?;
        let body = match &obj.body {
            ObjBody::Fields(vs) => {
                WireBody::Fields(vs.iter().map(&conv).collect::<Result<Vec<_>>>()?)
            }
            ObjBody::ByteArray(b) => WireBody::ByteArray(b.clone()),
            ObjBody::FloatArray(f) => WireBody::FloatArray(f.clone()),
            ObjBody::RefArray(vs) => {
                WireBody::RefArray(vs.iter().map(&conv).collect::<Result<Vec<_>>>()?)
            }
        };
        // Reverse direction: attach the mobile-side id from the mapping
        // table (0 = new object created at the clone).
        let mapped_id = match (direction, mapping) {
            (Direction::Reverse, Some(t)) => t.mid_for_cid(id.0).unwrap_or(0),
            _ => 0,
        };
        objects.push(WireObject {
            origin_id: id.0,
            mapped_id,
            class_name: p.program.class(obj.class).name.clone(),
            zygote_seq: obj.zygote_seq,
            body,
        });
    }

    // ---- frames -----------------------------------------------------------
    let mut frames = Vec::with_capacity(thread.frames.len());
    for f in &thread.frames {
        frames.push(WireFrame {
            class_name: p.program.class(f.method.class).name.clone(),
            method_name: p.program.method(f.method).name.clone(),
            pc: f.pc as u32,
            ret_reg_plus1: f.ret_reg.map(|r| r + 1).unwrap_or(0),
            regs: f.regs.iter().map(&conv).collect::<Result<Vec<_>>>()?,
        });
    }

    // ---- statics ----------------------------------------------------------
    let mut statics = Vec::new();
    for (ci, class_statics) in p.statics.iter().enumerate() {
        if p.program.classes[ci].system {
            continue;
        }
        for (idx, v) in class_statics.iter().enumerate() {
            match base {
                // Delta capture: unchanged slots are implied by the
                // baseline; changed ones ship their current value, Null
                // included, so a static cleared since the sync is
                // cleared at the receiver too.
                Some(b) if opts.incremental_statics => {
                    if p.statics_epoch[ci][idx] <= b.epoch {
                        continue;
                    }
                }
                // Full capture (or the legacy full-statics delta shape):
                // null statics are implied — full-capture receivers
                // reset app statics before applying.
                _ => {
                    if matches!(v, Value::Null) {
                        continue;
                    }
                }
            }
            statics.push(WireStatic {
                class_name: p.program.classes[ci].name.clone(),
                idx: idx as u16,
                value: conv(v)?,
            });
        }
    }
    stats.statics_shipped = statics.len();

    Ok(RawCapture {
        frames,
        objects,
        zygote_refs,
        statics,
        reached_members,
        shipped: order,
        stats,
    })
}

/// Capture thread `tid` of `p` in full. For reverse captures pass the
/// clone-side mapping table so each object carries its mobile-side MID.
pub fn capture_thread(
    p: &Process,
    tid: u32,
    direction: Direction,
    mapping: Option<&MappingTable>,
    opts: CaptureOptions,
) -> Result<(CapturePacket, CaptureStats)> {
    let raw = capture_core(p, tid, direction, mapping, opts, None)?;
    let packet = CapturePacket {
        direction,
        thread_id: tid,
        clock_us: p.clock.now_us(),
        frames: raw.frames,
        objects: raw.objects,
        zygote_refs: raw.zygote_refs,
        statics: raw.statics,
    };
    let mut stats = raw.stats;
    stats.bytes = packet.encode().len();
    Ok((packet, stats))
}

/// Convenience: measure the state size (bytes) a migration at the current
/// point of thread `tid` would transfer. Used by the dynamic profiler for
/// profile-tree edge annotations (§3.2: "perform the suspend-and-capture
/// operation of the migrator, measure the state size, and discard the
/// captured state").
pub fn measure_state_size(p: &Process, tid: u32, opts: CaptureOptions) -> Result<u64> {
    let (_packet, stats) = capture_thread(p, tid, Direction::Forward, None, opts)?;
    Ok(stats.bytes as u64)
}
