//! Suspend and capture (paper §4.1).
//!
//! Collects a suspended thread's execution state for transfer: virtual
//! stack frames (register contents, pc — stored by method *name* for
//! portability), all heap objects reachable from the frames and from the
//! app-class static fields (a mark-and-sweep-style traversal), and the
//! statics themselves. Clean Zygote objects are referenced by
//! (class, seq) name instead of being shipped when the §4.3 optimization
//! is enabled.

use std::collections::HashMap;

use crate::appvm::process::Process;
use crate::appvm::value::{ObjBody, ObjId, Value};
use crate::error::{CloneCloudError, Result};

use super::format::{
    CapturePacket, Direction, WireBody, WireFrame, WireObject, WireStatic, WireValue,
};
use super::mapping::MappingTable;

/// Capture options.
#[derive(Debug, Clone, Copy)]
pub struct CaptureOptions {
    /// Enable the Zygote-diff optimization (§4.3). Off = ship everything
    /// reachable, including clean template objects (the E4 ablation).
    pub zygote_diff: bool,
}

impl Default for CaptureOptions {
    fn default() -> Self {
        CaptureOptions { zygote_diff: true }
    }
}

/// Capture statistics (feeds metrics and the E4 ablation bench).
#[derive(Debug, Clone, Default)]
pub struct CaptureStats {
    /// Objects serialized in full.
    pub objects: usize,
    /// Clean Zygote objects referenced by name instead of shipped.
    pub zygote_skipped: usize,
    /// Encoded packet size.
    pub bytes: usize,
}

/// Capture thread `tid` of `p`. For reverse captures pass the clone-side
/// mapping table so each object carries its mobile-side MID.
pub fn capture_thread(
    p: &Process,
    tid: u32,
    direction: Direction,
    mapping: Option<&MappingTable>,
    opts: CaptureOptions,
) -> Result<(CapturePacket, CaptureStats)> {
    let thread = p.thread(tid)?;
    if thread.frames.is_empty() {
        return Err(CloneCloudError::migration("capture of a frame-less thread"));
    }

    // ---- traversal: assign slots to shipped objects, names to skipped
    // Zygote objects ------------------------------------------------------
    let mut slot_of: HashMap<u64, u32> = HashMap::new();
    let mut order: Vec<ObjId> = Vec::new();
    let mut zygote_of: HashMap<u64, u32> = HashMap::new();
    let mut zygote_refs: Vec<(String, u32)> = Vec::new();
    let mut stats = CaptureStats::default();

    // Roots: every register of every frame + app-class statics.
    let mut stack: Vec<ObjId> = thread.roots();
    for (ci, class_statics) in p.statics.iter().enumerate() {
        if p.program.classes[ci].system {
            continue;
        }
        stack.extend(class_statics.iter().filter_map(|v| v.as_ref()));
    }

    while let Some(id) = stack.pop() {
        if slot_of.contains_key(&id.0) || zygote_of.contains_key(&id.0) {
            continue;
        }
        let obj = p.heap.get(id)?;
        let clean_zygote = opts.zygote_diff && obj.zygote_seq.is_some() && !obj.dirty;
        if clean_zygote {
            // Referenced by name; children are template-internal and
            // identical on the receiving side — not traversed.
            let zi = zygote_refs.len() as u32;
            zygote_refs.push((
                p.program.class(obj.class).name.clone(),
                obj.zygote_seq.unwrap(),
            ));
            zygote_of.insert(id.0, zi);
            stats.zygote_skipped += 1;
            continue;
        }
        slot_of.insert(id.0, order.len() as u32);
        order.push(id);
        stack.extend(obj.body.refs());
    }
    stats.objects = order.len();

    let conv = |v: &Value| -> Result<WireValue> {
        Ok(match v {
            Value::Null => WireValue::Null,
            Value::Int(x) => WireValue::Int(*x),
            Value::Float(x) => WireValue::Float(*x),
            Value::Ref(r) => {
                if let Some(&s) = slot_of.get(&r.0) {
                    WireValue::Slot(s)
                } else if let Some(&z) = zygote_of.get(&r.0) {
                    WireValue::Zygote(z)
                } else {
                    return Err(CloneCloudError::migration(format!(
                        "reference to untraversed object {}",
                        r.0
                    )));
                }
            }
        })
    };

    // ---- objects ---------------------------------------------------------
    let mut objects = Vec::with_capacity(order.len());
    for &id in &order {
        let obj = p.heap.get(id)?;
        let body = match &obj.body {
            ObjBody::Fields(vs) => {
                WireBody::Fields(vs.iter().map(&conv).collect::<Result<Vec<_>>>()?)
            }
            ObjBody::ByteArray(b) => WireBody::ByteArray(b.clone()),
            ObjBody::FloatArray(f) => WireBody::FloatArray(f.clone()),
            ObjBody::RefArray(vs) => {
                WireBody::RefArray(vs.iter().map(&conv).collect::<Result<Vec<_>>>()?)
            }
        };
        // Reverse direction: attach the mobile-side id from the mapping
        // table (0 = new object created at the clone).
        let mapped_id = match (direction, mapping) {
            (Direction::Reverse, Some(t)) => t.mid_for_cid(id.0).unwrap_or(0),
            _ => 0,
        };
        objects.push(WireObject {
            origin_id: id.0,
            mapped_id,
            class_name: p.program.class(obj.class).name.clone(),
            zygote_seq: obj.zygote_seq,
            body,
        });
    }

    // ---- frames -----------------------------------------------------------
    let mut frames = Vec::with_capacity(thread.frames.len());
    for f in &thread.frames {
        frames.push(WireFrame {
            class_name: p.program.class(f.method.class).name.clone(),
            method_name: p.program.method(f.method).name.clone(),
            pc: f.pc as u32,
            ret_reg_plus1: f.ret_reg.map(|r| r + 1).unwrap_or(0),
            regs: f.regs.iter().map(&conv).collect::<Result<Vec<_>>>()?,
        });
    }

    // ---- statics ----------------------------------------------------------
    let mut statics = Vec::new();
    for (ci, class_statics) in p.statics.iter().enumerate() {
        if p.program.classes[ci].system {
            continue;
        }
        for (idx, v) in class_statics.iter().enumerate() {
            // Null statics are implied; ship only meaningful values.
            if matches!(v, Value::Null) {
                continue;
            }
            statics.push(WireStatic {
                class_name: p.program.classes[ci].name.clone(),
                idx: idx as u16,
                value: conv(v)?,
            });
        }
    }

    let packet = CapturePacket {
        direction,
        thread_id: tid,
        clock_us: p.clock.now_us(),
        frames,
        objects,
        zygote_refs,
        statics,
    };
    stats.bytes = packet.encode().len();
    Ok((packet, stats))
}

/// Convenience: measure the state size (bytes) a migration at the current
/// point of thread `tid` would transfer. Used by the dynamic profiler for
/// profile-tree edge annotations (§3.2: "perform the suspend-and-capture
/// operation of the migrator, measure the state size, and discard the
/// captured state").
pub fn measure_state_size(p: &Process, tid: u32, opts: CaptureOptions) -> Result<u64> {
    let (_packet, stats) = capture_thread(p, tid, Direction::Forward, None, opts)?;
    Ok(stats.bytes as u64)
}
