//! Suspend and capture (paper §4.1).
//!
//! Collects a suspended thread's execution state for transfer: virtual
//! stack frames (register contents, pc — stored by method *name* for
//! portability), all heap objects reachable from the frames and from the
//! app-class static fields (a mark-and-sweep-style traversal), and the
//! statics themselves. Clean Zygote objects are referenced by
//! (class, seq) name instead of being shipped when the §4.3 optimization
//! is enabled.
//!
//! The same traversal also powers **delta captures**: given a session
//! baseline (the set of objects the receiver already holds, plus the
//! epoch of the last sync), objects that are members of the baseline and
//! whose mutation epoch is not newer than it are emitted as
//! [`WireValue::Base`] references instead of being serialized. Their
//! children are still traversed — an unchanged object may point at a
//! changed one — whereas clean Zygote objects remain name-addressed and
//! untraversed exactly as in a full capture.

use std::collections::{HashMap, HashSet};

use crate::appvm::process::Process;
use crate::appvm::value::{ObjBody, ObjId, Value};
use crate::error::{CloneCloudError, Result};

use super::format::{
    CapturePacket, Direction, WireBody, WireFrame, WireObject, WireStatic, WireValue,
};
use super::mapping::MappingTable;

/// Capture options.
#[derive(Debug, Clone, Copy)]
pub struct CaptureOptions {
    /// Enable the Zygote-diff optimization (§4.3). Off = ship everything
    /// reachable, including clean template objects (the E4 ablation).
    pub zygote_diff: bool,
    /// Delta captures ship only statics written since the baseline
    /// epoch (unchanged slots are implied by the baseline). Off = every
    /// delta re-sends the full non-null statics section — the PR 2 wire
    /// shape, kept for the bench ablation. Full captures are unaffected.
    pub incremental_statics: bool,
}

impl Default for CaptureOptions {
    fn default() -> Self {
        CaptureOptions {
            zygote_diff: true,
            incremental_statics: true,
        }
    }
}

/// Capture statistics (feeds metrics and the E4 ablation bench).
#[derive(Debug, Clone, Default)]
pub struct CaptureStats {
    /// Objects serialized in full.
    pub objects: usize,
    /// Clean Zygote objects referenced by name instead of shipped.
    pub zygote_skipped: usize,
    /// Baseline objects referenced by id instead of shipped (delta).
    pub base_skipped: usize,
    /// Static slots serialized into the statics section.
    pub statics_shipped: usize,
    /// Encoded packet size.
    pub bytes: usize,
    /// Objects this capture examined: every traversal visit on the
    /// per-object path, every dirty-page entry + marked fresh object on
    /// the paged path — the capture-work headline the zygote_scale bench
    /// compares.
    pub objects_scanned: usize,
    /// Pages whose contents were examined (paged captures only).
    pub pages_scanned: usize,
    /// Scanned pages that held at least one dirty object.
    pub pages_dirty: usize,
}

/// The sender's view of the session baseline during a delta capture: who
/// is a member of the shared state, and what its **mobile-side** id is.
/// `Base` references always carry the MID — the session-stable object
/// name — so the phone resolves them directly and the clone goes through
/// its persistent mapping table.
pub(crate) enum BaseView<'a> {
    /// Phone side: members are the phone's own ids.
    Mobile(&'a HashSet<u64>),
    /// Clone side: members are the CIDs in the session mapping table.
    CloneTable(&'a MappingTable),
}

impl BaseView<'_> {
    pub(crate) fn mid_of(&self, local: u64) -> Option<u64> {
        match self {
            BaseView::Mobile(mids) => mids.contains(&local).then_some(local),
            BaseView::CloneTable(t) => t.mid_for_cid(local),
        }
    }

    /// Every MID in the baseline (the paged path starts from "everything
    /// retained" and subtracts the deletions the page scan surfaced).
    pub(crate) fn member_mids(&self) -> Vec<u64> {
        match self {
            BaseView::Mobile(mids) => mids.iter().copied().collect(),
            BaseView::CloneTable(t) => t
                .entries()
                .iter()
                .filter_map(|e| match (e.mid, e.cid) {
                    (Some(m), Some(_)) => Some(m),
                    _ => None,
                })
                .collect(),
        }
    }
}

/// Baseline parameters for a delta capture.
pub(crate) struct DeltaBase<'a> {
    /// Objects with `epoch <= base_epoch` are unchanged since the sync.
    pub epoch: u64,
    pub view: BaseView<'a>,
}

/// The raw output of a capture traversal, before packet framing.
pub(crate) struct RawCapture {
    pub frames: Vec<WireFrame>,
    pub objects: Vec<WireObject>,
    pub zygote_refs: Vec<(String, u32)>,
    pub statics: Vec<WireStatic>,
    /// Every baseline member reached (by MID), whether shipped dirty or
    /// referenced via `Base`. Members NOT in this set died locally — the
    /// delta's `deleted` list.
    pub reached_members: HashSet<u64>,
    /// Local ids of every shipped object, in slot order.
    pub shipped: Vec<ObjId>,
    pub stats: CaptureStats,
}

/// Capture thread `tid` of `p`. For reverse captures pass the clone-side
/// mapping table so each object carries its mobile-side MID. With `base`,
/// performs a delta capture against the session baseline.
pub(crate) fn capture_core(
    p: &Process,
    tid: u32,
    direction: Direction,
    mapping: Option<&MappingTable>,
    opts: CaptureOptions,
    base: Option<&DeltaBase>,
) -> Result<RawCapture> {
    let thread = p.thread(tid)?;
    if thread.frames.is_empty() {
        return Err(CloneCloudError::migration("capture of a frame-less thread"));
    }

    // ---- traversal: assign slots to shipped objects, names to skipped
    // Zygote objects, MIDs to unchanged baseline members -------------------
    let mut slot_of: HashMap<u64, u32> = HashMap::new();
    let mut order: Vec<ObjId> = Vec::new();
    let mut zygote_of: HashMap<u64, u32> = HashMap::new();
    let mut zygote_refs: Vec<(String, u32)> = Vec::new();
    let mut base_of: HashMap<u64, u64> = HashMap::new();
    let mut reached_members: HashSet<u64> = HashSet::new();
    let mut stats = CaptureStats::default();

    // Roots: every register of every frame + app-class statics.
    let mut stack: Vec<ObjId> = thread.roots();
    for (ci, class_statics) in p.statics.iter().enumerate() {
        if p.program.classes[ci].system {
            continue;
        }
        stack.extend(class_statics.iter().filter_map(|v| v.as_ref()));
    }

    while let Some(id) = stack.pop() {
        if slot_of.contains_key(&id.0)
            || zygote_of.contains_key(&id.0)
            || base_of.contains_key(&id.0)
        {
            continue;
        }
        let obj = p.heap.get(id)?;
        stats.objects_scanned += 1;

        // Delta: a baseline member the receiver already holds. Unchanged
        // since the sync epoch => reference by id; changed => ship below
        // (the receiver overwrites in place). Either way its children are
        // traversed — an unchanged parent can reach a changed child.
        let member_mid = base.and_then(|b| b.view.mid_of(id.0));
        if let (Some(b), Some(mid)) = (base, member_mid) {
            reached_members.insert(mid);
            if obj.epoch <= b.epoch {
                base_of.insert(id.0, mid);
                stats.base_skipped += 1;
                stack.extend(obj.body.refs());
                continue;
            }
        }

        // Clean Zygote template object (never a baseline member — members
        // were shipped once, which dirties the receiving twin): reference
        // by (class, seq) name; children are template-internal and
        // identical on the receiving side — not traversed. A template
        // object missing its sequence name (malformed heap) degrades to
        // being shipped like an app object instead of aborting.
        if member_mid.is_none() && opts.zygote_diff && !obj.dirty {
            if let Some(seq) = obj.zygote_seq {
                let zi = zygote_refs.len() as u32;
                zygote_refs.push((p.program.class(obj.class).name.clone(), seq));
                zygote_of.insert(id.0, zi);
                stats.zygote_skipped += 1;
                continue;
            }
        }

        slot_of.insert(id.0, order.len() as u32);
        order.push(id);
        stack.extend(obj.body.refs());
    }
    stats.objects = order.len();

    let conv = |v: &Value| -> Result<WireValue> {
        Ok(match v {
            Value::Null => WireValue::Null,
            Value::Int(x) => WireValue::Int(*x),
            Value::Float(x) => WireValue::Float(*x),
            Value::Ref(r) => {
                if let Some(&s) = slot_of.get(&r.0) {
                    WireValue::Slot(s)
                } else if let Some(&z) = zygote_of.get(&r.0) {
                    WireValue::Zygote(z)
                } else if let Some(&m) = base_of.get(&r.0) {
                    WireValue::Base(m)
                } else {
                    return Err(CloneCloudError::migration(format!(
                        "reference to untraversed object {}",
                        r.0
                    )));
                }
            }
        })
    };

    let incremental_epoch = match base {
        Some(b) if opts.incremental_statics => Some(b.epoch),
        _ => None,
    };
    let (objects, frames, statics) =
        emit_state_sections(p, thread, direction, mapping, incremental_epoch, &order, &conv)?;
    stats.statics_shipped = statics.len();

    Ok(RawCapture {
        frames,
        objects,
        zygote_refs,
        statics,
        reached_members,
        shipped: order,
        stats,
    })
}

/// Emit the objects / frames / statics sections for an already-decided
/// shipping set, with `conv` translating references into wire values —
/// the one place the capsule's section shape lives, shared by the
/// traversal and paged capture paths (they differ only in how `order`
/// and `conv` were built). Emission order (objects, frames, statics) is
/// load-bearing for the paged path's lazily-assigned Zygote name
/// indexes.
///
/// `incremental_epoch = Some(e)`: delta capture — unchanged static
/// slots (epoch <= e) are implied by the baseline; changed ones ship
/// their current value, Null included, so a static cleared since the
/// sync is cleared at the receiver too. `None`: full capture (or the
/// legacy full-statics delta shape) — null statics are implied, and
/// full-capture receivers reset app statics before applying.
fn emit_state_sections(
    p: &Process,
    thread: &crate::appvm::thread::VmThread,
    direction: Direction,
    mapping: Option<&MappingTable>,
    incremental_epoch: Option<u64>,
    order: &[ObjId],
    conv: &dyn Fn(&Value) -> Result<WireValue>,
) -> Result<(Vec<WireObject>, Vec<WireFrame>, Vec<WireStatic>)> {
    // ---- objects ---------------------------------------------------------
    let mut objects = Vec::with_capacity(order.len());
    for &id in order {
        let obj = p.heap.get(id)?;
        let body = match &obj.body {
            ObjBody::Fields(vs) => {
                WireBody::Fields(vs.iter().map(conv).collect::<Result<Vec<_>>>()?)
            }
            ObjBody::ByteArray(b) => WireBody::ByteArray(b.clone()),
            ObjBody::FloatArray(f) => WireBody::FloatArray(f.clone()),
            ObjBody::RefArray(vs) => {
                WireBody::RefArray(vs.iter().map(conv).collect::<Result<Vec<_>>>()?)
            }
        };
        // Reverse direction: attach the mobile-side id from the mapping
        // table (0 = new object created at the clone).
        let mapped_id = match (direction, mapping) {
            (Direction::Reverse, Some(t)) => t.mid_for_cid(id.0).unwrap_or(0),
            _ => 0,
        };
        objects.push(WireObject {
            origin_id: id.0,
            mapped_id,
            class_name: p.program.class(obj.class).name.clone(),
            zygote_seq: obj.zygote_seq,
            body,
        });
    }

    // ---- frames -----------------------------------------------------------
    let mut frames = Vec::with_capacity(thread.frames.len());
    for f in &thread.frames {
        frames.push(WireFrame {
            class_name: p.program.class(f.method.class).name.clone(),
            method_name: p.program.method(f.method).name.clone(),
            pc: f.pc as u32,
            ret_reg_plus1: f.ret_reg.map(|r| r + 1).unwrap_or(0),
            regs: f.regs.iter().map(conv).collect::<Result<Vec<_>>>()?,
        });
    }

    // ---- statics ----------------------------------------------------------
    let mut statics = Vec::new();
    for (ci, class_statics) in p.statics.iter().enumerate() {
        if p.program.classes[ci].system {
            continue;
        }
        for (idx, v) in class_statics.iter().enumerate() {
            match incremental_epoch {
                Some(e) => {
                    if p.statics_epoch[ci][idx] <= e {
                        continue;
                    }
                }
                None => {
                    if matches!(v, Value::Null) {
                        continue;
                    }
                }
            }
            statics.push(WireStatic {
                class_name: p.program.classes[ci].name.clone(),
                idx: idx as u16,
                value: conv(v)?,
            });
        }
    }

    Ok((objects, frames, statics))
}

/// Page-accelerated delta capture: instead of traversing the whole
/// reachable heap, scan only the pages the write barriers stamped since
/// the baseline epoch ([`crate::appvm::Heap::scan_dirty_pages`]).
///
/// Soundness rests on one property of the epoch barrier: **a clean
/// object can never reference a post-baseline object** — storing such a
/// reference would have stamped the referrer. Therefore:
/// * every changed/new object lives on a dirty page (found by the scan);
/// * every path from the roots to a *fresh* object runs through the
///   frames, the statics, or a dirty object (so reachability of fresh
///   objects is decidable inside the dirty set — the mini-mark below);
/// * baseline members and dirty Zygote-named objects ship
///   unconditionally (the receiver holds a twin to overwrite in place;
///   shipping an unreachable one is wasted bytes, never corruption);
/// * deletions are exactly the member ids the scan found missing —
///   `Heap::remove`/`Heap::gc` stamp the page of everything they drop,
///   and GC removes whole unreachable subgraphs, so surviving objects
///   never dangle into the deleted set.
///
/// A mutation that bypasses the barrier is *not* shipped; the canonical
/// `state_digest` then disagrees at the next sync and the session
/// degrades to a full capture (`NeedFull`) — a missed stamp costs a
/// resend, never wrong bytes (the reverse merge checks the digest before
/// touching any state).
pub(crate) fn capture_core_paged(
    p: &Process,
    tid: u32,
    direction: Direction,
    mapping: Option<&MappingTable>,
    opts: CaptureOptions,
    base: &DeltaBase,
) -> Result<RawCapture> {
    use std::cell::RefCell;

    let thread = p.thread(tid)?;
    if thread.frames.is_empty() {
        return Err(CloneCloudError::migration("capture of a frame-less thread"));
    }

    let scan = p.heap.scan_dirty_pages(base.epoch);
    let mut stats = CaptureStats {
        pages_scanned: scan.pages_scanned,
        pages_dirty: scan.pages_dirty,
        objects_scanned: scan.dirty.len(),
        ..CaptureStats::default()
    };

    // Partition the dirty set. Members and dirty Zygote-named objects
    // are "anchored" — the receiver holds a twin to overwrite — and ship
    // as-is; fresh objects ship only if still reachable.
    let mut anchored: Vec<ObjId> = Vec::new();
    let mut fresh: HashMap<u64, &crate::appvm::value::Object> = HashMap::new();
    for &id in &scan.dirty {
        let obj = p.heap.get(id)?;
        if base.view.mid_of(id.0).is_some() || obj.zygote_seq.is_some() {
            anchored.push(id);
        } else {
            fresh.insert(id.0, obj);
        }
    }

    // Mini-mark: which fresh objects are reachable? Roots are the frame
    // registers, the app statics, and the references out of anchored
    // dirty objects (a clean object cannot point at a fresh one).
    let mut work: Vec<ObjId> = thread.roots();
    for (ci, class_statics) in p.statics.iter().enumerate() {
        if p.program.classes[ci].system {
            continue;
        }
        work.extend(class_statics.iter().filter_map(|v| v.as_ref()));
    }
    for &id in &anchored {
        work.extend(p.heap.get(id)?.body.refs());
    }
    let mut marked: HashSet<u64> = HashSet::new();
    while let Some(id) = work.pop() {
        if !fresh.contains_key(&id.0) || !marked.insert(id.0) {
            continue;
        }
        stats.objects_scanned += 1;
        work.extend(fresh[&id.0].body.refs());
    }

    let mut order: Vec<ObjId> = anchored;
    order.extend(marked.iter().map(|&id| ObjId(id)));
    order.sort_unstable();
    stats.objects = order.len();
    let slot_of: HashMap<u64, u32> = order
        .iter()
        .enumerate()
        .map(|(i, id)| (id.0, i as u32))
        .collect();

    // Members that died since the sync: GC/remove stamped their pages,
    // so the missing-id list names them; everything else is retained.
    let mut reached_members: HashSet<u64> =
        base.view.member_mids().into_iter().collect();
    for &gone in &scan.missing {
        if let Some(mid) = base.view.mid_of(gone) {
            reached_members.remove(&mid);
        }
    }

    // Zygote name references are assigned lazily, at the first value
    // that mentions a clean template object (the traversal path instead
    // listed every reachable template object — pure capsule weight when
    // no shipped value referenced them).
    let zygote_of: RefCell<HashMap<u64, u32>> = RefCell::new(HashMap::new());
    let zygote_names: RefCell<Vec<(String, u32)>> = RefCell::new(Vec::new());
    let base_seen: RefCell<HashSet<u64>> = RefCell::new(HashSet::new());
    let conv = |v: &Value| -> Result<WireValue> {
        Ok(match v {
            Value::Null => WireValue::Null,
            Value::Int(x) => WireValue::Int(*x),
            Value::Float(x) => WireValue::Float(*x),
            Value::Ref(r) => {
                if let Some(&s) = slot_of.get(&r.0) {
                    return Ok(WireValue::Slot(s));
                }
                if let Some(mid) = base.view.mid_of(r.0) {
                    base_seen.borrow_mut().insert(mid);
                    return Ok(WireValue::Base(mid));
                }
                // Bind the cache probe so its RefCell guard drops before
                // the insert below re-borrows mutably.
                let cached = zygote_of.borrow().get(&r.0).copied();
                if let Some(z) = cached {
                    return Ok(WireValue::Zygote(z));
                }
                let obj = p.heap.get(*r)?;
                match obj.zygote_seq {
                    Some(seq) if !obj.dirty => {
                        let mut names = zygote_names.borrow_mut();
                        let zi = names.len() as u32;
                        names.push((p.program.class(obj.class).name.clone(), seq));
                        zygote_of.borrow_mut().insert(r.0, zi);
                        WireValue::Zygote(zi)
                    }
                    // Unreachable under the barrier invariant; bail so
                    // the caller degrades to a full traversal.
                    _ => {
                        return Err(CloneCloudError::migration(format!(
                            "paged capture: reference to unclassifiable object {}",
                            r.0
                        )))
                    }
                }
            }
        })
    };

    let incremental_epoch = opts.incremental_statics.then_some(base.epoch);
    let (objects, frames, statics) =
        emit_state_sections(p, thread, direction, mapping, incremental_epoch, &order, &conv)?;
    stats.statics_shipped = statics.len();
    stats.base_skipped = base_seen.into_inner().len();
    let zygote_refs = zygote_names.into_inner();
    stats.zygote_skipped = zygote_refs.len();

    Ok(RawCapture {
        frames,
        objects,
        zygote_refs,
        statics,
        reached_members,
        shipped: order,
        stats,
    })
}

/// Capture thread `tid` of `p` in full. For reverse captures pass the
/// clone-side mapping table so each object carries its mobile-side MID.
pub fn capture_thread(
    p: &Process,
    tid: u32,
    direction: Direction,
    mapping: Option<&MappingTable>,
    opts: CaptureOptions,
) -> Result<(CapturePacket, CaptureStats)> {
    let raw = capture_core(p, tid, direction, mapping, opts, None)?;
    let packet = CapturePacket {
        direction,
        thread_id: tid,
        clock_us: p.clock.now_us(),
        frames: raw.frames,
        objects: raw.objects,
        zygote_refs: raw.zygote_refs,
        statics: raw.statics,
    };
    let mut stats = raw.stats;
    stats.bytes = packet.encode()?.len();
    Ok((packet, stats))
}

/// Convenience: measure the state size (bytes) a migration at the current
/// point of thread `tid` would transfer. Used by the dynamic profiler for
/// profile-tree edge annotations (§3.2: "perform the suspend-and-capture
/// operation of the migrator, measure the state size, and discard the
/// captured state").
pub fn measure_state_size(p: &Process, tid: u32, opts: CaptureOptions) -> Result<u64> {
    let (_packet, stats) = capture_thread(p, tid, Direction::Forward, None, opts)?;
    Ok(stats.bytes as u64)
}
