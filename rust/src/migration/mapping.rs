//! The object mapping table (paper §4.2, Figure 8).
//!
//! References are native memory addresses in most application-layer VMs —
//! meaningless across address spaces and reused over time. CloneCloud
//! instead keys migration on per-VM unique object IDs: MID at the mobile
//! device, CID at the clone. The table exists only during capture and
//! reintegration; it is created at migration start and destroyed after
//! the merge.

use std::collections::HashMap;

/// One mapping entry. `None` encodes the paper's "null" column: an object
/// that does not (yet) have a counterpart on that side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MapEntry {
    pub mid: Option<u64>,
    pub cid: Option<u64>,
}

/// MID <-> CID mapping table.
#[derive(Debug, Clone, Default)]
pub struct MappingTable {
    entries: Vec<MapEntry>,
    by_mid: HashMap<u64, usize>,
    by_cid: HashMap<u64, usize>,
}

impl MappingTable {
    pub fn new() -> MappingTable {
        MappingTable::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert an entry; panics (debug) on duplicate non-null keys.
    pub fn insert(&mut self, mid: Option<u64>, cid: Option<u64>) -> usize {
        let idx = self.entries.len();
        self.entries.push(MapEntry { mid, cid });
        if let Some(m) = mid {
            debug_assert!(!self.by_mid.contains_key(&m), "duplicate MID {m}");
            self.by_mid.insert(m, idx);
        }
        if let Some(c) = cid {
            debug_assert!(!self.by_cid.contains_key(&c), "duplicate CID {c}");
            self.by_cid.insert(c, idx);
        }
        idx
    }

    /// Fill the CID of the entry holding `mid` (clone-side instantiation:
    /// "the clone recreates all the objects with null CIDs, assigning
    /// valid fresh CIDs").
    pub fn assign_cid(&mut self, mid: u64, cid: u64) {
        if let Some(&idx) = self.by_mid.get(&mid) {
            self.entries[idx].cid = Some(cid);
            self.by_cid.insert(cid, idx);
        }
    }

    pub fn mid_for_cid(&self, cid: u64) -> Option<u64> {
        self.by_cid.get(&cid).and_then(|&i| self.entries[i].mid)
    }

    pub fn cid_for_mid(&self, mid: u64) -> Option<u64> {
        self.by_mid.get(&mid).and_then(|&i| self.entries[i].cid)
    }

    pub fn contains_cid(&self, cid: u64) -> bool {
        self.by_cid.contains_key(&cid)
    }

    pub fn contains_mid(&self, mid: u64) -> bool {
        self.by_mid.contains_key(&mid)
    }

    pub fn entries(&self) -> &[MapEntry] {
        &self.entries
    }

    /// Every non-null CID in the table. Slot GC roots these: a baseline
    /// member must survive collection however unreachable it looks,
    /// because a future delta may address it with a `Base` reference.
    pub fn cids(&self) -> impl Iterator<Item = u64> + '_ {
        self.entries.iter().filter_map(|e| e.cid)
    }

    /// Drop the entries holding the given MIDs (the delta path's
    /// `deleted` list: baseline members that died on the other side).
    /// Returns the number of entries removed.
    pub fn remove_mids(&mut self, mids: &[u64]) -> usize {
        if mids.is_empty() {
            return 0;
        }
        let doomed: std::collections::HashSet<u64> = mids.iter().copied().collect();
        let before = self.entries.len();
        self.entries
            .retain(|e| !matches!(e.mid, Some(m) if doomed.contains(&m)));
        self.rebuild_index();
        before - self.entries.len()
    }

    fn rebuild_index(&mut self) {
        self.by_mid.clear();
        self.by_cid.clear();
        for (i, e) in self.entries.iter().enumerate() {
            if let Some(m) = e.mid {
                self.by_mid.insert(m, i);
            }
            if let Some(c) = e.cid {
                self.by_cid.insert(c, i);
            }
        }
    }

    /// Drop entries whose CID is not in `returning` — objects from the
    /// original thread that died at the clone ("entries in the table
    /// whose CID does not appear in captured objects are deleted").
    /// Returns the number dropped.
    pub fn retain_cids(&mut self, returning: &HashMap<u64, ()>) -> usize {
        let before = self.entries.len();
        self.entries
            .retain(|e| matches!(e.cid, Some(c) if returning.contains_key(&c)));
        self.rebuild_index();
        before - self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reproduce the paper's Figure 8 scenario end to end.
    #[test]
    fn figure8_scenario() {
        // Initial migration: objects with MIDs 1, 2, 3 captured.
        let mut t = MappingTable::new();
        t.insert(Some(1), None);
        t.insert(Some(2), None);
        t.insert(Some(3), None);

        // At the clone, fresh CIDs 11, 12, 13 are assigned.
        t.assign_cid(1, 11);
        t.assign_cid(2, 12);
        t.assign_cid(3, 13);
        assert_eq!(t.cid_for_mid(2), Some(12));

        // Thread returns: captured clone objects are CIDs 11, 13 (object
        // with CID 12 died), plus new objects CIDs 14, 15 (address of the
        // dead object may have been reused — but its *ID* cannot be).
        let returning: HashMap<u64, ()> =
            [(11, ()), (13, ()), (14, ()), (15, ())].into_iter().collect();
        let dropped = t.retain_cids(&returning);
        assert_eq!(dropped, 1, "the dead object's entry is deleted");
        assert_eq!(t.mid_for_cid(11), Some(1));
        assert_eq!(t.mid_for_cid(13), Some(3));
        assert_eq!(t.mid_for_cid(12), None);

        // New clone objects get entries with null MID.
        for cid in [14u64, 15] {
            if !t.contains_cid(cid) {
                t.insert(None, Some(cid));
            }
        }
        assert_eq!(t.len(), 4);
        // Back at the mobile device: null-MID entries become fresh
        // objects; non-null MIDs are overwritten with returned state.
        let fresh: Vec<_> = t.entries().iter().filter(|e| e.mid.is_none()).collect();
        assert_eq!(fresh.len(), 2);
    }

    #[test]
    fn lookups_roundtrip() {
        let mut t = MappingTable::new();
        t.insert(Some(5), Some(50));
        assert_eq!(t.mid_for_cid(50), Some(5));
        assert_eq!(t.cid_for_mid(5), Some(50));
        assert_eq!(t.cid_for_mid(6), None);
    }
}
