//! Resume and merge (paper §4.2).
//!
//! Forward direction: overlay a captured thread context onto a clean
//! clone process — allocate every shipped object (assigning fresh CIDs
//! into the mapping table), patch references, rebuild the stack frames,
//! mark the thread runnable.
//!
//! Reverse direction: *merge* the returned context into the original
//! process — overwrite objects with non-null MIDs, create objects with
//! null MIDs, leave orphans to the garbage collector.

use crate::appvm::bytecode::ClassId;
use crate::appvm::process::Process;
use crate::appvm::thread::{Frame, ThreadStatus, VmThread};
use crate::appvm::value::{ObjBody, ObjId, Object, Value};
use crate::error::{CloneCloudError, Result};

use super::format::{CapturePacket, Direction, WireBody, WireValue};
use super::mapping::MappingTable;
use super::zygote_diff::ZygoteIndex;

/// Merge statistics.
#[derive(Debug, Clone, Default)]
pub struct MergeStats {
    /// Objects freshly created on this side.
    pub created: usize,
    /// Objects overwritten in place (non-null mapped id / Zygote name).
    pub overwritten: usize,
}

/// Resolve the local object id each wire object lands on, allocating
/// placeholders for fresh objects. Returns slot -> local id.
fn place_objects(
    p: &mut Process,
    packet: &CapturePacket,
    zidx: &ZygoteIndex,
    use_mapped: bool,
    stats: &mut MergeStats,
) -> Result<Vec<ObjId>> {
    let mut locals = Vec::with_capacity(packet.objects.len());
    for wo in &packet.objects {
        let class = p
            .program
            .class_id(&wo.class_name)
            .ok_or_else(|| {
                CloneCloudError::migration(format!("unknown class '{}'", wo.class_name))
            })?;
        let local = if let Some(seq) = wo.zygote_seq {
            // Dirty Zygote object: overwrite the local template twin.
            stats.overwritten += 1;
            zidx.lookup(&wo.class_name, seq)?
        } else if use_mapped && wo.mapped_id != 0 {
            // Reverse direction, known MID: overwrite in place.
            let id = ObjId(wo.mapped_id);
            if !p.heap.contains(id) {
                return Err(CloneCloudError::migration(format!(
                    "returned object maps to dead local id {}",
                    wo.mapped_id
                )));
            }
            stats.overwritten += 1;
            id
        } else {
            stats.created += 1;
            p.heap.alloc(Object {
                class,
                body: ObjBody::Fields(Vec::new()), // placeholder
                zygote_seq: None,
                dirty: true,
            })
        };
        locals.push(local);
    }
    Ok(locals)
}

fn make_value_resolver<'a>(
    locals: &'a [ObjId],
    zlocal: &'a [ObjId],
) -> impl Fn(&WireValue) -> Result<Value> + 'a {
    move |v: &WireValue| -> Result<Value> {
        Ok(match v {
            WireValue::Null => Value::Null,
            WireValue::Int(x) => Value::Int(*x),
            WireValue::Float(x) => Value::Float(*x),
            WireValue::Slot(s) => Value::Ref(*locals.get(*s as usize).ok_or_else(|| {
                CloneCloudError::migration(format!("slot {s} out of range"))
            })?),
            WireValue::Zygote(z) => Value::Ref(*zlocal.get(*z as usize).ok_or_else(|| {
                CloneCloudError::migration(format!("zygote ref {z} out of range"))
            })?),
        })
    }
}

/// Fill object bodies + statics + build frames from a packet. Shared by
/// both directions once placement is done.
fn apply_packet(
    p: &mut Process,
    packet: &CapturePacket,
    locals: &[ObjId],
    zlocal: &[ObjId],
) -> Result<Vec<Frame>> {
    let resolve = make_value_resolver(locals, zlocal);

    // Object bodies.
    for (wo, &local) in packet.objects.iter().zip(locals) {
        let body = match &wo.body {
            WireBody::Fields(vs) => {
                ObjBody::Fields(vs.iter().map(&resolve).collect::<Result<Vec<_>>>()?)
            }
            WireBody::ByteArray(b) => ObjBody::ByteArray(b.clone()),
            WireBody::FloatArray(f) => ObjBody::FloatArray(f.clone()),
            WireBody::RefArray(vs) => {
                ObjBody::RefArray(vs.iter().map(&resolve).collect::<Result<Vec<_>>>()?)
            }
        };
        p.heap.get_mut(local)?.body = body;
    }

    // Statics.
    for ws in &packet.statics {
        let cid: ClassId = p.program.class_id(&ws.class_name).ok_or_else(|| {
            CloneCloudError::migration(format!("unknown class '{}'", ws.class_name))
        })?;
        let v = resolve(&ws.value)?;
        let slot = p
            .statics
            .get_mut(cid.0 as usize)
            .and_then(|s| s.get_mut(ws.idx as usize))
            .ok_or_else(|| CloneCloudError::migration("static index out of range"))?;
        *slot = v;
    }

    // Frames.
    let mut frames = Vec::with_capacity(packet.frames.len());
    for wf in &packet.frames {
        let mref = p.program.resolve(&wf.class_name, &wf.method_name)?;
        let mut frame = Frame::new(
            mref,
            p.program.method(mref).nregs.max(wf.regs.len()),
            if wf.ret_reg_plus1 == 0 {
                None
            } else {
                Some(wf.ret_reg_plus1 - 1)
            },
        );
        for (i, rv) in wf.regs.iter().enumerate() {
            frame.regs[i] = resolve(rv)?;
        }
        frame.pc = wf.pc as usize;
        frames.push(frame);
    }
    Ok(frames)
}

fn resolve_zygote_locals(packet: &CapturePacket, zidx: &ZygoteIndex) -> Result<Vec<ObjId>> {
    packet
        .zygote_refs
        .iter()
        .map(|(name, seq)| zidx.lookup(name, *seq))
        .collect()
}

/// Forward direction: instantiate a migrated thread in a clone process.
/// Returns the new thread id and the clone-side mapping table.
pub fn instantiate_at_clone(
    clone: &mut Process,
    packet: &CapturePacket,
    zidx: &ZygoteIndex,
) -> Result<(u32, MappingTable, MergeStats)> {
    if packet.direction != Direction::Forward {
        return Err(CloneCloudError::migration("expected a forward capture"));
    }
    let mut stats = MergeStats::default();
    let zlocal = resolve_zygote_locals(packet, zidx)?;
    let locals = place_objects(clone, packet, zidx, false, &mut stats)?;

    // Build the mapping table: MID (origin) -> freshly assigned CID.
    let mut table = MappingTable::new();
    for (wo, &local) in packet.objects.iter().zip(&locals) {
        table.insert(Some(wo.origin_id), Some(local.0));
    }

    let frames = apply_packet(clone, packet, &locals, &zlocal)?;
    let tid = clone.threads.len() as u32;
    let mut t = VmThread::new(tid);
    t.frames = frames;
    t.status = ThreadStatus::Runnable;
    clone.threads.push(t);
    clone.clock.advance_to_us(packet.clock_us);
    Ok((tid, table, stats))
}

/// Reverse direction: merge a returned thread context back into the
/// original process, updating thread `tid` in place. Orphaned objects
/// (migrated out, died at the clone) become unreachable and are left for
/// the garbage collector (§4.2).
pub fn merge_at_mobile(
    p: &mut Process,
    tid: u32,
    packet: &CapturePacket,
    zidx: &ZygoteIndex,
) -> Result<MergeStats> {
    if packet.direction != Direction::Reverse {
        return Err(CloneCloudError::migration("expected a reverse capture"));
    }
    let mut stats = MergeStats::default();
    let zlocal = resolve_zygote_locals(packet, zidx)?;
    let locals = place_objects(p, packet, zidx, true, &mut stats)?;
    let frames = apply_packet(p, packet, &locals, &zlocal)?;

    let t = p.thread_mut(tid)?;
    t.frames = frames;
    t.status = ThreadStatus::Runnable;
    t.suspend_count = 0;
    p.clock.advance_to_us(packet.clock_us);
    Ok(stats)
}

/// Capture-local object count validator used in tests: every Slot in the
/// packet must be within range.
pub fn validate_packet(packet: &CapturePacket) -> Result<()> {
    let n = packet.objects.len() as u32;
    let nz = packet.zygote_refs.len() as u32;
    let chk = |v: &WireValue| -> Result<()> {
        match v {
            WireValue::Slot(s) if *s >= n => {
                Err(CloneCloudError::migration(format!("slot {s} >= {n}")))
            }
            WireValue::Zygote(z) if *z >= nz => {
                Err(CloneCloudError::migration(format!("zygote {z} >= {nz}")))
            }
            _ => Ok(()),
        }
    };
    for f in &packet.frames {
        for v in &f.regs {
            chk(v)?;
        }
    }
    for o in &packet.objects {
        match &o.body {
            WireBody::Fields(vs) | WireBody::RefArray(vs) => {
                for v in vs {
                    chk(v)?;
                }
            }
            _ => {}
        }
    }
    for s in &packet.statics {
        chk(&s.value)?;
    }
    Ok(())
}
