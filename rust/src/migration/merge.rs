//! Resume and merge (paper §4.2).
//!
//! Forward direction: overlay a captured thread context onto a clean
//! clone process — allocate every shipped object (assigning fresh CIDs
//! into the mapping table), patch references, rebuild the stack frames,
//! mark the thread runnable.
//!
//! Reverse direction: *merge* the returned context into the original
//! process — overwrite objects with non-null MIDs, create objects with
//! null MIDs, leave orphans to the garbage collector.
//!
//! Delta capsules reuse the same machinery (see [`super::delta`]): the
//! value resolver additionally understands [`WireValue::Base`] references
//! to session-baseline objects the receiver already holds — resolved
//! through the persistent mapping table at the clone, or directly by MID
//! at the mobile device.

use crate::appvm::bytecode::ClassId;
use crate::appvm::process::Process;
use crate::appvm::thread::{Frame, ThreadStatus, VmThread};
use crate::appvm::value::{ObjBody, ObjId, Object, Value};
use crate::error::{CloneCloudError, Result};

use super::format::{CapturePacket, Direction, WireBody, WireFrame, WireObject, WireStatic, WireValue};
use super::mapping::MappingTable;
use super::zygote_diff::ZygoteIndex;

/// Merge statistics.
#[derive(Debug, Clone, Default)]
pub struct MergeStats {
    /// Objects freshly created on this side.
    pub created: usize,
    /// Objects overwritten in place (non-null mapped id / Zygote name /
    /// session baseline).
    pub overwritten: usize,
}

/// How [`WireValue::Base`] references resolve on the receiving side.
pub(crate) enum BaseResolve<'a> {
    /// Full packets never carry `Base`; treat one as corruption.
    Reject,
    /// Clone side: resolve MID -> CID through the session mapping table.
    Table(&'a MappingTable),
    /// Mobile side: the MID *is* the local id (validated by the caller
    /// against the live heap before resolution).
    Local,
}

/// Resolve the local object id each wire object lands on, allocating
/// placeholders for fresh objects. Returns slot -> local id.
fn place_objects(
    p: &mut Process,
    packet: &CapturePacket,
    zidx: &ZygoteIndex,
    use_mapped: bool,
    stats: &mut MergeStats,
) -> Result<Vec<ObjId>> {
    let mut locals = Vec::with_capacity(packet.objects.len());
    for wo in &packet.objects {
        let class = p
            .program
            .class_id(&wo.class_name)
            .ok_or_else(|| {
                CloneCloudError::migration(format!("unknown class '{}'", wo.class_name))
            })?;
        let local = if let Some(seq) = wo.zygote_seq {
            // Dirty Zygote object: overwrite the local template twin.
            stats.overwritten += 1;
            zidx.lookup(&wo.class_name, seq)?
        } else if use_mapped && wo.mapped_id != 0 {
            // Reverse direction, known MID: overwrite in place.
            let id = ObjId(wo.mapped_id);
            if !p.heap.contains(id) {
                return Err(CloneCloudError::migration(format!(
                    "returned object maps to dead local id {}",
                    wo.mapped_id
                )));
            }
            stats.overwritten += 1;
            id
        } else {
            stats.created += 1;
            p.heap.alloc(placeholder(class))
        };
        locals.push(local);
    }
    Ok(locals)
}

/// A placeholder object for a slot whose body is filled in a second pass.
pub(crate) fn placeholder(class: ClassId) -> Object {
    Object {
        class,
        body: ObjBody::Fields(Vec::new()),
        zygote_seq: None,
        dirty: true,
        epoch: 0, // stamped by `Heap::alloc`
    }
}

pub(crate) fn make_value_resolver<'a>(
    locals: &'a [ObjId],
    zlocal: &'a [ObjId],
    base: BaseResolve<'a>,
) -> impl Fn(&WireValue) -> Result<Value> + 'a {
    move |v: &WireValue| -> Result<Value> {
        Ok(match v {
            WireValue::Null => Value::Null,
            WireValue::Int(x) => Value::Int(*x),
            WireValue::Float(x) => Value::Float(*x),
            WireValue::Slot(s) => Value::Ref(*locals.get(*s as usize).ok_or_else(|| {
                CloneCloudError::migration(format!("slot {s} out of range"))
            })?),
            WireValue::Zygote(z) => Value::Ref(*zlocal.get(*z as usize).ok_or_else(|| {
                CloneCloudError::migration(format!("zygote ref {z} out of range"))
            })?),
            WireValue::Base(mid) => match &base {
                BaseResolve::Reject => {
                    return Err(CloneCloudError::migration(
                        "baseline reference in a full capture",
                    ))
                }
                BaseResolve::Table(t) => {
                    Value::Ref(ObjId(t.cid_for_mid(*mid).ok_or_else(|| {
                        CloneCloudError::migration(format!(
                            "baseline object {mid} missing from the session table"
                        ))
                    })?))
                }
                BaseResolve::Local => Value::Ref(ObjId(*mid)),
            },
        })
    }
}

/// Fill object bodies + statics + build frames. Shared by the full and
/// delta paths once placement is done.
pub(crate) fn apply_sections(
    p: &mut Process,
    frames_in: &[WireFrame],
    objects: &[WireObject],
    statics: &[WireStatic],
    locals: &[ObjId],
    zlocal: &[ObjId],
    base: BaseResolve<'_>,
) -> Result<Vec<Frame>> {
    let resolve = make_value_resolver(locals, zlocal, base);

    // Object bodies.
    for (wo, &local) in objects.iter().zip(locals) {
        let body = match &wo.body {
            WireBody::Fields(vs) => {
                ObjBody::Fields(vs.iter().map(&resolve).collect::<Result<Vec<_>>>()?)
            }
            WireBody::ByteArray(b) => ObjBody::ByteArray(b.clone()),
            WireBody::FloatArray(f) => ObjBody::FloatArray(f.clone()),
            WireBody::RefArray(vs) => {
                ObjBody::RefArray(vs.iter().map(&resolve).collect::<Result<Vec<_>>>()?)
            }
        };
        p.heap.get_mut(local)?.body = body;
    }

    // Statics — through the write barrier, so the applied slots carry
    // the receiver's current epoch and count as clean after the
    // post-merge baseline is recorded (exactly like object bodies, which
    // are stamped by `Heap::get_mut` above).
    for ws in statics {
        let cid: ClassId = p.program.class_id(&ws.class_name).ok_or_else(|| {
            CloneCloudError::migration(format!("unknown class '{}'", ws.class_name))
        })?;
        let v = resolve(&ws.value)?;
        p.put_static(cid.0 as usize, ws.idx as usize, v)
            .map_err(|_| CloneCloudError::migration("static index out of range"))?;
    }

    // Frames.
    let mut frames = Vec::with_capacity(frames_in.len());
    for wf in frames_in {
        let mref = p.program.resolve(&wf.class_name, &wf.method_name)?;
        let mut frame = Frame::new(
            mref,
            p.program.method(mref).nregs.max(wf.regs.len()),
            if wf.ret_reg_plus1 == 0 {
                None
            } else {
                Some(wf.ret_reg_plus1 - 1)
            },
        );
        for (i, rv) in wf.regs.iter().enumerate() {
            frame.regs[i] = resolve(rv)?;
        }
        frame.pc = wf.pc as usize;
        frames.push(frame);
    }
    Ok(frames)
}

pub(crate) fn resolve_zygote_locals(
    zygote_refs: &[(String, u32)],
    zidx: &ZygoteIndex,
) -> Result<Vec<ObjId>> {
    zygote_refs
        .iter()
        .map(|(name, seq)| zidx.lookup(name, *seq))
        .collect()
}

/// Forward direction: instantiate a migrated thread in a clone process.
/// Returns the new thread id and the clone-side mapping table.
pub fn instantiate_at_clone(
    clone: &mut Process,
    packet: &CapturePacket,
    zidx: &ZygoteIndex,
) -> Result<(u32, MappingTable, MergeStats)> {
    if packet.direction != Direction::Forward {
        return Err(CloneCloudError::migration("expected a forward capture"));
    }
    // Full packets imply null statics instead of shipping them; clear
    // whatever a previous session left in this (possibly reused) slot.
    clone.reset_app_statics();
    let mut stats = MergeStats::default();
    let zlocal = resolve_zygote_locals(&packet.zygote_refs, zidx)?;
    let locals = place_objects(clone, packet, zidx, false, &mut stats)?;

    // Build the mapping table: MID (origin) -> freshly assigned CID.
    let mut table = MappingTable::new();
    for (wo, &local) in packet.objects.iter().zip(&locals) {
        table.insert(Some(wo.origin_id), Some(local.0));
    }

    let frames = apply_sections(
        clone,
        &packet.frames,
        &packet.objects,
        &packet.statics,
        &locals,
        &zlocal,
        BaseResolve::Reject,
    )?;
    let tid = clone.threads.len() as u32;
    let mut t = VmThread::new(tid);
    t.frames = frames;
    t.status = ThreadStatus::Runnable;
    clone.threads.push(t);
    clone.clock.advance_to_us(packet.clock_us);
    Ok((tid, table, stats))
}

/// Reverse direction: merge a returned thread context back into the
/// original process, updating thread `tid` in place. Orphaned objects
/// (migrated out, died at the clone) become unreachable and are left for
/// the garbage collector (§4.2).
pub fn merge_at_mobile(
    p: &mut Process,
    tid: u32,
    packet: &CapturePacket,
    zidx: &ZygoteIndex,
) -> Result<MergeStats> {
    if packet.direction != Direction::Reverse {
        return Err(CloneCloudError::migration("expected a reverse capture"));
    }
    // Symmetric to the clone side: a full reverse capture carries the
    // clone's complete statics view with nulls implied, so stale
    // non-null slots here must not survive the merge.
    p.reset_app_statics();
    let mut stats = MergeStats::default();
    let zlocal = resolve_zygote_locals(&packet.zygote_refs, zidx)?;
    let locals = place_objects(p, packet, zidx, true, &mut stats)?;
    let frames = apply_sections(
        p,
        &packet.frames,
        &packet.objects,
        &packet.statics,
        &locals,
        &zlocal,
        BaseResolve::Reject,
    )?;

    let t = p.thread_mut(tid)?;
    t.frames = frames;
    t.status = ThreadStatus::Runnable;
    t.suspend_count = 0;
    p.clock.advance_to_us(packet.clock_us);
    Ok(stats)
}

/// Capture-local object count validator used in tests: every Slot in the
/// packet must be within range, and a full packet may not carry baseline
/// references.
pub fn validate_packet(packet: &CapturePacket) -> Result<()> {
    let n = packet.objects.len() as u32;
    let nz = packet.zygote_refs.len() as u32;
    let chk = |v: &WireValue| -> Result<()> {
        match v {
            WireValue::Slot(s) if *s >= n => {
                Err(CloneCloudError::migration(format!("slot {s} >= {n}")))
            }
            WireValue::Zygote(z) if *z >= nz => {
                Err(CloneCloudError::migration(format!("zygote {z} >= {nz}")))
            }
            WireValue::Base(m) => Err(CloneCloudError::migration(format!(
                "baseline reference {m} in a full capture"
            ))),
            _ => Ok(()),
        }
    };
    for f in &packet.frames {
        for v in &f.regs {
            chk(v)?;
        }
    }
    for o in &packet.objects {
        match &o.body {
            WireBody::Fields(vs) | WireBody::RefArray(vs) => {
                for v in vs {
                    chk(v)?;
                }
            }
            _ => {}
        }
    }
    for s in &packet.statics {
        chk(&s.value)?;
    }
    Ok(())
}
