//! hprof-like portable capture format (paper §4.1, §5).
//!
//! The prototype extends Android's hprof heap-dump format; this module is
//! the equivalent: a self-contained binary encoding of a captured thread.
//! Portability rules from the paper:
//!
//! * all scalars in **network byte order** (`util::bytes`);
//! * stack frames name their method by **class + method name**, never a
//!   native code pointer;
//! * object references are **capture-local slots** (or Zygote
//!   (class, seq) names, or — in delta capsules — session-baseline ids),
//!   never addresses;
//! * every object carries its origin-VM object id (MID or CID) plus, when
//!   known, its id on the receiving VM — the wire form of the mapping
//!   table columns.
//!
//! The section encoders/decoders (string table, frames, objects, zygote
//! refs, statics) are shared with the incremental capsule format in
//! [`super::delta`]: a delta capsule is the same sections under a
//! different header, restricted to the objects that changed since the
//! negotiated baseline epoch.

use crate::error::{CloneCloudError, Result};
use crate::util::bytes::{WireReader, WireWriter};

/// Magic + version for the capture format ("CCHP" = CloneCloud hprof).
/// v2 interns class/method names in a string table: a 40k-object Zygote
/// capture repeats a handful of class names tens of thousands of times,
/// and naming them by index cut encoded captures ~40% (§Perf P1).
pub(crate) const MAGIC: u32 = 0x4343_4850;
const VERSION: u16 = 2;

/// Build-side string interner.
#[derive(Default)]
pub(crate) struct Strings {
    table: Vec<String>,
    index: std::collections::HashMap<String, u32>,
}

impl Strings {
    pub(crate) fn intern(&mut self, s: &str) -> u32 {
        if let Some(&i) = self.index.get(s) {
            return i;
        }
        let i = self.table.len() as u32;
        self.table.push(s.to_string());
        self.index.insert(s.to_string(), i);
        i
    }
}

// ---------------------------------------------------------------------------
// Session-lifetime string dictionary
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

fn fnv_str(mut h: u64, s: &str) -> u64 {
    for &b in (s.len() as u32)
        .to_be_bytes()
        .iter()
        .chain(s.as_bytes().iter())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// A session-lifetime string dictionary, negotiated via the Hello
/// capability bit `CAP_SESSION_DICT`. The per-capsule string table
/// re-learns the same class/method names every capsule; a dict-mode
/// capsule instead ships only the dictionary *additions* plus indices
/// into the shared prefix, guarded by a rolling digest of that prefix.
/// A digest mismatch is answered with the typed `NeedFull` signal and
/// **both** endpoints reset to the empty dictionary — mismatch degrades
/// to a re-seeded (or inline-table) capsule, never to corruption.
#[derive(Debug, Clone)]
pub struct SessionDict {
    entries: Vec<String>,
    index: std::collections::HashMap<String, u32>,
    digest: u64,
    /// Strings resolved from pre-existing entries — names a per-capsule
    /// table would have re-shipped.
    pub hits: u64,
    /// Bytes those hits would have cost in a per-capsule table
    /// (length prefix + payload).
    pub hit_bytes: u64,
    /// Entries appended over the session's lifetime (monotonic across
    /// resets).
    pub additions: u64,
    /// Digest-mismatch resets this replica has been through.
    pub resets: u64,
}

impl Default for SessionDict {
    fn default() -> Self {
        SessionDict::new()
    }
}

impl SessionDict {
    pub fn new() -> SessionDict {
        SessionDict {
            entries: Vec::new(),
            index: std::collections::HashMap::new(),
            digest: FNV_OFFSET,
            hits: 0,
            hit_bytes: 0,
            additions: 0,
            resets: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Rolling digest of the entry list (order-sensitive). Two replicas
    /// with equal digests decode each other's indices identically.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// Drop every entry (digest-mismatch recovery). The usage counters
    /// survive — they meter the session, not the current prefix.
    pub fn reset(&mut self) {
        self.entries.clear();
        self.index.clear();
        self.digest = FNV_OFFSET;
        self.resets += 1;
    }

    fn push(&mut self, s: String) -> u32 {
        let i = self.entries.len() as u32;
        self.digest = fnv_str(self.digest, &s);
        self.index.insert(s.clone(), i);
        self.entries.push(s);
        self.additions += 1;
        i
    }

    fn lookup(&self, i: u32) -> Result<String> {
        self.entries.get(i as usize).cloned().ok_or_else(|| {
            CloneCloudError::Wire(format!("dictionary index {i} out of range"))
        })
    }
}

/// How a capsule's sections are encoded with respect to the session
/// dictionary. `Off` is the pre-dict wire layout (no mode byte) — the
/// only legal choice when the Hello negotiation did not land on
/// `CAP_SESSION_DICT`. On a dict-negotiated channel every capsule leads
/// its sections with a self-describing mode byte: `Inline` (0) carries
/// the classic per-capsule table, `Shared` (1) the dictionary form.
pub enum DictMode<'a> {
    Off,
    Inline,
    Shared(&'a mut SessionDict),
}

/// Decode-side counterpart of [`DictMode`]: `Off` expects the pre-dict
/// layout; `Negotiated` expects the mode byte and can decode either
/// per-capsule form against the given replica.
pub enum DictRead<'a> {
    Off,
    Negotiated(&'a mut SessionDict),
}

/// Migration direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Mobile -> clone (migration).
    Forward,
    /// Clone -> mobile (reintegration).
    Reverse,
}

/// A value on the wire. References are capture slots, Zygote names, or —
/// in delta capsules — ids of objects the receiver already holds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireValue {
    Null,
    Int(i64),
    Float(f64),
    /// Index into `CapturePacket::objects`.
    Slot(u32),
    /// Index into `CapturePacket::zygote_refs` (a clean template object,
    /// not shipped — §4.3).
    Zygote(u32),
    /// A session-baseline object the receiver already holds, named by its
    /// mobile-side id (delta capsules only; full captures never emit it).
    Base(u64),
}

/// Object payload on the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum WireBody {
    Fields(Vec<WireValue>),
    ByteArray(Vec<u8>),
    FloatArray(Vec<f32>),
    RefArray(Vec<WireValue>),
}

/// One captured object.
#[derive(Debug, Clone, PartialEq)]
pub struct WireObject {
    /// Object id in the SENDER's VM (MID forward / CID reverse).
    pub origin_id: u64,
    /// Object id in the RECEIVER's VM if known (0 = none): reverse
    /// migration fills this with the MID from the mapping table so the
    /// mobile device knows which object to overwrite.
    pub mapped_id: u64,
    pub class_name: String,
    /// Set when this is a *dirty* Zygote object: the receiver overwrites
    /// its own (class, seq) template object instead of allocating.
    pub zygote_seq: Option<u32>,
    pub body: WireBody,
}

/// One captured stack frame.
#[derive(Debug, Clone, PartialEq)]
pub struct WireFrame {
    pub class_name: String,
    pub method_name: String,
    pub pc: u32,
    /// Caller return register + 1; 0 = none.
    pub ret_reg_plus1: u8,
    pub regs: Vec<WireValue>,
}

/// A captured static field.
#[derive(Debug, Clone, PartialEq)]
pub struct WireStatic {
    pub class_name: String,
    pub idx: u16,
    pub value: WireValue,
}

/// The thread-state sections every capsule flavor carries: frames, the
/// shipped objects, by-name Zygote references, and static fields.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WireSections {
    pub frames: Vec<WireFrame>,
    pub objects: Vec<WireObject>,
    /// Clean Zygote objects referenced by (class name, seq) only.
    pub zygote_refs: Vec<(String, u32)>,
    pub statics: Vec<WireStatic>,
}

/// Name indexes from the intern pass (pass 1), consumed by the shared
/// emission pass (pass 2).
struct NameIndexes {
    frames: Vec<(u32, u32)>,
    objects: Vec<u32>,
    zygotes: Vec<u32>,
    statics: Vec<u32>,
}

/// Pass 1: intern every name in a deterministic order, against whatever
/// backing store `intern` writes to (per-capsule table or session dict).
fn intern_names(
    frames: &[WireFrame],
    objects: &[WireObject],
    zygote_refs: &[(String, u32)],
    statics: &[WireStatic],
    mut intern: impl FnMut(&str) -> u32,
) -> NameIndexes {
    NameIndexes {
        frames: frames
            .iter()
            .map(|f| (intern(&f.class_name), intern(&f.method_name)))
            .collect(),
        objects: objects.iter().map(|o| intern(&o.class_name)).collect(),
        zygotes: zygote_refs.iter().map(|(name, _)| intern(name)).collect(),
        statics: statics.iter().map(|s| intern(&s.class_name)).collect(),
    }
}

/// Pass 2: emit every section with names replaced by their indexes.
fn emit_sections(
    w: &mut WireWriter,
    frames: &[WireFrame],
    objects: &[WireObject],
    zygote_refs: &[(String, u32)],
    statics: &[WireStatic],
    names: &NameIndexes,
) -> Result<()> {
    w.put_count(frames.len())?;
    for (f, &(cn, mn)) in frames.iter().zip(&names.frames) {
        w.put_u32(cn);
        w.put_u32(mn);
        w.put_u32(f.pc);
        w.put_u8(f.ret_reg_plus1);
        w.put_count(f.regs.len())?;
        for v in &f.regs {
            encode_value(w, v);
        }
    }

    w.put_count(objects.len())?;
    for (o, &cn) in objects.iter().zip(&names.objects) {
        w.put_u64(o.origin_id);
        w.put_u64(o.mapped_id);
        w.put_u32(cn);
        match o.zygote_seq {
            Some(s) => {
                w.put_u8(1);
                w.put_u32(s);
            }
            None => w.put_u8(0),
        }
        encode_body(w, &o.body)?;
    }

    w.put_count(zygote_refs.len())?;
    for ((_, seq), &cn) in zygote_refs.iter().zip(&names.zygotes) {
        w.put_u32(cn);
        w.put_u32(*seq);
    }

    w.put_count(statics.len())?;
    for (s, &cn) in statics.iter().zip(&names.statics) {
        w.put_u32(cn);
        w.put_u16(s.idx);
        encode_value(w, &s.value);
    }
    Ok(())
}

/// Encode the string table followed by every section (shared tail of
/// both the full and the delta capsule formats; pre-dict layout).
pub(crate) fn encode_sections(
    w: &mut WireWriter,
    frames: &[WireFrame],
    objects: &[WireObject],
    zygote_refs: &[(String, u32)],
    statics: &[WireStatic],
) -> Result<()> {
    let mut strings = Strings::default();
    let names = intern_names(frames, objects, zygote_refs, statics, |s| {
        strings.intern(s)
    });
    w.put_count(strings.table.len())?;
    for s in &strings.table {
        w.put_str(s);
    }
    emit_sections(w, frames, objects, zygote_refs, statics, &names)
}

/// Dict-aware section encoder. `Off` emits the pre-dict layout
/// byte-for-byte; the other modes prefix the self-describing mode byte
/// and either the classic table (`Inline`) or the dictionary header
/// (`Shared`: prefix digest + additions + indices into the grown dict).
pub(crate) fn encode_sections_with(
    w: &mut WireWriter,
    frames: &[WireFrame],
    objects: &[WireObject],
    zygote_refs: &[(String, u32)],
    statics: &[WireStatic],
    dict: DictMode<'_>,
) -> Result<()> {
    match dict {
        DictMode::Off => encode_sections(w, frames, objects, zygote_refs, statics),
        DictMode::Inline => {
            w.put_u8(0);
            encode_sections(w, frames, objects, zygote_refs, statics)
        }
        DictMode::Shared(d) => {
            w.put_u8(1);
            w.put_u64(d.digest());
            let mut additions: Vec<String> = Vec::new();
            let mut add_index: std::collections::HashMap<String, u32> =
                std::collections::HashMap::new();
            // A per-capsule table would have shipped each distinct name
            // once; meter the savings per distinct hit, not per use.
            let mut seen: std::collections::HashSet<u32> = std::collections::HashSet::new();
            let names = intern_names(frames, objects, zygote_refs, statics, |s| {
                if let Some(&i) = d.index.get(s) {
                    if seen.insert(i) {
                        d.hits += 1;
                        d.hit_bytes += 4 + s.len() as u64;
                    }
                    return i;
                }
                if let Some(&i) = add_index.get(s) {
                    return i;
                }
                let i = (d.entries.len() + additions.len()) as u32;
                add_index.insert(s.to_string(), i);
                additions.push(s.to_string());
                i
            });
            w.put_count(additions.len())?;
            for s in &additions {
                w.put_str(s);
            }
            // Absorb the additions so the next capsule's prefix digest
            // covers them (the receiver does the same on decode).
            for s in additions {
                d.push(s);
            }
            emit_sections(w, frames, objects, zygote_refs, statics, &names)
        }
    }
}

impl WireSections {
    /// Encode this section set (see [`encode_sections`]).
    pub(crate) fn encode_into(&self, w: &mut WireWriter) -> Result<()> {
        encode_sections(w, &self.frames, &self.objects, &self.zygote_refs, &self.statics)
    }

    /// Encode with an explicit dictionary mode (see
    /// [`encode_sections_with`]).
    pub(crate) fn encode_into_with(&self, w: &mut WireWriter, dict: DictMode<'_>) -> Result<()> {
        encode_sections_with(
            w,
            &self.frames,
            &self.objects,
            &self.zygote_refs,
            &self.statics,
            dict,
        )
    }

    /// Decode the string table + sections (shared tail; see
    /// `encode_into`). Does not check reader exhaustion — callers do.
    pub(crate) fn decode_from(r: &mut WireReader) -> Result<WireSections> {
        // Every section count is validated against the bytes actually
        // remaining (each entry has a fixed minimum wire size), so a
        // corrupt count can never force a huge pre-allocation.
        let nstrings = r.get_u32()? as usize;
        let nstrings = r.checked_count(nstrings, 4)?;
        let mut strings = Vec::with_capacity(nstrings);
        for _ in 0..nstrings {
            strings.push(r.get_str()?);
        }
        let lookup = |i: u32| -> Result<String> {
            strings
                .get(i as usize)
                .cloned()
                .ok_or_else(|| CloneCloudError::Wire(format!("string index {i} out of range")))
        };
        Self::decode_body_sections(r, &lookup)
    }

    /// Dict-aware decoder. Returns the sections plus whether the capsule
    /// rode the shared dictionary (`true` = mode 1), so receivers can
    /// answer in the same mode. A prefix-digest mismatch resets the
    /// local replica and degrades with the typed `NeedFull` signal —
    /// both ends then re-seed from the empty dictionary.
    pub(crate) fn decode_from_with(
        r: &mut WireReader,
        dict: DictRead<'_>,
    ) -> Result<(WireSections, bool)> {
        let d = match dict {
            DictRead::Off => return Ok((Self::decode_from(r)?, false)),
            DictRead::Negotiated(d) => d,
        };
        match r.get_u8()? {
            0 => Ok((Self::decode_from(r)?, false)),
            1 => {
                let digest = r.get_u64()?;
                if digest != d.digest() {
                    let local = d.digest();
                    d.reset();
                    return Err(CloneCloudError::need_full(format!(
                        "session dictionary digest mismatch (sender {digest:#x} != \
                         local {local:#x}) — replica reset, resend against the \
                         empty dictionary"
                    )));
                }
                let nadd = r.get_u32()? as usize;
                let nadd = r.checked_count(nadd, 4)?;
                // Additions are held back until the whole section tail
                // parses. Absorbing them eagerly would let a capsule
                // that dies halfway through its body leave the replica
                // holding entries the digest handshake never covered —
                // a hostile or corrupted capsule could silently fork
                // the replicas and poison every later digest check.
                let mut pending: Vec<String> = Vec::with_capacity(nadd);
                for _ in 0..nadd {
                    pending.push(r.get_str()?);
                }
                let base = d.len() as u32;
                let sections = {
                    let d = &*d;
                    let pending = &pending;
                    let lookup = |i: u32| -> Result<String> {
                        if i < base {
                            d.lookup(i)
                        } else {
                            pending.get((i - base) as usize).cloned().ok_or_else(|| {
                                CloneCloudError::Wire(format!(
                                    "dictionary index {i} out of range"
                                ))
                            })
                        }
                    };
                    Self::decode_body_sections(r, &lookup)?
                };
                // Absorb only when the capsule consumed its buffer
                // exactly: the sections are the final wire field of
                // both capsule flavors, so leftover bytes mean the
                // outer decoder is about to reject the capsule as
                // trailing garbage — its (possibly forged) additions
                // must not survive that rejection.
                if r.is_done() {
                    for s in pending {
                        d.push(s);
                    }
                }
                Ok((sections, true))
            }
            m => Err(CloneCloudError::Wire(format!("bad dictionary mode {m}"))),
        }
    }

    /// The section tail after the string store (table or dictionary).
    fn decode_body_sections(
        r: &mut WireReader,
        lookup: &dyn Fn(u32) -> Result<String>,
    ) -> Result<WireSections> {
        let nframes = r.get_u32()? as usize;
        let nframes = r.checked_count(nframes, 17)?;
        let mut frames = Vec::with_capacity(nframes);
        for _ in 0..nframes {
            let class_name = lookup(r.get_u32()?)?;
            let method_name = lookup(r.get_u32()?)?;
            let pc = r.get_u32()?;
            let ret_reg_plus1 = r.get_u8()?;
            let nregs = r.get_u32()? as usize;
            let nregs = r.checked_count(nregs, 1)?;
            let mut regs = Vec::with_capacity(nregs);
            for _ in 0..nregs {
                regs.push(decode_value(r)?);
            }
            frames.push(WireFrame {
                class_name,
                method_name,
                pc,
                ret_reg_plus1,
                regs,
            });
        }

        let nobjs = r.get_u32()? as usize;
        let nobjs = r.checked_count(nobjs, 22)?;
        let mut objects = Vec::with_capacity(nobjs);
        for _ in 0..nobjs {
            let origin_id = r.get_u64()?;
            let mapped_id = r.get_u64()?;
            let class_name = lookup(r.get_u32()?)?;
            let zygote_seq = if r.get_u8()? == 1 {
                Some(r.get_u32()?)
            } else {
                None
            };
            let body = decode_body(r)?;
            objects.push(WireObject {
                origin_id,
                mapped_id,
                class_name,
                zygote_seq,
                body,
            });
        }

        let nzy = r.get_u32()? as usize;
        let nzy = r.checked_count(nzy, 8)?;
        let mut zygote_refs = Vec::with_capacity(nzy);
        for _ in 0..nzy {
            let name = lookup(r.get_u32()?)?;
            let seq = r.get_u32()?;
            zygote_refs.push((name, seq));
        }

        let nst = r.get_u32()? as usize;
        let nst = r.checked_count(nst, 7)?;
        let mut statics = Vec::with_capacity(nst);
        for _ in 0..nst {
            let class_name = lookup(r.get_u32()?)?;
            let idx = r.get_u16()?;
            let value = decode_value(r)?;
            statics.push(WireStatic {
                class_name,
                idx,
                value,
            });
        }

        Ok(WireSections {
            frames,
            objects,
            zygote_refs,
            statics,
        })
    }
}

pub(crate) fn encode_direction(w: &mut WireWriter, d: Direction) {
    w.put_u8(match d {
        Direction::Forward => 0,
        Direction::Reverse => 1,
    });
}

pub(crate) fn decode_direction(r: &mut WireReader) -> Result<Direction> {
    match r.get_u8()? {
        0 => Ok(Direction::Forward),
        1 => Ok(Direction::Reverse),
        d => Err(CloneCloudError::Wire(format!("bad direction {d}"))),
    }
}

/// The full capture packet.
#[derive(Debug, Clone, PartialEq)]
pub struct CapturePacket {
    pub direction: Direction,
    pub thread_id: u32,
    /// Sender's virtual clock at capture (µs) — the receiver advances to
    /// this so time is consistent across the migration.
    pub clock_us: f64,
    pub frames: Vec<WireFrame>,
    pub objects: Vec<WireObject>,
    /// Clean Zygote objects referenced by (class name, seq) only.
    pub zygote_refs: Vec<(String, u32)>,
    pub statics: Vec<WireStatic>,
}

impl CapturePacket {
    /// Serialize to network-byte-order bytes. Class/method names are
    /// interned into a string table written up front. Fails only when a
    /// collection count cannot be represented on the wire (see
    /// [`WireWriter::put_count`]).
    pub fn encode(&self) -> Result<Vec<u8>> {
        self.encode_with(DictMode::Off)
    }

    /// Serialize under an explicit session-dictionary mode.
    pub fn encode_with(&self, dict: DictMode<'_>) -> Result<Vec<u8>> {
        let mut w = WireWriter::with_capacity(4096);
        self.encode_into_with(&mut w, dict)?;
        Ok(w.into_vec())
    }

    /// Serialize into an existing writer, so a session-lifetime scratch
    /// buffer can be reused across trips instead of growing a fresh
    /// vector from zero each time.
    pub fn encode_into_with(&self, w: &mut WireWriter, dict: DictMode<'_>) -> Result<()> {
        w.put_u32(MAGIC);
        w.put_u16(VERSION);
        encode_direction(w, self.direction);
        w.put_u32(self.thread_id);
        w.put_f64(self.clock_us);
        encode_sections_with(
            w,
            &self.frames,
            &self.objects,
            &self.zygote_refs,
            &self.statics,
            dict,
        )
    }

    /// Decode from bytes (pre-dict layout).
    pub fn decode(buf: &[u8]) -> Result<CapturePacket> {
        Ok(Self::decode_with(buf, DictRead::Off)?.0)
    }

    /// Decode under an explicit session-dictionary mode; the flag says
    /// whether the capsule rode the shared dictionary.
    pub fn decode_with(buf: &[u8], dict: DictRead<'_>) -> Result<(CapturePacket, bool)> {
        let mut r = WireReader::new(buf);
        let magic = r.get_u32()?;
        if magic != MAGIC {
            return Err(CloneCloudError::Wire(format!("bad magic {magic:#x}")));
        }
        let version = r.get_u16()?;
        if version != VERSION {
            return Err(CloneCloudError::Wire(format!("unsupported version {version}")));
        }
        let direction = decode_direction(&mut r)?;
        let thread_id = r.get_u32()?;
        let clock_us = r.get_f64()?;
        let (s, used_dict) = WireSections::decode_from_with(&mut r, dict)?;
        if !r.is_done() {
            return Err(CloneCloudError::Wire(format!(
                "{} trailing bytes",
                r.remaining()
            )));
        }
        Ok((
            CapturePacket {
                direction,
                thread_id,
                clock_us,
                frames: s.frames,
                objects: s.objects,
                zygote_refs: s.zygote_refs,
                statics: s.statics,
            },
            used_dict,
        ))
    }
}

pub(crate) fn encode_value(w: &mut WireWriter, v: &WireValue) {
    match v {
        WireValue::Null => w.put_u8(0),
        WireValue::Int(x) => {
            w.put_u8(1);
            w.put_i64(*x);
        }
        WireValue::Float(x) => {
            w.put_u8(2);
            w.put_f64(*x);
        }
        WireValue::Slot(s) => {
            w.put_u8(3);
            w.put_u32(*s);
        }
        WireValue::Zygote(z) => {
            w.put_u8(4);
            w.put_u32(*z);
        }
        WireValue::Base(m) => {
            w.put_u8(5);
            w.put_u64(*m);
        }
    }
}

pub(crate) fn decode_value(r: &mut WireReader) -> Result<WireValue> {
    Ok(match r.get_u8()? {
        0 => WireValue::Null,
        1 => WireValue::Int(r.get_i64()?),
        2 => WireValue::Float(r.get_f64()?),
        3 => WireValue::Slot(r.get_u32()?),
        4 => WireValue::Zygote(r.get_u32()?),
        5 => WireValue::Base(r.get_u64()?),
        t => return Err(CloneCloudError::Wire(format!("bad value tag {t}"))),
    })
}

fn encode_body(w: &mut WireWriter, b: &WireBody) -> Result<()> {
    match b {
        WireBody::Fields(vs) => {
            w.put_u8(0);
            w.put_count(vs.len())?;
            for v in vs {
                encode_value(w, v);
            }
        }
        WireBody::ByteArray(bytes) => {
            w.put_u8(1);
            w.put_bytes(bytes);
        }
        WireBody::FloatArray(fs) => {
            w.put_u8(2);
            w.put_count(fs.len())?;
            for f in fs {
                w.put_f32(*f);
            }
        }
        WireBody::RefArray(vs) => {
            w.put_u8(3);
            w.put_count(vs.len())?;
            for v in vs {
                encode_value(w, v);
            }
        }
    }
    Ok(())
}

fn decode_body(r: &mut WireReader) -> Result<WireBody> {
    Ok(match r.get_u8()? {
        0 => {
            let n = r.get_u32()? as usize;
            let n = r.checked_count(n, 1)?;
            let mut vs = Vec::with_capacity(n);
            for _ in 0..n {
                vs.push(decode_value(r)?);
            }
            WireBody::Fields(vs)
        }
        1 => WireBody::ByteArray(r.get_bytes()?),
        2 => {
            let n = r.get_u32()? as usize;
            let n = r.checked_count(n, 4)?;
            let mut fs = Vec::with_capacity(n);
            for _ in 0..n {
                fs.push(r.get_f32()?);
            }
            WireBody::FloatArray(fs)
        }
        3 => {
            let n = r.get_u32()? as usize;
            let n = r.checked_count(n, 1)?;
            let mut vs = Vec::with_capacity(n);
            for _ in 0..n {
                vs.push(decode_value(r)?);
            }
            WireBody::RefArray(vs)
        }
        t => return Err(CloneCloudError::Wire(format!("bad body tag {t}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample() -> CapturePacket {
        CapturePacket {
            direction: Direction::Forward,
            thread_id: 3,
            clock_us: 123.5,
            frames: vec![WireFrame {
                class_name: "App".into(),
                method_name: "scan".into(),
                pc: 17,
                ret_reg_plus1: 2,
                regs: vec![
                    WireValue::Null,
                    WireValue::Int(-9),
                    WireValue::Float(2.5),
                    WireValue::Slot(1),
                    WireValue::Zygote(0),
                ],
            }],
            objects: vec![
                WireObject {
                    origin_id: 42,
                    mapped_id: 0,
                    class_name: "App".into(),
                    zygote_seq: None,
                    body: WireBody::Fields(vec![WireValue::Slot(1), WireValue::Int(7)]),
                },
                WireObject {
                    origin_id: 43,
                    mapped_id: 7,
                    class_name: "[arr]".into(),
                    zygote_seq: Some(12),
                    body: WireBody::ByteArray(vec![1, 2, 3]),
                },
            ],
            zygote_refs: vec![("sys.String".into(), 99)],
            statics: vec![WireStatic {
                class_name: "App".into(),
                idx: 0,
                value: WireValue::Slot(0),
            }],
        }
    }

    #[test]
    fn roundtrip() {
        let p = sample();
        let bytes = p.encode().unwrap();
        let q = CapturePacket::decode(&bytes).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let p = sample();
        let mut bytes = p.encode().unwrap();
        bytes[0] ^= 0xFF;
        assert!(CapturePacket::decode(&bytes).is_err());
        let bytes2 = p.encode().unwrap();
        assert!(CapturePacket::decode(&bytes2[..bytes2.len() - 3]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = sample().encode().unwrap();
        bytes.push(0);
        assert!(CapturePacket::decode(&bytes).is_err());
    }

    #[test]
    fn wire_is_network_byte_order() {
        // MAGIC is the first u32, big-endian.
        let bytes = sample().encode().unwrap();
        assert_eq!(&bytes[..4], &[0x43, 0x43, 0x48, 0x50]);
    }

    #[test]
    fn float_arrays_roundtrip_precisely() {
        let mut p = sample();
        p.objects[1].body = WireBody::FloatArray(vec![1.5, -0.25, 3.0e-8]);
        let q = CapturePacket::decode(&p.encode().unwrap()).unwrap();
        assert_eq!(p.objects[1].body, q.objects[1].body);
    }

    // ---- property tests (mirroring the `Msg` prop suite) ---------------

    /// Generate an arbitrary wire value, including the delta-only `Base`
    /// kind so the codec is exercised beyond what full captures emit.
    pub(super) fn gen_value(rng: &mut Rng) -> WireValue {
        match rng.index(6) {
            0 => WireValue::Null,
            1 => WireValue::Int(rng.next_u64() as i64),
            2 => WireValue::Float(rng.range_i64(-1_000_000, 1_000_000) as f64 / 64.0),
            3 => WireValue::Slot(rng.next_u64() as u32),
            4 => WireValue::Zygote(rng.next_u64() as u32),
            _ => WireValue::Base(rng.next_u64()),
        }
    }

    fn gen_name(rng: &mut Rng) -> String {
        // Small pool so the string table sees real sharing, plus the
        // occasional unique (and non-ASCII) name.
        const POOL: &[&str] = &["App", "sys.String", "[arr]", "Работа", "x.y.Z"];
        if rng.chance(0.8) {
            POOL[rng.index(POOL.len())].to_string()
        } else {
            format!("C{}", rng.next_u64())
        }
    }

    fn gen_body(rng: &mut Rng) -> WireBody {
        match rng.index(4) {
            0 => WireBody::Fields((0..rng.index(6)).map(|_| gen_value(rng)).collect()),
            1 => {
                let mut b = vec![0u8; rng.index(512)];
                rng.fill_bytes(&mut b);
                WireBody::ByteArray(b)
            }
            2 => WireBody::FloatArray(
                (0..rng.index(64)).map(|_| rng.range_f32(-1e6, 1e6)).collect(),
            ),
            _ => WireBody::RefArray((0..rng.index(6)).map(|_| gen_value(rng)).collect()),
        }
    }

    /// Generate an arbitrary capture packet. The codec does not require
    /// semantic consistency (in-range slots etc.), so none is imposed —
    /// any structurally valid packet must round-trip.
    pub(super) fn gen_packet(rng: &mut Rng) -> CapturePacket {
        CapturePacket {
            direction: if rng.chance(0.5) {
                Direction::Forward
            } else {
                Direction::Reverse
            },
            thread_id: rng.next_u64() as u32,
            clock_us: rng.range_i64(0, 1 << 40) as f64 / 16.0,
            frames: (0..rng.index(4))
                .map(|_| WireFrame {
                    class_name: gen_name(rng),
                    method_name: gen_name(rng),
                    pc: rng.next_u64() as u32,
                    ret_reg_plus1: rng.byte(),
                    regs: (0..rng.index(8)).map(|_| gen_value(rng)).collect(),
                })
                .collect(),
            objects: (0..rng.index(8))
                .map(|_| WireObject {
                    origin_id: rng.next_u64(),
                    mapped_id: rng.next_u64(),
                    class_name: gen_name(rng),
                    zygote_seq: rng.chance(0.3).then(|| rng.next_u64() as u32),
                    body: gen_body(rng),
                })
                .collect(),
            zygote_refs: (0..rng.index(4))
                .map(|_| (gen_name(rng), rng.next_u64() as u32))
                .collect(),
            statics: (0..rng.index(4))
                .map(|_| WireStatic {
                    class_name: gen_name(rng),
                    idx: rng.next_u64() as u16,
                    value: gen_value(rng),
                })
                .collect(),
        }
    }

    #[test]
    fn prop_packets_roundtrip() {
        use crate::util::prop::{ensure_eq, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xCA97_0001,
                cases: 150,
            },
            gen_packet,
            |p| {
                let bytes = p.encode().map_err(|e| format!("encode failed: {e}"))?;
                let decoded = CapturePacket::decode(&bytes)
                    .map_err(|e| format!("decode failed: {e}"))?;
                ensure_eq(decoded, p.clone(), "decode(encode(p))")
            },
        );
    }

    #[test]
    fn prop_strict_prefixes_never_decode() {
        use crate::util::prop::{ensure, forall, PropConfig};
        // Every field is length-prefixed and decode demands exhaustion,
        // so any strict prefix of a valid encoding must be a clean error
        // (never a panic, never a silent partial parse).
        forall(
            PropConfig {
                seed: 0xCA97_0002,
                cases: 150,
            },
            |rng| {
                let bytes = gen_packet(rng).encode().unwrap();
                let cut = rng.index(bytes.len());
                (bytes, cut)
            },
            |(bytes, cut)| {
                ensure(
                    CapturePacket::decode(&bytes[..*cut]).is_err(),
                    "prefix decoded",
                )
            },
        );
    }

    #[test]
    fn prop_garbage_never_panics() {
        use crate::util::prop::{forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xCA97_0003,
                cases: 300,
            },
            |rng| {
                // Half the cases start from a valid header so the fuzz
                // reaches the section decoders, not just the magic check.
                let mut b = if rng.chance(0.5) {
                    let mut w = crate::util::bytes::WireWriter::new();
                    w.put_u32(MAGIC);
                    w.put_u16(2);
                    w.into_vec()
                } else {
                    Vec::new()
                };
                let mut tail = vec![0u8; rng.index(256)];
                rng.fill_bytes(&mut tail);
                b.extend_from_slice(&tail);
                b
            },
            |bytes| {
                let _ = CapturePacket::decode(bytes); // Ok or Err; no panic.
                Ok(())
            },
        );
    }

    // ---- session-dictionary codec (property suite) ----------------------

    /// A whole session's worth of capsules rides one sender/receiver
    /// dictionary pair: every capsule round-trips, the replicas' digests
    /// agree after every capsule, and repeated names stop being shipped
    /// (dictionary hits accumulate).
    #[test]
    fn prop_session_dict_roundtrips_and_stays_coherent() {
        use crate::util::prop::{ensure, ensure_eq, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xD1C7_0001,
                cases: 60,
            },
            |rng| (0..4).map(|_| gen_packet(rng)).collect::<Vec<_>>(),
            |packets| {
                let mut tx = SessionDict::new();
                let mut rx = SessionDict::new();
                for p in packets {
                    let bytes = p
                        .encode_with(DictMode::Shared(&mut tx))
                        .map_err(|e| format!("encode: {e}"))?;
                    let (q, used) = CapturePacket::decode_with(
                        &bytes,
                        DictRead::Negotiated(&mut rx),
                    )
                    .map_err(|e| format!("decode: {e}"))?;
                    ensure(used, "capsule rode the shared dictionary")?;
                    ensure_eq(q, p.clone(), "decode(encode(p))")?;
                    ensure_eq(rx.digest(), tx.digest(), "replica digests agree")?;
                    ensure_eq(rx.len(), tx.len(), "replica sizes agree")?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_dict_strict_prefixes_never_decode() {
        use crate::util::prop::{ensure, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xD1C7_0002,
                cases: 100,
            },
            |rng| {
                let mut tx = SessionDict::new();
                let bytes = gen_packet(rng).encode_with(DictMode::Shared(&mut tx)).unwrap();
                let cut = rng.index(bytes.len());
                (bytes, cut)
            },
            |(bytes, cut)| {
                let mut rx = SessionDict::new();
                ensure(
                    CapturePacket::decode_with(
                        &bytes[..*cut],
                        DictRead::Negotiated(&mut rx),
                    )
                    .is_err(),
                    "prefix decoded",
                )
            },
        );
    }

    #[test]
    fn prop_dict_garbage_never_panics() {
        use crate::util::prop::{forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xD1C7_0003,
                cases: 300,
            },
            |rng| {
                // Start from a valid header + a dictionary mode byte so
                // the fuzz reaches the dict header/additions parser.
                let mut w = crate::util::bytes::WireWriter::new();
                w.put_u32(MAGIC);
                w.put_u16(2);
                w.put_u8(0); // direction
                w.put_u32(0); // thread id
                w.put_f64(0.0); // clock
                w.put_u8(if rng.chance(0.5) { 1 } else { rng.byte() });
                let mut b = w.into_vec();
                let mut tail = vec![0u8; rng.index(256)];
                rng.fill_bytes(&mut tail);
                b.extend_from_slice(&tail);
                b
            },
            |bytes| {
                let mut rx = SessionDict::new();
                // Ok or Err both fine; no panic, whatever the dict state.
                let _ = CapturePacket::decode_with(bytes, DictRead::Negotiated(&mut rx));
                Ok(())
            },
        );
    }

    /// A diverged replica rejects with the typed `NeedFull`, resets
    /// itself to empty, and then accepts a resend encoded against the
    /// (also reset) sender dictionary — the fallback is a re-seed, never
    /// corruption.
    #[test]
    fn dict_digest_mismatch_degrades_to_reset_and_reseed() {
        let p = sample();
        let mut tx = SessionDict::new();
        // Warm the sender with a capsule the receiver never saw.
        let _lost = p.encode_with(DictMode::Shared(&mut tx)).unwrap();
        assert!(!tx.is_empty());

        let mut rx = SessionDict::new();
        let bytes = p.encode_with(DictMode::Shared(&mut tx)).unwrap();
        let err = CapturePacket::decode_with(&bytes, DictRead::Negotiated(&mut rx))
            .unwrap_err();
        assert!(err.is_need_full(), "typed NeedFull signal: {err}");
        assert!(rx.is_empty(), "replica reset on mismatch");
        assert_eq!(rx.resets, 1);

        // Both ends reset: the resend re-seeds and decodes cleanly.
        tx.reset();
        let bytes = p.encode_with(DictMode::Shared(&mut tx)).unwrap();
        let (q, used) =
            CapturePacket::decode_with(&bytes, DictRead::Negotiated(&mut rx)).unwrap();
        assert!(used);
        assert_eq!(q, p);
        assert_eq!(rx.digest(), tx.digest());
    }

    /// Mode 0 on a negotiated channel: the classic per-capsule table,
    /// self-describing, and the replica is untouched.
    #[test]
    fn dict_inline_mode_is_self_describing() {
        let p = sample();
        let bytes = p.encode_with(DictMode::Inline).unwrap();
        let mut rx = SessionDict::new();
        let (q, used) =
            CapturePacket::decode_with(&bytes, DictRead::Negotiated(&mut rx)).unwrap();
        assert!(!used, "inline capsules do not touch the dictionary");
        assert_eq!(q, p);
        assert!(rx.is_empty());
        // And the unnegotiated layout is byte-identical to the legacy
        // encoder (one mode byte shorter than Inline).
        assert_eq!(p.encode().unwrap(), p.encode_with(DictMode::Off).unwrap());
        assert_eq!(bytes.len(), p.encode().unwrap().len() + 1);
    }

    /// Dictionary hits meter what a per-capsule table would have
    /// re-shipped: a repeat capsule with no new names costs only the
    /// dict header, strictly less than its inline-table form.
    #[test]
    fn dict_repeat_capsules_beat_the_per_capsule_table() {
        let p = sample();
        let mut tx = SessionDict::new();
        let first = p.encode_with(DictMode::Shared(&mut tx)).unwrap();
        let hits_before = tx.hits;
        let second = p.encode_with(DictMode::Shared(&mut tx)).unwrap();
        assert!(tx.hits > hits_before, "repeat names hit the dictionary");
        assert!(tx.hit_bytes > 0);
        assert!(
            second.len() < p.encode_with(DictMode::Inline).unwrap().len(),
            "repeat capsule beats the inline table ({} vs {})",
            second.len(),
            p.encode_with(DictMode::Inline).unwrap().len()
        );
        assert!(second.len() < first.len(), "additions shipped only once");
    }
}
