//! Thread-granularity migration (paper §4) + epoch-based delta transfer.
//!
//! * [`capture`] — suspend-and-capture: frames + reachable heap + statics
//!   (full, or restricted to the dirty set for delta capsules — found by
//!   the per-page epoch scan, O(dirty pages), with the per-object
//!   traversal kept as the ablation baseline).
//! * [`format`] — hprof-like portable wire encoding (network byte order);
//!   section codecs shared by both capsule flavors, with an optional
//!   session-lifetime string dictionary ([`SessionDict`]) replacing the
//!   per-capsule table on negotiated channels.
//! * [`mapping`] — the MID/CID object-mapping table (Fig. 8), promoted to
//!   session lifetime by the delta pipeline.
//! * [`merge`] — clone-side instantiation and mobile-side state merge.
//! * [`delta`] — incremental capsules: per-session baseline caches,
//!   mutation-epoch dirty sets, digest-guarded `NeedFull` fallback.
//! * [`zygote_diff`] — the §4.3 transfer optimization.
//! * [`migrator`] — the per-process orchestration + cost accounting (both
//!   the classic full-packet API and the session-aware capsule API).

pub mod capture;
pub mod delta;
pub mod format;
pub mod mapping;
pub mod merge;
pub mod migrator;
pub mod zygote_diff;

pub use capture::{capture_thread, measure_state_size, CaptureOptions, CaptureStats};
pub use delta::{
    collect_slot_garbage, scatter_range, shard_capsule, Capsule, CloneSession, DeltaPacket,
    MobileSession, SlotGcStats, CAPSULE_CLOCK_OFFSET,
};
pub use format::{CapturePacket, DictMode, DictRead, Direction, SessionDict};
pub use mapping::MappingTable;
pub use merge::{instantiate_at_clone, merge_at_mobile, validate_packet, MergeStats};
pub use migrator::{MigrationPhases, Migrator};
pub use zygote_diff::ZygoteIndex;

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::interp::{run_thread, NoHooks, RunExit};
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::process::Process;
    use crate::appvm::value::{ObjBody, Value};
    use crate::appvm::zygote::build_template;
    use crate::appvm::Program;
    use crate::config::CostParams;
    use crate::device::{DeviceSpec, Location};
    use crate::vfs::SimFs;

    /// A program whose worker mutates state on both sides of a migration
    /// point: builds an array, migrates (ccstart), fills it remotely,
    /// reintegrates (ccstop), then sums it locally.
    const PROG: &str = r#"
class Work app
  static out
  method main nargs=0 regs=8
    const r0 64
    newarr r1 float r0
    invoke r2 Work.fill r1
    puts Work.out r2
    retv
  end
  method fill nargs=1 regs=8
    ccstart 0
    len r1 r0
    const r2 0
  loop:
    ifge r2 r1 @done
    i2f r3 r2
    aput r0 r2 r3
    const r4 1
    add r2 r2 r4
    goto @loop
  done:
    ccstop 0
    # sum it up
    const r2 0
    constf r5 0.0
  sum:
    ifge r2 r1 @end
    aget r3 r0 r2
    fadd r5 r5 r3
    const r4 1
    add r2 r2 r4
    goto @sum
  end:
    ret r5
  end
end
"#;

    fn make_proc(loc: Location, program: &Arc<Program>, zygote: usize) -> Process {
        let template = build_template(program, zygote, 99);
        let dev = match loc {
            Location::Mobile => DeviceSpec::phone_g1(),
            Location::Clone => DeviceSpec::clone_desktop(),
        };
        Process::fork_from_zygote(
            program.clone(),
            &template,
            dev,
            loc,
            NodeEnv::with_rust_compute(SimFs::new()),
        )
    }

    /// Full round trip: phone runs to ccstart, migrates, clone executes
    /// the body, returns at ccstop, phone merges and finishes. The final
    /// result must equal the monolithic run's.
    #[test]
    fn migration_roundtrip_preserves_semantics() {
        let program = Arc::new(assemble(PROG).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();

        // Monolithic reference run.
        let mut mono = make_proc(Location::Mobile, &program, 50);
        let main = program.entry().unwrap();
        let tid = mono.spawn_thread(main, &[]).unwrap();
        let mut exit = run_thread(&mut mono, tid, &mut NoHooks, 1_000_000).unwrap();
        // Local policy: skip partition points.
        while matches!(
            exit,
            RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. }
        ) {
            exit = run_thread(&mut mono, tid, &mut NoHooks, 1_000_000).unwrap();
        }
        assert!(matches!(exit, RunExit::Completed(_)));
        let expected = mono.statics[main.class.0 as usize][0];
        // sum 0..64 = 2016
        assert_eq!(expected.as_float(), Some(2016.0));

        // Distributed run.
        let mut phone = make_proc(Location::Mobile, &program, 50);
        let mut clone = make_proc(Location::Clone, &program, 50);
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
        let RunExit::MigrationPoint { point } = exit else {
            panic!("expected migration point, got {exit:?}")
        };
        assert_eq!(point, 0);

        let migrator = Migrator::new(CostParams::default());
        let (packet, phases) = migrator.migrate_out(&mut phone, tid).unwrap();
        assert!(phases.bytes_out > 0);
        validate_packet(&packet).unwrap();

        // Wire round trip (encode/decode) like the real transport does.
        let packet = CapturePacket::decode(&packet.encode().unwrap()).unwrap();
        let (ctid, table, _) = migrator.receive_at_clone(&mut clone, &packet).unwrap();
        assert_eq!(table.len(), packet.objects.len());

        // Clone executes the offloaded body up to the reintegration point.
        let exit = run_thread(&mut clone, ctid, &mut NoHooks, 1_000_000).unwrap();
        assert!(
            matches!(exit, RunExit::ReintegrationPoint { point: 0 }),
            "{exit:?}"
        );

        let (rpacket, _, _dropped) =
            migrator.return_from_clone(&mut clone, ctid, table).unwrap();
        let rpacket = CapturePacket::decode(&rpacket.encode().unwrap()).unwrap();
        migrator.merge_back(&mut phone, tid, &rpacket).unwrap();

        // Phone finishes the thread.
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)), "{exit:?}");
        let got = phone.statics[main.class.0 as usize][0];
        assert_eq!(got, expected, "distributed result == monolithic result");
    }

    /// The Zygote-diff optimization must cut shipped objects drastically
    /// without changing semantics (E4's mechanism).
    #[test]
    fn zygote_diff_reduces_shipped_objects() {
        let program = Arc::new(assemble(PROG).unwrap());
        let main = program.entry().unwrap();

        let run = |zygote_diff: bool| -> (usize, usize) {
            let mut phone = make_proc(Location::Mobile, &program, 2000);
            // Root a zygote object from a static so captures see the
            // template graph.
            let some_zy = phone.heap.iter().map(|(id, _)| id).min().unwrap();
            phone.statics[main.class.0 as usize][0] = Value::Ref(some_zy);
            let tid = phone.spawn_thread(main, &[]).unwrap();
            let _ = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
            let mut m = Migrator::new(CostParams::default());
            m.opts.zygote_diff = zygote_diff;
            let (packet, phases) = m.migrate_out(&mut phone, tid).unwrap();
            let _ = packet;
            (phases.objects_shipped, phases.zygote_skipped)
        };

        let (with_objs, with_skipped) = run(true);
        let (without_objs, without_skipped) = run(false);
        assert_eq!(without_skipped, 0);
        assert!(with_skipped >= 1);
        assert!(
            without_objs > with_objs,
            "diff on: {with_objs} shipped; off: {without_objs}"
        );
    }

    /// New objects created at the clone arrive as fresh objects at the
    /// phone; objects that died at the clone drop out of the mapping.
    #[test]
    fn clone_created_objects_materialize_at_phone() {
        const P2: &str = r#"
class Gen app
  static keep
  method main nargs=0 regs=4
    invokev Gen.work
    retv
  end
  method work nargs=0 regs=6
    ccstart 1
    const r0 16
    newarr r1 byte r0
    const r2 0
    const r3 7
    aput r1 r2 r3
    puts Gen.keep r1
    ccstop 1
    retv
  end
end
"#;
        let program = Arc::new(assemble(P2).unwrap());
        let main = program.entry().unwrap();
        let mut phone = make_proc(Location::Mobile, &program, 20);
        let mut clone = make_proc(Location::Clone, &program, 20);
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000).unwrap();
        assert!(matches!(exit, RunExit::MigrationPoint { .. }));
        let migrator = Migrator::new(CostParams::default());
        let (packet, _) = migrator.migrate_out(&mut phone, tid).unwrap();
        let (ctid, table, _) = migrator.receive_at_clone(&mut clone, &packet).unwrap();
        let exit = run_thread(&mut clone, ctid, &mut NoHooks, 100_000).unwrap();
        assert!(matches!(exit, RunExit::ReintegrationPoint { .. }));
        let (rp, _, _) = migrator.return_from_clone(&mut clone, ctid, table).unwrap();
        let (stats, _) = migrator.merge_back(&mut phone, tid, &rp).unwrap();
        assert!(stats.created >= 1, "the clone-allocated array came back");
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)));
        // The array created at the clone is now reachable on the phone.
        let kept = phone.statics[main.class.0 as usize][0].as_ref().unwrap();
        match &phone.heap.get(kept).unwrap().body {
            ObjBody::ByteArray(b) => assert_eq!(b[0], 7),
            other => panic!("expected byte array, got {other:?}"),
        }
    }

    /// Multi-round offload program for the delta tests: N byte arrays
    /// hang off a static; each round the phone dirties one byte of one
    /// array, offloads a byte-sum over it (the clone dirties another
    /// byte AND allocates a fresh array into `keep` — exercising the
    /// assignment piggyback and the deleted list), and accumulates the
    /// result. Only O(1) of the N arrays changes per round — exactly the
    /// shape delta migration exploits.
    const DELTA_PROG: &str = r#"
class D app
  static data
  static out
  static keep
  method main nargs=0 regs=12
    const r0 8
    newarr r1 val r0
    puts D.data r1
    const r2 0
    const r3 2048
  mk:
    ifge r2 r0 @mkd
    newarr r4 byte r3
    aput r1 r2 r4
    const r5 1
    add r2 r2 r5
    goto @mk
  mkd:
    const r6 0
    const r10 0
  loop:
    ifge r6 r0 @done
    aget r4 r1 r6
    const r5 0
    aput r4 r5 r6
    invoke r8 D.work r4
    add r10 r10 r8
    const r5 1
    add r6 r6 r5
    goto @loop
  done:
    puts D.out r10
    retv
  end
  method work nargs=1 regs=8
    ccstart 0
    len r1 r0
    const r2 0
    const r3 0
  sum:
    ifge r2 r1 @sd
    aget r4 r0 r2
    add r3 r3 r4
    const r5 1
    add r2 r2 r5
    goto @sum
  sd:
    const r6 1
    aput r0 r6 r3
    const r7 4
    newarr r2 byte r7
    const r6 0
    aput r2 r6 r3
    puts D.keep r2
    ccstop 0
    ret r3
  end
end
"#;

    /// Drive a full phone/clone session through the capsule API; returns
    /// (final `out` static, final `keep` array bytes, per-round
    /// (is_delta, forward bytes), fallback count).
    fn run_capsule_session(
        delta: bool,
        evict_before_round: Option<usize>,
    ) -> (Value, Vec<u8>, Vec<(bool, usize)>, usize) {
        let program = Arc::new(assemble(DELTA_PROG).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let main = program.entry().unwrap();
        let mut phone = make_proc(Location::Mobile, &program, 40);
        let mut clone = make_proc(Location::Clone, &program, 40);
        let migrator = Migrator::new(CostParams::default());
        let mut msess = MobileSession::new(delta);
        let mut csess = CloneSession::new(delta);

        let tid = phone.spawn_thread(main, &[]).unwrap();
        let mut rounds = Vec::new();
        let mut fallbacks = 0usize;
        loop {
            match run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap() {
                RunExit::Completed(_) => break,
                RunExit::ReintegrationPoint { .. } => continue,
                RunExit::MigrationPoint { .. } => {
                    if Some(rounds.len()) == evict_before_round {
                        csess.evict();
                    }
                    let (capsule, _) = migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();
                    // Wire roundtrip, with the NeedFull fallback the real
                    // drivers implement.
                    let mut bytes = capsule.encode().unwrap();
                    let mut sent = Capsule::decode(&bytes).unwrap();
                    let ctid = loop {
                        match migrator.receive_capsule_at_clone(&mut clone, &sent, &mut csess) {
                            Ok((ctid, _)) => break ctid,
                            Err(e) if e.is_need_full() => {
                                fallbacks += 1;
                                let (full, _) =
                                    migrator.recapture_full(&mut phone, tid, &mut msess).unwrap();
                                bytes = full.encode().unwrap();
                                sent = Capsule::decode(&bytes).unwrap();
                            }
                            Err(e) => panic!("receive: {e}"),
                        }
                    };
                    rounds.push((sent.is_delta(), bytes.len()));

                    let exit = run_thread(&mut clone, ctid, &mut NoHooks, 10_000_000).unwrap();
                    assert!(matches!(exit, RunExit::ReintegrationPoint { .. }), "{exit:?}");
                    let (rcap, _, _) = migrator
                        .return_capsule_from_clone(&mut clone, ctid, &mut csess)
                        .unwrap();
                    let rcap = Capsule::decode(&rcap.encode().unwrap()).unwrap();
                    migrator
                        .merge_back_capsule(&mut phone, tid, &rcap, &mut msess)
                        .unwrap();
                }
                other => panic!("{other:?}"),
            }
        }
        let out = phone.statics[main.class.0 as usize][1];
        let keep = phone.statics[main.class.0 as usize][2]
            .as_ref()
            .expect("keep holds the clone-allocated array");
        let keep_bytes = match &phone.heap.get(keep).unwrap().body {
            ObjBody::ByteArray(b) => b.clone(),
            other => panic!("expected byte array, got {other:?}"),
        };
        (out, keep_bytes, rounds, fallbacks)
    }

    /// The page-epoch scan and the per-object traversal are two
    /// implementations of the same capture semantics: a whole session
    /// driven through each lands on bit-identical application state,
    /// and the paged side's GC-driven `deleted` lists keep membership
    /// pruned (the traversal prunes by reachability every round).
    #[test]
    fn paged_and_traversal_delta_sessions_agree_bit_for_bit() {
        let run = |paged: bool| -> (Value, Vec<u8>, usize) {
            let program = Arc::new(assemble(DELTA_PROG).unwrap());
            let main = program.entry().unwrap();
            let mut phone = make_proc(Location::Mobile, &program, 40);
            let mut clone = make_proc(Location::Clone, &program, 40);
            let migrator = Migrator::new(CostParams::default());
            let mut msess = MobileSession::new(true);
            msess.set_paged(paged);
            msess.set_gc_interval(2); // prune aggressively on the paged path
            let mut csess = CloneSession::new(true);
            csess.set_paged(paged);

            let tid = phone.spawn_thread(main, &[]).unwrap();
            let mut deleted_total = 0usize;
            loop {
                match run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap() {
                    RunExit::Completed(_) => break,
                    RunExit::ReintegrationPoint { .. } => continue,
                    RunExit::MigrationPoint { .. } => {
                        let (capsule, _) =
                            migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();
                        let sent = Capsule::decode(&capsule.encode().unwrap()).unwrap();
                        if let Capsule::Delta(d) = &sent {
                            deleted_total += d.deleted.len();
                        }
                        let (ctid, _) = migrator
                            .receive_capsule_at_clone(&mut clone, &sent, &mut csess)
                            .unwrap();
                        let exit =
                            run_thread(&mut clone, ctid, &mut NoHooks, 10_000_000).unwrap();
                        assert!(matches!(exit, RunExit::ReintegrationPoint { .. }));
                        let (rcap, _, _) = migrator
                            .return_capsule_from_clone(&mut clone, ctid, &mut csess)
                            .unwrap();
                        let rcap = Capsule::decode(&rcap.encode().unwrap()).unwrap();
                        migrator
                            .merge_back_capsule(&mut phone, tid, &rcap, &mut msess)
                            .unwrap();
                    }
                    other => panic!("{other:?}"),
                }
            }
            let out = phone.statics[main.class.0 as usize][1];
            let keep = phone.statics[main.class.0 as usize][2].as_ref().unwrap();
            let keep_bytes = match &phone.heap.get(keep).unwrap().body {
                ObjBody::ByteArray(b) => b.clone(),
                other => panic!("expected byte array, got {other:?}"),
            };
            (out, keep_bytes, deleted_total)
        };

        let (out_paged, keep_paged, deleted_paged) = run(true);
        let (out_trav, keep_trav, deleted_trav) = run(false);
        assert_eq!(out_paged, out_trav, "bit-identical results");
        assert_eq!(keep_paged, keep_trav, "clone-created state matches too");
        assert!(
            deleted_trav >= 1,
            "traversal reports reachability deletions (old keep arrays)"
        );
        assert!(
            deleted_paged >= 1,
            "mobile GC feeds the paged path's deleted list"
        );
    }

    /// The heap-growth trigger fires the mobile GC from allocation rate:
    /// a trace that allocates every round collects before the count
    /// cadence (set far beyond the run) would ever come due.
    #[test]
    fn gc_growth_trigger_collects_earlier_than_count_cadence() {
        let run = |growth: u64| -> (Value, u64) {
            let program = Arc::new(assemble(DELTA_PROG).unwrap());
            let main = program.entry().unwrap();
            let mut phone = make_proc(Location::Mobile, &program, 40);
            let mut clone = make_proc(Location::Clone, &program, 40);
            let migrator = Migrator::new(CostParams::default());
            let mut msess = MobileSession::new(true);
            msess.set_gc_interval(1_000); // count cadence never fires here
            msess.set_gc_growth(growth);
            let mut csess = CloneSession::new(true);
            let tid = phone.spawn_thread(main, &[]).unwrap();
            loop {
                match run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap() {
                    RunExit::Completed(_) => break,
                    RunExit::ReintegrationPoint { .. } => continue,
                    RunExit::MigrationPoint { .. } => {
                        let (capsule, _) =
                            migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();
                        let sent = Capsule::decode(&capsule.encode().unwrap()).unwrap();
                        let (ctid, _) = migrator
                            .receive_capsule_at_clone(&mut clone, &sent, &mut csess)
                            .unwrap();
                        let exit =
                            run_thread(&mut clone, ctid, &mut NoHooks, 10_000_000).unwrap();
                        assert!(matches!(exit, RunExit::ReintegrationPoint { .. }));
                        let (rcap, _, _) = migrator
                            .return_capsule_from_clone(&mut clone, ctid, &mut csess)
                            .unwrap();
                        let rcap = Capsule::decode(&rcap.encode().unwrap()).unwrap();
                        migrator
                            .merge_back_capsule(&mut phone, tid, &rcap, &mut msess)
                            .unwrap();
                    }
                    other => panic!("{other:?}"),
                }
            }
            (phone.statics[main.class.0 as usize][1], msess.gc_runs())
        };
        let (out_off, runs_off) = run(0);
        let (out_on, runs_on) = run(2);
        assert_eq!(runs_off, 0, "count cadence alone never fires in this run");
        assert!(
            runs_on >= 1,
            "allocation growth trips the collector early (ran {runs_on})"
        );
        assert_eq!(out_on, out_off, "GC timing is invisible to results");
    }

    /// Delta and full capsule paths must produce bit-identical results,
    /// and repeat rounds must ship dramatically fewer bytes via deltas.
    #[test]
    fn delta_session_matches_full_and_ships_less() {
        let (full_out, full_keep, full_rounds, _) = run_capsule_session(false, None);
        let (delta_out, delta_keep, delta_rounds, fallbacks) = run_capsule_session(true, None);
        assert_eq!(delta_out, full_out, "delta path is bit-identical");
        assert_eq!(delta_keep, full_keep, "clone-created state matches too");
        assert_eq!(fallbacks, 0);
        assert_eq!(full_rounds.len(), delta_rounds.len());
        assert!(full_rounds.iter().all(|&(d, _)| !d));
        assert!(!delta_rounds[0].0, "first contact is a full capture");
        assert!(
            delta_rounds[1..].iter().all(|&(d, _)| d),
            "repeat rounds ride deltas: {delta_rounds:?}"
        );
        // Steady-state rounds ship a small fraction of the full capsule.
        let full_steady: usize = full_rounds[1..].iter().map(|&(_, b)| b).sum();
        let delta_steady: usize = delta_rounds[1..].iter().map(|&(_, b)| b).sum();
        assert!(
            delta_steady * 5 <= full_steady,
            "delta {delta_steady}B vs full {full_steady}B"
        );
    }

    /// Evicting the clone baseline mid-session (worker recycle) triggers
    /// the NeedFull fallback; the session recovers and results still
    /// match the full path.
    #[test]
    fn delta_digest_mismatch_falls_back_to_full() {
        let (full_out, full_keep, _, _) = run_capsule_session(false, None);
        let (out, keep, rounds, fallbacks) = run_capsule_session(true, Some(4));
        assert_eq!(out, full_out, "fallback preserves bit-identical results");
        assert_eq!(keep, full_keep);
        assert_eq!(fallbacks, 1, "exactly one NeedFull fallback");
        assert!(!rounds[4].0, "the evicted round went out in full");
        assert!(rounds[5].0, "the session re-established deltas afterwards");
    }

    /// The epoch-coherence invariant end to end: after every sync both
    /// endpoints advance their epoch, so a second, no-change round ships
    /// no objects at all.
    #[test]
    fn unchanged_state_ships_no_objects() {
        let program = Arc::new(assemble(PROG).unwrap());
        let main = program.entry().unwrap();
        let mut phone = make_proc(Location::Mobile, &program, 30);
        let migrator = Migrator::new(CostParams::default());
        let mut msess = MobileSession::new(true);
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
        assert!(matches!(exit, RunExit::MigrationPoint { .. }));

        let (first, _) = migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();
        assert!(!first.is_delta());
        // Nothing ran in between: a re-capture of the same state is a
        // delta with zero shipped objects.
        phone.thread_mut(tid).unwrap().status =
            crate::appvm::thread::ThreadStatus::Runnable;
        let (second, phases) = migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();
        match &second {
            Capsule::Delta(d) => {
                assert_eq!(d.sections.objects.len(), 0, "no dirty objects");
                assert!(d.deleted.is_empty());
            }
            Capsule::Full(_) => panic!("expected a delta"),
        }
        assert_eq!(phases.objects_shipped, 0);
        assert!(phases.base_skipped > 0, "members referenced by id");
    }

    /// Running the partitioned binary with the "don't migrate" policy —
    /// just continuing at CcStart — must equal monolithic execution.
    #[test]
    fn local_execution_of_partitioned_binary_is_unchanged() {
        let program = Arc::new(assemble(PROG).unwrap());
        let main = program.entry().unwrap();
        let mut p = make_proc(Location::Mobile, &program, 10);
        let tid = p.spawn_thread(main, &[]).unwrap();
        loop {
            match run_thread(&mut p, tid, &mut NoHooks, 1_000_000).unwrap() {
                RunExit::Completed(_) => break,
                RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. } => continue,
                other => panic!("{other:?}"),
            }
        }
        let got = p.statics[main.class.0 as usize][0];
        assert_eq!(got.as_float(), Some(2016.0));
    }

    /// A data-parallel span in the scatter convention: `work(begin, end,
    /// shards)` fills per-index byte arrays pre-allocated by the caller,
    /// so shard i's writes land in slot i only (disjoint heaps), and the
    /// method returns a constant so no post-reintegration code depends on
    /// shard-private registers. `main` invokes it monolithically as
    /// `work(0, N, N)` and then sums the slots locally.
    const SCATTER_PROG: &str = r#"
class S app
  static data
  static out
  method main nargs=0 regs=12
    const r0 4
    newarr r1 val r0
    puts S.data r1
    const r6 16
    const r2 0
  mk:
    ifge r2 r0 @mkd
    newarr r4 byte r6
    aput r1 r2 r4
    const r5 1
    add r2 r2 r5
    goto @mk
  mkd:
    const r2 0
    invoke r7 S.work r2 r0 r0
    const r2 0
    const r8 0
  so:
    ifge r2 r0 @sod
    aget r4 r1 r2
    const r3 0
  si:
    ifge r3 r6 @sid
    aget r5 r4 r3
    add r8 r8 r5
    const r9 1
    add r3 r3 r9
    goto @si
  sid:
    const r9 1
    add r2 r2 r9
    goto @so
  sod:
    add r8 r8 r7
    puts S.out r8
    retv
  end
  method work nargs=3 regs=12
    ccstart 0
    gets r3 S.data
  outer:
    ifge r0 r1 @done
    aget r4 r3 r0
    len r5 r4
    const r6 0
  inner:
    ifge r6 r5 @id
    mul r7 r0 r6
    add r7 r7 r0
    aput r4 r6 r7
    const r8 1
    add r6 r6 r8
    goto @inner
  id:
    const r8 1
    add r0 r0 r8
    goto @outer
  done:
    ccstop 0
    const r9 0
    ret r9
  end
end
"#;

    /// Drive one shard sub-job on a fresh clone slot: apply the (patched)
    /// forward capsule, run to the reintegration point, capture the
    /// reverse capsule.
    fn run_shard(
        program: &Arc<Program>,
        migrator: &Migrator,
        forward: &Capsule,
    ) -> Capsule {
        let mut clone = make_proc(Location::Clone, program, 40);
        let mut csess = CloneSession::new(true);
        let sent = Capsule::decode(&forward.encode().unwrap()).unwrap();
        let (ctid, _) = migrator
            .receive_capsule_at_clone(&mut clone, &sent, &mut csess)
            .unwrap();
        let exit = run_thread(&mut clone, ctid, &mut NoHooks, 10_000_000).unwrap();
        assert!(matches!(exit, RunExit::ReintegrationPoint { .. }), "{exit:?}");
        let (rcap, _, _) = migrator
            .return_capsule_from_clone(&mut clone, ctid, &mut csess)
            .unwrap();
        Capsule::decode(&rcap.encode().unwrap()).unwrap()
    }

    fn scatter_slot_bytes(phone: &Process, main: crate::appvm::MRef) -> Vec<Vec<u8>> {
        let data = phone.statics[main.class.0 as usize][0].as_ref().unwrap();
        let slots = match &phone.heap.get(data).unwrap().body {
            ObjBody::RefArray(vs) => vs.clone(),
            other => panic!("expected ref array, got {other:?}"),
        };
        slots
            .iter()
            .map(|v| match &phone.heap.get(v.as_ref().unwrap()).unwrap().body {
                ObjBody::ByteArray(b) => b.clone(),
                other => panic!("expected byte array, got {other:?}"),
            })
            .collect()
    }

    /// Tentpole invariant: a 4-way scatter of one forward baseline merges
    /// to bit-identical state as the single-clone offload, advances the
    /// clock to the slowest shard (not the sum), and ends the delta
    /// session.
    #[test]
    fn scatter_gather_matches_single_clone_bit_for_bit() {
        let program = Arc::new(assemble(SCATTER_PROG).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let main = program.entry().unwrap();
        let migrator = Migrator::new(CostParams::default());

        // Single-clone reference offload.
        let (single_out, single_slots) = {
            let mut phone = make_proc(Location::Mobile, &program, 40);
            let mut msess = MobileSession::new(true);
            let tid = phone.spawn_thread(main, &[]).unwrap();
            let exit = run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap();
            assert!(matches!(exit, RunExit::MigrationPoint { .. }));
            let (capsule, _) =
                migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();
            let rcap = run_shard(&program, &migrator, &capsule);
            migrator
                .merge_back_capsule(&mut phone, tid, &rcap, &mut msess)
                .unwrap();
            let exit = run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap();
            assert!(matches!(exit, RunExit::Completed(_)), "{exit:?}");
            (
                phone.statics[main.class.0 as usize][1],
                scatter_slot_bytes(&phone, main),
            )
        };
        // sum over slot i, index j of i*(j+1): 136 * (0+1+2+3)
        assert_eq!(single_out.as_int(), Some(816));

        // Scattered run: one capture, four patched sub-jobs, one gather.
        let mut phone = make_proc(Location::Mobile, &program, 40);
        let mut msess = MobileSession::new(true);
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap();
        assert!(matches!(exit, RunExit::MigrationPoint { .. }));
        let (capsule, _) = migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();
        assert!(!capsule.is_delta(), "first capture is full");

        let mut deltas = Vec::new();
        for i in 0..4i64 {
            let sub = shard_capsule(&capsule, i, i + 1).unwrap();
            match run_shard(&program, &migrator, &sub) {
                Capsule::Delta(d) => deltas.push(d),
                Capsule::Full(_) => panic!("shard answered in full"),
            }
        }
        let max_shard_clock = deltas.iter().fold(f64::MIN, |a, d| a.max(d.clock_us));

        let (stats, _) = migrator
            .gather_scatter_capsules(&mut phone, tid, &deltas, &mut msess)
            .unwrap();
        assert_eq!(stats.overwritten, 4, "each shard dirtied its own slot");
        assert!(
            phone.clock.now_us() >= max_shard_clock,
            "gather advances to the slowest shard"
        );
        assert!(
            !msess.has_baseline(),
            "the gather ends the delta session (next capture is full)"
        );

        let exit = run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)), "{exit:?}");
        assert_eq!(
            phone.statics[main.class.0 as usize][1],
            single_out,
            "scatter result is bit-identical to the single-clone offload"
        );
        assert_eq!(scatter_slot_bytes(&phone, main), single_slots);
    }

    /// Overlapping shard write sets are refused *before* any mutation:
    /// the typed conflict leaves the process and baseline untouched, so
    /// the caller degrades to a single-clone offload of the same capture
    /// and still lands on the correct result — never corruption.
    #[test]
    fn scatter_conflict_degrades_to_single_clone_without_corruption() {
        let program = Arc::new(assemble(SCATTER_PROG).unwrap());
        let main = program.entry().unwrap();
        let migrator = Migrator::new(CostParams::default());
        let mut phone = make_proc(Location::Mobile, &program, 40);
        let mut msess = MobileSession::new(true);
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap();
        assert!(matches!(exit, RunExit::MigrationPoint { .. }));
        let (capsule, _) = migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();

        // Ranges [0,2) and [1,3) both dirty slot 1.
        let mut deltas = Vec::new();
        for (b, e) in [(0i64, 2i64), (1, 3)] {
            let sub = shard_capsule(&capsule, b, e).unwrap();
            match run_shard(&program, &migrator, &sub) {
                Capsule::Delta(d) => deltas.push(d),
                Capsule::Full(_) => panic!("shard answered in full"),
            }
        }
        let err = migrator
            .gather_scatter_capsules(&mut phone, tid, &deltas, &mut msess)
            .unwrap_err();
        assert!(err.is_scatter_conflict(), "{err}");
        assert!(msess.has_baseline(), "conflict leaves the baseline intact");
        for slot in scatter_slot_bytes(&phone, main) {
            assert!(
                slot.iter().all(|&b| b == 0),
                "conflict left the phone heap untouched"
            );
        }

        // Degrade: the original monolithic capture is still valid.
        let rcap = run_shard(&program, &migrator, &capsule);
        migrator
            .merge_back_capsule(&mut phone, tid, &rcap, &mut msess)
            .unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)), "{exit:?}");
        assert_eq!(phone.statics[main.class.0 as usize][1].as_int(), Some(816));
    }

    /// The shard patch validates the `(begin, end, shards)` convention
    /// and refuses non-conforming spans and delta capsules.
    #[test]
    fn shard_capsule_validates_the_convention() {
        let program = Arc::new(assemble(SCATTER_PROG).unwrap());
        let main = program.entry().unwrap();
        let migrator = Migrator::new(CostParams::default());
        let mut phone = make_proc(Location::Mobile, &program, 40);
        let mut msess = MobileSession::new(true);
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let _ = run_thread(&mut phone, tid, &mut NoHooks, 10_000_000).unwrap();
        let (capsule, _) = migrator.migrate_out_capsule(&mut phone, tid, &mut msess).unwrap();

        let patched = shard_capsule(&capsule, 2, 3).unwrap();
        let Capsule::Full(p) = &patched else { panic!() };
        let top = p.frames.last().unwrap();
        assert_eq!(top.regs[0], crate::migration::format::WireValue::Int(2));
        assert_eq!(top.regs[1], crate::migration::format::WireValue::Int(3));
        // The monolithic original is untouched (one capture, N patches).
        let Capsule::Full(orig) = &capsule else { panic!() };
        assert_eq!(
            orig.frames.last().unwrap().regs[0],
            crate::migration::format::WireValue::Int(0)
        );

        // A non-shard-shaped span (PROG's fill(arr) has a ref in r0).
        let program2 = Arc::new(assemble(PROG).unwrap());
        let main2 = program2.entry().unwrap();
        let mut phone2 = make_proc(Location::Mobile, &program2, 30);
        let mut msess2 = MobileSession::new(true);
        let tid2 = phone2.spawn_thread(main2, &[]).unwrap();
        let _ = run_thread(&mut phone2, tid2, &mut NoHooks, 1_000_000).unwrap();
        let (c2, _) = migrator.migrate_out_capsule(&mut phone2, tid2, &mut msess2).unwrap();
        assert!(shard_capsule(&c2, 0, 1).is_err());
    }
}
