//! Thread-granularity migration (paper §4).
//!
//! * [`capture`] — suspend-and-capture: frames + reachable heap + statics.
//! * [`format`] — hprof-like portable wire encoding (network byte order).
//! * [`mapping`] — the MID/CID object-mapping table (Fig. 8).
//! * [`merge`] — clone-side instantiation and mobile-side state merge.
//! * [`zygote_diff`] — the §4.3 transfer optimization.
//! * [`migrator`] — the per-process orchestration + cost accounting.

pub mod capture;
pub mod format;
pub mod mapping;
pub mod merge;
pub mod migrator;
pub mod zygote_diff;

pub use capture::{capture_thread, measure_state_size, CaptureOptions, CaptureStats};
pub use format::{CapturePacket, Direction};
pub use mapping::MappingTable;
pub use merge::{instantiate_at_clone, merge_at_mobile, validate_packet, MergeStats};
pub use migrator::{MigrationPhases, Migrator};
pub use zygote_diff::ZygoteIndex;

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::interp::{run_thread, NoHooks, RunExit};
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::process::Process;
    use crate::appvm::value::{ObjBody, Value};
    use crate::appvm::zygote::build_template;
    use crate::appvm::Program;
    use crate::config::CostParams;
    use crate::device::{DeviceSpec, Location};
    use crate::vfs::SimFs;

    /// A program whose worker mutates state on both sides of a migration
    /// point: builds an array, migrates (ccstart), fills it remotely,
    /// reintegrates (ccstop), then sums it locally.
    const PROG: &str = r#"
class Work app
  static out
  method main nargs=0 regs=8
    const r0 64
    newarr r1 float r0
    invoke r2 Work.fill r1
    puts Work.out r2
    retv
  end
  method fill nargs=1 regs=8
    ccstart 0
    len r1 r0
    const r2 0
  loop:
    ifge r2 r1 @done
    i2f r3 r2
    aput r0 r2 r3
    const r4 1
    add r2 r2 r4
    goto @loop
  done:
    ccstop 0
    # sum it up
    const r2 0
    constf r5 0.0
  sum:
    ifge r2 r1 @end
    aget r3 r0 r2
    fadd r5 r5 r3
    const r4 1
    add r2 r2 r4
    goto @sum
  end:
    ret r5
  end
end
"#;

    fn make_proc(loc: Location, program: &Arc<Program>, zygote: usize) -> Process {
        let template = build_template(program, zygote, 99);
        let dev = match loc {
            Location::Mobile => DeviceSpec::phone_g1(),
            Location::Clone => DeviceSpec::clone_desktop(),
        };
        Process::fork_from_zygote(
            program.clone(),
            &template,
            dev,
            loc,
            NodeEnv::with_rust_compute(SimFs::new()),
        )
    }

    /// Full round trip: phone runs to ccstart, migrates, clone executes
    /// the body, returns at ccstop, phone merges and finishes. The final
    /// result must equal the monolithic run's.
    #[test]
    fn migration_roundtrip_preserves_semantics() {
        let program = Arc::new(assemble(PROG).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();

        // Monolithic reference run.
        let mut mono = make_proc(Location::Mobile, &program, 50);
        let main = program.entry().unwrap();
        let tid = mono.spawn_thread(main, &[]).unwrap();
        let mut exit = run_thread(&mut mono, tid, &mut NoHooks, 1_000_000).unwrap();
        // Local policy: skip partition points.
        while matches!(
            exit,
            RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. }
        ) {
            exit = run_thread(&mut mono, tid, &mut NoHooks, 1_000_000).unwrap();
        }
        assert!(matches!(exit, RunExit::Completed(_)));
        let expected = mono.statics[main.class.0 as usize][0];
        // sum 0..64 = 2016
        assert_eq!(expected.as_float(), Some(2016.0));

        // Distributed run.
        let mut phone = make_proc(Location::Mobile, &program, 50);
        let mut clone = make_proc(Location::Clone, &program, 50);
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
        let RunExit::MigrationPoint { point } = exit else {
            panic!("expected migration point, got {exit:?}")
        };
        assert_eq!(point, 0);

        let migrator = Migrator::new(CostParams::default());
        let (packet, phases) = migrator.migrate_out(&mut phone, tid).unwrap();
        assert!(phases.bytes_out > 0);
        validate_packet(&packet).unwrap();

        // Wire round trip (encode/decode) like the real transport does.
        let packet = CapturePacket::decode(&packet.encode()).unwrap();
        let (ctid, table, _) = migrator.receive_at_clone(&mut clone, &packet).unwrap();
        assert_eq!(table.len(), packet.objects.len());

        // Clone executes the offloaded body up to the reintegration point.
        let exit = run_thread(&mut clone, ctid, &mut NoHooks, 1_000_000).unwrap();
        assert!(
            matches!(exit, RunExit::ReintegrationPoint { point: 0 }),
            "{exit:?}"
        );

        let (rpacket, _, _dropped) =
            migrator.return_from_clone(&mut clone, ctid, table).unwrap();
        let rpacket = CapturePacket::decode(&rpacket.encode()).unwrap();
        migrator.merge_back(&mut phone, tid, &rpacket).unwrap();

        // Phone finishes the thread.
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)), "{exit:?}");
        let got = phone.statics[main.class.0 as usize][0];
        assert_eq!(got, expected, "distributed result == monolithic result");
    }

    /// The Zygote-diff optimization must cut shipped objects drastically
    /// without changing semantics (E4's mechanism).
    #[test]
    fn zygote_diff_reduces_shipped_objects() {
        let program = Arc::new(assemble(PROG).unwrap());
        let main = program.entry().unwrap();

        let run = |zygote_diff: bool| -> (usize, usize) {
            let mut phone = make_proc(Location::Mobile, &program, 2000);
            // Root a zygote object from a static so captures see the
            // template graph.
            let some_zy = phone.heap.iter().map(|(id, _)| id).min().unwrap();
            phone.statics[main.class.0 as usize][0] = Value::Ref(some_zy);
            let tid = phone.spawn_thread(main, &[]).unwrap();
            let _ = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
            let mut m = Migrator::new(CostParams::default());
            m.opts.zygote_diff = zygote_diff;
            let (packet, phases) = m.migrate_out(&mut phone, tid).unwrap();
            let _ = packet;
            (phases.objects_shipped, phases.zygote_skipped)
        };

        let (with_objs, with_skipped) = run(true);
        let (without_objs, without_skipped) = run(false);
        assert_eq!(without_skipped, 0);
        assert!(with_skipped >= 1);
        assert!(
            without_objs > with_objs,
            "diff on: {with_objs} shipped; off: {without_objs}"
        );
    }

    /// New objects created at the clone arrive as fresh objects at the
    /// phone; objects that died at the clone drop out of the mapping.
    #[test]
    fn clone_created_objects_materialize_at_phone() {
        const P2: &str = r#"
class Gen app
  static keep
  method main nargs=0 regs=4
    invokev Gen.work
    retv
  end
  method work nargs=0 regs=6
    ccstart 1
    const r0 16
    newarr r1 byte r0
    const r2 0
    const r3 7
    aput r1 r2 r3
    puts Gen.keep r1
    ccstop 1
    retv
  end
end
"#;
        let program = Arc::new(assemble(P2).unwrap());
        let main = program.entry().unwrap();
        let mut phone = make_proc(Location::Mobile, &program, 20);
        let mut clone = make_proc(Location::Clone, &program, 20);
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000).unwrap();
        assert!(matches!(exit, RunExit::MigrationPoint { .. }));
        let migrator = Migrator::new(CostParams::default());
        let (packet, _) = migrator.migrate_out(&mut phone, tid).unwrap();
        let (ctid, table, _) = migrator.receive_at_clone(&mut clone, &packet).unwrap();
        let exit = run_thread(&mut clone, ctid, &mut NoHooks, 100_000).unwrap();
        assert!(matches!(exit, RunExit::ReintegrationPoint { .. }));
        let (rp, _, _) = migrator.return_from_clone(&mut clone, ctid, table).unwrap();
        let (stats, _) = migrator.merge_back(&mut phone, tid, &rp).unwrap();
        assert!(stats.created >= 1, "the clone-allocated array came back");
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)));
        // The array created at the clone is now reachable on the phone.
        let kept = phone.statics[main.class.0 as usize][0].as_ref().unwrap();
        match &phone.heap.get(kept).unwrap().body {
            ObjBody::ByteArray(b) => assert_eq!(b[0], 7),
            other => panic!("expected byte array, got {other:?}"),
        }
    }

    /// Running the partitioned binary with the "don't migrate" policy —
    /// just continuing at CcStart — must equal monolithic execution.
    #[test]
    fn local_execution_of_partitioned_binary_is_unchanged() {
        let program = Arc::new(assemble(PROG).unwrap());
        let main = program.entry().unwrap();
        let mut p = make_proc(Location::Mobile, &program, 10);
        let tid = p.spawn_thread(main, &[]).unwrap();
        loop {
            match run_thread(&mut p, tid, &mut NoHooks, 1_000_000).unwrap() {
                RunExit::Completed(_) => break,
                RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. } => continue,
                other => panic!("{other:?}"),
            }
        }
        let got = p.statics[main.class.0 as usize][0];
        assert_eq!(got.as_float(), Some(2016.0));
    }
}
