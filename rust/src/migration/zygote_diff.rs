//! Zygote-diff transfer optimization (paper §4.3).
//!
//! Because the Zygote template boots independently on the phone and the
//! clone with identical (class name, construction sequence) object names,
//! a capture can reference any *clean* template object by name instead of
//! shipping it — typically saving ~40,000 object transmissions per
//! migration. This module builds the name -> local-object index each
//! process uses to resolve such references.
//!
//! A malformed template heap (duplicate (class, seq) names) surfaces as a
//! typed [`CloneCloudError::Migration`] from [`ZygoteIndex::try_build`]
//! rather than a panic; receivers degrade to requesting a full capture
//! ([`CloneCloudError::NeedFull`]) instead of aborting the session.

use std::collections::HashMap;

use crate::appvm::class::Program;
use crate::appvm::heap::Heap;
use crate::appvm::value::ObjId;
use crate::error::{CloneCloudError, Result};

/// (class name, construction seq) -> local object id.
#[derive(Debug, Clone, Default)]
pub struct ZygoteIndex {
    by_name: HashMap<(String, u32), ObjId>,
}

impl ZygoteIndex {
    /// Build the index from a process heap (scans for template objects).
    /// Duplicate names keep the last-seen object — use [`try_build`] when
    /// a malformed heap must be detected rather than papered over.
    ///
    /// [`try_build`]: ZygoteIndex::try_build
    pub fn build(program: &Program, heap: &Heap) -> ZygoteIndex {
        let mut by_name = HashMap::new();
        for (id, obj) in heap.iter() {
            if let Some(seq) = obj.zygote_seq {
                let cname = program.class(obj.class).name.clone();
                by_name.insert((cname, seq), id);
            }
        }
        ZygoteIndex { by_name }
    }

    /// Build the index, returning a typed error if the heap carries two
    /// objects with the same (class, seq) name — the §4.3 naming
    /// assumption is broken and name references would be ambiguous.
    pub fn try_build(program: &Program, heap: &Heap) -> Result<ZygoteIndex> {
        let mut by_name = HashMap::new();
        for (id, obj) in heap.iter() {
            if let Some(seq) = obj.zygote_seq {
                let cname = program.class(obj.class).name.clone();
                if by_name.insert((cname.clone(), seq), id).is_some() {
                    return Err(CloneCloudError::migration(format!(
                        "malformed Zygote heap: duplicate template name ({cname}, {seq})"
                    )));
                }
            }
        }
        Ok(ZygoteIndex { by_name })
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }
    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Resolve a (class, seq) name to the local object.
    pub fn lookup(&self, class_name: &str, seq: u32) -> Result<ObjId> {
        self.by_name
            .get(&(class_name.to_string(), seq))
            .copied()
            .ok_or_else(|| {
                CloneCloudError::migration(format!(
                    "no local Zygote object ({class_name}, {seq}) — template mismatch"
                ))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::value::Object;
    use crate::appvm::zygote::{build_template, install_system_classes};
    use std::sync::Arc;

    #[test]
    fn independent_boots_resolve_same_names() {
        let mut p = Program::new();
        install_system_classes(&mut p);
        let p = Arc::new(p);
        // Two independently-built templates (same parameters) — the §4.3
        // assumption: same (class, seq) names on both devices.
        let phone = build_template(&p, 300, 7);
        let clone = build_template(&p, 300, 7);
        let pi = ZygoteIndex::build(&p, &phone);
        let ci = ZygoteIndex::build(&p, &clone);
        assert_eq!(pi.len(), 300);
        assert_eq!(ci.len(), 300);
        for (id, obj) in phone.iter() {
            let name = p.class(obj.class).name.clone();
            let seq = obj
                .zygote_seq
                .expect("template objects carry their (class, seq) name");
            assert_eq!(pi.lookup(&name, seq).unwrap(), id);
            // The clone resolves the same name (possibly different id,
            // same (class, seq) object).
            let cid = ci.lookup(&name, seq).unwrap();
            assert_eq!(clone.get(cid).unwrap().zygote_seq, Some(seq));
        }
    }

    #[test]
    fn missing_name_is_an_error() {
        let mut p = Program::new();
        install_system_classes(&mut p);
        let p = Arc::new(p);
        let h = build_template(&p, 10, 1);
        let idx = ZygoteIndex::build(&p, &h);
        assert!(idx.lookup("sys.String", 9999).is_err());
    }

    #[test]
    fn duplicate_template_names_are_a_typed_error() {
        let mut p = Program::new();
        install_system_classes(&mut p);
        let p = Arc::new(p);
        let mut h = build_template(&p, 10, 1);
        assert!(ZygoteIndex::try_build(&p, &h).is_ok());

        // Forge a duplicate (class, seq) name — a malformed heap.
        let (_, sample) = h.iter().next().map(|(id, o)| (id, o.clone())).unwrap();
        let mut forged = Object::new_fields(sample.class, 0);
        forged.zygote_seq = sample.zygote_seq;
        forged.dirty = false;
        h.alloc(forged);

        let err = ZygoteIndex::try_build(&p, &h).unwrap_err();
        assert!(
            err.to_string().contains("duplicate template name"),
            "{err}"
        );
        assert!(!err.is_need_full(), "capture-side error, not the wire signal");
    }
}
