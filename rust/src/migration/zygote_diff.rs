//! Zygote-diff transfer optimization (paper §4.3).
//!
//! Because the Zygote template boots independently on the phone and the
//! clone with identical (class name, construction sequence) object names,
//! a capture can reference any *clean* template object by name instead of
//! shipping it — typically saving ~40,000 object transmissions per
//! migration. This module builds the name -> local-object index each
//! process uses to resolve such references.

use std::collections::HashMap;

use crate::appvm::class::Program;
use crate::appvm::heap::Heap;
use crate::appvm::value::ObjId;
use crate::error::{CloneCloudError, Result};

/// (class name, construction seq) -> local object id.
#[derive(Debug, Clone, Default)]
pub struct ZygoteIndex {
    by_name: HashMap<(String, u32), ObjId>,
}

impl ZygoteIndex {
    /// Build the index from a process heap (scans for template objects).
    pub fn build(program: &Program, heap: &Heap) -> ZygoteIndex {
        let mut by_name = HashMap::new();
        for (id, obj) in heap.iter() {
            if let Some(seq) = obj.zygote_seq {
                let cname = program.class(obj.class).name.clone();
                by_name.insert((cname, seq), id);
            }
        }
        ZygoteIndex { by_name }
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }
    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Resolve a (class, seq) name to the local object.
    pub fn lookup(&self, class_name: &str, seq: u32) -> Result<ObjId> {
        self.by_name
            .get(&(class_name.to_string(), seq))
            .copied()
            .ok_or_else(|| {
                CloneCloudError::migration(format!(
                    "no local Zygote object ({class_name}, {seq}) — template mismatch"
                ))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::zygote::{build_template, install_system_classes};
    use std::sync::Arc;

    #[test]
    fn independent_boots_resolve_same_names() {
        let mut p = Program::new();
        install_system_classes(&mut p);
        let p = Arc::new(p);
        // Two independently-built templates (same parameters) — the §4.3
        // assumption: same (class, seq) names on both devices.
        let phone = build_template(&p, 300, 7);
        let clone = build_template(&p, 300, 7);
        let pi = ZygoteIndex::build(&p, &phone);
        let ci = ZygoteIndex::build(&p, &clone);
        assert_eq!(pi.len(), 300);
        assert_eq!(ci.len(), 300);
        for (id, obj) in phone.iter() {
            let name = p.class(obj.class).name.clone();
            let seq = obj.zygote_seq.unwrap();
            assert_eq!(pi.lookup(&name, seq).unwrap(), id);
            // The clone resolves the same name (possibly different id,
            // same (class, seq) object).
            let cid = ci.lookup(&name, seq).unwrap();
            assert_eq!(clone.get(cid).unwrap().zygote_seq, Some(seq));
        }
    }

    #[test]
    fn missing_name_is_an_error() {
        let mut p = Program::new();
        install_system_classes(&mut p);
        let p = Arc::new(p);
        let h = build_template(&p, 10, 1);
        let idx = ZygoteIndex::build(&p, &h);
        assert!(idx.lookup("sys.String", 9999).is_err());
    }
}
