//! The warm pool: pre-forked clone processes.
//!
//! Provisioning a clone is the farm's only expensive control-plane step:
//! fork a process image from the deterministic Zygote template
//! (`appvm::zygote::build_template`). The pool pays that cost off the
//! session critical path — each worker pre-forks `target` processes at
//! startup and re-fills **only while its job queue is empty** — so a
//! session start normally just pops a ready process and attaches the
//! phone's synchronized file system (a *pool hit*). When demand outruns
//! the pool, the fork happens inline (a *cold fork*, counted as a miss);
//! the hit/miss split is the pool's headline metric.
//!
//! `Process` is deliberately not `Send` (each node loads its own compute
//! backend on its own thread), so a `WarmPool` is per-worker state, owned
//! and touched only by that worker's OS thread. Only the counters are
//! shared, via [`PoolStats`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::appvm::process::Process;
use crate::appvm::{Heap, Program};
use crate::config::CostParams;
use crate::device::{DeviceSpec, Location};
use crate::vfs::SimFs;

use super::EnvFactory;

/// Farm-wide pool counters (all workers share one instance).
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Session starts served by a pre-forked process.
    pub hits: AtomicU64,
    /// Session starts that had to cold-fork inline.
    pub misses: AtomicU64,
    /// Background re-forks performed while a worker was idle.
    pub refills: AtomicU64,
}

/// One worker's reserve of pre-forked clone processes.
pub struct WarmPool {
    program: Arc<Program>,
    template: Arc<Heap>,
    device: DeviceSpec,
    costs: CostParams,
    make_env: EnvFactory,
    stats: Arc<PoolStats>,
    ready: Vec<Process>,
    target: usize,
}

impl WarmPool {
    /// Build a pool and pre-fork `target` processes immediately.
    pub fn new(
        program: Arc<Program>,
        template: Arc<Heap>,
        costs: CostParams,
        make_env: EnvFactory,
        target: usize,
        stats: Arc<PoolStats>,
    ) -> WarmPool {
        let mut pool = WarmPool {
            program,
            template,
            device: DeviceSpec::clone_desktop(),
            costs,
            make_env,
            stats,
            ready: Vec::with_capacity(target),
            target,
        };
        for _ in 0..target {
            let p = pool.fork_one();
            pool.ready.push(p);
        }
        pool
    }

    fn fork_one(&self) -> Process {
        let mut p = Process::fork_from_zygote(
            self.program.clone(),
            &self.template,
            self.device.clone(),
            Location::Clone,
            (self.make_env)(SimFs::new()),
        );
        p.cost_params = Some(self.costs.clone());
        p
    }

    /// Take a clone process for a new phone session, attaching the
    /// phone's synchronized file system. Pops a warm process when one is
    /// ready; cold-forks inline otherwise.
    pub fn take(&mut self, fs: &SimFs) -> Process {
        let mut p = match self.ready.pop() {
            Some(p) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                p
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                self.fork_one()
            }
        };
        p.env.vfs = fs.synchronize();
        p
    }

    /// Re-fork up to the target. Callers invoke this only when idle, so
    /// refills never delay an admitted migration.
    pub fn refill(&mut self) {
        while self.ready.len() < self.target {
            let p = self.fork_one();
            self.ready.push(p);
            self.stats.refills.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Pre-forked processes currently ready.
    pub fn ready(&self) -> usize {
        self.ready.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::zygote::{build_template, install_system_classes};

    fn parts() -> (Arc<Program>, Arc<Heap>) {
        let mut p = Program::new();
        install_system_classes(&mut p);
        let p = p.into_shared();
        let t = Arc::new(build_template(&p, 100, 9));
        (p, t)
    }

    #[test]
    fn hits_then_cold_forks_then_refills() {
        let (program, template) = parts();
        let stats = Arc::new(PoolStats::default());
        let mut pool = WarmPool::new(
            program,
            template,
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
            2,
            stats.clone(),
        );
        assert_eq!(pool.ready(), 2);

        let mut fs = SimFs::new();
        fs.add("x", vec![1, 2, 3]);
        let a = pool.take(&fs);
        let b = pool.take(&fs);
        let c = pool.take(&fs);
        assert_eq!(stats.hits.load(Ordering::Relaxed), 2);
        assert_eq!(stats.misses.load(Ordering::Relaxed), 1);
        // Every taken process got the session fs and the zygote heap.
        for p in [&a, &b, &c] {
            assert_eq!(p.env.vfs.count(), 1);
            assert_eq!(p.heap.len(), 100);
            assert_eq!(p.location, Location::Clone);
        }

        pool.refill();
        assert_eq!(pool.ready(), 2);
        assert_eq!(stats.refills.load(Ordering::Relaxed), 2);
    }
}
