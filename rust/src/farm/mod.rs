//! CloneFarm: a multi-tenant clone-pool scheduler (beyond the paper).
//!
//! The paper's runtime pairs one phone with one clone over one channel
//! (`nodemanager::CloneServer`). This subsystem is the fleet layer that
//! ThinkAir-style elasticity asks for: **N** concurrent phone sessions
//! served by **M** clone workers.
//!
//! Pieces (one module each):
//! * [`pool`] — warm pool of processes pre-forked from the deterministic
//!   Zygote template; provisioning amortized off the critical path
//!   (pool-hit vs cold-fork is the headline metric).
//! * [`policy`] — pluggable placement: round-robin, least-loaded, and
//!   affinity-by-phone (keeps a phone's provisioned clone slot — heap,
//!   synchronized fs — warm across repeat migrations).
//! * [`admission`] — bounded in-flight window with backpressure, so the
//!   farm queues predictably instead of collapsing under load.
//! * [`worker`] — one OS thread per clone worker; owns the non-`Send`
//!   processes and backends; execution core shared with `CloneServer`
//!   (`nodemanager::execute_migration`).
//! * [`session`] — [`FarmClone`], the phone-side handle implementing
//!   `exec::CloneChannel`; many sessions multiplex over the worker pool.
//! * [`farm`] — [`CloneFarm`] orchestration, [`FarmHandle`]s, and the
//!   [`FarmStats`] snapshot.
//!
//! The network front door (accept loop speaking the existing
//! `protocol::Msg` wire protocol) lives in `nodemanager::gateway`
//! (blocking, thread-per-connection) and `nodemanager::gateway_async`
//! (nonblocking sharded readiness loop); see `farm/README.md` and
//! `docs/ARCHITECTURE.md`.
#![warn(missing_docs)]

pub mod admission;
#[allow(clippy::module_inception)]
pub mod farm;
pub mod policy;
pub mod pool;
pub mod session;
pub(crate) mod worker;

pub use admission::Admission;
pub use farm::{CloneFarm, FarmConfig, FarmHandle, FarmStats, WorkerStats};
pub use policy::{PlacementPolicy, Scheduler};
pub use pool::{PoolStats, WarmPool};
pub use session::{FarmClone, PendingProbe, PendingRoundtrip, SessionStats, Submit};

use crate::appvm::natives::NodeEnv;
use crate::vfs::SimFs;

/// Factory for per-worker node environments. Invoked on the worker's own
/// OS thread, so the compute backend (PJRT handles are thread-local) is
/// created where it is used — the reason this is a factory and not a
/// shared environment.
pub type EnvFactory = std::sync::Arc<dyn Fn(SimFs) -> NodeEnv + Send + Sync>;

/// Assembly for the synthetic farm workload used by the `farm` CLI demo,
/// `examples/farm_offload.rs`, and `benches/farm_throughput.rs`: read the
/// phone's file at the clone (exercises fs sync), byte-sum it, then spin
/// `iters` loop iterations of clone-side compute. Result: byte sum +
/// `iters`, checkable bit-exactly against a monolithic run.
pub fn synthetic_offload_src(iters: i64) -> String {
    format!(
        r#"
class FarmWork app
  static out
  method main nargs=0 regs=4
    invoke r0 FarmWork.work
    puts FarmWork.out r0
    retv
  end
  method work nargs=0 regs=12
    ccstart 0
    const r0 0
    const r1 0
    const r2 64
    invoke r3 FarmWork.read r0 r1 r2
    len r4 r3
    const r5 0
    const r6 0
  bytes:
    ifge r5 r4 @bdone
    aget r7 r3 r5
    add r6 r6 r7
    const r8 1
    add r5 r5 r8
    goto @bytes
  bdone:
    const r5 0
    const r8 1
    const r9 {iters}
  spin:
    ifge r5 r9 @sdone
    add r6 r6 r8
    add r5 r5 r8
    goto @spin
  sdone:
    ccstop 0
    ret r6
  end
  method read nargs=3 regs=3 native=fs.read
end
"#
    )
}

/// The value `synthetic_offload_src` computes for a given phone fs.
pub fn synthetic_expected(fs: &SimFs, iters: i64) -> i64 {
    let bytes = fs.read(0, 0, 64).unwrap_or(&[]);
    bytes.iter().map(|&b| b as i64).sum::<i64>() + iters
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::process::Process;
    use crate::appvm::zygote::build_template;
    use crate::config::{CostParams, ExecTierKind, NetworkProfile};
    use crate::device::{DeviceSpec, Location};
    use crate::exec::run_distributed;
    use crate::util::rng::Rng;

    const ITERS: i64 = 5_000;
    const ZY_OBJECTS: usize = 300;
    const ZY_SEED: u64 = 7;

    fn farm_program() -> Arc<crate::appvm::Program> {
        let p = Arc::new(assemble(&synthetic_offload_src(ITERS)).unwrap());
        crate::appvm::verifier::verify_program(&p).unwrap();
        p
    }

    fn phone_fs(phone: u64) -> SimFs {
        let mut bytes = vec![0u8; 64];
        Rng::new(phone + 1).fill_bytes(&mut bytes);
        let mut fs = SimFs::new();
        fs.add("data.bin", bytes);
        fs
    }

    /// N concurrent phone sessions over M workers: every phone's merged
    /// result must be bit-identical to its own monolithic expectation.
    #[test]
    fn concurrent_sessions_merge_correct_results() {
        let program = farm_program();
        let cfg = FarmConfig {
            workers: 2,
            warm_per_worker: 1,
            queue_depth: 4,
            policy: PlacementPolicy::RoundRobin,
            zygote_objects: ZY_OBJECTS,
            zygote_seed: ZY_SEED,
            fuel: 100_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::Tier1,
        };
        let farm = CloneFarm::start(
            program.clone(),
            cfg,
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        let handle = farm.handle();
        let template = Arc::new(build_template(&program, ZY_OBJECTS, ZY_SEED));

        let mut joins = Vec::new();
        for phone in 0..6u64 {
            let program = program.clone();
            let template = template.clone();
            let fs = phone_fs(phone);
            let expected = synthetic_expected(&fs, ITERS);
            let mut session = handle.session(phone, fs.clone());
            joins.push(std::thread::spawn(move || {
                let mut p = Process::fork_from_zygote(
                    program.clone(),
                    &template,
                    DeviceSpec::phone_g1(),
                    Location::Mobile,
                    NodeEnv::with_rust_compute(fs),
                );
                let out = run_distributed(
                    &mut p,
                    &mut session,
                    &NetworkProfile::wifi(),
                    &CostParams::default(),
                )
                .unwrap();
                assert_eq!(out.migrations, 1);
                let main = program.entry().unwrap();
                let got = p.statics[main.class.0 as usize][0].as_int().unwrap();
                assert_eq!(got, expected, "phone {phone} merged result");
                session.close();
                session.stats.clone()
            }));
        }
        for j in joins {
            let stats = j.join().unwrap();
            assert_eq!(stats.migrations, 1);
            assert_eq!(stats.errors, 0);
        }

        let stats = farm.shutdown();
        assert_eq!(stats.sessions_opened, 6);
        assert_eq!(stats.sessions_closed, 6);
        assert_eq!(stats.migrations, 6);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.pool_hits + stats.pool_misses, 6, "6 provisions");
        assert!(stats.pool_hits >= 2, "warm pool served the first takes");
        assert!(stats.instrs_executed > ITERS as u64 * 6);
        assert_eq!(stats.worker_jobs.iter().sum::<u64>(), 6);
    }

    /// Repeat migrations from one phone under affinity reuse one clone
    /// slot: exactly one provision however many roundtrips happen.
    #[test]
    fn affinity_reuses_the_phone_slot() {
        let program = farm_program();
        let cfg = FarmConfig {
            workers: 3,
            warm_per_worker: 1,
            queue_depth: 4,
            policy: PlacementPolicy::Affinity,
            zygote_objects: ZY_OBJECTS,
            zygote_seed: ZY_SEED,
            fuel: 100_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::Tier1,
        };
        let farm = CloneFarm::start(
            program.clone(),
            cfg,
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        let template = Arc::new(build_template(&program, ZY_OBJECTS, ZY_SEED));
        let fs = phone_fs(42);
        let expected = synthetic_expected(&fs, ITERS);
        let mut session = farm.session(42, fs.clone());

        for _ in 0..3 {
            let mut p = Process::fork_from_zygote(
                program.clone(),
                &template,
                DeviceSpec::phone_g1(),
                Location::Mobile,
                NodeEnv::with_rust_compute(fs.synchronize()),
            );
            run_distributed(
                &mut p,
                &mut session,
                &NetworkProfile::wifi(),
                &CostParams::default(),
            )
            .unwrap();
            let main = program.entry().unwrap();
            assert_eq!(
                p.statics[main.class.0 as usize][0].as_int(),
                Some(expected)
            );
        }
        session.close();
        drop(session);
        let stats = farm.shutdown();
        assert_eq!(stats.migrations, 3);
        assert_eq!(
            stats.pool_hits + stats.pool_misses,
            1,
            "one provision for three migrations"
        );
    }

    /// Affinity-pinned worker slots retain the delta baseline across
    /// repeat offloads: after first contact every migration rides a
    /// delta capsule; recycling the slot (session close) degrades the
    /// next delta to a `NeedFull` fallback and the session re-arms.
    #[test]
    fn delta_baseline_survives_repeat_offloads_and_recycle() {
        let program = farm_program();
        let cfg = FarmConfig {
            workers: 2,
            warm_per_worker: 1,
            queue_depth: 4,
            policy: PlacementPolicy::Affinity,
            zygote_objects: ZY_OBJECTS,
            zygote_seed: ZY_SEED,
            fuel: 100_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::Tier1,
        };
        let farm = CloneFarm::start(
            program.clone(),
            cfg,
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        let template = Arc::new(build_template(&program, ZY_OBJECTS, ZY_SEED));
        let fs = phone_fs(7);
        let expected = synthetic_expected(&fs, ITERS);
        let main = program.entry().unwrap();

        let mut p = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(fs.synchronize()),
        );
        let mut msess = crate::migration::MobileSession::new(true);

        let mut session = farm.session(7, fs.clone());
        session.set_delta(true);
        for _ in 0..3 {
            let out = crate::exec::run_distributed_session(
                &mut p,
                &mut session,
                &NetworkProfile::wifi(),
                &CostParams::default(),
                &mut msess,
            )
            .unwrap();
            assert_eq!(out.delta_fallbacks, 0);
            assert_eq!(
                p.statics[main.class.0 as usize][0].as_int(),
                Some(expected)
            );
        }
        // Recycle the slot: close retires the phone's clone on every
        // worker; the phone still holds its baseline, so the next delta
        // is rejected and transparently resent in full.
        session.close();
        drop(session);
        let mut session = farm.session(7, fs.clone());
        session.set_delta(true);
        let out = crate::exec::run_distributed_session(
            &mut p,
            &mut session,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut msess,
        )
        .unwrap();
        assert_eq!(out.delta_fallbacks, 1, "evicted slot forced one fallback");
        assert_eq!(
            p.statics[main.class.0 as usize][0].as_int(),
            Some(expected),
            "fallback run still merges the right result"
        );
        session.close();
        drop(session);

        let stats = farm.shutdown();
        assert_eq!(stats.migrations, 4);
        assert_eq!(stats.errors, 0, "NeedFull is not an error");
        assert_eq!(
            stats.delta_migrations, 2,
            "repeat offloads on the warm slot rode deltas"
        );
        assert_eq!(stats.delta_rejects, 1);
    }

    /// A scatter-annotated span fans its sub-jobs across the farm's warm
    /// lanes: 4 sub-jobs, one gather, bit-identical result, and the
    /// scatter counters account every lane.
    #[test]
    fn scatter_fans_across_farm_lanes() {
        use crate::exec::{
            run_distributed_policy, scatter_workload_expected, scatter_workload_src,
            PolicyEngine,
        };

        const SLOTS: i64 = 8;
        const CELLS: i64 = 64;
        let program =
            Arc::new(assemble(&scatter_workload_src(SLOTS, CELLS, 4)).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let cfg = FarmConfig {
            workers: 2,
            warm_per_worker: 2,
            queue_depth: 4,
            policy: PlacementPolicy::RoundRobin,
            zygote_objects: ZY_OBJECTS,
            zygote_seed: ZY_SEED,
            fuel: 100_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::Tier1,
        };
        let farm = CloneFarm::start(
            program.clone(),
            cfg,
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        let template = Arc::new(build_template(&program, ZY_OBJECTS, ZY_SEED));
        let fs = phone_fs(7);
        let main = program.entry().unwrap();

        let mut p = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(fs.clone()),
        );
        let mut msess = crate::migration::MobileSession::new(true);
        let mut engine = PolicyEngine::force_offload();
        engine.set_span_shards(0, 4);

        let mut session = farm.session(7, fs.clone());
        session.set_delta(true);
        let out = run_distributed_policy(
            &mut p,
            &mut session,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut msess,
            &mut engine,
        )
        .unwrap();
        assert_eq!(out.scatter_offloads, 1, "the gather committed");
        assert_eq!(out.scatter_shards, 4);
        assert_eq!(out.scatter_failures, 0);
        assert_eq!(out.channel_errors, 0);
        assert_eq!(
            p.statics[main.class.0 as usize][1].as_int(),
            Some(scatter_workload_expected(SLOTS, CELLS)),
            "farm-gathered result is bit-identical"
        );
        session.close();
        drop(session);

        let stats = farm.shutdown();
        assert_eq!(stats.scatter_subjobs, 4, "every lane served one sub-job");
        assert_eq!(stats.scatter_gathers, 1);
        assert_eq!(stats.scatter_lanes, 4);
        assert_eq!(stats.scatter_failed, 0);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.sessions_opened, 1);
        assert_eq!(stats.sessions_closed, 1);
    }

    /// A recycled slot is detected by the digest heartbeat BEFORE any
    /// delta is built: the driver pre-arms the full path, so the farm
    /// sees zero doomed deltas (`delta_rejects == 0`) — contrast with
    /// `delta_baseline_survives_repeat_offloads_and_recycle`, where the
    /// same recycle costs one shipped-and-rejected delta.
    #[test]
    fn heartbeat_prearms_full_capture_after_recycle() {
        let program = farm_program();
        let cfg = FarmConfig {
            workers: 2,
            warm_per_worker: 1,
            queue_depth: 4,
            policy: PlacementPolicy::Affinity,
            zygote_objects: ZY_OBJECTS,
            zygote_seed: ZY_SEED,
            fuel: 100_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::Tier1,
        };
        let farm = CloneFarm::start(
            program.clone(),
            cfg,
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        let template = Arc::new(build_template(&program, ZY_OBJECTS, ZY_SEED));
        let fs = phone_fs(9);
        let expected = synthetic_expected(&fs, ITERS);
        let main = program.entry().unwrap();

        let mut p = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(fs.synchronize()),
        );
        let mut msess = crate::migration::MobileSession::new(true);
        msess.heartbeat_every(std::time::Duration::ZERO);

        let mut session = farm.session(9, fs.clone());
        session.set_delta(true);
        crate::exec::run_distributed_session(
            &mut p,
            &mut session,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut msess,
        )
        .unwrap();
        // Recycle the slot; the phone still holds its baseline.
        session.close();
        drop(session);
        assert!(msess.has_baseline());

        let mut session = farm.session(9, fs.clone());
        session.set_delta(true);
        let out = crate::exec::run_distributed_session(
            &mut p,
            &mut session,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut msess,
        )
        .unwrap();
        assert_eq!(out.heartbeat_preempts, 1, "divergence caught by heartbeat");
        assert_eq!(out.delta_fallbacks, 0, "no doomed delta was shipped");
        assert_eq!(out.full_roundtrips, 1);
        assert_eq!(
            p.statics[main.class.0 as usize][0].as_int(),
            Some(expected)
        );
        session.close();
        drop(session);

        let stats = farm.shutdown();
        assert_eq!(stats.delta_rejects, 0, "NeedFull never cost a capsule");
        assert_eq!(stats.heartbeats, 1);
        assert_eq!(stats.heartbeat_divergent, 1);
    }

    /// Soak: ≥100 roundtrips on one affinity-pinned slot. Periodic slot
    /// GC keeps tombstone threads and the slot heap bounded (the seed
    /// leaked one tombstone thread per roundtrip), without ever evicting
    /// the live delta baseline.
    #[test]
    fn soak_slot_gc_bounds_clone_growth() {
        const ROUNDTRIPS: usize = 110;
        const GC_INTERVAL: u64 = 8;
        let iters: i64 = 2_000;
        let program = Arc::new(assemble(&synthetic_offload_src(iters)).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let cfg = FarmConfig {
            workers: 2,
            warm_per_worker: 1,
            queue_depth: 4,
            policy: PlacementPolicy::Affinity,
            zygote_objects: ZY_OBJECTS,
            zygote_seed: ZY_SEED,
            fuel: 100_000_000,
            slot_gc_interval: GC_INTERVAL,
            exec_tier: ExecTierKind::Tier1,
        };
        let farm = CloneFarm::start(
            program.clone(),
            cfg,
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        let template = Arc::new(build_template(&program, ZY_OBJECTS, ZY_SEED));
        let fs = phone_fs(3);
        let expected = synthetic_expected(&fs, iters);
        let main = program.entry().unwrap();

        let mut p = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(fs.synchronize()),
        );
        let mut msess = crate::migration::MobileSession::new(true);
        let mut session = farm.session(3, fs.clone());
        session.set_delta(true);
        for _ in 0..ROUNDTRIPS {
            let out = crate::exec::run_distributed_session(
                &mut p,
                &mut session,
                &NetworkProfile::wifi(),
                &CostParams::default(),
                &mut msess,
            )
            .unwrap();
            assert_eq!(out.delta_fallbacks, 0, "GC never evicted the baseline");
            assert_eq!(
                p.statics[main.class.0 as usize][0].as_int(),
                Some(expected)
            );
        }
        session.close();
        drop(session);

        let stats = farm.shutdown();
        assert_eq!(stats.migrations as usize, ROUNDTRIPS);
        assert_eq!(stats.errors, 0);
        assert_eq!(
            stats.delta_migrations as usize,
            ROUNDTRIPS - 1,
            "every repeat offload rode a delta"
        );
        assert!(
            stats.slot_gc_runs >= (ROUNDTRIPS as u64 / GC_INTERVAL) - 1,
            "periodic GC ran ({} runs)",
            stats.slot_gc_runs
        );
        assert!(stats.slot_gc_threads > 0, "tombstone threads reclaimed");
        assert!(
            stats.slot_threads_peak <= GC_INTERVAL + 1,
            "no per-roundtrip tombstone growth across {ROUNDTRIPS} roundtrips \
             (peak {} threads)",
            stats.slot_threads_peak
        );
        assert!(
            stats.slot_heap_peak < ZY_OBJECTS as u64 + 300,
            "slot heap bounded near the template size (peak {} objects)",
            stats.slot_heap_peak
        );
    }

    /// A closed session refuses further roundtrips.
    #[test]
    fn closed_session_errors() {
        let program = farm_program();
        let farm = CloneFarm::start(
            program,
            FarmConfig {
                workers: 1,
                warm_per_worker: 0,
                queue_depth: 1,
                policy: PlacementPolicy::RoundRobin,
                zygote_objects: 50,
                zygote_seed: 1,
                fuel: 1_000_000,
                slot_gc_interval: 8,
                exec_tier: ExecTierKind::Tier1,
            },
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        let mut session = farm.session(1, SimFs::new());
        session.close();
        let err = session.roundtrip_bytes(vec![]).unwrap_err().to_string();
        assert!(err.contains("closed"), "{err}");
        farm.shutdown();
    }
}
