//! Phone sessions: the farm-side clone channel.
//!
//! A [`FarmClone`] is what a phone holds instead of a dedicated
//! `NodeManager` channel: a lightweight handle that runs each migration
//! roundtrip through admission → placement → a worker queue, and blocks
//! for the reverse capture. It implements `exec::CloneChannel`, so
//! `exec::run_distributed` drives a farm session exactly like an inline
//! or TCP clone — N phones hold N sessions multiplexed over M workers.
//!
//! Closing a session (explicitly or on drop) retires the phone's clone
//! slots on every worker.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::error::{CloneCloudError, Result};
use crate::exec::distributed::CloneChannel;
use crate::migration::MobileSession;
use crate::nodemanager::{HeartbeatOutcome, TransferBytes};
use crate::vfs::SimFs;

use super::farm::FarmShared;
use super::worker::{FarmMsg, Job};

/// Per-session counters (the admission wait is the queueing signal the
/// phone actually feels).
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    /// Completed migration roundtrips.
    pub migrations: u64,
    /// Failed roundtrips (worker loss, execution faults; NeedFull
    /// fallbacks are not errors).
    pub errors: u64,
    /// Forward capsule bytes shipped (including rejected deltas).
    pub bytes_up: u64,
    /// Reverse capsule bytes received.
    pub bytes_down: u64,
    /// Total milliseconds this session spent blocked at admission.
    pub admission_wait_ms: f64,
}

/// Outcome of a non-blocking submission
/// ([`FarmClone::try_begin_roundtrip`]).
pub enum Submit {
    /// Admitted and queued on a worker: poll the ticket.
    Pending(PendingRoundtrip),
    /// The admission window was full. The forward frame comes back
    /// untouched so the caller can retry later without a copy.
    Backpressure(Vec<u8>),
}

/// An admitted, in-flight roundtrip awaiting its reverse capture.
///
/// Holds the session's admission slot: polling it to completion
/// releases the slot, and dropping an unfinished ticket (connection
/// died mid-roundtrip) releases it too — admission can never leak.
pub struct PendingRoundtrip {
    shared: Arc<FarmShared>,
    reply_rx: mpsc::Receiver<Result<Vec<u8>>>,
    worker: usize,
    up: u64,
    admitted: bool,
}

impl PendingRoundtrip {
    /// Release the admission slot exactly once.
    fn settle_admission(&mut self) {
        if self.admitted {
            self.admitted = false;
            self.shared.admission.release();
        }
    }
}

impl Drop for PendingRoundtrip {
    fn drop(&mut self) {
        self.settle_admission();
    }
}

/// An in-flight heartbeat probe ([`FarmClone::try_begin_heartbeat`]).
/// Probes bypass admission, so dropping one leaks nothing.
pub struct PendingProbe {
    reply_rx: mpsc::Receiver<Result<()>>,
    worker: usize,
}

fn worker_dropped_reply(worker: usize) -> CloneCloudError {
    CloneCloudError::Transport(format!(
        "farm worker {worker} dropped the session reply"
    ))
}

/// One phone's session on the clone farm.
pub struct FarmClone {
    shared: Arc<FarmShared>,
    senders: Vec<Sender<FarmMsg>>,
    phone: u64,
    fs: Arc<SimFs>,
    fs_version: u32,
    closed: bool,
    /// Delta capsules negotiated for this session. The affinity-pinned
    /// worker slot then keeps the baseline cache across roundtrips.
    delta: bool,
    /// Session string dictionary negotiated (the worker slot keeps the
    /// clone-side replica; like delta, it needs affinity placement).
    dict: bool,
    /// Trace context negotiated (`CAP_TRACE_CTX`). Unlike delta/dict it
    /// is stateless per job — no affinity requirement — so the gateway
    /// never masks it.
    trace: bool,
    /// Live per-session counters.
    pub stats: SessionStats,
}

impl FarmClone {
    pub(crate) fn new(
        shared: Arc<FarmShared>,
        senders: Vec<Sender<FarmMsg>>,
        phone: u64,
        fs: SimFs,
    ) -> FarmClone {
        FarmClone {
            shared,
            senders,
            phone,
            fs: Arc::new(fs),
            fs_version: 0,
            closed: false,
            delta: false,
            dict: false,
            trace: false,
            stats: SessionStats::default(),
        }
    }

    /// The phone id this session is keyed on (placement hash input).
    pub fn phone_id(&self) -> u64 {
        self.phone
    }

    /// Enable/disable delta capsules for this session (the gateway arms
    /// this after Hello negotiation; in-process callers set it directly).
    pub fn set_delta(&mut self, on: bool) {
        self.delta = on;
    }

    /// Whether delta capsules are enabled on this session.
    pub fn delta_enabled(&self) -> bool {
        self.delta
    }

    /// Enable/disable the shared string dictionary for this session
    /// (the gateway arms it from the Hello negotiation; in-process
    /// callers set it directly).
    pub fn set_dict(&mut self, on: bool) {
        self.dict = on;
    }

    /// Whether the session dictionary is enabled.
    pub fn dict_enabled(&self) -> bool {
        self.dict
    }

    /// Enable/disable the trace-context envelope for this session (the
    /// gateway arms it from the Hello negotiation; in-process callers
    /// set it directly).
    pub fn set_trace(&mut self, on: bool) {
        self.trace = on;
    }

    /// Whether the trace-context envelope is enabled.
    pub fn trace_enabled(&self) -> bool {
        self.trace
    }

    /// Replace the session's synchronized file system. Clone slots pick
    /// the new image up on their next migration (version check).
    pub fn set_fs(&mut self, fs: SimFs) {
        self.fs = Arc::new(fs);
        self.fs_version += 1;
    }

    /// One migration roundtrip through the farm: admission (bounded,
    /// blocking), placement, worker execution, reverse capture.
    pub fn roundtrip_bytes(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        if self.closed {
            return Err(CloneCloudError::Transport("farm session closed".into()));
        }
        let waited_ms = self.shared.admission.acquire();
        self.stats.admission_wait_ms += waited_ms;
        self.shared
            .admission_wait_us
            .fetch_add((waited_ms * 1e3) as u64, Ordering::Relaxed);

        let up = forward.len() as u64;
        let (worker, reply_rx) = match self.submit_job(forward, 0) {
            Ok(x) => x,
            Err(e) => {
                self.shared.admission.release();
                return Err(e);
            }
        };
        let reply = reply_rx
            .recv()
            .map_err(|_| worker_dropped_reply(worker));
        self.shared.admission.release();
        self.settle(up, reply)
    }

    /// Queue one roundtrip **without blocking**: the async gateway's
    /// shard threads submit here and keep sweeping other connections
    /// while the farm executes. A full admission window hands the
    /// forward frame back untouched ([`Submit::Backpressure`]) so the
    /// caller retries on a later sweep with no copy. A successful
    /// submission yields a [`PendingRoundtrip`] ticket to poll with
    /// [`FarmClone::poll_roundtrip`].
    pub fn try_begin_roundtrip(&mut self, forward: Vec<u8>) -> Result<Submit> {
        if self.closed {
            return Err(CloneCloudError::Transport("farm session closed".into()));
        }
        if !self.shared.admission.try_acquire() {
            return Ok(Submit::Backpressure(forward));
        }
        let up = forward.len() as u64;
        match self.submit_job(forward, 0) {
            Ok((worker, reply_rx)) => Ok(Submit::Pending(PendingRoundtrip {
                shared: self.shared.clone(),
                reply_rx,
                worker,
                up,
                admitted: true,
            })),
            Err(e) => {
                self.shared.admission.release();
                Err(e)
            }
        }
    }

    /// Poll a ticket from [`FarmClone::try_begin_roundtrip`]: `None`
    /// while the farm is still executing, `Some(result)` exactly once
    /// when the reverse capture (or its error) is in. Bookkeeping —
    /// admission release, per-session and farm-wide counters — is
    /// identical to the blocking path, so blocking and async gateways
    /// report the same numbers for the same work.
    pub fn poll_roundtrip(
        &mut self,
        pending: &mut PendingRoundtrip,
    ) -> Option<Result<(Vec<u8>, TransferBytes)>> {
        let reply = match pending.reply_rx.try_recv() {
            Ok(r) => Ok(r),
            Err(mpsc::TryRecvError::Empty) => return None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Err(worker_dropped_reply(pending.worker))
            }
        };
        pending.settle_admission();
        Some(self.settle(pending.up, reply))
    }

    /// Placement + worker handoff shared by the blocking and pending
    /// paths. The caller owns the admission slot; on a send failure the
    /// scheduler bookkeeping is undone and the error counted, but the
    /// slot is NOT released here (the caller knows how it acquired it).
    fn submit_job(
        &mut self,
        forward: Vec<u8>,
        lane: u32,
    ) -> Result<(usize, mpsc::Receiver<Result<Vec<u8>>>)> {
        // Lane 0 keeps the phone's affinity placement (the delta/dict
        // slot lives there); scatter lanes perturb the placement key so
        // the shards of one phone spread across workers instead of
        // queueing behind each other.
        let key = self
            .phone
            .wrapping_add((lane as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let worker = self.shared.scheduler.pick(key);
        self.shared.scheduler.job_started(worker);
        let (reply_tx, reply_rx) = mpsc::channel();
        let job = Job {
            phone: self.phone,
            lane,
            fs: self.fs.clone(),
            fs_version: self.fs_version,
            forward,
            delta_ok: self.delta,
            // The session dictionary lives on the lane-0 affinity slot;
            // arming it on scatter lanes would grow N diverging replicas
            // of the phone's one dictionary. Sub-jobs ship plain names.
            dict_ok: self.dict && lane == 0,
            submitted: Instant::now(),
            reply: reply_tx,
        };
        if self.senders[worker].send(FarmMsg::Work(job)).is_err() {
            self.shared.scheduler.job_finished(worker);
            self.stats.errors += 1;
            self.shared.errors.fetch_add(1, Ordering::Relaxed);
            return Err(CloneCloudError::Transport(format!(
                "farm worker {worker} is down"
            )));
        }
        Ok((worker, reply_rx))
    }

    /// Fold a worker reply into session + farm counters (one place, so
    /// every path — blocking, polled — accounts identically).
    fn settle(
        &mut self,
        up: u64,
        reply: Result<Result<Vec<u8>>>,
    ) -> Result<(Vec<u8>, TransferBytes)> {
        match reply {
            Ok(Ok(bytes)) => {
                let down = bytes.len() as u64;
                self.stats.migrations += 1;
                self.stats.bytes_up += up;
                self.stats.bytes_down += down;
                self.shared.migrations.fetch_add(1, Ordering::Relaxed);
                self.shared.bytes_up.fetch_add(up, Ordering::Relaxed);
                self.shared.bytes_down.fetch_add(down, Ordering::Relaxed);
                Ok((bytes, TransferBytes { up, down }))
            }
            // NeedFull is the recoverable delta-fallback signal, not a
            // session failure: the driver re-sends a full capture. The
            // rejected delta still crossed the uplink — count it, so the
            // farm's byte counters agree with the driver's.
            Ok(Err(e)) if e.is_need_full() => {
                self.stats.bytes_up += up;
                self.shared.bytes_up.fetch_add(up, Ordering::Relaxed);
                Err(e)
            }
            Ok(Err(e)) => {
                self.stats.errors += 1;
                self.shared.errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
            Err(e) => {
                self.stats.errors += 1;
                self.shared.errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Scatter one migration over `frames.len()` lanes: sub-job frame i
    /// is queued on slot `(phone, i)` and the replies are gathered back
    /// in shard order. The whole fan-out holds **one** admission slot —
    /// a scatter is one logical migration, and acquiring N slots while
    /// holding earlier ones could deadlock two concurrent scatters on a
    /// small admission window.
    ///
    /// Any dead lane or shard error fails the gather (the driver
    /// degrades to a single-clone offload); queued replies are still
    /// drained so no worker blocks on a dropped receiver and the byte
    /// counters stay honest.
    pub fn scatter_bytes(
        &mut self,
        frames: Vec<Vec<u8>>,
    ) -> Result<(Vec<Vec<u8>>, TransferBytes)> {
        if self.closed {
            return Err(CloneCloudError::Transport("farm session closed".into()));
        }
        if frames.is_empty() {
            return Err(CloneCloudError::migration("scatter of zero sub-jobs"));
        }
        let waited_ms = self.shared.admission.acquire();
        self.stats.admission_wait_ms += waited_ms;
        self.shared
            .admission_wait_us
            .fetch_add((waited_ms * 1e3) as u64, Ordering::Relaxed);

        let mut up = 0u64;
        let mut pendings = Vec::with_capacity(frames.len());
        let mut submit_err = None;
        for (lane, forward) in frames.into_iter().enumerate() {
            up += forward.len() as u64;
            match self.submit_job(forward, lane as u32) {
                Ok(x) => pendings.push(x),
                Err(e) => {
                    submit_err = Some(e);
                    break;
                }
            }
        }
        let mut replies = Vec::with_capacity(pendings.len());
        for (worker, reply_rx) in pendings {
            replies.push(reply_rx.recv().map_err(|_| worker_dropped_reply(worker)));
        }
        self.shared.admission.release();
        if let Some(e) = submit_err {
            // submit_job already counted the error.
            self.shared.scatter_failed.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }

        let mut out = Vec::with_capacity(replies.len());
        let mut down = 0u64;
        for reply in replies {
            match reply {
                Ok(Ok(bytes)) => {
                    down += bytes.len() as u64;
                    out.push(bytes);
                }
                Ok(Err(e)) | Err(e) => {
                    self.stats.errors += 1;
                    self.shared.errors.fetch_add(1, Ordering::Relaxed);
                    self.shared.scatter_failed.fetch_add(1, Ordering::Relaxed);
                    // The uplink bytes crossed even though the gather
                    // failed — count them, like a rejected delta.
                    self.stats.bytes_up += up;
                    self.shared.bytes_up.fetch_add(up, Ordering::Relaxed);
                    return Err(e);
                }
            }
        }
        let lanes = out.len() as u64;
        self.stats.migrations += 1;
        self.stats.bytes_up += up;
        self.stats.bytes_down += down;
        self.shared.migrations.fetch_add(1, Ordering::Relaxed);
        self.shared.bytes_up.fetch_add(up, Ordering::Relaxed);
        self.shared.bytes_down.fetch_add(down, Ordering::Relaxed);
        self.shared.scatter_gathers.fetch_add(1, Ordering::Relaxed);
        self.shared.scatter_lanes.fetch_add(lanes, Ordering::Relaxed);
        Ok((out, TransferBytes { up, down }))
    }

    /// Digest-only heartbeat: verify the phone's baseline digest against
    /// the slot on the placement worker without building a capsule. The
    /// typed `NeedFull` error means the slot is gone or diverged — the
    /// caller should drop its baseline and plan a full capture.
    pub fn heartbeat_probe(&mut self, digest: u64, assignments: &[(u64, u64)]) -> Result<()> {
        let (worker, reply_rx) = self.submit_heartbeat(digest, assignments)?;
        reply_rx.recv().map_err(|_| {
            CloneCloudError::Transport(format!(
                "farm worker {worker} dropped the heartbeat reply"
            ))
        })?
    }

    /// Queue a heartbeat probe without blocking for the worker's
    /// answer; poll the ticket with [`FarmClone::poll_heartbeat`].
    /// Heartbeats bypass admission (they carry no capsule), so there is
    /// no backpressure arm.
    pub fn try_begin_heartbeat(
        &mut self,
        digest: u64,
        assignments: &[(u64, u64)],
    ) -> Result<PendingProbe> {
        let (worker, reply_rx) = self.submit_heartbeat(digest, assignments)?;
        Ok(PendingProbe { reply_rx, worker })
    }

    /// Poll a [`FarmClone::try_begin_heartbeat`] ticket: `None` while
    /// the worker is busy, the probe's result exactly once thereafter.
    pub fn poll_heartbeat(&mut self, pending: &mut PendingProbe) -> Option<Result<()>> {
        match pending.reply_rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(CloneCloudError::Transport(format!(
                    "farm worker {} dropped the heartbeat reply",
                    pending.worker
                ))))
            }
        }
    }

    fn submit_heartbeat(
        &mut self,
        digest: u64,
        assignments: &[(u64, u64)],
    ) -> Result<(usize, mpsc::Receiver<Result<()>>)> {
        if self.closed {
            return Err(CloneCloudError::Transport("farm session closed".into()));
        }
        // Affinity placement lands on the worker holding the slot; any
        // other policy answers NeedFull (delta is not armed there).
        let worker = self.shared.scheduler.pick(self.phone);
        let (reply_tx, reply_rx) = mpsc::channel();
        self.senders[worker]
            .send(FarmMsg::Heartbeat {
                phone: self.phone,
                digest,
                assignments: assignments.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| {
                CloneCloudError::Transport(format!("farm worker {worker} is down"))
            })?;
        Ok((worker, reply_rx))
    }

    /// End the session: retire this phone's clone slot on every worker.
    /// Idempotent; also invoked on drop.
    pub fn close(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        for s in &self.senders {
            let _ = s.send(FarmMsg::Retire { phone: self.phone });
        }
        self.shared.sessions_closed.fetch_add(1, Ordering::Relaxed);
    }
}

impl CloneChannel for FarmClone {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        self.roundtrip_bytes(forward)
    }

    fn delta_capable(&self) -> bool {
        self.delta_enabled()
    }

    fn disarm_delta(&mut self) {
        self.set_delta(false);
    }

    fn dict_capable(&self) -> bool {
        self.dict
    }

    fn trace_capable(&self) -> bool {
        self.trace
    }

    fn heartbeat(&mut self, session: &mut MobileSession) -> Result<HeartbeatOutcome> {
        if !self.delta {
            return Ok(HeartbeatOutcome::Unsupported);
        }
        crate::nodemanager::drive_heartbeat(session, |_epoch, digest, assignments| {
            self.heartbeat_probe(digest, assignments)
        })
    }

    fn record_policy(&mut self, offloads: u64, local: u64, mispredictions: u64) {
        let s = &self.shared;
        s.policy_offloads.fetch_add(offloads, Ordering::Relaxed);
        s.policy_local_fallbacks.fetch_add(local, Ordering::Relaxed);
        s.policy_mispredictions
            .fetch_add(mispredictions, Ordering::Relaxed);
    }

    fn scatter_capable(&self) -> bool {
        true
    }

    fn scatter(&mut self, frames: Vec<Vec<u8>>) -> Result<(Vec<Vec<u8>>, TransferBytes)> {
        self.scatter_bytes(frames)
    }
}

impl Drop for FarmClone {
    fn drop(&mut self) {
        self.close();
    }
}
