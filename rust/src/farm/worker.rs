//! Clone workers: one OS thread per pool slot.
//!
//! A worker owns everything that cannot cross threads — its warm pool,
//! its per-phone clone processes, its compute backend — and serves jobs
//! from an mpsc queue. The execution core is shared with the single-phone
//! server (`nodemanager::execute_migration`): decode the forward capture,
//! instantiate the migrant thread, drive it to its reintegration point,
//! capture it back.
//!
//! Per-phone state: the first migration from a phone provisions a clone
//! slot for it (warm-pool take), and later migrations reuse the slot —
//! with the affinity policy, a phone's repeat migrations always land on
//! the worker already holding its slot. A version number on the session
//! file system keeps the slot's synchronized fs current without re-paying
//! the sync when nothing changed.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

use crate::appvm::process::Process;
use crate::appvm::ExecTier;
use crate::config::{CostParams, ExecTierKind};
use crate::error::{CloneCloudError, Result};
use crate::migration::{collect_slot_garbage, CloneSession, Migrator};
use crate::nodemanager::{execute_migration, CloneServeStats};
use crate::trace::Tracer;
use crate::vfs::SimFs;

use super::farm::FarmShared;
use super::pool::WarmPool;

/// One admitted migration roundtrip.
pub(crate) struct Job {
    pub phone: u64,
    /// Scatter lane (0 for plain roundtrips): shard i of a scatter runs
    /// on slot `(phone, i)`, so concurrent sub-jobs never share a clone
    /// process or its virtual clock.
    pub lane: u32,
    pub fs: Arc<SimFs>,
    pub fs_version: u32,
    pub forward: Vec<u8>,
    /// The session negotiated delta capsules.
    pub delta_ok: bool,
    /// The session negotiated the shared string dictionary (slot keeps
    /// the replica).
    pub dict_ok: bool,
    pub submitted: Instant,
    pub reply: Sender<Result<Vec<u8>>>,
}

/// Messages a worker consumes.
pub(crate) enum FarmMsg {
    Work(Job),
    /// Digest heartbeat: verify the phone's baseline digest against the
    /// slot's session state without building or shipping a capsule.
    Heartbeat {
        phone: u64,
        digest: u64,
        assignments: Vec<(u64, u64)>,
        reply: Sender<Result<()>>,
    },
    /// The phone's session closed; free its clone slot.
    Retire { phone: u64 },
    Shutdown,
}

/// A provisioned per-phone clone process. The slot retains the delta
/// session baseline (persistent MID/CID table + epoch + digest) across
/// repeat migrations from its phone — the payoff of affinity placement.
/// Retiring the slot (session close / worker recycle) drops the baseline;
/// the phone's next delta is answered with `NeedFull` and the session
/// re-establishes from a full capture.
struct CloneSlot {
    proc: Process,
    fs_version: u32,
    session: CloneSession,
    /// Roundtrips served by this slot (drives periodic slot GC).
    roundtrips: u64,
    /// Dictionary hit-bytes already flushed to the farm counters.
    dict_hit_bytes_reported: u64,
    /// Per-slot execution tier: the profile state and translation cache
    /// live (and stay valid) with the slot's process across roundtrips.
    tier: ExecTier,
}

/// Worker thread body. Exits on `Shutdown` or when every sender is gone.
#[allow(clippy::too_many_arguments)]
pub(crate) fn worker_main(
    idx: usize,
    rx: Receiver<FarmMsg>,
    mut pool: WarmPool,
    shared: Arc<FarmShared>,
    costs: CostParams,
    fuel: u64,
    slot_gc_interval: u64,
    exec_tier: ExecTierKind,
) {
    let migrator = Migrator::new(costs);
    // Keyed by (phone, lane): lane 0 is the affinity slot plain
    // roundtrips and heartbeats use; scatter shards get their own.
    let mut slots: HashMap<(u64, u32), CloneSlot> = HashMap::new();
    // The worker itself records nothing: jobs that carry a trace context
    // get an ephemeral per-job tracer inside `execute_migration`, whose
    // events ride the reply back to the phone's timeline.
    let mut tracer = Tracer::disabled();
    loop {
        // Drain eagerly; refill the warm pool only when the queue is
        // empty so provisioning stays off the migration critical path.
        let msg = match rx.try_recv() {
            Ok(m) => m,
            Err(TryRecvError::Empty) => {
                pool.refill();
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            }
            Err(TryRecvError::Disconnected) => break,
        };
        match msg {
            FarmMsg::Work(job) => {
                let wait_us = job.submitted.elapsed().as_micros() as u64;
                shared.queue_wait_us.fetch_add(wait_us, Ordering::Relaxed);
                shared
                    .queue_ms
                    .lock()
                    .unwrap()
                    .record(wait_us as f64 / 1e3);

                let t0 = Instant::now();
                let slot = slots.entry((job.phone, job.lane)).or_insert_with(|| CloneSlot {
                    proc: pool.take(&job.fs),
                    fs_version: job.fs_version,
                    session: CloneSession::new(job.delta_ok),
                    roundtrips: 0,
                    dict_hit_bytes_reported: 0,
                    tier: ExecTier::from_kind(exec_tier),
                });
                if slot.fs_version != job.fs_version {
                    slot.proc.env.vfs = job.fs.synchronize();
                    slot.fs_version = job.fs_version;
                }
                slot.session.set_enabled(job.delta_ok);
                slot.session.set_dict_enabled(job.dict_ok);

                let mut serve = CloneServeStats::default();
                let result = execute_migration(
                    &migrator,
                    &mut slot.proc,
                    &job.forward,
                    fuel,
                    &mut serve,
                    &mut slot.session,
                    &mut tracer,
                    &mut slot.tier,
                );
                if matches!(&result, Err(e) if e.is_need_full()) {
                    shared.delta_rejects.fetch_add(1, Ordering::Relaxed);
                }
                shared
                    .delta_migrations
                    .fetch_add(serve.delta_migrations as u64, Ordering::Relaxed);
                shared
                    .scatter_subjobs
                    .fetch_add(serve.scatter_subjobs, Ordering::Relaxed);
                shared
                    .instrs_executed
                    .fetch_add(serve.instrs_executed, Ordering::Relaxed);
                shared
                    .tier_promotions
                    .fetch_add(serve.tier_promotions, Ordering::Relaxed);
                shared
                    .tier_translations
                    .fetch_add(serve.tier_translations, Ordering::Relaxed);
                shared
                    .tier_cache_hits
                    .fetch_add(serve.tier_cache_hits, Ordering::Relaxed);
                shared
                    .tier1_instrs
                    .fetch_add(serve.tier1_instrs, Ordering::Relaxed);
                // Flush the slot dictionary's savings into the farm-wide
                // counter (monotonic across resets, so a plain delta).
                let (hit_bytes, _) = slot.session.dict_stats();
                shared.dict_hit_bytes.fetch_add(
                    hit_bytes - slot.dict_hit_bytes_reported,
                    Ordering::Relaxed,
                );
                slot.dict_hit_bytes_reported = hit_bytes;

                if result.is_ok() {
                    slot.roundtrips += 1;
                    // High-water marks BEFORE collection: this is the
                    // tombstone growth the soak test bounds.
                    shared
                        .slot_threads_peak
                        .fetch_max(slot.proc.threads.len() as u64, Ordering::Relaxed);
                    shared
                        .slot_heap_peak
                        .fetch_max(slot.proc.heap.len() as u64, Ordering::Relaxed);
                    if slot_gc_interval > 0 && slot.roundtrips % slot_gc_interval == 0 {
                        let gc = collect_slot_garbage(&mut slot.proc, &slot.session);
                        shared.slot_gc_runs.fetch_add(1, Ordering::Relaxed);
                        shared
                            .slot_gc_threads
                            .fetch_add(gc.threads_reclaimed as u64, Ordering::Relaxed);
                        shared
                            .slot_gc_objects
                            .fetch_add(gc.objects_reclaimed as u64, Ordering::Relaxed);
                    }
                }

                let ws = &shared.worker_stats[idx];
                ws.jobs.fetch_add(1, Ordering::Relaxed);
                let busy_us = t0.elapsed().as_micros() as u64;
                ws.busy_us.fetch_add(busy_us, Ordering::Relaxed);
                shared.exec_ms.lock().unwrap().record(busy_us as f64 / 1e3);
                shared.scheduler.job_finished(idx);
                // A dead session (dropped receiver) is not the worker's
                // problem; the admission slot is released by the session
                // side regardless.
                let _ = job.reply.send(result);
            }
            FarmMsg::Heartbeat {
                phone,
                digest,
                assignments,
                reply,
            } => {
                shared.heartbeats.fetch_add(1, Ordering::Relaxed);
                let res = match slots.get_mut(&(phone, 0)) {
                    Some(slot) => slot.session.check_heartbeat(&slot.proc, digest, &assignments),
                    None => Err(CloneCloudError::need_full("no clone slot for this phone")),
                };
                if matches!(&res, Err(e) if e.is_need_full()) {
                    shared.heartbeat_divergent.fetch_add(1, Ordering::Relaxed);
                }
                let _ = reply.send(res);
            }
            FarmMsg::Retire { phone } => {
                // Every lane of the phone, not just the affinity slot.
                slots.retain(|k, _| k.0 != phone);
            }
            FarmMsg::Shutdown => break,
        }
    }
}
