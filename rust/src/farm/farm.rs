//! The clone farm: M workers serving N concurrent phone sessions.
//!
//! `CloneFarm::start` builds the deterministic Zygote template **once**,
//! spawns the worker threads (each pre-warming its own pool in
//! parallel), and hands out [`FarmHandle`]s. A handle is `Clone + Send`:
//! gateways and phone threads open sessions from it concurrently.
//!
//! Lifecycle: `start` → any number of `session`s → `shutdown` (drains
//! workers and returns the final stats). Dropping the farm without
//! `shutdown` also stops the workers (their queues disconnect), but
//! skips the join.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::appvm::zygote::build_template;
use crate::appvm::Program;
use crate::config::{CostParams, ExecTierKind, FarmParams};
use crate::error::{CloneCloudError, Result};
use crate::nodemanager::program_hash;
use crate::util::stats::LogHistogram;
use crate::vfs::SimFs;

use super::admission::Admission;
use super::policy::{PlacementPolicy, Scheduler};
use super::pool::PoolStats;
use super::session::FarmClone;
use super::worker::{worker_main, FarmMsg};
use super::EnvFactory;

/// Runtime configuration for one farm instance.
#[derive(Debug, Clone)]
pub struct FarmConfig {
    /// Clone workers (pool size M): one OS thread + warm pool each.
    pub workers: usize,
    /// Pre-forked processes kept ready per worker.
    pub warm_per_worker: usize,
    /// Farm-wide bound on in-flight migrations (admission window).
    pub queue_depth: usize,
    /// Placement of phone jobs onto workers.
    pub policy: PlacementPolicy,
    /// Zygote template size — must match the phones' (§4.3
    /// deterministic naming is what makes the diff optimization sound).
    pub zygote_objects: usize,
    /// Zygote template seed — must match the phones', like
    /// [`FarmConfig::zygote_objects`].
    pub zygote_seed: u64,
    /// Interpreter fuel per offloaded span.
    pub fuel: u64,
    /// Collect a clone slot's garbage (tombstone threads + orphaned
    /// object graphs) every this many roundtrips; 0 = never.
    pub slot_gc_interval: u64,
    /// Execution tier for offloaded spans on every worker slot
    /// (`config.exec_tier`; "interp" is the ablation baseline).
    pub exec_tier: ExecTierKind,
}

impl Default for FarmConfig {
    fn default() -> Self {
        FarmConfig {
            workers: 4,
            warm_per_worker: 2,
            queue_depth: 64,
            policy: PlacementPolicy::Affinity,
            zygote_objects: 40_000,
            zygote_seed: 0xC10E,
            fuel: 2_000_000_000,
            slot_gc_interval: 8,
            exec_tier: ExecTierKind::default(),
        }
    }
}

impl FarmConfig {
    /// Combine the `config` file's farm section with the run's zygote
    /// parameters.
    pub fn from_params(
        params: &FarmParams,
        zygote_objects: usize,
        zygote_seed: u64,
    ) -> Result<FarmConfig> {
        Ok(FarmConfig {
            workers: params.workers,
            warm_per_worker: params.warm_per_worker,
            queue_depth: params.queue_depth,
            policy: PlacementPolicy::parse(&params.policy)?,
            zygote_objects,
            zygote_seed,
            slot_gc_interval: params.slot_gc_interval,
            ..FarmConfig::default()
        })
    }
}

/// Per-worker counters.
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// Jobs this worker served.
    pub jobs: AtomicU64,
    /// Wall-clock microseconds this worker spent executing jobs.
    pub busy_us: AtomicU64,
}

/// State shared by sessions, workers, and handles.
pub(crate) struct FarmShared {
    pub scheduler: Scheduler,
    pub admission: Admission,
    pub pool: Arc<PoolStats>,
    pub worker_stats: Vec<WorkerStats>,
    pub program_hash: u64,
    pub zygote_objects: usize,
    pub zygote_seed: u64,
    pub next_session: AtomicU64,
    pub sessions_opened: AtomicU64,
    pub sessions_closed: AtomicU64,
    pub migrations: AtomicU64,
    pub errors: AtomicU64,
    pub bytes_up: AtomicU64,
    pub bytes_down: AtomicU64,
    pub instrs_executed: AtomicU64,
    pub admission_wait_us: AtomicU64,
    pub queue_wait_us: AtomicU64,
    /// Migrations served from delta capsules (baseline-cache hits).
    pub delta_migrations: AtomicU64,
    /// Delta capsules answered with `NeedFull` (evicted/incoherent
    /// baseline; the phone fell back to a full capture).
    pub delta_rejects: AtomicU64,
    /// Digest heartbeats answered (and the divergent subset).
    pub heartbeats: AtomicU64,
    pub heartbeat_divergent: AtomicU64,
    /// Phone-side policy decisions, aggregated across sessions at the
    /// end of each run (`CloneChannel::record_policy`).
    pub policy_offloads: AtomicU64,
    pub policy_local_fallbacks: AtomicU64,
    pub policy_mispredictions: AtomicU64,
    /// Slot-GC activity + per-slot high-water marks (tombstone growth).
    pub slot_gc_runs: AtomicU64,
    pub slot_gc_threads: AtomicU64,
    pub slot_gc_objects: AtomicU64,
    pub slot_threads_peak: AtomicU64,
    pub slot_heap_peak: AtomicU64,
    /// Gateway frame-layer byte counters: capsule (raw) vs wire
    /// (sealed) bytes per direction — the compression ratio inputs.
    pub wire_raw_up: AtomicU64,
    pub wire_up: AtomicU64,
    pub wire_raw_down: AtomicU64,
    pub wire_down: AtomicU64,
    /// Bytes the slot session dictionaries saved (names a per-capsule
    /// table would have re-shipped), flushed per job by the workers.
    pub dict_hit_bytes: AtomicU64,
    /// Scatter fan-out: sub-job frames served by workers, completed
    /// gathers (one per scatter, counted by the session), lanes fanned
    /// across those gathers, and gathers that failed (a dead lane or
    /// shard error; the phone degrades to a single-clone offload).
    pub scatter_subjobs: AtomicU64,
    pub scatter_gathers: AtomicU64,
    pub scatter_lanes: AtomicU64,
    pub scatter_failed: AtomicU64,
    /// Tier-1 engine activity across all worker slots (zero under the
    /// `exec_tier = interp` ablation), flushed per job by the workers.
    pub tier_promotions: AtomicU64,
    pub tier_translations: AtomicU64,
    pub tier_cache_hits: AtomicU64,
    pub tier1_instrs: AtomicU64,
    /// Gateway-wide latency distributions (wall-clock ms), log-bucketed
    /// so the snapshot can report percentiles, not just totals: time a
    /// job waited in a worker queue after admission, and time a worker
    /// spent executing it. Workers record one sample per job; the lock
    /// is uncontended relative to the work between samples.
    pub queue_ms: Mutex<LogHistogram>,
    pub exec_ms: Mutex<LogHistogram>,
}

/// A point-in-time snapshot of farm counters.
#[derive(Debug, Clone, Default)]
pub struct FarmStats {
    /// Worker pool size M.
    pub workers: usize,
    /// Placement policy name ("round-robin" | "least-loaded" | "affinity").
    pub policy: &'static str,
    /// Sessions opened on the farm so far.
    pub sessions_opened: u64,
    /// Sessions closed so far.
    pub sessions_closed: u64,
    /// Migration roundtrips served.
    pub migrations: u64,
    /// Jobs that ended in an error (`NeedFull` is not an error).
    pub errors: u64,
    /// Capsule bytes received from phones.
    pub bytes_up: u64,
    /// Capsule bytes returned to phones.
    pub bytes_down: u64,
    /// Instructions executed on behalf of migrated threads.
    pub instrs_executed: u64,
    /// Provisions served from a warm pool process.
    pub pool_hits: u64,
    /// Provisions that had to cold-fork.
    pub pool_misses: u64,
    /// Background refills the warm pools performed.
    pub pool_refills: u64,
    /// Migrations that rode delta capsules (vs full captures).
    pub delta_migrations: u64,
    /// Delta capsules the farm rejected with `NeedFull`.
    pub delta_rejects: u64,
    /// Digest heartbeats answered.
    pub heartbeats: u64,
    /// Heartbeats that found a divergent/missing baseline.
    pub heartbeat_divergent: u64,
    /// Phone-side policy decisions the sessions reported: spans migrated.
    pub offloads: u64,
    /// Spans the policy kept local.
    pub local_fallbacks: u64,
    /// After-the-fact policy mispredictions.
    pub mispredictions: u64,
    /// Periodic slot collections run.
    pub slot_gc_runs: u64,
    /// Tombstone threads slot GC reclaimed.
    pub slot_gc_threads: u64,
    /// Orphaned object-graph copies slot GC reclaimed.
    pub slot_gc_objects: u64,
    /// High-water mark of threads alive in any one clone slot.
    pub slot_threads_peak: u64,
    /// High-water mark of heap objects in any one clone slot.
    pub slot_heap_peak: u64,
    /// Gateway frame-layer bytes: raw capsule bytes phone → farm.
    pub wire_raw_up: u64,
    /// Sealed wire bytes phone → farm (equals raw when no codec).
    pub wire_up: u64,
    /// Raw capsule bytes farm → phone.
    pub wire_raw_down: u64,
    /// Sealed wire bytes farm → phone.
    pub wire_down: u64,
    /// Bytes the slot session dictionaries saved vs per-capsule tables.
    pub dict_hit_bytes: u64,
    /// Sub-job frames the workers served (scatter shards).
    pub scatter_subjobs: u64,
    /// Scatter gathers sessions completed (one per fanned migration).
    pub scatter_gathers: u64,
    /// Lanes fanned across all completed gathers.
    pub scatter_lanes: u64,
    /// Gathers that failed (dead lane / shard error → phone degraded).
    pub scatter_failed: u64,
    /// Tier-1 engine activity across all worker slots (zero under the
    /// `exec_tier = interp` ablation): promotions past the hotness
    /// threshold.
    pub tier_promotions: u64,
    /// Successful tier-1 translations.
    pub tier_translations: u64,
    /// Hot activations served from the translation cache.
    pub tier_cache_hits: u64,
    /// Instructions run by translated tier-1 segments.
    pub tier1_instrs: u64,
    /// Total time sessions spent blocked at admission.
    pub admission_wait_ms: f64,
    /// Total time jobs waited in worker queues after admission.
    pub queue_wait_ms: f64,
    /// Queue-wait latency distribution (wall ms), one sample per served
    /// job — NaN percentiles until a job has run.
    pub queue_hist: LogHistogram,
    /// Execution latency distribution (wall ms), one sample per job.
    pub exec_hist: LogHistogram,
    /// Jobs served, per worker.
    pub worker_jobs: Vec<u64>,
    /// Wall-clock ms spent executing, per worker.
    pub worker_busy_ms: Vec<f64>,
}

impl FarmStats {
    /// Fraction of session provisions served from the warm pool.
    pub fn pool_hit_rate(&self) -> f64 {
        let total = self.pool_hits + self.pool_misses;
        if total == 0 {
            return 1.0;
        }
        self.pool_hits as f64 / total as f64
    }
}

/// A cloneable, sendable handle for opening sessions on a running farm.
#[derive(Clone)]
pub struct FarmHandle {
    shared: Arc<FarmShared>,
    senders: Vec<Sender<FarmMsg>>,
}

impl FarmHandle {
    /// Open a session for `phone` with its synchronized file system.
    /// Phone ids identify clone slots: concurrent sessions must use
    /// distinct ids (or use [`FarmHandle::session_auto`]).
    pub fn session(&self, phone: u64, fs: SimFs) -> FarmClone {
        self.shared.sessions_opened.fetch_add(1, Ordering::Relaxed);
        FarmClone::new(self.shared.clone(), self.senders.clone(), phone, fs)
    }

    /// Open a session with a farm-assigned unique phone id (the high bit
    /// is set so auto ids never collide with caller-chosen small ids).
    pub fn session_auto(&self, fs: SimFs) -> FarmClone {
        let id = self.shared.next_session.fetch_add(1, Ordering::Relaxed) | (1 << 63);
        self.session(id, fs)
    }

    /// Identity of the program the farm serves.
    pub fn program_hash(&self) -> u64 {
        self.shared.program_hash
    }

    /// The farm's Zygote template parameters (objects, seed).
    pub fn zygote_params(&self) -> (usize, u64) {
        (self.shared.zygote_objects, self.shared.zygote_seed)
    }

    /// Whether this farm's placement keeps a phone's repeat migrations
    /// on the worker holding its delta baseline. Only affinity placement
    /// does — arming delta under round-robin or least-loaded would turn
    /// most migrations into a `NeedFull` reject plus a full resend,
    /// strictly worse than full captures.
    pub fn delta_friendly(&self) -> bool {
        matches!(self.shared.scheduler.policy(), PlacementPolicy::Affinity)
    }

    /// Feed the gateway's frame-layer byte counters: raw capsule bytes
    /// vs sealed wire bytes, one call per served migration.
    pub fn record_wire(&self, raw_up: u64, wire_up: u64, raw_down: u64, wire_down: u64) {
        let s = &self.shared;
        s.wire_raw_up.fetch_add(raw_up, Ordering::Relaxed);
        s.wire_up.fetch_add(wire_up, Ordering::Relaxed);
        s.wire_raw_down.fetch_add(raw_down, Ordering::Relaxed);
        s.wire_down.fetch_add(wire_down, Ordering::Relaxed);
    }

    /// Snapshot the farm-wide counters and latency histograms.
    pub fn stats(&self) -> FarmStats {
        let s = &self.shared;
        FarmStats {
            workers: s.scheduler.workers(),
            policy: s.scheduler.policy().name(),
            sessions_opened: s.sessions_opened.load(Ordering::Relaxed),
            sessions_closed: s.sessions_closed.load(Ordering::Relaxed),
            migrations: s.migrations.load(Ordering::Relaxed),
            errors: s.errors.load(Ordering::Relaxed),
            bytes_up: s.bytes_up.load(Ordering::Relaxed),
            bytes_down: s.bytes_down.load(Ordering::Relaxed),
            instrs_executed: s.instrs_executed.load(Ordering::Relaxed),
            pool_hits: s.pool.hits.load(Ordering::Relaxed),
            pool_misses: s.pool.misses.load(Ordering::Relaxed),
            pool_refills: s.pool.refills.load(Ordering::Relaxed),
            delta_migrations: s.delta_migrations.load(Ordering::Relaxed),
            delta_rejects: s.delta_rejects.load(Ordering::Relaxed),
            heartbeats: s.heartbeats.load(Ordering::Relaxed),
            heartbeat_divergent: s.heartbeat_divergent.load(Ordering::Relaxed),
            offloads: s.policy_offloads.load(Ordering::Relaxed),
            local_fallbacks: s.policy_local_fallbacks.load(Ordering::Relaxed),
            mispredictions: s.policy_mispredictions.load(Ordering::Relaxed),
            slot_gc_runs: s.slot_gc_runs.load(Ordering::Relaxed),
            slot_gc_threads: s.slot_gc_threads.load(Ordering::Relaxed),
            slot_gc_objects: s.slot_gc_objects.load(Ordering::Relaxed),
            slot_threads_peak: s.slot_threads_peak.load(Ordering::Relaxed),
            slot_heap_peak: s.slot_heap_peak.load(Ordering::Relaxed),
            wire_raw_up: s.wire_raw_up.load(Ordering::Relaxed),
            wire_up: s.wire_up.load(Ordering::Relaxed),
            wire_raw_down: s.wire_raw_down.load(Ordering::Relaxed),
            wire_down: s.wire_down.load(Ordering::Relaxed),
            dict_hit_bytes: s.dict_hit_bytes.load(Ordering::Relaxed),
            scatter_subjobs: s.scatter_subjobs.load(Ordering::Relaxed),
            scatter_gathers: s.scatter_gathers.load(Ordering::Relaxed),
            scatter_lanes: s.scatter_lanes.load(Ordering::Relaxed),
            scatter_failed: s.scatter_failed.load(Ordering::Relaxed),
            tier_promotions: s.tier_promotions.load(Ordering::Relaxed),
            tier_translations: s.tier_translations.load(Ordering::Relaxed),
            tier_cache_hits: s.tier_cache_hits.load(Ordering::Relaxed),
            tier1_instrs: s.tier1_instrs.load(Ordering::Relaxed),
            admission_wait_ms: s.admission_wait_us.load(Ordering::Relaxed) as f64 / 1e3,
            queue_wait_ms: s.queue_wait_us.load(Ordering::Relaxed) as f64 / 1e3,
            queue_hist: s.queue_ms.lock().unwrap().clone(),
            exec_hist: s.exec_ms.lock().unwrap().clone(),
            worker_jobs: s
                .worker_stats
                .iter()
                .map(|w| w.jobs.load(Ordering::Relaxed))
                .collect(),
            worker_busy_ms: s
                .worker_stats
                .iter()
                .map(|w| w.busy_us.load(Ordering::Relaxed) as f64 / 1e3)
                .collect(),
        }
    }
}

/// A running clone farm.
pub struct CloneFarm {
    handle: FarmHandle,
    threads: Vec<JoinHandle<()>>,
}

impl CloneFarm {
    /// Boot the farm: build the Zygote template once, then spawn the
    /// workers (each warms its pool on its own thread, in parallel).
    pub fn start(
        program: Arc<Program>,
        cfg: FarmConfig,
        costs: CostParams,
        make_env: EnvFactory,
    ) -> Result<CloneFarm> {
        if cfg.workers == 0 {
            return Err(CloneCloudError::Config(
                "farm needs at least one worker".into(),
            ));
        }
        let template = Arc::new(build_template(&program, cfg.zygote_objects, cfg.zygote_seed));
        let shared = Arc::new(FarmShared {
            scheduler: Scheduler::new(cfg.policy, cfg.workers),
            admission: Admission::new(cfg.queue_depth),
            pool: Arc::new(PoolStats::default()),
            worker_stats: (0..cfg.workers).map(|_| WorkerStats::default()).collect(),
            program_hash: program_hash(&program),
            zygote_objects: cfg.zygote_objects,
            zygote_seed: cfg.zygote_seed,
            next_session: AtomicU64::new(0),
            sessions_opened: AtomicU64::new(0),
            sessions_closed: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            bytes_up: AtomicU64::new(0),
            bytes_down: AtomicU64::new(0),
            instrs_executed: AtomicU64::new(0),
            admission_wait_us: AtomicU64::new(0),
            queue_wait_us: AtomicU64::new(0),
            delta_migrations: AtomicU64::new(0),
            delta_rejects: AtomicU64::new(0),
            heartbeats: AtomicU64::new(0),
            heartbeat_divergent: AtomicU64::new(0),
            policy_offloads: AtomicU64::new(0),
            policy_local_fallbacks: AtomicU64::new(0),
            policy_mispredictions: AtomicU64::new(0),
            slot_gc_runs: AtomicU64::new(0),
            slot_gc_threads: AtomicU64::new(0),
            slot_gc_objects: AtomicU64::new(0),
            slot_threads_peak: AtomicU64::new(0),
            slot_heap_peak: AtomicU64::new(0),
            wire_raw_up: AtomicU64::new(0),
            wire_up: AtomicU64::new(0),
            wire_raw_down: AtomicU64::new(0),
            wire_down: AtomicU64::new(0),
            dict_hit_bytes: AtomicU64::new(0),
            scatter_subjobs: AtomicU64::new(0),
            scatter_gathers: AtomicU64::new(0),
            scatter_lanes: AtomicU64::new(0),
            scatter_failed: AtomicU64::new(0),
            tier_promotions: AtomicU64::new(0),
            tier_translations: AtomicU64::new(0),
            tier_cache_hits: AtomicU64::new(0),
            tier1_instrs: AtomicU64::new(0),
            queue_ms: Mutex::new(LogHistogram::new()),
            exec_ms: Mutex::new(LogHistogram::new()),
        });

        let mut senders = Vec::with_capacity(cfg.workers);
        let mut threads = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            let program = program.clone();
            let template = template.clone();
            let costs = costs.clone();
            let make_env = make_env.clone();
            let shared = shared.clone();
            let warm = cfg.warm_per_worker;
            let fuel = cfg.fuel;
            let slot_gc = cfg.slot_gc_interval;
            let exec_tier = cfg.exec_tier;
            let jh = std::thread::Builder::new()
                .name(format!("farm-worker-{i}"))
                .spawn(move || {
                    // The pool (and through it every clone process and
                    // compute backend) is built on the worker's own
                    // thread — `Process` never crosses threads.
                    let pool = super::pool::WarmPool::new(
                        program,
                        template,
                        costs.clone(),
                        make_env,
                        warm,
                        shared.pool.clone(),
                    );
                    worker_main(i, rx, pool, shared, costs, fuel, slot_gc, exec_tier);
                })
                .map_err(|e| {
                    CloneCloudError::Runtime(format!("spawn farm worker {i}: {e}"))
                })?;
            threads.push(jh);
        }
        Ok(CloneFarm {
            handle: FarmHandle { shared, senders },
            threads,
        })
    }

    /// A cloneable handle for opening sessions from other threads.
    pub fn handle(&self) -> FarmHandle {
        self.handle.clone()
    }

    /// Convenience for `handle().session(...)`.
    pub fn session(&self, phone: u64, fs: SimFs) -> FarmClone {
        self.handle.session(phone, fs)
    }

    /// Snapshot the farm counters (see [`FarmHandle::stats`]).
    pub fn stats(&self) -> FarmStats {
        self.handle.stats()
    }

    /// Stop the workers and return the final counters. Call after all
    /// sessions finished; jobs still queued behind the shutdown marker
    /// are dropped (their sessions see a transport error).
    pub fn shutdown(mut self) -> FarmStats {
        for s in &self.handle.senders {
            let _ = s.send(FarmMsg::Shutdown);
        }
        for jh in self.threads.drain(..) {
            let _ = jh.join();
        }
        self.handle.stats()
    }
}
