//! Placement policies and the scheduler that applies them.
//!
//! The scheduler answers one question per admitted migration: *which
//! clone worker runs this phone's offloaded span?* Three policies:
//!
//! * **round-robin** — rotate over workers; maximal spread, ignores both
//!   load and locality.
//! * **least-loaded** — pick the worker with the fewest outstanding jobs
//!   (queued + executing); best latency under skewed session lengths.
//! * **affinity** — hash the phone id onto a worker so every migration
//!   from one phone lands on the same worker. The worker then reuses the
//!   phone's provisioned clone process, so its synchronized file system
//!   and heap stay warm across repeat migrations (the MID/CID mapping
//!   machinery re-instantiates per roundtrip, but the Zygote template
//!   fork and fs sync are paid once per phone instead of once per
//!   (phone, worker) pair).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::error::{CloneCloudError, Result};

/// How sessions map onto clone workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Rotate over workers regardless of load or locality.
    RoundRobin,
    /// Pick the worker with the fewest outstanding jobs.
    LeastLoaded,
    /// Hash the phone id onto a worker (keeps its clone slot warm).
    Affinity,
}

impl PlacementPolicy {
    /// Parse a config-file / CLI policy name.
    pub fn parse(s: &str) -> Result<PlacementPolicy> {
        match s {
            "round-robin" | "rr" => Ok(PlacementPolicy::RoundRobin),
            "least-loaded" | "ll" => Ok(PlacementPolicy::LeastLoaded),
            "affinity" => Ok(PlacementPolicy::Affinity),
            other => Err(CloneCloudError::Config(format!(
                "unknown placement policy '{other}' (round-robin|least-loaded|affinity)"
            ))),
        }
    }

    /// The canonical config-file spelling of this policy.
    pub fn name(self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::Affinity => "affinity",
        }
    }
}

/// SplitMix64 finalizer: uncorrelates consecutive phone ids so affinity
/// placement spreads phones evenly over a small worker count.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Thread-safe placement state shared by all sessions of a farm.
pub struct Scheduler {
    policy: PlacementPolicy,
    /// Round-robin cursor.
    next: AtomicUsize,
    /// Outstanding jobs per worker (incremented at dispatch, decremented
    /// when the worker finishes the job).
    inflight: Vec<AtomicUsize>,
}

impl Scheduler {
    /// Build a scheduler for `workers` clone workers.
    pub fn new(policy: PlacementPolicy, workers: usize) -> Scheduler {
        assert!(workers >= 1, "scheduler needs at least one worker");
        Scheduler {
            policy,
            next: AtomicUsize::new(0),
            inflight: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// Number of workers this scheduler places onto.
    pub fn workers(&self) -> usize {
        self.inflight.len()
    }

    /// The policy this scheduler applies.
    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Choose the worker for one migration from `phone`.
    pub fn pick(&self, phone: u64) -> usize {
        let n = self.inflight.len();
        match self.policy {
            PlacementPolicy::RoundRobin => self.next.fetch_add(1, Ordering::Relaxed) % n,
            PlacementPolicy::Affinity => (mix64(phone) % n as u64) as usize,
            PlacementPolicy::LeastLoaded => {
                let mut best = 0;
                let mut best_load = usize::MAX;
                for (i, c) in self.inflight.iter().enumerate() {
                    let load = c.load(Ordering::Relaxed);
                    if load < best_load {
                        best = i;
                        best_load = load;
                    }
                }
                best
            }
        }
    }

    /// Record a job dispatched to `worker` (feeds least-loaded).
    pub fn job_started(&self, worker: usize) {
        self.inflight[worker].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a job completed by `worker`.
    pub fn job_finished(&self, worker: usize) {
        self.inflight[worker].fetch_sub(1, Ordering::Relaxed);
    }

    /// Outstanding jobs (queued + executing) on `worker`.
    pub fn inflight(&self, worker: usize) -> usize {
        self.inflight[worker].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(
            PlacementPolicy::parse("affinity").unwrap(),
            PlacementPolicy::Affinity
        );
        assert_eq!(
            PlacementPolicy::parse("rr").unwrap(),
            PlacementPolicy::RoundRobin
        );
        assert!(PlacementPolicy::parse("nope").is_err());
    }

    #[test]
    fn round_robin_cycles() {
        let s = Scheduler::new(PlacementPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| s.pick(0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn affinity_is_sticky_and_spreads() {
        let s = Scheduler::new(PlacementPolicy::Affinity, 4);
        let mut covered = [false; 4];
        for phone in 0..64u64 {
            let w = s.pick(phone);
            assert_eq!(w, s.pick(phone), "same phone -> same worker");
            covered[w] = true;
        }
        assert!(covered.iter().all(|&c| c), "64 phones cover all 4 workers");
    }

    #[test]
    fn least_loaded_prefers_idle_worker() {
        let s = Scheduler::new(PlacementPolicy::LeastLoaded, 3);
        s.job_started(0);
        s.job_started(0);
        s.job_started(1);
        assert_eq!(s.pick(9), 2);
        s.job_started(2);
        s.job_started(2);
        s.job_finished(0);
        s.job_finished(0);
        assert_eq!(s.pick(9), 0);
    }
}
