//! Admission control: a bounded farm-wide in-flight window.
//!
//! Phone2Cloud's observation: offload only pays while the cloud side
//! absorbs load without queueing collapse. The farm therefore bounds how
//! many migrations may be in flight (queued at workers + executing) at
//! once. When the window is full, new roundtrips *block at admission* on
//! the phone side instead of piling unbounded work onto worker queues —
//! backpressure, not collapse. The time spent blocked is reported per
//! session and in aggregate, so saturation is visible in metrics rather
//! than silently folded into latency.

use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// A counting gate with a fixed capacity (a tiny semaphore; std has none).
pub struct Admission {
    depth: usize,
    inflight: Mutex<usize>,
    freed: Condvar,
}

impl Admission {
    /// `depth` is clamped to at least 1 (a zero-depth farm would admit
    /// nothing and deadlock every session).
    pub fn new(depth: usize) -> Admission {
        Admission {
            depth: depth.max(1),
            inflight: Mutex::new(0),
            freed: Condvar::new(),
        }
    }

    /// Block until a slot is free, take it, and return the milliseconds
    /// spent waiting.
    pub fn acquire(&self) -> f64 {
        let t0 = Instant::now();
        let mut n = self.inflight.lock().unwrap();
        while *n >= self.depth {
            n = self.freed.wait(n).unwrap();
        }
        *n += 1;
        t0.elapsed().as_secs_f64() * 1e3
    }

    /// Take a slot only if one is free *right now*. The async gateway's
    /// shard threads go through here — they must never park on
    /// admission, because one saturated farm would stall every other
    /// connection on the shard. Returns whether the slot was taken; on
    /// `false` the caller keeps the work queued locally (backpressure)
    /// and retries on a later sweep.
    pub fn try_acquire(&self) -> bool {
        let mut n = self.inflight.lock().unwrap();
        if *n >= self.depth {
            return false;
        }
        *n += 1;
        true
    }

    /// Release a slot taken by `acquire` / `try_acquire`.
    pub fn release(&self) {
        let mut n = self.inflight.lock().unwrap();
        *n = n.saturating_sub(1);
        drop(n);
        self.freed.notify_one();
    }

    /// Currently admitted (queued + executing) migrations.
    pub fn in_flight(&self) -> usize {
        *self.inflight.lock().unwrap()
    }

    /// The configured window size (after the ≥ 1 clamp).
    pub fn depth(&self) -> usize {
        self.depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn admits_up_to_depth_without_blocking() {
        let a = Admission::new(2);
        assert!(a.acquire() < 100.0);
        assert!(a.acquire() < 100.0);
        assert_eq!(a.in_flight(), 2);
        a.release();
        assert_eq!(a.in_flight(), 1);
    }

    #[test]
    fn depth_zero_clamps_to_one() {
        let a = Admission::new(0);
        assert_eq!(a.depth(), 1);
        a.acquire();
        assert_eq!(a.in_flight(), 1);
    }

    #[test]
    fn try_acquire_never_blocks() {
        let a = Admission::new(1);
        assert!(a.try_acquire());
        // Window full: refuse instantly instead of parking.
        let t0 = Instant::now();
        assert!(!a.try_acquire());
        assert!(t0.elapsed() < Duration::from_millis(100));
        a.release();
        assert!(a.try_acquire());
        assert_eq!(a.in_flight(), 1);
    }

    #[test]
    fn full_window_blocks_until_release() {
        let a = Arc::new(Admission::new(1));
        a.acquire();
        let (tx, rx) = mpsc::channel();
        let a2 = a.clone();
        let waiter = std::thread::spawn(move || {
            let waited_ms = a2.acquire();
            tx.send(waited_ms).unwrap();
        });
        // The waiter must still be blocked while the slot is held.
        assert!(
            rx.recv_timeout(Duration::from_millis(100)).is_err(),
            "acquire returned before release"
        );
        a.release();
        let waited_ms = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(waited_ms >= 0.0);
        waiter.join().unwrap();
        assert_eq!(a.in_flight(), 1, "slot handed over to the waiter");
    }
}
