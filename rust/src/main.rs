//! `clonecloud` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (see `clonecloud help`):
//!   partition    analyze + profile + solve a partition for an app
//!   run          run an app monolithically or under CloneCloud
//!   table1       regenerate the paper's Table 1
//!   clone-serve  run a clone node (TCP listener) for distributed mode
//!   farm         run the multi-tenant clone farm (demo or TCP gateway)
//!   inspect      dump program / partition information

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = clonecloud::cli::main(&args);
    std::process::exit(code);
}
