//! PJRT client wrapper + the PJRT-backed [`ComputeBackend`].
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`, with
//! outputs unwrapped via `Literal::to_tuple()` (aot.py lowers with
//! `return_tuple=True`).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::appvm::natives::{shapes, ComputeBackend};
use crate::error::{CloneCloudError, Result};

use super::manifest::Manifest;

fn rt_err(e: xla::Error) -> CloneCloudError {
    CloneCloudError::runtime(format!("xla: {e}"))
}

/// A loaded PJRT runtime: one compiled executable per artifact.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    /// Executions per artifact (metrics; Mutex: ComputeBackend is &self).
    calls: Mutex<HashMap<String, u64>>,
}

impl PjrtRuntime {
    /// Load and compile every artifact in `dir` (expects `manifest.json`).
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(rt_err)?;
        let mut exes = HashMap::new();
        for (name, spec) in &manifest.artifacts {
            let proto =
                xla::HloModuleProto::from_text_file(&spec.file).map_err(rt_err)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(rt_err)?;
            exes.insert(name.clone(), exe);
        }
        Ok(PjrtRuntime {
            client,
            exes,
            manifest,
            calls: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.exes.keys().cloned().collect()
    }

    pub fn call_counts(&self) -> HashMap<String, u64> {
        self.calls.lock().unwrap().clone()
    }

    /// Execute artifact `name` with f32 inputs (shapes validated against
    /// the manifest). Returns the raw output literals.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.get(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(CloneCloudError::runtime(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, tspec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if data.len() != tspec.numel() {
                return Err(CloneCloudError::runtime(format!(
                    "{name}: input {i} has {} elements, expected {} {:?}",
                    data.len(),
                    tspec.numel(),
                    tspec.shape
                )));
            }
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = tspec.shape.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims).map_err(rt_err)?);
        }
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| CloneCloudError::runtime(format!("no executable '{name}'")))?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(rt_err)?;
        let tuple = result[0][0].to_literal_sync().map_err(rt_err)?;
        let outs = tuple.to_tuple().map_err(rt_err)?;
        if outs.len() != spec.outputs.len() {
            return Err(CloneCloudError::runtime(format!(
                "{name}: got {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            )));
        }
        *self
            .calls
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += 1;
        Ok(outs)
    }
}

fn to_f32(l: &xla::Literal, ctx: &str) -> Result<Vec<f32>> {
    l.to_vec::<f32>()
        .map_err(|e| CloneCloudError::runtime(format!("{ctx}: {e}")))
}

fn to_i32(l: &xla::Literal, ctx: &str) -> Result<Vec<i32>> {
    l.to_vec::<i32>()
        .map_err(|e| CloneCloudError::runtime(format!("{ctx}: {e}")))
}

/// The production [`ComputeBackend`]: every compute native dispatches to
/// a compiled artifact. "Native everywhere" in the paper's sense — both
/// the phone process and the clone process hold one of these.
pub struct PjrtCompute {
    rt: std::sync::Arc<PjrtRuntime>,
}

impl PjrtCompute {
    pub fn new(rt: std::sync::Arc<PjrtRuntime>) -> PjrtCompute {
        PjrtCompute { rt }
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.rt
    }
}

impl ComputeBackend for PjrtCompute {
    fn scan_chunk(&self, chunk: &[f32], sigs: &[f32]) -> Result<(Vec<f32>, f32)> {
        let outs = self.rt.execute_f32("scan_chunk", &[chunk, sigs])?;
        let counts = to_f32(&outs[0], "scan_chunk.counts")?;
        let total = to_f32(&outs[1], "scan_chunk.total")?[0];
        Ok((counts, total))
    }

    fn face_detect(
        &self,
        img: &[f32],
        filters: &[f32],
        thresh: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let t = [thresh];
        let outs = self.rt.execute_f32("face_detect", &[img, filters, &t])?;
        let maxima = to_f32(&outs[0], "face_detect.maxima")?;
        let counts = to_f32(&outs[1], "face_detect.counts")?;
        let faces = to_f32(&outs[2], "face_detect.faces")?[0];
        Ok((maxima, counts, faces))
    }

    fn categorize(&self, users: &[f32], cats: &[f32]) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)> {
        let outs = self.rt.execute_f32("categorize", &[users, cats])?;
        let scores = to_f32(&outs[0], "categorize.scores")?;
        let best = to_i32(&outs[1], "categorize.best")?;
        let best_score = to_f32(&outs[2], "categorize.best_score")?;
        debug_assert_eq!(scores.len(), shapes::N_USERS * shapes::N_CATS);
        Ok((scores, best, best_score))
    }

    fn name(&self) -> &'static str {
        "pjrt-cpu"
    }
}

#[cfg(test)]
mod tests {
    //! These tests need `artifacts/` (run `make artifacts` first); they
    //! are skipped gracefully when artifacts are absent so `cargo test`
    //! stays hermetic.
    use super::*;
    use crate::appvm::natives::RustCompute;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn runtime() -> Option<Arc<PjrtRuntime>> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(Arc::new(PjrtRuntime::load(&dir).expect("load artifacts")))
    }

    #[test]
    fn loads_all_artifacts() {
        let Some(rt) = runtime() else { return };
        let mut names = rt.artifact_names();
        names.sort();
        assert_eq!(names, vec!["categorize", "face_detect", "scan_chunk"]);
        assert_eq!(rt.platform().to_lowercase().contains("cpu"), true);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let Some(rt) = runtime() else { return };
        let bad = vec![0f32; 7];
        assert!(rt.execute_f32("scan_chunk", &[&bad, &bad]).is_err());
    }

    #[test]
    fn pjrt_matches_rust_reference_scan() {
        let Some(rt) = runtime() else { return };
        let pjrt = PjrtCompute::new(rt);
        let rust = RustCompute;
        let mut rng = Rng::new(11);
        let mut chunk = vec![0f32; shapes::CHUNK];
        for v in chunk.iter_mut() {
            *v = rng.below(256) as f32;
        }
        let mut sigs = vec![0f32; shapes::SIG_LEN * shapes::N_SIGS];
        for v in sigs.iter_mut() {
            *v = rng.below(256) as f32;
        }
        // Plant signature 9 at offset 100.
        for k in 0..shapes::SIG_LEN {
            chunk[100 + k] = sigs[k * shapes::N_SIGS + 9];
        }
        let (pc, pt) = pjrt.scan_chunk(&chunk, &sigs).unwrap();
        let (rc, rt_) = rust.scan_chunk(&chunk, &sigs).unwrap();
        assert_eq!(pt, rt_, "totals agree");
        assert_eq!(pc, rc, "per-signature counts agree");
        assert!(pt >= 1.0);
    }

    #[test]
    fn pjrt_matches_rust_reference_categorize() {
        let Some(rt) = runtime() else { return };
        let pjrt = PjrtCompute::new(rt);
        let rust = RustCompute;
        let mut rng = Rng::new(13);
        let mut users = vec![0f32; shapes::N_USERS * shapes::KDIM];
        let mut cats = vec![0f32; shapes::KDIM * shapes::N_CATS];
        for v in users.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        for v in cats.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let (ps, pb, pbs) = pjrt.categorize(&users, &cats).unwrap();
        let (rs, rb, rbs) = rust.categorize(&users, &cats).unwrap();
        assert_eq!(pb, rb, "argmax agrees");
        for (a, b) in ps.iter().zip(&rs) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        for (a, b) in pbs.iter().zip(&rbs) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn pjrt_matches_rust_reference_face_detect() {
        let Some(rt) = runtime() else { return };
        let pjrt = PjrtCompute::new(rt);
        let rust = RustCompute;
        let mut rng = Rng::new(17);
        let mut img = vec![0f32; shapes::IMG * shapes::IMG];
        for v in img.iter_mut() {
            *v = rng.range_f32(0.0, 1.0);
        }
        let mut filters = vec![0f32; 64 * shapes::N_FILTERS];
        for f in 0..shapes::N_FILTERS {
            let mut col = vec![0f32; 64];
            let mut mean = 0.0;
            for c in col.iter_mut() {
                *c = rng.range_f32(-1.0, 1.0);
                mean += *c;
            }
            mean /= 64.0;
            for (k, c) in col.iter().enumerate() {
                filters[k * shapes::N_FILTERS + f] = c - mean;
            }
        }
        let (pm, pc, pf) = pjrt.face_detect(&img, &filters, 1.5).unwrap();
        let (rm, rc, rf) = rust.face_detect(&img, &filters, 1.5).unwrap();
        for (a, b) in pm.iter().zip(&rm) {
            assert!((a - b).abs() < 1e-3, "maxima {a} vs {b}");
        }
        assert_eq!(pc, rc, "counts agree");
        assert_eq!(pf, rf);
    }
}
