//! PJRT runtime: loads the AOT HLO artifacts and executes them from the
//! L3 hot path.
//!
//! `python/compile/aot.py` lowers each L2 JAX model (which embeds the L1
//! Pallas kernels) ONCE to HLO *text* (see DESIGN.md; the text parser
//! reassigns instruction ids, dodging the 64-bit-id proto incompatibility
//! between jax >= 0.5 and xla_extension 0.5.1). This module compiles each
//! artifact on the PJRT CPU client at startup and caches one loaded
//! executable per model — Python never runs on the request path.

mod manifest;
mod pjrt;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt::{PjrtCompute, PjrtRuntime};

use std::path::Path;
use std::sync::Arc;

use crate::appvm::natives::{ComputeBackend, RustCompute};

/// The best available backend: the PJRT artifacts if present (production
/// path), else the pure-Rust reference (hermetic tests). The choice is
/// printed so bench logs are unambiguous about what executed.
pub fn default_backend(artifacts_dir: &Path) -> Arc<dyn ComputeBackend> {
    match PjrtRuntime::load(artifacts_dir) {
        Ok(rt) => {
            eprintln!(
                "[runtime] PJRT backend: {} ({} artifacts)",
                rt.platform(),
                rt.artifact_names().len()
            );
            Arc::new(PjrtCompute::new(Arc::new(rt)))
        }
        Err(e) => {
            eprintln!("[runtime] falling back to rust-reference backend: {e}");
            Arc::new(RustCompute)
        }
    }
}
