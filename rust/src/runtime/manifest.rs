//! Artifact manifest: the contract between `aot.py` and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{CloneCloudError, Result};
use crate::util::json::{self, Json};

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let shape = v
            .get("shape")
            .as_arr()
            .ok_or_else(|| CloneCloudError::runtime("tensor spec missing shape"))?
            .iter()
            .map(|x| {
                x.as_usize()
                    .ok_or_else(|| CloneCloudError::runtime("bad shape element"))
            })
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .as_str()
            .ok_or_else(|| CloneCloudError::runtime("tensor spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed `artifacts/manifest.json`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            CloneCloudError::runtime(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = json::parse(text)?;
        let obj = v
            .as_obj()
            .ok_or_else(|| CloneCloudError::runtime("manifest must be an object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in obj {
            let file = entry
                .get("file")
                .as_str()
                .ok_or_else(|| CloneCloudError::runtime(format!("{name}: missing file")))?;
            let inputs = entry
                .get("inputs")
                .as_arr()
                .ok_or_else(|| CloneCloudError::runtime(format!("{name}: missing inputs")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .get("outputs")
                .as_arr()
                .ok_or_else(|| CloneCloudError::runtime(format!("{name}: missing outputs")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    sha256: entry.get("sha256").as_str().unwrap_or("").to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            CloneCloudError::runtime(format!("artifact '{name}' not in manifest"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "scan_chunk": {
        "file": "scan_chunk.hlo.txt",
        "sha256": "ab",
        "inputs": [
          {"shape": [4096], "dtype": "float32"},
          {"shape": [16, 128], "dtype": "float32"}
        ],
        "outputs": [
          {"shape": [128], "dtype": "float32"},
          {"shape": [], "dtype": "float32"}
        ]
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        let s = m.get("scan_chunk").unwrap();
        assert_eq!(s.file, PathBuf::from("/a/scan_chunk.hlo.txt"));
        assert_eq!(s.inputs[1].shape, vec![16, 128]);
        assert_eq!(s.inputs[1].numel(), 2048);
        assert_eq!(s.outputs[1].shape, Vec::<usize>::new());
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("[]", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"x": {"file": "f"}}"#, Path::new(".")).is_err());
    }
}
