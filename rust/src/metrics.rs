//! Run-level metrics aggregation: one place to collect what a run did
//! (instructions, native calls, migrations, bytes) for reports and the
//! benches' summary lines.

use std::borrow::Cow;
use std::collections::BTreeMap;

use crate::appvm::process::Process;
use crate::exec::DistOutcome;
use crate::trace::TraceReport;

/// A flat, printable metrics snapshot. Keys are `Cow<'static, str>`:
/// the common case — a fixed metric name — never allocates, while
/// computed names (per-worker, per-phase) pass an owned `String`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<Cow<'static, str>, u64>,
    pub gauges: BTreeMap<Cow<'static, str>, f64>,
}

impl MetricsSnapshot {
    pub fn count(&mut self, name: impl Into<Cow<'static, str>>, v: u64) {
        *self.counters.entry(name.into()).or_insert(0) += v;
    }

    pub fn gauge(&mut self, name: impl Into<Cow<'static, str>>, v: f64) {
        self.gauges.insert(name.into(), v);
    }

    /// Absorb a process's VM metrics + native-call counts.
    pub fn absorb_process(&mut self, prefix: &str, p: &Process) {
        self.count(format!("{prefix}.instrs"), p.metrics.instrs);
        self.count(format!("{prefix}.invokes"), p.metrics.invokes);
        self.count(format!("{prefix}.native_calls"), p.metrics.native_calls);
        self.count(format!("{prefix}.allocations"), p.metrics.allocations);
        for (name, n) in &p.env.native_calls {
            self.count(format!("{prefix}.native.{name}"), *n);
        }
        self.gauge(format!("{prefix}.virtual_ms"), p.clock.now_ms());
        self.gauge(format!("{prefix}.heap_objects"), p.heap.len() as f64);
    }

    /// Absorb a distributed-run outcome.
    pub fn absorb_dist(&mut self, out: &DistOutcome) {
        self.count("migrations", out.migrations as u64);
        self.count("bytes.up", out.transfer.up);
        self.count("bytes.down", out.transfer.down);
        // Per-direction migration wire bytes under the `migration.`
        // namespace, so delta benches and farm reports can show bytes
        // saved without ad-hoc plumbing.
        self.count("migration.bytes_out", out.transfer.up);
        self.count("migration.bytes_in", out.transfer.down);
        // Pre-compression capsule bytes: the raw/wire quotient is the
        // session's per-direction compression ratio.
        self.count("migration.raw_out", out.raw_up);
        self.count("migration.raw_in", out.raw_down);
        self.count("migration.delta.roundtrips", out.delta_roundtrips as u64);
        self.count("migration.full.roundtrips", out.full_roundtrips as u64);
        self.count("migration.delta.fallbacks", out.delta_fallbacks as u64);
        // Capture-work counters (page-epoch scan) and session-dictionary
        // savings — the zygote_scale bench's headline numbers.
        self.count("migration.objects_scanned", out.objects_scanned as u64);
        self.count("migration.pages_scanned", out.pages_scanned as u64);
        self.count("migration.pages_dirty", out.pages_dirty as u64);
        self.count("migration.dict.hit_bytes", out.dict_hit_bytes);
        self.count("migration.dict.additions", out.dict_additions);
        self.count("migration.dict.fallbacks", out.dict_fallbacks as u64);
        self.count(
            "migration.heartbeat.preempts",
            out.heartbeat_preempts as u64,
        );
        // Runtime partition-policy decisions (exec::policy): how often
        // the engine migrated, stayed local, was wrong after the fact,
        // and absorbed a dead channel.
        self.count("policy.offloads", out.offloads as u64);
        self.count("policy.local_fallbacks", out.local_fallbacks as u64);
        self.count("policy.mispredictions", out.mispredictions as u64);
        self.count("policy.channel_errors", out.channel_errors as u64);
        // Local-vs-clone races on marginal decisions, and which leg the
        // virtual clock crowned.
        self.count("policy.speculation.races", out.speculations as u64);
        self.count(
            "policy.speculation.local_wins",
            out.speculation_local_wins as u64,
        );
        self.count(
            "policy.speculation.clone_wins",
            out.speculation_clone_wins as u64,
        );
        // Scatter/gather fan-outs: committed gathers, lanes fanned, and
        // the two refusal flavors (typed write-set conflict vs lane or
        // link failure) — both degrade to the single-clone offload.
        self.count("migration.scatter.offloads", out.scatter_offloads as u64);
        self.count("migration.scatter.shards", out.scatter_shards as u64);
        self.count("migration.scatter.conflicts", out.scatter_conflicts as u64);
        self.count("migration.scatter.failures", out.scatter_failures as u64);
        self.count("objects.shipped", out.objects_shipped as u64);
        self.count("objects.zygote_skipped", out.zygote_skipped as u64);
        self.count("objects.base_skipped", out.base_skipped as u64);
        self.count("statics.shipped", out.statics_shipped as u64);
        if out.migrations > 0 {
            self.gauge(
                "migration.delta.hit_rate",
                out.delta_roundtrips as f64 / out.migrations as f64,
            );
        }
        if out.transfer.up > 0 {
            self.gauge(
                "migration.compression.ratio_out",
                out.raw_up as f64 / out.transfer.up as f64,
            );
        }
        if out.transfer.down > 0 {
            self.gauge(
                "migration.compression.ratio_in",
                out.raw_down as f64 / out.transfer.down as f64,
            );
        }
        self.gauge("virtual_ms", out.virtual_ms);
        self.gauge("phase.suspend_capture_ms", out.suspend_capture_ms);
        self.gauge("phase.uplink_ms", out.uplink_ms);
        self.gauge("phase.downlink_ms", out.downlink_ms);
        self.gauge("phase.merge_ms", out.merge_ms);
    }

    /// Absorb a clone-farm stats snapshot (aggregate throughput, queue
    /// pressure, pool effectiveness, per-worker utilization).
    pub fn absorb_farm(&mut self, f: &crate::farm::FarmStats) {
        self.count("farm.sessions_opened", f.sessions_opened);
        self.count("farm.sessions_closed", f.sessions_closed);
        self.count("farm.migrations", f.migrations);
        self.count("farm.errors", f.errors);
        self.count("farm.bytes.up", f.bytes_up);
        self.count("farm.bytes.down", f.bytes_down);
        self.count("farm.instrs_executed", f.instrs_executed);
        self.count("farm.tier.promotions", f.tier_promotions);
        self.count("farm.tier.translations", f.tier_translations);
        self.count("farm.tier.cache_hits", f.tier_cache_hits);
        self.count("farm.tier.tier1_instrs", f.tier1_instrs);
        self.count("farm.pool.hits", f.pool_hits);
        self.count("farm.pool.misses", f.pool_misses);
        self.count("farm.pool.refills", f.pool_refills);
        self.count("farm.delta.migrations", f.delta_migrations);
        self.count("farm.delta.rejects", f.delta_rejects);
        self.count("farm.heartbeats", f.heartbeats);
        self.count("farm.heartbeat.divergent", f.heartbeat_divergent);
        self.count("farm.policy.offloads", f.offloads);
        self.count("farm.policy.local_fallbacks", f.local_fallbacks);
        self.count("farm.policy.mispredictions", f.mispredictions);
        self.count("farm.scatter.subjobs", f.scatter_subjobs);
        self.count("farm.scatter.gathers", f.scatter_gathers);
        self.count("farm.scatter.lanes", f.scatter_lanes);
        self.count("farm.scatter.failed", f.scatter_failed);
        self.count("farm.slot_gc.runs", f.slot_gc_runs);
        self.count("farm.slot_gc.threads", f.slot_gc_threads);
        self.count("farm.slot_gc.objects", f.slot_gc_objects);
        self.count("farm.wire.raw_up", f.wire_raw_up);
        self.count("farm.wire.up", f.wire_up);
        self.count("farm.wire.raw_down", f.wire_raw_down);
        self.count("farm.wire.down", f.wire_down);
        self.count("farm.dict.hit_bytes", f.dict_hit_bytes);
        self.gauge("farm.slot.threads_peak", f.slot_threads_peak as f64);
        self.gauge("farm.slot.heap_peak", f.slot_heap_peak as f64);
        if f.wire_up > 0 {
            self.gauge(
                "farm.compression.ratio_up",
                f.wire_raw_up as f64 / f.wire_up as f64,
            );
        }
        if f.wire_down > 0 {
            self.gauge(
                "farm.compression.ratio_down",
                f.wire_raw_down as f64 / f.wire_down as f64,
            );
        }
        self.gauge("farm.pool.hit_rate", f.pool_hit_rate());
        if f.migrations > 0 {
            self.gauge(
                "farm.delta.hit_rate",
                f.delta_migrations as f64 / f.migrations as f64,
            );
        }
        self.gauge("farm.admission_wait_ms", f.admission_wait_ms);
        self.gauge("farm.queue_wait_ms", f.queue_wait_ms);
        if !f.queue_hist.is_empty() {
            self.gauge("farm.queue.p50_ms", f.queue_hist.p50());
            self.gauge("farm.queue.p95_ms", f.queue_hist.p95());
            self.gauge("farm.queue.p99_ms", f.queue_hist.p99());
        }
        if !f.exec_hist.is_empty() {
            self.gauge("farm.exec.p50_ms", f.exec_hist.p50());
            self.gauge("farm.exec.p95_ms", f.exec_hist.p95());
            self.gauge("farm.exec.p99_ms", f.exec_hist.p99());
        }
        for (i, (jobs, busy)) in f.worker_jobs.iter().zip(&f.worker_busy_ms).enumerate() {
            self.count(format!("farm.worker{i}.jobs"), *jobs);
            self.gauge(format!("farm.worker{i}.busy_ms"), *busy);
        }
    }

    /// Absorb an async-gateway drain report (connection churn, framing
    /// pressure, and the accept→shard handoff latency percentiles).
    pub fn absorb_gateway(&mut self, g: &crate::nodemanager::GatewayStats) {
        self.count("gateway.accepts", g.accepts);
        self.count("gateway.accept_errors", g.accept_errors);
        self.count("gateway.migrations", g.migrations);
        self.count("gateway.decode_stalls", g.decode_stalls);
        self.count("gateway.short_writes", g.short_writes);
        self.count("gateway.backpressure_stalls", g.backpressure_stalls);
        self.count("gateway.protocol_errors", g.protocol_errors);
        self.gauge("gateway.conns_peak", g.conns_peak as f64);
        if !g.handoff_ms.is_empty() {
            self.gauge("gateway.handoff.p50_ms", g.handoff_ms.p50());
            self.gauge("gateway.handoff.p95_ms", g.handoff_ms.p95());
            self.gauge("gateway.handoff.p99_ms", g.handoff_ms.p99());
        }
    }

    /// Absorb a trace report: per-(endpoint, phase) duration percentiles
    /// under `trace.<endpoint>.<phase>.*`, counter totals, and the
    /// decision/misprediction tallies. Durations are virtual-clock ms —
    /// the same clock the spans were stamped with.
    pub fn absorb_trace(&mut self, rep: &TraceReport) {
        self.count("trace.events", rep.events);
        self.count("trace.dropped", rep.dropped);
        self.count("trace.decisions", rep.decisions);
        self.count("trace.mispredictions", rep.mispredictions);
        for ph in &rep.phases {
            if ph.hist.is_empty() {
                continue;
            }
            let base = format!("trace.{}.{}", ph.endpoint.name(), ph.phase.name());
            self.count(format!("{base}.spans"), ph.hist.count());
            self.gauge(format!("{base}.p50_ms"), ph.hist.p50());
            self.gauge(format!("{base}.p95_ms"), ph.hist.p95());
            self.gauge(format!("{base}.p99_ms"), ph.hist.p99());
        }
        for (c, total) in &rep.counters {
            self.gauge(format!("trace.counter.{}", c.name()), *total);
        }
        for (m, n) in &rep.instants {
            self.count(format!("trace.mark.{}", m.name()), *n);
        }
    }

    /// Render as sorted `key = value` lines.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k} = {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("{k} = {v:.3}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_farm_maps_all_headline_metrics() {
        let mut m = MetricsSnapshot::default();
        let f = crate::farm::FarmStats {
            workers: 2,
            policy: "affinity",
            sessions_opened: 4,
            sessions_closed: 4,
            migrations: 9,
            pool_hits: 3,
            pool_misses: 1,
            admission_wait_ms: 12.5,
            worker_jobs: vec![5, 4],
            worker_busy_ms: vec![10.0, 8.0],
            tier_promotions: 2,
            tier1_instrs: 5_000,
            scatter_subjobs: 8,
            scatter_gathers: 2,
            scatter_lanes: 8,
            scatter_failed: 1,
            ..Default::default()
        };
        m.absorb_farm(&f);
        assert_eq!(m.counters["farm.migrations"], 9);
        assert_eq!(m.counters["farm.worker1.jobs"], 4);
        assert_eq!(m.counters["farm.tier.promotions"], 2);
        assert_eq!(m.counters["farm.tier.tier1_instrs"], 5_000);
        assert_eq!(m.counters["farm.scatter.subjobs"], 8);
        assert_eq!(m.counters["farm.scatter.gathers"], 2);
        assert_eq!(m.counters["farm.scatter.lanes"], 8);
        assert_eq!(m.counters["farm.scatter.failed"], 1);
        assert!((m.gauges["farm.pool.hit_rate"] - 0.75).abs() < 1e-9);
        assert!(m.render().contains("farm.admission_wait_ms = 12.500"));
    }

    #[test]
    fn absorb_dist_records_per_direction_bytes_and_delta() {
        let mut m = MetricsSnapshot::default();
        let out = DistOutcome {
            migrations: 4,
            transfer: crate::nodemanager::TransferBytes {
                up: 1000,
                down: 2000,
            },
            raw_up: 3000,
            raw_down: 2000,
            delta_roundtrips: 3,
            full_roundtrips: 1,
            delta_fallbacks: 1,
            heartbeat_preempts: 1,
            statics_shipped: 7,
            offloads: 4,
            local_fallbacks: 2,
            mispredictions: 1,
            scatter_offloads: 1,
            scatter_shards: 4,
            scatter_conflicts: 1,
            speculations: 3,
            speculation_local_wins: 1,
            speculation_clone_wins: 2,
            ..Default::default()
        };
        m.absorb_dist(&out);
        assert_eq!(m.counters["migration.bytes_out"], 1000);
        assert_eq!(m.counters["migration.bytes_in"], 2000);
        assert_eq!(m.counters["migration.raw_out"], 3000);
        assert_eq!(m.counters["migration.delta.roundtrips"], 3);
        assert_eq!(m.counters["migration.full.roundtrips"], 1);
        assert_eq!(m.counters["migration.delta.fallbacks"], 1);
        assert_eq!(m.counters["migration.heartbeat.preempts"], 1);
        assert_eq!(m.counters["statics.shipped"], 7);
        assert_eq!(m.counters["policy.offloads"], 4);
        assert_eq!(m.counters["policy.local_fallbacks"], 2);
        assert_eq!(m.counters["policy.mispredictions"], 1);
        assert_eq!(m.counters["policy.channel_errors"], 0);
        assert_eq!(m.counters["policy.speculation.races"], 3);
        assert_eq!(m.counters["policy.speculation.local_wins"], 1);
        assert_eq!(m.counters["policy.speculation.clone_wins"], 2);
        assert_eq!(m.counters["migration.scatter.offloads"], 1);
        assert_eq!(m.counters["migration.scatter.shards"], 4);
        assert_eq!(m.counters["migration.scatter.conflicts"], 1);
        assert_eq!(m.counters["migration.scatter.failures"], 0);
        assert!((m.gauges["migration.delta.hit_rate"] - 0.75).abs() < 1e-9);
        assert!((m.gauges["migration.compression.ratio_out"] - 3.0).abs() < 1e-9);
        assert!((m.gauges["migration.compression.ratio_in"] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn absorb_gateway_maps_counters_and_handoff_percentiles() {
        let mut m = MetricsSnapshot::default();
        let mut g = crate::nodemanager::GatewayStats {
            accepts: 10,
            conns_peak: 6,
            migrations: 8,
            decode_stalls: 3,
            backpressure_stalls: 1,
            ..Default::default()
        };
        g.handoff_ms.record(0.5);
        g.handoff_ms.record(2.0);
        m.absorb_gateway(&g);
        assert_eq!(m.counters["gateway.accepts"], 10);
        assert_eq!(m.counters["gateway.migrations"], 8);
        assert_eq!(m.counters["gateway.decode_stalls"], 3);
        assert_eq!(m.counters["gateway.backpressure_stalls"], 1);
        assert_eq!(m.counters["gateway.protocol_errors"], 0);
        assert!((m.gauges["gateway.conns_peak"] - 6.0).abs() < 1e-9);
        assert!(m.gauges["gateway.handoff.p99_ms"] >= m.gauges["gateway.handoff.p50_ms"]);
    }

    #[test]
    fn counters_accumulate_and_render() {
        let mut m = MetricsSnapshot::default();
        m.count("a", 2);
        m.count("a", 3);
        m.gauge("t", 1.5);
        assert_eq!(m.counters["a"], 5);
        let s = m.render();
        assert!(s.contains("a = 5"));
        assert!(s.contains("t = 1.500"));
    }
}
