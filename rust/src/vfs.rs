//! Simulated phone file system.
//!
//! The paper's virus scanner walks the phone file system (100 KB - 10 MB
//! total) and the image-search app reads the photo directory. The node
//! manager synchronizes this file system to the clone at provisioning
//! time (§4: "application-unspecific node maintenance, including
//! file-system synchronization"), which is what makes `fs.*` natives
//! available on both devices ("native everywhere").

use crate::util::rng::Rng;

/// One file.
#[derive(Debug, Clone, PartialEq)]
pub struct SimFile {
    pub name: String,
    pub bytes: Vec<u8>,
}

/// A flat simulated file system.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimFs {
    files: Vec<SimFile>,
}

impl SimFs {
    pub fn new() -> SimFs {
        SimFs::default()
    }

    pub fn add(&mut self, name: &str, bytes: Vec<u8>) -> usize {
        self.files.push(SimFile {
            name: name.to_string(),
            bytes,
        });
        self.files.len() - 1
    }

    pub fn count(&self) -> usize {
        self.files.len()
    }

    pub fn file(&self, idx: usize) -> Option<&SimFile> {
        self.files.get(idx)
    }

    pub fn size(&self, idx: usize) -> Option<usize> {
        self.files.get(idx).map(|f| f.bytes.len())
    }

    /// Read up to `len` bytes at `offset` (short reads at EOF).
    pub fn read(&self, idx: usize, offset: usize, len: usize) -> Option<&[u8]> {
        let f = self.files.get(idx)?;
        if offset > f.bytes.len() {
            return Some(&[]);
        }
        let end = (offset + len).min(f.bytes.len());
        Some(&f.bytes[offset..end])
    }

    pub fn total_bytes(&self) -> usize {
        self.files.iter().map(|f| f.bytes.len()).sum()
    }

    /// Byte-identical copy for clone synchronization.
    pub fn synchronize(&self) -> SimFs {
        self.clone()
    }

    /// Generate a file system totalling ~`total_bytes`, split into files
    /// of roughly `file_size` bytes, with `sig_plants` virus signatures
    /// planted at random offsets (each plant is `sig` bytes copied in).
    pub fn generate_corpus(
        rng: &mut Rng,
        total_bytes: usize,
        file_size: usize,
        plants: &[Vec<u8>],
    ) -> SimFs {
        let mut fs = SimFs::new();
        let nfiles = (total_bytes + file_size - 1) / file_size.max(1);
        let mut remaining = total_bytes;
        for i in 0..nfiles {
            let sz = remaining.min(file_size);
            remaining -= sz;
            let mut bytes = vec![0u8; sz];
            rng.fill_bytes(&mut bytes);
            fs.add(&format!("file_{i:04}.bin"), bytes);
        }
        // Plant signatures.
        for sig in plants {
            if fs.count() == 0 || sig.is_empty() {
                continue;
            }
            let fi = rng.index(fs.count());
            let f = &mut fs.files[fi];
            if f.bytes.len() >= sig.len() {
                let off = rng.index(f.bytes.len() - sig.len() + 1);
                f.bytes[off..off + sig.len()].copy_from_slice(sig);
            }
        }
        fs
    }

    /// Generate a photo directory: `n` grayscale images of `side`^2 bytes,
    /// `faces` of them containing a planted face pattern.
    pub fn generate_gallery(
        rng: &mut Rng,
        n: usize,
        side: usize,
        face_pattern: &[u8],
        faces: usize,
    ) -> SimFs {
        let mut fs = SimFs::new();
        for i in 0..n {
            let mut img = vec![0u8; side * side];
            rng.fill_bytes(&mut img);
            // Soften noise so planted faces stand out.
            for px in img.iter_mut() {
                *px /= 4;
            }
            if i < faces && face_pattern.len() <= img.len() {
                let row = rng.index(side.saturating_sub(8).max(1));
                let col = rng.index(side.saturating_sub(8).max(1));
                for (k, &p) in face_pattern.iter().enumerate().take(64) {
                    let (dr, dc) = (k / 8, k % 8);
                    img[(row + dr) * side + col + dc] = p;
                }
            }
            fs.add(&format!("img_{i:04}.gray"), img);
        }
        fs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_read_roundtrip() {
        let mut fs = SimFs::new();
        let i = fs.add("a.bin", vec![1, 2, 3, 4, 5]);
        assert_eq!(fs.count(), 1);
        assert_eq!(fs.size(i), Some(5));
        assert_eq!(fs.read(i, 1, 3), Some(&[2u8, 3, 4][..]));
        assert_eq!(fs.read(i, 4, 10), Some(&[5u8][..]), "short read at EOF");
        assert_eq!(fs.read(i, 9, 1), Some(&[][..]), "past EOF");
        assert_eq!(fs.read(9, 0, 1), None, "no such file");
    }

    #[test]
    fn corpus_total_size_and_plants() {
        let mut rng = Rng::new(1);
        let sig = vec![0xAA; 16];
        let fs = SimFs::generate_corpus(&mut rng, 100 * 1024, 32 * 1024, &[sig.clone()]);
        assert_eq!(fs.total_bytes(), 100 * 1024);
        assert_eq!(fs.count(), 4);
        // The signature is present in exactly one file.
        let hits: usize = (0..fs.count())
            .map(|i| {
                let b = &fs.file(i).unwrap().bytes;
                b.windows(16).filter(|w| *w == &sig[..]).count()
            })
            .sum();
        assert!(hits >= 1);
    }

    #[test]
    fn corpus_is_deterministic() {
        let a = SimFs::generate_corpus(&mut Rng::new(7), 10_000, 4_096, &[]);
        let b = SimFs::generate_corpus(&mut Rng::new(7), 10_000, 4_096, &[]);
        assert_eq!(a, b);
    }

    #[test]
    fn synchronize_is_identical() {
        let mut rng = Rng::new(2);
        let fs = SimFs::generate_corpus(&mut rng, 5_000, 1_000, &[]);
        assert_eq!(fs.synchronize(), fs);
    }

    #[test]
    fn gallery_shapes() {
        let mut rng = Rng::new(3);
        let pat = vec![250u8; 64];
        let fs = SimFs::generate_gallery(&mut rng, 5, 64, &pat, 2);
        assert_eq!(fs.count(), 5);
        assert!(fs.iter_sizes().all(|s| s == 64 * 64));
    }

    impl SimFs {
        fn iter_sizes(&self) -> impl Iterator<Item = usize> + '_ {
            self.files.iter().map(|f| f.bytes.len())
        }
    }
}
