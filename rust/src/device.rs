//! Device execution model: the phone / clone speed asymmetry.
//!
//! The paper's testbed is an HTC G1 (Android Dev Phone 1) against a
//! 2.83 GHz desktop clone; Table 1's Max-Speedup column shows the clone
//! executing the same workloads 18-26x faster. We model each device as a
//! multiplier over a baseline per-unit cost (DESIGN.md §2-3).

/// Where a piece of execution runs. Matches the paper's L(m) encoding:
/// 0 = mobile device, 1 = clone.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Location {
    Mobile,
    Clone,
}

impl Location {
    pub fn as_bit(self) -> u8 {
        match self {
            Location::Mobile => 0,
            Location::Clone => 1,
        }
    }
    pub fn from_bit(b: u8) -> Location {
        if b == 0 {
            Location::Mobile
        } else {
            Location::Clone
        }
    }
    pub fn other(self) -> Location {
        match self {
            Location::Mobile => Location::Clone,
            Location::Clone => Location::Mobile,
        }
    }
}

impl std::fmt::Display for Location {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Location::Mobile => write!(f, "mobile"),
            Location::Clone => write!(f, "clone"),
        }
    }
}

/// Execution-speed model of one device.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// Multiplier over baseline (clone-class) cost: clone = 1.0, the G1
    /// phone ~ 20x. Every virtual-time charge on this device is scaled
    /// by this factor.
    pub cpu_factor: f64,
}

impl DeviceSpec {
    /// The paper's Android Dev Phone 1 (HTC G1), ~20-26x slower than the
    /// desktop clone across the three applications (Table 1 Max Speedup).
    pub fn phone_g1() -> DeviceSpec {
        DeviceSpec {
            name: "android-dev-phone-1".into(),
            cpu_factor: 21.0,
        }
    }

    /// The paper's clone: 2.83 GHz Dell desktop running Android-x86 VM.
    pub fn clone_desktop() -> DeviceSpec {
        DeviceSpec {
            name: "dell-desktop-2.83ghz".into(),
            cpu_factor: 1.0,
        }
    }

    /// Scale a baseline cost (in µs) to this device.
    pub fn scale_us(&self, base_us: f64) -> f64 {
        base_us * self.cpu_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn location_bits_roundtrip() {
        assert_eq!(Location::from_bit(Location::Mobile.as_bit()), Location::Mobile);
        assert_eq!(Location::from_bit(Location::Clone.as_bit()), Location::Clone);
        assert_eq!(Location::Mobile.other(), Location::Clone);
    }

    #[test]
    fn phone_is_much_slower() {
        let p = DeviceSpec::phone_g1();
        let c = DeviceSpec::clone_desktop();
        let ratio = p.scale_us(1.0) / c.scale_us(1.0);
        assert!(ratio >= 18.0 && ratio <= 27.0, "paper's observed range");
    }
}
