//! The end-to-end CloneCloud pipeline: everything between "here is an
//! app" and "here is Table 1's row for it".
//!
//! Offline (per app x input x network, paper §3): static analysis →
//! dual-platform profiling → cost model → ILP solve → bytecode rewrite →
//! partition DB entry. Online (§4): pick the binary for the current
//! conditions and run it, migrating at its partition points.

use std::sync::Arc;

use crate::appvm::natives::ComputeBackend;
use crate::appvm::Program;
use crate::apps::{build_process, App, Size};
use crate::config::{Config, NetworkProfile};
use crate::device::Location;
use crate::error::Result;
use crate::exec::{run_distributed, run_monolithic, DistOutcome, InlineClone, MonoOutcome};
use crate::partitioner::{
    profile_run, rewrite_with_partition, solve_partition, validate_partition, Cfg, CostModel,
    Partition, ProfileTree, SpanCostUs,
};

/// Timing + size diagnostics of one full partitioning run (E2).
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    pub methods_profiled: usize,
    /// Wall seconds: profiling execution on the phone process.
    pub profile_phone_s: f64,
    /// Wall seconds: profiling execution on the clone process.
    pub profile_clone_s: f64,
    /// Wall seconds spent measuring migration state sizes (the paper's
    /// separate "profiling migration cost" phase).
    pub profile_migration_s: f64,
    /// Wall seconds: building the static CFG + constraints (jchord's
    /// role).
    pub static_analysis_s: f64,
    /// Wall seconds: generating + solving the ILP (Mosek's role).
    pub solve_s: f64,
    /// Virtual profile-run times, for the paper's phone/clone contrast.
    pub profile_phone_virtual_ms: f64,
    pub profile_clone_virtual_ms: f64,
}

/// Profile one app execution on both platforms (the T / T' pair).
pub fn profile_pair(
    app: &dyn App,
    program: &Arc<Program>,
    size: Size,
    cfg: &Config,
    backend: &Arc<dyn ComputeBackend>,
) -> Result<(ProfileTree, ProfileTree, PipelineReport)> {
    let mut report = PipelineReport::default();

    let mut phone = build_process(
        app,
        program.clone(),
        size,
        cfg,
        Location::Mobile,
        backend.clone(),
        false,
    )?;
    let entry = program.entry()?;
    let (t_mobile, pr) = profile_run(&mut phone, entry, &[], true)?;
    report.profile_phone_s = pr.wall_s - pr.state_measure_wall_s;
    report.profile_migration_s = pr.state_measure_wall_s;
    report.profile_phone_virtual_ms = pr.virtual_ms;
    report.methods_profiled = pr.methods_profiled;

    // Clone profiling: the paper's clone is a full Android image, so
    // pinned calls execute there during profiling (allow_pinned).
    let mut clone = build_process(
        app,
        program.clone(),
        size,
        cfg,
        Location::Clone,
        backend.clone(),
        true,
    )?;
    let (t_clone, cr) = profile_run(&mut clone, entry, &[], false)?;
    report.profile_clone_s = cr.wall_s;
    report.profile_clone_virtual_ms = cr.virtual_ms;

    Ok((t_mobile, t_clone, report))
}

/// Solve a partition from already-collected profile trees (profiling is
/// network-independent; only the cost-model pricing changes per network,
/// so one T/T' pair serves every execution condition — this is how the
/// partition database for multiple conditions is filled from one
/// profiling campaign).
pub fn partition_from_trees(
    app: &dyn App,
    trees: &(ProfileTree, ProfileTree),
    cfg: &Config,
    net: &NetworkProfile,
) -> Result<(Partition, f64, f64)> {
    let program = app.program();
    let t0 = std::time::Instant::now();
    let cfg_graph = Cfg::build(&program);
    let static_s = t0.elapsed().as_secs_f64();
    let cost_model = CostModel::build_scaled(
        &[(&trees.0, &trees.1)],
        &cfg.costs,
        net,
        cfg.phone.cpu_factor,
        cfg.clone.cpu_factor,
    );
    let (mut partition, solve_report) = solve_partition(&program, &cfg_graph, &cost_model)?;
    validate_partition(&program, &cfg_graph, &partition)?;
    // Price each chosen span for the runtime policy engine: the
    // per-invocation inclusive time of the method on each platform
    // (the profile trees are already device-scaled virtual time).
    let migrate: Vec<_> = partition.migrate.iter().copied().collect();
    for m in migrate {
        let n_mobile = trees.0.invocation_count(m).max(1) as f64;
        let n_clone = trees.1.invocation_count(m).max(1) as f64;
        partition.span_costs.insert(
            m,
            SpanCostUs {
                local_us: trees.0.method_inclusive_us(m) / n_mobile,
                clone_us: trees.1.method_inclusive_us(m) / n_clone,
            },
        );
    }
    Ok((partition, static_s, solve_report.solve_wall_s))
}

/// Full offline partitioning for one (app, input, network).
pub fn partition_app(
    app: &dyn App,
    size: Size,
    cfg: &Config,
    net: &NetworkProfile,
    backend: &Arc<dyn ComputeBackend>,
) -> Result<(Partition, PipelineReport)> {
    let program = app.program();
    let (t_mobile, t_clone, mut report) = profile_pair(app, &program, size, cfg, backend)?;
    let (partition, static_s, solve_s) =
        partition_from_trees(app, &(t_mobile, t_clone), cfg, net)?;
    report.static_analysis_s = static_s;
    report.solve_s = solve_s;
    Ok((partition, report))
}

/// One Table 1 cell pair for a network: execution time + partition label.
#[derive(Debug, Clone)]
pub struct CcCell {
    pub exec_ms: f64,
    pub label: &'static str,
    pub speedup: f64,
    pub dist: Option<DistOutcome>,
}

/// One full Table 1 row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub app: &'static str,
    pub input: String,
    pub phone_ms: f64,
    pub clone_ms: f64,
    pub max_speedup: f64,
    pub threeg: CcCell,
    pub wifi: CcCell,
    pub result: String,
}

/// Run the monolithic phone + clone columns.
pub fn monolithic_pair(
    app: &dyn App,
    size: Size,
    cfg: &Config,
    backend: &Arc<dyn ComputeBackend>,
) -> Result<(MonoOutcome, MonoOutcome, String)> {
    let program = app.program();
    let mut phone = build_process(
        app, program.clone(), size, cfg, Location::Mobile, backend.clone(), false,
    )?;
    let po = run_monolithic(&mut phone)?;
    let result = app.check(&phone, size)?;
    let mut clone = build_process(
        app, program.clone(), size, cfg, Location::Clone, backend.clone(), true,
    )?;
    let co = run_monolithic(&mut clone)?;
    app.check(&clone, size)?;
    Ok((po, co, result))
}

/// Run the CloneCloud column for one network from pre-collected profile
/// trees: solve, and execute distributed (inline clone) if Offload.
pub fn clonecloud_cell_from_trees(
    app: &dyn App,
    trees: &(ProfileTree, ProfileTree),
    size: Size,
    cfg: &Config,
    net: &NetworkProfile,
    backend: &Arc<dyn ComputeBackend>,
    phone_ms: f64,
) -> Result<CcCell> {
    let (partition, _static_s, _solve_s) = partition_from_trees(app, trees, cfg, net)?;
    run_cell(app, partition, size, cfg, net, backend, phone_ms)
}

/// Run the CloneCloud column for one network: partition, and execute
/// distributed (inline clone) if the partition says Offload.
pub fn clonecloud_cell(
    app: &dyn App,
    size: Size,
    cfg: &Config,
    net: &NetworkProfile,
    backend: &Arc<dyn ComputeBackend>,
    phone_ms: f64,
) -> Result<CcCell> {
    let (partition, _report) = partition_app(app, size, cfg, net, backend)?;
    run_cell(app, partition, size, cfg, net, backend, phone_ms)
}

fn run_cell(
    app: &dyn App,
    partition: Partition,
    size: Size,
    cfg: &Config,
    net: &NetworkProfile,
    backend: &Arc<dyn ComputeBackend>,
    phone_ms: f64,
) -> Result<CcCell> {
    if !partition.is_offload() {
        return Ok(CcCell {
            exec_ms: phone_ms,
            label: "Local",
            speedup: 1.0,
            dist: None,
        });
    }
    let program = app.program();
    let (rewritten, _points) = rewrite_with_partition(&program, &partition)?;
    let rewritten = Arc::new(rewritten);
    let mut phone = build_process(
        app, rewritten.clone(), size, cfg, Location::Mobile, backend.clone(), false,
    )?;
    let clone_proc = build_process(
        app, rewritten.clone(), size, cfg, Location::Clone, backend.clone(), false,
    )?;
    let mut channel = InlineClone::new(clone_proc, cfg.costs.clone());
    let out = run_distributed(&mut phone, &mut channel, net, &cfg.costs)?;
    app.check(&phone, size)?;
    Ok(CcCell {
        exec_ms: out.virtual_ms,
        label: "Offload",
        speedup: phone_ms / out.virtual_ms,
        dist: Some(out),
    })
}

/// Produce one complete Table 1 row.
pub fn table1_row(
    app: &dyn App,
    size: Size,
    cfg: &Config,
    backend: &Arc<dyn ComputeBackend>,
) -> Result<Table1Row> {
    let (po, co, result) = monolithic_pair(app, size, cfg, backend)?;
    // Profile once; price the cost model per network (profiling is
    // network-independent).
    let program = app.program();
    let (tm, tc, _rep) = profile_pair(app, &program, size, cfg, backend)?;
    let trees = (tm, tc);
    let threeg = clonecloud_cell_from_trees(
        app, &trees, size, cfg, &NetworkProfile::threeg(), backend, po.virtual_ms,
    )?;
    let wifi = clonecloud_cell_from_trees(
        app, &trees, size, cfg, &NetworkProfile::wifi(), backend, po.virtual_ms,
    )?;
    Ok(Table1Row {
        app: {
            // Stable short label.
            match app.name() {
                "virus" => "Virus scanning",
                "image" => "Image search",
                "behavior" => "Behavior profiling",
                other => Box::leak(other.to_string().into_boxed_str()),
            }
        },
        input: app.input_label(size),
        phone_ms: po.virtual_ms,
        clone_ms: co.virtual_ms,
        max_speedup: po.virtual_ms / co.virtual_ms,
        threeg,
        wifi,
        result,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::natives::RustCompute;
    use crate::apps::VirusScan;

    fn cfg() -> Config {
        Config {
            zygote_objects: 300,
            ..Config::default()
        }
    }

    #[test]
    fn full_pipeline_on_small_virus_workload() {
        let app = VirusScan;
        let backend: Arc<dyn ComputeBackend> = Arc::new(RustCompute);
        let cfg = cfg();
        let (p_wifi, report) =
            partition_app(&app, Size::Small, &cfg, &NetworkProfile::wifi(), &backend).unwrap();
        // Paper Table 1: 100 KB virus scan stays LOCAL on both networks.
        assert!(!p_wifi.is_offload(), "small scan stays local on wifi");
        // Bytecode app methods only (natives are inline, §3.2):
        // main, scan_all, scan_file.
        assert!(report.methods_profiled >= 3);
        assert!(report.profile_migration_s >= 0.0);
        let (p_3g, _) =
            partition_app(&app, Size::Small, &cfg, &NetworkProfile::threeg(), &backend).unwrap();
        assert!(!p_3g.is_offload(), "small scan stays local on 3g");
    }

    #[test]
    fn table1_row_small_is_consistent() {
        let app = VirusScan;
        let backend: Arc<dyn ComputeBackend> = Arc::new(RustCompute);
        let row = table1_row(&app, Size::Small, &cfg(), &backend).unwrap();
        assert!(row.max_speedup > 15.0, "clone much faster: {}", row.max_speedup);
        assert_eq!(row.threeg.label, "Local");
        assert!((row.threeg.exec_ms - row.phone_ms).abs() < 1e-6);
        assert!(row.result.contains("infected"));
    }
}
