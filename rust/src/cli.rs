//! The `clonecloud` command-line interface (hand-rolled; no clap in the
//! offline environment — DESIGN.md §2).
//!
//! ```text
//! clonecloud partition --app virus --size medium [--config cfg.json] [--db out.json]
//! clonecloud run --app image --size large --network wifi [--mode local|clonecloud]
//! clonecloud table1
//! clonecloud clone-serve --listen 127.0.0.1:7077 --app virus
//! clonecloud farm --phones 32 --workers 4 --policy affinity
//! clonecloud farm --listen 127.0.0.1:7077 --app virus --workers 8
//! clonecloud policy --db out.json
//! clonecloud policy --trace wifi,edge,wifi --rounds 12
//! clonecloud trace --rounds 6 --out session.trace.json
//! clonecloud inspect --app behavior
//! clonecloud help
//! ```

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use crate::apps::{all_apps, build_process, App, BehaviorProfile, ImageSearch, Size, VirusScan};
use crate::config::{Config, NetworkProfile};
use crate::device::{DeviceSpec, Location};
use crate::error::{CloneCloudError, Result};
use crate::exec::{
    delta_statics_workload_src, delta_workload_expected, run_distributed_session,
    run_distributed_with, run_monolithic, Decision, InlineClone, PolicyEngine, SpanCost,
};
use crate::farm::{
    synthetic_expected, synthetic_offload_src, CloneFarm, FarmConfig, PlacementPolicy,
};
use crate::metrics::MetricsSnapshot;
use crate::nodemanager::{
    serve_farm, serve_farm_async, AsyncGatewayConfig, CloneServer, GatewayKind, TcpEndpoint,
};
use crate::partitioner::{rewrite_with_partition, Cfg, PartitionDb, PartitionEntry};
use crate::pipeline::{partition_app, table1_row};
use crate::runtime::default_backend;
use crate::util::bench::Table;

const HELP: &str = "\
clonecloud — CloneCloud (Chun et al., 2010) reproduction

USAGE:
  clonecloud <command> [options]

COMMANDS:
  partition    profile + solve a partition for an app under a network
  run          run an app (local or CloneCloud) and report times
  table1       regenerate the paper's Table 1
  clone-serve  run a clone node on a TCP listener (one phone)
  farm         run the multi-tenant clone farm: in-proc demo, or a TCP
               serve-many gateway with --listen
  policy       dump the partition DB (--db) and/or drive the runtime
               policy engine across a network trace, printing each
               invocation's migrate/local decision + estimator state
  trace        run a traced farm session (flight recorder on), print the
               per-phase percentile table, and export the merged
               phone+clone timeline as Chrome trace-event JSON (--out;
               load in Perfetto / chrome://tracing)
  inspect      dump an app's program, CFG, and constraint sets
  help         this text

OPTIONS:
  --app <virus|image|behavior>   application           (default: virus)
  --size <small|medium|large>    workload size         (default: medium)
  --network <3g|wifi>            execution conditions  (default: wifi)
  --mode <auto|local|clonecloud> run mode              (default: auto)
  --config <file.json>           config overrides
  --db <file.json>               partition database path
  --listen <addr:port>           clone-serve / farm bind address

FARM OPTIONS (defaults from the config 'farm' section):
  --workers <n>                  clone worker threads
  --warm <n>                     pre-forked processes per worker
  --queue <n>                    admission window (in-flight bound)
  --policy <round-robin|least-loaded|affinity>
  --gateway <async|blocking>     serve path (async = sharded readiness loop)
  --shards <n>                   async gateway shard threads
  --phones <n>                   demo mode: concurrent phone sessions
  --iters <n>                    demo mode: clone-side work per session

POLICY OPTIONS (engine tunables from the config 'policy' section):
  --trace <net,net,...>          network trace segments (default wifi,edge,wifi)
  --segment <n>                  migration trips per trace segment (default 4)
  --rounds <n>                   repeat-offload rounds, <= 256 (default 12)
  --payload <bytes>              per-round working-set bytes (default 4096)

TRACE OPTIONS (recorder tunables from the config 'trace' section):
  --rounds <n>                   offload rounds, <= 256 (default 6)
  --payload <bytes>              per-round working-set bytes (default 2048)
  --out <file.json>              Chrome trace output path (default session.trace.json)
";

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = args
                .get(i + 1)
                .ok_or_else(|| CloneCloudError::Config(format!("--{key} needs a value")))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        } else {
            return Err(CloneCloudError::Config(format!("unexpected argument '{a}'")));
        }
    }
    Ok(flags)
}

fn app_by_name(name: &str) -> Result<Box<dyn App>> {
    match name {
        "virus" => Ok(Box::new(VirusScan)),
        "image" => Ok(Box::new(ImageSearch)),
        "behavior" => Ok(Box::new(BehaviorProfile)),
        other => Err(CloneCloudError::Config(format!("unknown app '{other}'"))),
    }
}

fn size_by_name(name: &str) -> Result<Size> {
    match name {
        "small" => Ok(Size::Small),
        "medium" => Ok(Size::Medium),
        "large" => Ok(Size::Large),
        other => Err(CloneCloudError::Config(format!("unknown size '{other}'"))),
    }
}

fn load_config(flags: &HashMap<String, String>) -> Result<Config> {
    match flags.get("config") {
        Some(path) => Config::load(Path::new(path)),
        None => Ok(Config::default()),
    }
}

fn cmd_partition(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
    let size = size_by_name(flags.get("size").map(String::as_str).unwrap_or("medium"))?;
    let net = NetworkProfile::by_name(flags.get("network").map(String::as_str).unwrap_or("wifi"))
        .ok_or_else(|| CloneCloudError::Config("unknown network".into()))?;
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let (partition, report) = partition_app(app.as_ref(), size, &cfg, &net, &backend)?;
    let program = app.program();
    println!(
        "partition for ({}, {}, {}): {}",
        app.name(),
        app.input_label(size),
        net.name,
        partition.label()
    );
    for &m in &partition.migrate {
        println!("  R(m)=1: {}", program.method_name(m));
    }
    println!(
        "expected {:.2}s vs local {:.2}s; profiled {} methods \
         (phone {:.2}s wall, migration-cost {:.2}s wall, solve {:.3}s)",
        partition.expected_us / 1e6,
        partition.local_us / 1e6,
        report.methods_profiled,
        report.profile_phone_s,
        report.profile_migration_s,
        report.solve_s,
    );
    if let Some(db_path) = flags.get("db") {
        let path = Path::new(db_path);
        let mut db = if path.exists() {
            PartitionDb::load(path)?
        } else {
            PartitionDb::new()
        };
        db.put(PartitionEntry::from_partition(
            app.name(),
            &net.name,
            &program,
            &partition,
        ));
        db.save(path)?;
        println!("stored in {db_path} ({} entries)", db.len());
    }
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
    let size = size_by_name(flags.get("size").map(String::as_str).unwrap_or("medium"))?;
    let net = NetworkProfile::by_name(flags.get("network").map(String::as_str).unwrap_or("wifi"))
        .ok_or_else(|| CloneCloudError::Config("unknown network".into()))?;
    let mode = flags.get("mode").map(String::as_str).unwrap_or("auto");
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let program = app.program();

    let offload = match mode {
        "local" => false,
        "clonecloud" => true,
        "auto" => {
            let (p, _) = partition_app(app.as_ref(), size, &cfg, &net, &backend)?;
            p.is_offload()
        }
        other => return Err(CloneCloudError::Config(format!("unknown mode '{other}'"))),
    };

    if !offload {
        let mut p = build_process(
            app.as_ref(), program, size, &cfg, Location::Mobile, backend, false,
        )?;
        let out = run_monolithic(&mut p)?;
        println!(
            "local run: {:.2}s virtual, {} instrs ({})",
            out.virtual_ms / 1e3,
            out.instrs,
            app.check(&p, size)?
        );
    } else {
        let (partition, _) = partition_app(app.as_ref(), size, &cfg, &net, &backend)?;
        let (rewritten, _) = rewrite_with_partition(&program, &partition)?;
        let rewritten = Arc::new(rewritten);
        let mut phone = build_process(
            app.as_ref(), rewritten.clone(), size, &cfg, Location::Mobile, backend.clone(), false,
        )?;
        let clone = build_process(
            app.as_ref(), rewritten, size, &cfg, Location::Clone, backend, false,
        )?;
        let mut channel =
            InlineClone::new(clone, cfg.costs.clone()).with_exec_tier(cfg.exec_tier);
        if cfg.delta_migration {
            channel = channel.with_delta();
        }
        if cfg.session_dict {
            channel = channel.with_dict();
        }
        if !cfg.capture.paged {
            channel = channel.with_per_object_captures();
        }
        let mut session = crate::migration::MobileSession::new(cfg.delta_migration);
        session.set_dict_enabled(cfg.session_dict);
        session.set_paged(cfg.capture.paged);
        session.set_gc_interval(cfg.capture.mobile_gc_interval);
        session.set_gc_growth(cfg.capture.mobile_gc_growth_objects);
        if cfg.heartbeat_idle_ms > 0 {
            session.heartbeat_every(std::time::Duration::from_millis(cfg.heartbeat_idle_ms));
        }
        let out =
            run_distributed_session(&mut phone, &mut channel, &net, &cfg.costs, &mut session)?;
        println!(
            "CloneCloud run ({}): {:.2}s virtual, {} migration(s) ({} delta), \
             {}B up / {}B down ({})",
            net.name,
            out.virtual_ms / 1e3,
            out.migrations,
            out.delta_roundtrips,
            out.transfer.up,
            out.transfer.down,
            app.check(&phone, size)?
        );
    }
    Ok(())
}

fn cmd_table1(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let mut table = Table::new(
        "Table 1 (paper §6)",
        &["Application", "Input", "Phone(s)", "Clone(s)", "MaxSpd",
          "CC-3G(s)", "Part-3G", "Spd-3G", "CC-WiFi(s)", "Part-WiFi", "Spd-WiFi"],
    );
    for app in all_apps() {
        for size in Size::all() {
            let row = table1_row(app.as_ref(), size, &cfg, &backend)?;
            table.row(vec![
                row.app.to_string(),
                row.input,
                format!("{:.2}", row.phone_ms / 1e3),
                format!("{:.2}", row.clone_ms / 1e3),
                format!("{:.2}", row.max_speedup),
                format!("{:.2}", row.threeg.exec_ms / 1e3),
                row.threeg.label.into(),
                format!("{:.2}", row.threeg.speedup),
                format!("{:.2}", row.wifi.exec_ms / 1e3),
                row.wifi.label.into(),
                format!("{:.2}", row.wifi.speedup),
            ]);
        }
    }
    table.print();
    Ok(())
}

fn cmd_clone_serve(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
    let addr = flags
        .get("listen")
        .map(String::as_str)
        .unwrap_or("127.0.0.1:7077");
    // The phone's provision message carries its executable hash, so a
    // mismatched binary is rejected at the door.
    let program = app.program();
    let ep = TcpEndpoint::bind(addr)?;
    println!(
        "clone node listening on {} for app '{}'",
        ep.local_addr()?,
        app.name()
    );
    loop {
        let t = ep.accept()?;
        let artifacts = cfg.artifacts_dir.clone();
        let srv = CloneServer::new(
            t,
            program.clone(),
            cfg.costs.clone(),
            Box::new(move |fs| {
                crate::appvm::NodeEnv::new(fs, default_backend(Path::new(&artifacts)))
            }),
        )
        .with_exec_tier(cfg.exec_tier);
        match srv.serve() {
            Ok(stats) => println!("session done: {} migrations", stats.migrations),
            Err(e) => eprintln!("session error: {e}"),
        }
    }
}

fn flag_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        Some(s) => s
            .parse()
            .map_err(|_| CloneCloudError::Config(format!("--{key} must be a number, got '{s}'"))),
        None => Ok(default),
    }
}

fn cmd_farm(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let mut params = cfg.farm.clone();
    params.workers = flag_usize(flags, "workers", params.workers)?;
    params.warm_per_worker = flag_usize(flags, "warm", params.warm_per_worker)?;
    params.queue_depth = flag_usize(flags, "queue", params.queue_depth)?;
    if let Some(p) = flags.get("policy") {
        PlacementPolicy::parse(p)?; // validate now, fail fast
        params.policy = p.clone();
    }
    let mut farm_cfg = FarmConfig::from_params(&params, cfg.zygote_objects, cfg.seed)?;
    farm_cfg.exec_tier = cfg.exec_tier;

    if let Some(addr) = flags.get("listen") {
        // Serve-many gateway for a real app over TCP.
        let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
        let program = app.program();
        let artifacts = cfg.artifacts_dir.clone();
        let farm = CloneFarm::start(
            program,
            farm_cfg,
            cfg.costs.clone(),
            Arc::new(move |fs| {
                crate::appvm::NodeEnv::new(fs, default_backend(Path::new(&artifacts)))
            }),
        )?;
        let gateway = flags.get("gateway").unwrap_or(&params.gateway);
        let kind = GatewayKind::parse(gateway).ok_or_else(|| {
            CloneCloudError::Config(format!(
                "--gateway must be \"async\" or \"blocking\", got '{gateway}'"
            ))
        })?;
        let ep = TcpEndpoint::bind(addr)?;
        println!(
            "clone farm listening on {} for app '{}' ({} gateway, {} workers, warm {}, queue {}, policy {})",
            ep.local_addr()?,
            app.name(),
            kind.name(),
            params.workers,
            params.warm_per_worker,
            params.queue_depth,
            params.policy,
        );
        let timeout = if params.read_timeout_ms > 0 {
            Some(std::time::Duration::from_millis(params.read_timeout_ms))
        } else {
            None
        };
        return match kind {
            GatewayKind::Blocking => serve_farm(&ep, &farm.handle(), timeout, None),
            GatewayKind::Async => {
                let gw_cfg = AsyncGatewayConfig {
                    shards: flag_usize(flags, "shards", params.gateway_shards)?,
                    shard_queue_depth: params.shard_queue_depth,
                    read_timeout: timeout,
                    max_sessions: None,
                };
                serve_farm_async(&ep, &farm.handle(), &gw_cfg).map(|_| ())
            }
        };
    }

    // In-proc demo: N concurrent phones against the synthetic workload.
    let phones = flag_usize(flags, "phones", 16)?;
    let iters = flag_usize(flags, "iters", 50_000)? as i64;
    let program = Arc::new(crate::appvm::assembler::assemble(&synthetic_offload_src(
        iters,
    ))?);
    crate::appvm::verifier::verify_program(&program)?;
    let farm = CloneFarm::start(
        program.clone(),
        farm_cfg,
        cfg.costs.clone(),
        Arc::new(crate::appvm::NodeEnv::with_rust_compute),
    )?;
    let handle = farm.handle();
    let template = Arc::new(crate::appvm::zygote::build_template(
        &program,
        cfg.zygote_objects,
        cfg.seed,
    ));

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for phone in 0..phones as u64 {
        let program = program.clone();
        let template = template.clone();
        let costs = cfg.costs.clone();
        let mut fs = crate::vfs::SimFs::new();
        let mut bytes = vec![0u8; 64];
        crate::util::rng::Rng::new(cfg.seed ^ phone).fill_bytes(&mut bytes);
        fs.add("data.bin", bytes);
        let expected = synthetic_expected(&fs, iters);
        let mut session = handle.session(phone, fs.synchronize());
        // Delta only pays off when placement parks the phone's baseline
        // on one worker (affinity); other policies would thrash NeedFull.
        let delta = cfg.delta_migration && handle.delta_friendly();
        session.set_delta(delta);
        // Same placement constraint for the session dictionary replica.
        session.set_dict(cfg.session_dict && handle.delta_friendly());
        joins.push(std::thread::spawn(move || -> Result<()> {
            let mut p = crate::appvm::Process::fork_from_zygote(
                program.clone(),
                &template,
                crate::device::DeviceSpec::phone_g1(),
                Location::Mobile,
                crate::appvm::NodeEnv::with_rust_compute(fs),
            );
            let mut msess = crate::migration::MobileSession::new(delta);
            run_distributed_session(
                &mut p,
                &mut session,
                &NetworkProfile::wifi(),
                &costs,
                &mut msess,
            )?;
            let main = program.entry()?;
            let got = p.statics[main.class.0 as usize][0].as_int();
            if got != Some(expected) {
                return Err(CloneCloudError::migration(format!(
                    "phone {phone}: merged {got:?}, expected {expected}"
                )));
            }
            session.close();
            Ok(())
        }));
    }
    let mut failures = 0;
    for j in joins {
        match j.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                failures += 1;
                eprintln!("session failed: {e}");
            }
            Err(_) => {
                failures += 1;
                eprintln!("session panicked");
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let stats = farm.shutdown();
    println!(
        "farm demo: {phones} phones over {} workers (policy {}) in {wall_s:.3}s \
         = {:.1} sessions/s, pool hit rate {:.0}%, {failures} failure(s)",
        stats.workers,
        stats.policy,
        phones as f64 / wall_s,
        stats.pool_hit_rate() * 100.0,
    );
    let mut m = MetricsSnapshot::default();
    m.absorb_farm(&stats);
    print!("{}", m.render());
    if failures > 0 {
        return Err(CloneCloudError::migration(format!(
            "{failures} farm session(s) failed"
        )));
    }
    Ok(())
}

/// Dump the partition database and/or drive the runtime policy engine
/// live: a repeat-offload workload across a network trace, one decision
/// (with estimator state) printed per invocation.
fn cmd_policy(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    if let Some(db_path) = flags.get("db") {
        let db = PartitionDb::load(Path::new(db_path))?;
        println!("partition database {db_path}: {} entries", db.len());
        let mut table = Table::new(
            "Partition DB (conditions -> chosen partition + span prices)",
            &[
                "App",
                "Network",
                "Label",
                "Expected(s)",
                "Local(s)",
                "Spans (local/clone ms per call)",
            ],
        );
        for e in db.entries() {
            let spans = if e.migrate.is_empty() {
                "-".to_string()
            } else {
                e.migrate
                    .iter()
                    .enumerate()
                    .map(|(i, m)| {
                        format!(
                            "{m} ({:.1}/{:.1})",
                            e.span_local_ms.get(i).copied().unwrap_or(0.0),
                            e.span_clone_ms.get(i).copied().unwrap_or(0.0)
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(", ")
            };
            table.row(vec![
                e.app.clone(),
                e.network.clone(),
                e.label().to_string(),
                format!("{:.2}", e.expected_ms / 1e3),
                format!("{:.2}", e.local_ms / 1e3),
                spans,
            ]);
        }
        table.print();
        if !flags.contains_key("trace") {
            return Ok(());
        }
    }

    let rounds = flag_usize(flags, "rounds", 12)? as i64;
    if !(1..=256).contains(&rounds) {
        return Err(CloneCloudError::Config(
            "--rounds must be in 1..=256".into(),
        ));
    }
    let payload = flag_usize(flags, "payload", 4096)?.max(2) as i64;
    let segment = flag_usize(flags, "segment", 4)?.max(1);
    let trace = flags
        .get("trace")
        .map(String::as_str)
        .unwrap_or("wifi,edge,wifi");
    let profiles = trace
        .split(',')
        .map(|n| {
            NetworkProfile::by_name(n.trim()).ok_or_else(|| {
                CloneCloudError::Config(format!("unknown network '{}' in trace", n.trim()))
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let program = Arc::new(crate::appvm::assembler::assemble(
        &delta_statics_workload_src(rounds, payload, 8),
    )?);
    crate::appvm::verifier::verify_program(&program)?;
    let template = crate::appvm::zygote::build_template(
        &program,
        cfg.zygote_objects.min(2_000),
        cfg.seed,
    );
    let fork = |loc: Location| -> crate::appvm::Process {
        let dev = match loc {
            Location::Mobile => DeviceSpec::phone_g1(),
            Location::Clone => DeviceSpec::clone_desktop(),
        };
        crate::appvm::Process::fork_from_zygote(
            program.clone(),
            &template,
            dev,
            loc,
            crate::appvm::NodeEnv::with_rust_compute(crate::vfs::SimFs::new()),
        )
    };

    // Calibration: a forced-local run prices the span for the engine.
    let mut cal_phone = fork(Location::Mobile);
    let mut cal_channel =
        InlineClone::new(fork(Location::Clone), cfg.costs.clone()).with_exec_tier(cfg.exec_tier);
    let cal = run_distributed_with(
        &mut cal_phone,
        &mut cal_channel,
        |_| NetworkProfile::wifi(),
        &cfg.costs,
        &mut crate::migration::MobileSession::disabled(),
        &mut PolicyEngine::force_local(),
    )?;
    let local_ms = cal.virtual_ms / rounds as f64;
    let clone_ms = local_ms * cfg.clone.cpu_factor / cfg.phone.cpu_factor;

    let mut engine = PolicyEngine::from_params(&cfg.policy)?;
    engine.set_span(0, SpanCost { local_ms, clone_ms });
    let mut phone = fork(Location::Mobile);
    let mut channel =
        InlineClone::new(fork(Location::Clone), cfg.costs.clone()).with_exec_tier(cfg.exec_tier);
    if cfg.delta_migration {
        channel = channel.with_delta();
    }
    if cfg.session_dict {
        channel = channel.with_dict();
    }
    if !cfg.capture.paged {
        // The per-object ablation must cover BOTH directions, or the
        // scan counters would mix capture modes.
        channel = channel.with_per_object_captures();
    }
    let mut session = crate::migration::MobileSession::new(cfg.delta_migration);
    session.set_dict_enabled(cfg.session_dict);
    session.set_paged(cfg.capture.paged);
    session.set_gc_interval(cfg.capture.mobile_gc_interval);
    session.set_gc_growth(cfg.capture.mobile_gc_growth_objects);
    let profs = profiles.clone();
    let out = run_distributed_with(
        &mut phone,
        &mut channel,
        |trip| profs[(trip / segment).min(profs.len() - 1)].clone(),
        &cfg.costs,
        &mut session,
        &mut engine,
    )?;

    println!(
        "\nlive decisions: span local {local_ms:.1} ms / clone {clone_ms:.1} ms, \
         trace [{trace}] x {segment} trips/segment"
    );
    for d in &engine.log {
        let net = &profiles[(d.trip / segment).min(profiles.len() - 1)];
        let fmt = |v: Option<f64>| v.map_or_else(|| "?".to_string(), |x| format!("{x:.0}ms"));
        println!(
            "  trip {:>2} on {:<5} point {}: {:<7}{} local={} offload_est={}  [{}]",
            d.trip,
            net.name,
            d.point,
            match d.decision {
                Decision::Offload => "OFFLOAD",
                Decision::Local => "local",
            },
            if d.probe { " (probe)" } else { "" },
            fmt(d.local_ms),
            fmt(d.offload_est_ms),
            d.estimator,
        );
    }
    let main = program.entry()?;
    let got = phone.statics[main.class.0 as usize][1].as_int();
    let expected = delta_workload_expected(rounds);
    if got != Some(expected) {
        return Err(CloneCloudError::migration(format!(
            "policy run result {got:?} != expected {expected}"
        )));
    }
    println!(
        "policy run: {:.2}s virtual vs {:.2}s all-local, {} offloads / {} local \
         ({} mispredictions, {} delta trips), result verified",
        out.virtual_ms / 1e3,
        cal.virtual_ms / 1e3,
        out.offloads,
        out.local_fallbacks,
        out.mispredictions,
        out.delta_roundtrips,
    );
    Ok(())
}

/// Run one traced offload session against a small in-proc clone farm:
/// phone-side flight recorder on, clone events piggybacked home, merged
/// timeline exported as Chrome trace-event JSON plus a percentile table.
fn cmd_trace(flags: &HashMap<String, String>) -> Result<()> {
    use crate::exec::run_distributed_traced;
    use crate::trace::{chrome_trace_string, phone_coverage, Endpoint, Tracer};

    let cfg = load_config(flags)?;
    let rounds = flag_usize(flags, "rounds", 6)? as i64;
    if !(1..=256).contains(&rounds) {
        return Err(CloneCloudError::Config("--rounds must be in 1..=256".into()));
    }
    let payload = flag_usize(flags, "payload", 2048)?.max(2) as i64;
    let net = NetworkProfile::by_name(flags.get("network").map(String::as_str).unwrap_or("wifi"))
        .ok_or_else(|| CloneCloudError::Config("unknown network".into()))?;
    let out_path = flags
        .get("out")
        .map(String::as_str)
        .unwrap_or("session.trace.json");

    let program = Arc::new(crate::appvm::assembler::assemble(
        &delta_statics_workload_src(rounds, payload, 8),
    )?);
    crate::appvm::verifier::verify_program(&program)?;
    let zygote_objects = cfg.zygote_objects.min(2_000);
    let farm = CloneFarm::start(
        program.clone(),
        FarmConfig {
            workers: 1,
            warm_per_worker: 1,
            queue_depth: 4,
            policy: PlacementPolicy::Affinity,
            zygote_objects,
            zygote_seed: cfg.seed,
            fuel: 2_000_000_000,
            slot_gc_interval: cfg.farm.slot_gc_interval,
            exec_tier: cfg.exec_tier,
        },
        cfg.costs.clone(),
        Arc::new(crate::appvm::NodeEnv::with_rust_compute),
    )?;
    let handle = farm.handle();
    let mut session = handle.session_auto(crate::vfs::SimFs::new());
    session.set_delta(cfg.delta_migration && handle.delta_friendly());
    session.set_dict(cfg.session_dict && handle.delta_friendly());
    // In-proc sessions skip Hello; arm the capability directly.
    session.set_trace(true);

    let template = crate::appvm::zygote::build_template(&program, zygote_objects, cfg.seed);
    let mut phone = crate::appvm::Process::fork_from_zygote(
        program.clone(),
        &template,
        DeviceSpec::phone_g1(),
        Location::Mobile,
        crate::appvm::NodeEnv::with_rust_compute(crate::vfs::SimFs::new()),
    );
    let mut msess = crate::migration::MobileSession::new(session.delta_enabled());
    msess.set_dict_enabled(session.dict_enabled());
    msess.set_paged(cfg.capture.paged);
    msess.set_gc_interval(cfg.capture.mobile_gc_interval);
    msess.set_gc_growth(cfg.capture.mobile_gc_growth_objects);

    let mut tracer =
        Tracer::new(session.phone_id(), Endpoint::Phone, cfg.trace.ring_capacity.max(16));
    tracer.set_ship_clone_events(cfg.trace.ship_clone_events);
    let mut engine = crate::exec::PolicyEngine::force_offload().without_degrade();
    let out = run_distributed_traced(
        &mut phone,
        &mut session,
        &net,
        &cfg.costs,
        &mut msess,
        &mut engine,
        &mut tracer,
    )?;

    let main = program.entry()?;
    let got = phone.statics[main.class.0 as usize][1].as_int();
    let expected = delta_workload_expected(rounds);
    if got != Some(expected) {
        return Err(CloneCloudError::migration(format!(
            "traced run result {got:?} != expected {expected}"
        )));
    }

    let events: Vec<crate::trace::Event> = tracer.events().cloned().collect();
    let rep = tracer.report();
    let mut table = Table::new(
        "Phase latency (virtual ms)",
        &["Endpoint", "Phase", "Spans", "p50", "p95", "p99"],
    );
    for ph in &rep.phases {
        if ph.hist.is_empty() {
            continue;
        }
        table.row(vec![
            ph.endpoint.name().to_string(),
            ph.phase.name().to_string(),
            format!("{}", ph.hist.count()),
            format!("{:.3}", ph.hist.p50()),
            format!("{:.3}", ph.hist.p95()),
            format!("{:.3}", ph.hist.p99()),
        ]);
    }
    table.print();

    std::fs::write(out_path, chrome_trace_string(rep.session_id, &events))?;
    let clone_events = events.iter().filter(|e| e.endpoint == Endpoint::Clone).count();
    println!(
        "traced session: {} migration(s), {:.2}s virtual, {} event(s) \
         ({clone_events} clone-side, {} dropped), phone coverage {:.0}%",
        out.migrations,
        out.virtual_ms / 1e3,
        events.len(),
        rep.dropped,
        phone_coverage(&events) * 100.0,
    );
    println!("chrome trace written to {out_path} (load in Perfetto or chrome://tracing)");
    session.close();
    let mut m = MetricsSnapshot::default();
    m.absorb_dist(&out);
    m.absorb_trace(&rep);
    m.absorb_farm(&farm.shutdown());
    print!("{}", m.render());
    Ok(())
}

fn cmd_inspect(flags: &HashMap<String, String>) -> Result<()> {
    let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
    let program = app.program();
    println!("app '{}': {} classes", app.name(), program.classes.len());
    for class in &program.classes {
        if class.system {
            continue;
        }
        println!("  class {} ({} statics)", class.name, class.statics.len());
        for m in &class.methods {
            let kind = if m.is_native() { "native" } else { "bytecode" };
            let mut attrs = Vec::new();
            if m.pinned {
                attrs.push("pinned[V_M]");
            }
            if m.native_state {
                attrs.push("natstate[V_NatC]");
            }
            println!(
                "    {} ({kind}, {} instrs) {}",
                m.name,
                m.code.len(),
                attrs.join(" ")
            );
        }
    }
    let cfg_graph = Cfg::build(&program);
    println!(
        "  CFG: {} methods, {} DC edges, {} TC pairs",
        cfg_graph.len(),
        cfg_graph.dc_edges().len(),
        cfg_graph.tc_pairs().len()
    );
    let candidates = crate::partitioner::candidate_points(&program, &cfg_graph);
    println!(
        "  conditional-binary candidates ({}): {}",
        candidates.len(),
        candidates
            .iter()
            .map(|&m| program.method_name(m))
            .collect::<Vec<_>>()
            .join(", ")
    );
    Ok(())
}

/// CLI entrypoint; returns the process exit code.
pub fn main(args: &[String]) -> i32 {
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => {
            print!("{HELP}");
            return 2;
        }
    };
    let flags = match parse_flags(rest) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}\n");
            print!("{HELP}");
            return 2;
        }
    };
    let result = match cmd {
        "partition" => cmd_partition(&flags),
        "run" => cmd_run(&flags),
        "table1" => cmd_table1(&flags),
        "clone-serve" => cmd_clone_serve(&flags),
        "farm" => cmd_farm(&flags),
        "policy" => cmd_policy(&flags),
        "trace" => cmd_trace(&flags),
        "inspect" => cmd_inspect(&flags),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            return 0;
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{HELP}");
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parsing() {
        let f = parse_flags(&["--app".into(), "virus".into(), "--size".into(), "small".into()])
            .unwrap();
        assert_eq!(f["app"], "virus");
        assert_eq!(f["size"], "small");
        assert!(parse_flags(&["--app".into()]).is_err());
        assert!(parse_flags(&["stray".into()]).is_err());
    }

    #[test]
    fn name_resolution() {
        assert!(app_by_name("virus").is_ok());
        assert!(app_by_name("nope").is_err());
        assert_eq!(size_by_name("large").unwrap(), Size::Large);
        assert!(size_by_name("xl").is_err());
    }

    #[test]
    fn help_and_unknown_commands() {
        assert_eq!(main(&["help".into()]), 0);
        assert_eq!(main(&["wat".into()]), 2);
        assert_eq!(main(&[]), 2);
    }

    #[test]
    fn farm_demo_runs_small() {
        assert_eq!(
            main(&[
                "farm".into(),
                "--phones".into(),
                "2".into(),
                "--workers".into(),
                "1".into(),
                "--warm".into(),
                "1".into(),
                "--iters".into(),
                "1000".into(),
            ]),
            0
        );
    }

    #[test]
    fn farm_rejects_bad_flags() {
        assert_eq!(main(&["farm".into(), "--workers".into(), "x".into()]), 1);
        assert_eq!(
            main(&["farm".into(), "--policy".into(), "psychic".into()]),
            1
        );
    }

    #[test]
    fn trace_subcommand_exports_valid_chrome_trace() {
        let dir = std::env::temp_dir().join(format!("cctrace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("session.trace.json");
        assert_eq!(
            main(&[
                "trace".into(),
                "--rounds".into(),
                "4".into(),
                "--payload".into(),
                "64".into(),
                "--out".into(),
                path.to_string_lossy().into_owned(),
            ]),
            0,
            "trace subcommand"
        );
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).expect("valid trace-event JSON");
        let evs = doc.get("traceEvents").as_arr().expect("traceEvents array");
        let tids: std::collections::BTreeSet<i64> = evs
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .map(|e| e.get("tid").as_i64().unwrap())
            .collect();
        assert_eq!(tids.len(), 2, "both phone- and clone-side span lanes");
        std::fs::remove_dir_all(&dir).ok();

        assert_eq!(
            main(&["trace".into(), "--rounds".into(), "0".into()]),
            1,
            "rounds bound enforced"
        );
    }

    #[test]
    fn policy_dump_and_live_trace_run() {
        let dir = std::env::temp_dir().join(format!("ccpolicy_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let mut db = PartitionDb::new();
        db.put(PartitionEntry {
            app: "virus".into(),
            network: "wifi".into(),
            migrate: vec!["V.scan".into()],
            expected_ms: 1_000.0,
            local_ms: 2_000.0,
            span_local_ms: vec![1.5],
            span_clone_ms: vec![0.1],
            span_shards: vec![0],
        });
        db.save(&path).unwrap();
        assert_eq!(
            main(&[
                "policy".into(),
                "--db".into(),
                path.to_string_lossy().into_owned(),
            ]),
            0,
            "db dump"
        );
        std::fs::remove_dir_all(&dir).ok();

        assert_eq!(
            main(&[
                "policy".into(),
                "--rounds".into(),
                "6".into(),
                "--payload".into(),
                "64".into(),
                "--segment".into(),
                "2".into(),
                "--trace".into(),
                "wifi,edge,wifi".into(),
            ]),
            0,
            "live trace"
        );
        assert_eq!(
            main(&["policy".into(), "--trace".into(), "psychic".into()]),
            1,
            "unknown trace network rejected"
        );
    }

    #[test]
    fn inspect_runs() {
        assert_eq!(
            main(&["inspect".into(), "--app".into(), "behavior".into()]),
            0
        );
    }
}
