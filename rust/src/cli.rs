//! The `clonecloud` command-line interface (hand-rolled; no clap in the
//! offline environment — DESIGN.md §2).
//!
//! ```text
//! clonecloud partition --app virus --size medium [--config cfg.json] [--db out.json]
//! clonecloud run --app image --size large --network wifi [--mode local|clonecloud]
//! clonecloud table1
//! clonecloud clone-serve --listen 127.0.0.1:7077 --app virus
//! clonecloud inspect --app behavior
//! clonecloud help
//! ```

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use crate::apps::{all_apps, build_process, App, BehaviorProfile, ImageSearch, Size, VirusScan};
use crate::config::{Config, NetworkProfile};
use crate::device::Location;
use crate::error::{CloneCloudError, Result};
use crate::exec::{run_distributed, run_monolithic, InlineClone};
use crate::nodemanager::{CloneServer, TcpEndpoint};
use crate::partitioner::{rewrite_with_partition, Cfg, PartitionDb, PartitionEntry};
use crate::pipeline::{partition_app, table1_row};
use crate::runtime::default_backend;
use crate::util::bench::Table;

const HELP: &str = "\
clonecloud — CloneCloud (Chun et al., 2010) reproduction

USAGE:
  clonecloud <command> [options]

COMMANDS:
  partition    profile + solve a partition for an app under a network
  run          run an app (local or CloneCloud) and report times
  table1       regenerate the paper's Table 1
  clone-serve  run a clone node on a TCP listener
  inspect      dump an app's program, CFG, and constraint sets
  help         this text

OPTIONS:
  --app <virus|image|behavior>   application           (default: virus)
  --size <small|medium|large>    workload size         (default: medium)
  --network <3g|wifi>            execution conditions  (default: wifi)
  --mode <auto|local|clonecloud> run mode              (default: auto)
  --config <file.json>           config overrides
  --db <file.json>               partition database path
  --listen <addr:port>           clone-serve bind address
";

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = args
                .get(i + 1)
                .ok_or_else(|| CloneCloudError::Config(format!("--{key} needs a value")))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        } else {
            return Err(CloneCloudError::Config(format!("unexpected argument '{a}'")));
        }
    }
    Ok(flags)
}

fn app_by_name(name: &str) -> Result<Box<dyn App>> {
    match name {
        "virus" => Ok(Box::new(VirusScan)),
        "image" => Ok(Box::new(ImageSearch)),
        "behavior" => Ok(Box::new(BehaviorProfile)),
        other => Err(CloneCloudError::Config(format!("unknown app '{other}'"))),
    }
}

fn size_by_name(name: &str) -> Result<Size> {
    match name {
        "small" => Ok(Size::Small),
        "medium" => Ok(Size::Medium),
        "large" => Ok(Size::Large),
        other => Err(CloneCloudError::Config(format!("unknown size '{other}'"))),
    }
}

fn load_config(flags: &HashMap<String, String>) -> Result<Config> {
    match flags.get("config") {
        Some(path) => Config::load(Path::new(path)),
        None => Ok(Config::default()),
    }
}

fn cmd_partition(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
    let size = size_by_name(flags.get("size").map(String::as_str).unwrap_or("medium"))?;
    let net = NetworkProfile::by_name(flags.get("network").map(String::as_str).unwrap_or("wifi"))
        .ok_or_else(|| CloneCloudError::Config("unknown network".into()))?;
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let (partition, report) = partition_app(app.as_ref(), size, &cfg, &net, &backend)?;
    let program = app.program();
    println!(
        "partition for ({}, {}, {}): {}",
        app.name(),
        app.input_label(size),
        net.name,
        partition.label()
    );
    for &m in &partition.migrate {
        println!("  R(m)=1: {}", program.method_name(m));
    }
    println!(
        "expected {:.2}s vs local {:.2}s; profiled {} methods \
         (phone {:.2}s wall, migration-cost {:.2}s wall, solve {:.3}s)",
        partition.expected_us / 1e6,
        partition.local_us / 1e6,
        report.methods_profiled,
        report.profile_phone_s,
        report.profile_migration_s,
        report.solve_s,
    );
    if let Some(db_path) = flags.get("db") {
        let path = Path::new(db_path);
        let mut db = if path.exists() {
            PartitionDb::load(path)?
        } else {
            PartitionDb::new()
        };
        db.put(PartitionEntry::from_partition(
            app.name(),
            &net.name,
            &program,
            &partition,
        ));
        db.save(path)?;
        println!("stored in {db_path} ({} entries)", db.len());
    }
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
    let size = size_by_name(flags.get("size").map(String::as_str).unwrap_or("medium"))?;
    let net = NetworkProfile::by_name(flags.get("network").map(String::as_str).unwrap_or("wifi"))
        .ok_or_else(|| CloneCloudError::Config("unknown network".into()))?;
    let mode = flags.get("mode").map(String::as_str).unwrap_or("auto");
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let program = app.program();

    let offload = match mode {
        "local" => false,
        "clonecloud" => true,
        "auto" => {
            let (p, _) = partition_app(app.as_ref(), size, &cfg, &net, &backend)?;
            p.is_offload()
        }
        other => return Err(CloneCloudError::Config(format!("unknown mode '{other}'"))),
    };

    if !offload {
        let mut p = build_process(
            app.as_ref(), program, size, &cfg, Location::Mobile, backend, false,
        )?;
        let out = run_monolithic(&mut p)?;
        println!(
            "local run: {:.2}s virtual, {} instrs ({})",
            out.virtual_ms / 1e3,
            out.instrs,
            app.check(&p, size)?
        );
    } else {
        let (partition, _) = partition_app(app.as_ref(), size, &cfg, &net, &backend)?;
        let (rewritten, _) = rewrite_with_partition(&program, &partition)?;
        let rewritten = Arc::new(rewritten);
        let mut phone = build_process(
            app.as_ref(), rewritten.clone(), size, &cfg, Location::Mobile, backend.clone(), false,
        )?;
        let clone = build_process(
            app.as_ref(), rewritten, size, &cfg, Location::Clone, backend, false,
        )?;
        let mut channel = InlineClone::new(clone, cfg.costs.clone());
        let out = run_distributed(&mut phone, &mut channel, &net, &cfg.costs)?;
        println!(
            "CloneCloud run ({}): {:.2}s virtual, {} migration(s), {}B up / {}B down ({})",
            net.name,
            out.virtual_ms / 1e3,
            out.migrations,
            out.transfer.up,
            out.transfer.down,
            app.check(&phone, size)?
        );
    }
    Ok(())
}

fn cmd_table1(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let mut table = Table::new(
        "Table 1 (paper §6)",
        &["Application", "Input", "Phone(s)", "Clone(s)", "MaxSpd",
          "CC-3G(s)", "Part-3G", "Spd-3G", "CC-WiFi(s)", "Part-WiFi", "Spd-WiFi"],
    );
    for app in all_apps() {
        for size in Size::all() {
            let row = table1_row(app.as_ref(), size, &cfg, &backend)?;
            table.row(vec![
                row.app.to_string(),
                row.input,
                format!("{:.2}", row.phone_ms / 1e3),
                format!("{:.2}", row.clone_ms / 1e3),
                format!("{:.2}", row.max_speedup),
                format!("{:.2}", row.threeg.exec_ms / 1e3),
                row.threeg.label.into(),
                format!("{:.2}", row.threeg.speedup),
                format!("{:.2}", row.wifi.exec_ms / 1e3),
                row.wifi.label.into(),
                format!("{:.2}", row.wifi.speedup),
            ]);
        }
    }
    table.print();
    Ok(())
}

fn cmd_clone_serve(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
    let addr = flags
        .get("listen")
        .map(String::as_str)
        .unwrap_or("127.0.0.1:7077");
    // The phone's provision message carries its executable hash, so a
    // mismatched binary is rejected at the door.
    let program = app.program();
    let ep = TcpEndpoint::bind(addr)?;
    println!(
        "clone node listening on {} for app '{}'",
        ep.local_addr()?,
        app.name()
    );
    loop {
        let t = ep.accept()?;
        let artifacts = cfg.artifacts_dir.clone();
        let srv = CloneServer::new(
            t,
            program.clone(),
            cfg.costs.clone(),
            Box::new(move |fs| {
                crate::appvm::NodeEnv::new(fs, default_backend(Path::new(&artifacts)))
            }),
        );
        match srv.serve() {
            Ok(stats) => println!("session done: {} migrations", stats.migrations),
            Err(e) => eprintln!("session error: {e}"),
        }
    }
}

fn cmd_inspect(flags: &HashMap<String, String>) -> Result<()> {
    let app = app_by_name(flags.get("app").map(String::as_str).unwrap_or("virus"))?;
    let program = app.program();
    println!("app '{}': {} classes", app.name(), program.classes.len());
    for class in &program.classes {
        if class.system {
            continue;
        }
        println!("  class {} ({} statics)", class.name, class.statics.len());
        for m in &class.methods {
            let kind = if m.is_native() { "native" } else { "bytecode" };
            let mut attrs = Vec::new();
            if m.pinned {
                attrs.push("pinned[V_M]");
            }
            if m.native_state {
                attrs.push("natstate[V_NatC]");
            }
            println!(
                "    {} ({kind}, {} instrs) {}",
                m.name,
                m.code.len(),
                attrs.join(" ")
            );
        }
    }
    let cfg_graph = Cfg::build(&program);
    println!(
        "  CFG: {} methods, {} DC edges, {} TC pairs",
        cfg_graph.len(),
        cfg_graph.dc_edges().len(),
        cfg_graph.tc_pairs().len()
    );
    Ok(())
}

/// CLI entrypoint; returns the process exit code.
pub fn main(args: &[String]) -> i32 {
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => {
            print!("{HELP}");
            return 2;
        }
    };
    let flags = match parse_flags(rest) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}\n");
            print!("{HELP}");
            return 2;
        }
    };
    let result = match cmd {
        "partition" => cmd_partition(&flags),
        "run" => cmd_run(&flags),
        "table1" => cmd_table1(&flags),
        "clone-serve" => cmd_clone_serve(&flags),
        "inspect" => cmd_inspect(&flags),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            return 0;
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{HELP}");
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parsing() {
        let f = parse_flags(&["--app".into(), "virus".into(), "--size".into(), "small".into()])
            .unwrap();
        assert_eq!(f["app"], "virus");
        assert_eq!(f["size"], "small");
        assert!(parse_flags(&["--app".into()]).is_err());
        assert!(parse_flags(&["stray".into()]).is_err());
    }

    #[test]
    fn name_resolution() {
        assert!(app_by_name("virus").is_ok());
        assert!(app_by_name("nope").is_err());
        assert_eq!(size_by_name("large").unwrap(), Size::Large);
        assert!(size_by_name("xl").is_err());
    }

    #[test]
    fn help_and_unknown_commands() {
        assert_eq!(main(&["help".into()]), 0);
        assert_eq!(main(&["wat".into()]), 2);
        assert_eq!(main(&[]), 2);
    }

    #[test]
    fn inspect_runs() {
        assert_eq!(
            main(&["inspect".into(), "--app".into(), "behavior".into()]),
            0
        );
    }
}
