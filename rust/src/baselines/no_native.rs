//! "Virtualized-computation-only" migration baseline (paper §7).
//!
//! Prior application-layer VM migrators (cJVM, Jessica2, MERPATI) keep
//! every native feature exclusively on the original platform: only pure
//! virtualized computation may move. We model that by pinning EVERY
//! native method to the mobile device and re-running the CloneCloud
//! solver — any method that (transitively) touches a native then cannot
//! migrate, which collapses most of Table 1's offload opportunities.
//! The delta against the real solver is CloneCloud's "native everywhere"
//! contribution, quantified.

use crate::appvm::class::Program;
use crate::error::Result;
use crate::partitioner::{solve_partition, Cfg, CostModel, Partition, SolveReport};

/// Clone the program with all natives pinned (the prior-work restriction).
pub fn pin_all_natives(program: &Program) -> Program {
    let mut p = program.clone();
    for mref in p.all_methods() {
        if p.method(mref).is_native() {
            p.method_mut(mref).pinned = true;
        }
    }
    p
}

/// Solve under the no-native-everywhere restriction.
pub fn solve_no_native_everywhere(
    program: &Program,
    costs: &CostModel,
) -> Result<(Partition, SolveReport)> {
    let pinned = pin_all_natives(program);
    let cfg = Cfg::build(&pinned);
    solve_partition(&pinned, &cfg, costs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::assembler::assemble;

    /// A worker whose loop calls an everywhere-native (fs.read): real
    /// CloneCloud can offload it (fs is synchronized); the restricted
    /// baseline cannot.
    const SRC: &str = r#"
class A app
  method main nargs=0 regs=2
    invokev A.work
    retv
  end
  method work nargs=0 regs=6
    const r0 0
    const r1 0
    const r2 8
    invoke r3 A.read r0 r1 r2
    retv
  end
  method read nargs=3 regs=3 native=fs.read
end
"#;

    #[test]
    fn restriction_blocks_offload_that_clonecloud_allows() {
        let program = assemble(SRC).unwrap();
        let cfg = Cfg::build(&program);
        let work = program.resolve("A", "work").unwrap();
        let mut cm = CostModel::default();
        cm.mobile_us.insert(work, 1e6);
        cm.clone_us.insert(work, 1e3);
        cm.migr_us.insert(work, 100.0);
        // Real CloneCloud offloads work().
        let (p, _) = solve_partition(&program, &cfg, &cm).unwrap();
        assert!(p.migrate.contains(&work), "native-everywhere offload");
        // The prior-work baseline cannot.
        let (bp, _) = solve_no_native_everywhere(&program, &cm).unwrap();
        assert!(bp.migrate.is_empty());
        assert!(bp.expected_us >= p.expected_us);
    }
}
