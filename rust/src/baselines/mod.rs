//! Comparison baselines from the paper's related work (§7), for the E8
//! ablation bench:
//!
//! * [`mincut`] — class-granularity MINCUT partitioning with synchronous
//!   RPC at the boundary (the Java-partitioning line of work);
//! * [`no_native`] — thread migration restricted to pure virtualized
//!   computation (the DJVM/migration line of work);
//! * monolithic phone / clone executions are `exec::run_monolithic` on
//!   the respective device.

pub mod mincut;
pub mod no_native;

pub use mincut::{solve_class_partition, ClassPartition};
pub use no_native::{pin_all_natives, solve_no_native_everywhere};
