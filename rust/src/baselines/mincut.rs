//! Class-granularity MINCUT partitioning baseline (paper §7).
//!
//! The related work the paper contrasts against ([20, 25, 28]) partitions
//! Java *classes* into phone/server groups with MINCUT-style heuristics,
//! placing remote calls through synchronous RPC (RMI), and cannot place
//! classes with native state remotely. This module reproduces that
//! design point so the ablation bench (E8) can show what CloneCloud's
//! method granularity + native-everywhere + thread migration buy.
//!
//! The model: choose a location per app class minimizing
//!   Σ_m comp(m, loc(class(m)))  +  Σ_{cross-boundary DC edges} RPC cost,
//! where RPC cost is per *invocation* (one synchronous round trip each).
//! Classes containing pinned methods, native-state methods, or `main`
//! are anchored to the phone. Solved exactly by enumeration (apps have a
//! handful of classes).

use std::collections::HashMap;

use crate::appvm::bytecode::ClassId;
use crate::appvm::class::Program;
use crate::config::NetworkProfile;
use crate::device::Location;
use crate::error::{CloneCloudError, Result};
use crate::partitioner::{Cfg, CostModel};

/// Result of the class-level baseline.
#[derive(Debug, Clone)]
pub struct ClassPartition {
    pub locations: HashMap<ClassId, Location>,
    /// Modeled execution time (µs).
    pub expected_us: f64,
    /// All-local cost for comparison (µs).
    pub local_us: f64,
    pub remote_classes: Vec<String>,
}

/// Bytes assumed per RPC call (marshalled args + return).
const RPC_BYTES: u64 = 256;

/// Solve the class-granularity baseline.
pub fn solve_class_partition(
    program: &Program,
    cfg: &Cfg,
    costs: &CostModel,
    net: &NetworkProfile,
) -> Result<ClassPartition> {
    // App classes only; anchored = must stay on the phone.
    let mut classes: Vec<ClassId> = Vec::new();
    let mut anchored: Vec<bool> = Vec::new();
    for (ci, class) in program.classes.iter().enumerate() {
        if class.system {
            continue;
        }
        let cid = ClassId(ci as u16);
        // Prior-work restriction: classes with native methods of ANY
        // kind stay on the phone ("only Java classes without native
        // state can be placed remotely" — and these systems cannot remote
        // native calls at all), as do pinned methods and main.
        let anchor = class.methods.iter().any(|m| {
            m.pinned || m.native_state || m.native.is_some() || m.name == "main"
        });
        classes.push(cid);
        anchored.push(anchor);
    }
    let n = classes.len();
    if n > 20 {
        return Err(CloneCloudError::partitioner(
            "class-baseline enumeration capped at 20 classes",
        ));
    }
    let class_pos: HashMap<ClassId, usize> =
        classes.iter().enumerate().map(|(i, &c)| (c, i)).collect();

    // Per-class comp costs and per-edge invocation counts.
    let mut local_cost = vec![0.0f64; n];
    let mut remote_cost = vec![0.0f64; n];
    for m in program.app_methods() {
        let Some(&pos) = class_pos.get(&m.class) else { continue };
        local_cost[pos] += costs.mobile(m);
        remote_cost[pos] += costs.clone_side(m);
    }
    // Cross-class DC edges weighted by callee invocation counts.
    let mut edges: Vec<(usize, usize, f64)> = Vec::new();
    for (i, j) in cfg.dc_edges() {
        let (m1, m2) = (cfg.methods[i], cfg.methods[j]);
        let (Some(&c1), Some(&c2)) = (class_pos.get(&m1.class), class_pos.get(&m2.class)) else {
            continue;
        };
        if c1 == c2 {
            continue;
        }
        let calls = *costs.invocations.get(&m2).unwrap_or(&0) as f64;
        if calls > 0.0 {
            edges.push((c1, c2, calls));
        }
    }
    // One synchronous RPC round trip per call.
    let rpc_us_per_call =
        (net.transfer_ms(RPC_BYTES, true) + net.transfer_ms(RPC_BYTES, false)) * 1e3;

    let local_total: f64 = local_cost.iter().sum();
    let mut best_mask = 0u32;
    let mut best_cost = f64::INFINITY;
    'mask: for mask in 0u32..(1 << n) {
        // Anchored classes must be local (bit 0).
        for (i, &a) in anchored.iter().enumerate() {
            if a && (mask >> i) & 1 == 1 {
                continue 'mask;
            }
        }
        let mut cost = 0.0;
        for i in 0..n {
            cost += if (mask >> i) & 1 == 1 {
                remote_cost[i]
            } else {
                local_cost[i]
            };
        }
        for &(c1, c2, calls) in &edges {
            if (mask >> c1) & 1 != (mask >> c2) & 1 {
                cost += calls * rpc_us_per_call;
            }
        }
        if cost < best_cost {
            best_cost = cost;
            best_mask = mask;
        }
    }

    let mut locations = HashMap::new();
    let mut remote_classes = Vec::new();
    for (i, &cid) in classes.iter().enumerate() {
        let loc = if (best_mask >> i) & 1 == 1 {
            remote_classes.push(program.class(cid).name.clone());
            Location::Clone
        } else {
            Location::Mobile
        };
        locations.insert(cid, loc);
    }
    Ok(ClassPartition {
        locations,
        expected_us: best_cost,
        local_us: local_total,
        remote_classes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::bytecode::MRef;

    const SRC: &str = r#"
class UI app
  method main nargs=0 regs=2
    invokev Work.go
    retv
  end
  method show nargs=1 regs=1 native=ui.show
end
class Work app
  method go nargs=0 regs=2
    invokev Work.inner
    retv
  end
  method inner nargs=0 regs=2
    retv
  end
end
class Store app
  method load nargs=3 regs=3 native=fs.read natstate
end
"#;

    fn model(program: &Program, entries: &[(&str, &str, f64, f64, usize)]) -> CostModel {
        let mut cm = CostModel::default();
        for &(c, m, a, b, inv) in entries {
            let mref: MRef = program.resolve(c, m).unwrap();
            cm.mobile_us.insert(mref, a);
            cm.clone_us.insert(mref, b);
            cm.invocations.insert(mref, inv);
        }
        cm
    }

    #[test]
    fn offloads_compute_class_when_few_calls() {
        let program = assemble(SRC).unwrap();
        let cfg = Cfg::build(&program);
        let cm = model(
            &program,
            &[
                ("UI", "main", 10.0, 0.5, 1),
                ("Work", "go", 2_000_000.0, 100_000.0, 1),
                ("Work", "inner", 0.0, 0.0, 1),
            ],
        );
        let p = solve_class_partition(&program, &cfg, &cm, &NetworkProfile::wifi()).unwrap();
        assert!(p.remote_classes.contains(&"Work".to_string()));
        assert!(p.expected_us < p.local_us);
    }

    #[test]
    fn chatty_boundary_stays_local() {
        let program = assemble(SRC).unwrap();
        let cfg = Cfg::build(&program);
        // go is called 100000 times from main: RPC per call swamps the
        // compute win (the class-granularity pathology CloneCloud avoids
        // by migrating once).
        let cm = model(
            &program,
            &[
                ("UI", "main", 10.0, 0.5, 1),
                ("Work", "go", 2_000_000.0, 100_000.0, 100_000),
                ("Work", "inner", 0.0, 0.0, 100_000),
            ],
        );
        let p = solve_class_partition(&program, &cfg, &cm, &NetworkProfile::wifi()).unwrap();
        assert!(p.remote_classes.is_empty(), "{:?}", p.remote_classes);
    }

    #[test]
    fn native_state_class_anchored() {
        let program = assemble(SRC).unwrap();
        let cfg = Cfg::build(&program);
        let store = program.resolve("Store", "load").unwrap();
        let mut cm = CostModel::default();
        cm.mobile_us.insert(store, 1e9);
        cm.clone_us.insert(store, 1.0);
        cm.invocations.insert(store, 1);
        let p = solve_class_partition(&program, &cfg, &cm, &NetworkProfile::wifi()).unwrap();
        assert!(
            !p.remote_classes.contains(&"Store".to_string()),
            "prior-work baselines cannot move native state"
        );
    }
}
