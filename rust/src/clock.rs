//! Virtual-time ledger.
//!
//! All app-level "execution time" in this reproduction is *virtual*
//! microseconds accumulated here (DESIGN.md §3): interpreted instructions,
//! native compute, and migration phases charge time scaled by the device
//! they run on. Wall-clock time is reserved for the coordinator's own perf
//! measurements.

/// Monotonic virtual clock, microsecond resolution.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now_us: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time in microseconds.
    pub fn now_us(&self) -> f64 {
        self.now_us
    }

    /// Current virtual time in milliseconds.
    pub fn now_ms(&self) -> f64 {
        self.now_us / 1e3
    }

    /// Advance the clock by `us` virtual microseconds.
    pub fn charge_us(&mut self, us: f64) {
        debug_assert!(us >= 0.0, "negative time charge {us}");
        self.now_us += us;
    }

    /// Advance by milliseconds.
    pub fn charge_ms(&mut self, ms: f64) {
        self.charge_us(ms * 1e3);
    }

    /// Jump the clock forward to an absolute time (used when re-importing
    /// a migrated thread whose remote execution ended later than `now`).
    pub fn advance_to_us(&mut self, t_us: f64) {
        if t_us > self.now_us {
            self.now_us = t_us;
        }
    }

    /// Reset to zero (between benchmark runs).
    pub fn reset(&mut self) {
        self.now_us = 0.0;
    }
}

/// A span measured against a virtual clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VirtualSpan {
    pub start_us: f64,
    pub end_us: f64,
}

impl VirtualSpan {
    pub fn duration_us(&self) -> f64 {
        self.end_us - self.start_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let mut c = VirtualClock::new();
        c.charge_us(5.0);
        c.charge_ms(1.0);
        assert!((c.now_us() - 1005.0).abs() < 1e-9);
        assert!((c.now_ms() - 1.005).abs() < 1e-9);
    }

    #[test]
    fn advance_to_only_moves_forward() {
        let mut c = VirtualClock::new();
        c.charge_us(100.0);
        c.advance_to_us(50.0);
        assert_eq!(c.now_us(), 100.0);
        c.advance_to_us(200.0);
        assert_eq!(c.now_us(), 200.0);
    }
}
