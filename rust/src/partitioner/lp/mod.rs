//! LP/ILP solver substrate (Mosek replacement — DESIGN.md §2).
pub mod ilp;
pub mod simplex;
pub use ilp::{solve_exhaustive, solve_ilp, IlpResult};
pub use simplex::{solve_lp, Constraint, LpResult, Sense};
