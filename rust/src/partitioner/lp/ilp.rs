//! 0-1 integer programming by branch-and-bound over the LP relaxation.
//!
//! All variables are binary. Depth-first search, branching on the most
//! fractional variable, pruning by the incumbent objective. Exact for
//! the partitioner's problem sizes (tens of binaries); property tests
//! cross-check against exhaustive enumeration.

use super::simplex::{solve_lp, Constraint, LpResult, Sense};

/// ILP outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum IlpResult {
    Optimal { x: Vec<u8>, objective: f64 },
    Infeasible,
}

const INT_TOL: f64 = 1e-6;

/// Solve min c·x s.t. constraints, x ∈ {0,1}^n.
pub fn solve_ilp(n_vars: usize, c: &[f64], constraints: &[Constraint]) -> IlpResult {
    // Add 0/1 bounds for every variable.
    let mut cons: Vec<Constraint> = constraints.to_vec();
    for j in 0..n_vars {
        cons.push(Constraint {
            coeffs: vec![(j, 1.0)],
            sense: Sense::Le,
            rhs: 1.0,
        });
    }

    let mut best: Option<(Vec<u8>, f64)> = None;
    let mut fixed: Vec<Option<u8>> = vec![None; n_vars];
    branch(n_vars, c, &cons, &mut fixed, &mut best, 0);
    match best {
        Some((x, objective)) => IlpResult::Optimal { x, objective },
        None => IlpResult::Infeasible,
    }
}

fn branch(
    n_vars: usize,
    c: &[f64],
    base_cons: &[Constraint],
    fixed: &mut Vec<Option<u8>>,
    best: &mut Option<(Vec<u8>, f64)>,
    depth: usize,
) {
    // Build the LP with fixings as equalities.
    let mut cons = base_cons.to_vec();
    for (j, f) in fixed.iter().enumerate() {
        if let Some(v) = f {
            cons.push(Constraint {
                coeffs: vec![(j, 1.0)],
                sense: Sense::Eq,
                rhs: *v as f64,
            });
        }
    }
    let relax = solve_lp(n_vars, c, &cons);
    let (x, obj) = match relax {
        LpResult::Optimal { x, objective } => (x, objective),
        LpResult::Infeasible => return,
        LpResult::Unbounded => return, // bounded by 0/1 rows; defensive
    };
    // Prune by incumbent.
    if let Some((_, incumbent)) = best {
        if obj >= *incumbent - 1e-9 {
            return;
        }
    }
    // Integer-feasible?
    let frac_var = (0..n_vars)
        .filter(|&j| {
            let f = x[j].fract();
            f.min(1.0 - f) > INT_TOL && x[j] > INT_TOL && x[j] < 1.0 - INT_TOL
        })
        .max_by(|&a, &b| {
            let fa = (x[a] - 0.5).abs();
            let fb = (x[b] - 0.5).abs();
            fb.partial_cmp(&fa).unwrap() // most fractional = closest to 0.5
        });
    match frac_var {
        None => {
            let xi: Vec<u8> = x.iter().map(|&v| if v > 0.5 { 1 } else { 0 }).collect();
            let better = best.as_ref().map(|(_, b)| obj < *b - 1e-12).unwrap_or(true);
            if better {
                *best = Some((xi, obj));
            }
        }
        Some(j) => {
            if depth > 64 {
                return; // defensive depth guard
            }
            // Branch: try the rounding nearest the relaxation first.
            let order: [u8; 2] = if x[j] >= 0.5 { [1, 0] } else { [0, 1] };
            for v in order {
                fixed[j] = Some(v);
                branch(n_vars, c, base_cons, fixed, best, depth + 1);
                fixed[j] = None;
            }
        }
    }
}

/// Exhaustive 0-1 reference solver (for property tests; exponential).
pub fn solve_exhaustive(n_vars: usize, c: &[f64], constraints: &[Constraint]) -> IlpResult {
    assert!(n_vars <= 20, "exhaustive reference capped at 20 vars");
    let mut best: Option<(Vec<u8>, f64)> = None;
    'outer: for mask in 0u32..(1 << n_vars) {
        let x: Vec<u8> = (0..n_vars).map(|j| ((mask >> j) & 1) as u8).collect();
        for con in constraints {
            let lhs: f64 = con.coeffs.iter().map(|&(j, v)| v * x[j] as f64).sum();
            let ok = match con.sense {
                Sense::Le => lhs <= con.rhs + 1e-9,
                Sense::Eq => (lhs - con.rhs).abs() <= 1e-9,
                Sense::Ge => lhs >= con.rhs - 1e-9,
            };
            if !ok {
                continue 'outer;
            }
        }
        let obj: f64 = c.iter().zip(&x).map(|(ci, &xi)| ci * xi as f64).sum();
        if best.as_ref().map(|(_, b)| obj < *b - 1e-12).unwrap_or(true) {
            best = Some((x, obj));
        }
    }
    match best {
        Some((x, objective)) => IlpResult::Optimal { x, objective },
        None => IlpResult::Infeasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, ensure_close, forall, PropConfig};
    use crate::util::rng::Rng;

    fn con(coeffs: &[(usize, f64)], sense: Sense, rhs: f64) -> Constraint {
        Constraint {
            coeffs: coeffs.to_vec(),
            sense,
            rhs,
        }
    }

    #[test]
    fn knapsack() {
        // max 5a + 4b + 3c s.t. 2a + 3b + c <= 5  => min negated.
        let r = solve_ilp(
            3,
            &[-5.0, -4.0, -3.0],
            &[con(&[(0, 2.0), (1, 3.0), (2, 1.0)], Sense::Le, 5.0)],
        );
        match r {
            IlpResult::Optimal { x, objective } => {
                assert_eq!(x, vec![1, 1, 0], "a+b fills the knapsack exactly");
                assert!((objective + 9.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_binary_system() {
        let r = solve_ilp(
            2,
            &[1.0, 1.0],
            &[
                con(&[(0, 1.0), (1, 1.0)], Sense::Ge, 3.0), // needs > 2
            ],
        );
        assert_eq!(r, IlpResult::Infeasible);
    }

    #[test]
    fn xor_chain_integrality() {
        // L0=0; L1 = L0 xor R1; minimize (B-A)L1 + S R1 with big win for
        // L1=1 -> forces R1=1 integrally.
        let xor = |l2: usize, l1: usize, r2: usize| -> Vec<Constraint> {
            vec![
                con(&[(l2, 1.0), (l1, -1.0), (r2, 1.0)], Sense::Ge, 0.0),
                con(&[(l2, 1.0), (l1, -1.0), (r2, -1.0)], Sense::Le, 0.0),
                con(&[(l2, 1.0), (r2, -1.0), (l1, 1.0)], Sense::Ge, 0.0),
                con(&[(l2, 1.0), (r2, 1.0), (l1, 1.0)], Sense::Le, 2.0),
            ]
        };
        let mut cons = vec![con(&[(0, 1.0)], Sense::Eq, 0.0)];
        cons.extend(xor(1, 0, 2));
        let r = solve_ilp(3, &[0.0, -100.0, 7.0], &cons);
        match r {
            IlpResult::Optimal { x, objective } => {
                assert_eq!(x, vec![0, 1, 1]);
                assert!((objective + 93.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    /// The core correctness property: branch-and-bound == exhaustive on
    /// random small instances.
    #[test]
    fn prop_bnb_matches_exhaustive() {
        forall(
            PropConfig { seed: 0xB1B0, cases: 60 },
            |rng: &mut Rng| {
                let n = 2 + rng.index(6); // 2..7 vars
                let c: Vec<f64> = (0..n).map(|_| rng.range_i64(-20, 20) as f64).collect();
                let ncons = rng.index(5);
                let cons: Vec<Constraint> = (0..ncons)
                    .map(|_| {
                        let k = 1 + rng.index(n.min(3));
                        let idx = rng.choose_distinct(n, k);
                        let coeffs: Vec<(usize, f64)> = idx
                            .into_iter()
                            .map(|j| (j, rng.range_i64(-5, 5) as f64))
                            .collect();
                        let sense = match rng.index(3) {
                            0 => Sense::Le,
                            1 => Sense::Ge,
                            _ => Sense::Eq,
                        };
                        let rhs = rng.range_i64(-4, 6) as f64;
                        Constraint { coeffs, sense, rhs }
                    })
                    .collect();
                (n, c, cons)
            },
            |(n, c, cons)| {
                let a = solve_ilp(*n, c, cons);
                let b = solve_exhaustive(*n, c, cons);
                match (a, b) {
                    (IlpResult::Infeasible, IlpResult::Infeasible) => Ok(()),
                    (
                        IlpResult::Optimal { objective: oa, .. },
                        IlpResult::Optimal { objective: ob, .. },
                    ) => ensure_close(oa, ob, 1e-6, "objective"),
                    (a, b) => ensure(false, format!("feasibility mismatch: {a:?} vs {b:?}")),
                }
            },
        );
    }
}
