//! Dense two-phase primal simplex (the LP-relaxation engine under the
//! branch-and-bound ILP — our substitute for the paper's Mosek).
//!
//! Solves  min c·x  s.t.  A x {<=,=,>=} b,  x >= 0.
//! Bounded 0/1 variables are expressed by the caller as explicit
//! `x_i <= 1` rows. Bland's rule is used throughout, so the method cannot
//! cycle; problem sizes here (tens of variables, hundreds of rows) make
//! its slower convergence irrelevant.

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Le,
    Eq,
    Ge,
}

/// One linear constraint: `coeffs · x (sense) rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

/// LP outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
}

const EPS: f64 = 1e-9;

/// Solve min c·x subject to constraints, x >= 0.
pub fn solve_lp(n_vars: usize, c: &[f64], constraints: &[Constraint]) -> LpResult {
    assert_eq!(c.len(), n_vars);
    let m = constraints.len();

    // Normalize to equalities with slack/surplus, rhs >= 0.
    // Columns: [x (n) | slack/surplus (s) | artificial (a)].
    let mut rows: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut rhs: Vec<f64> = Vec::with_capacity(m);
    let mut senses: Vec<Sense> = Vec::with_capacity(m);
    for con in constraints {
        let mut row = vec![0.0; n_vars];
        for &(j, v) in &con.coeffs {
            assert!(j < n_vars, "coefficient index out of range");
            row[j] += v;
        }
        let (mut r, mut b, mut s) = (row, con.rhs, con.sense);
        if b < 0.0 {
            for v in r.iter_mut() {
                *v = -*v;
            }
            b = -b;
            s = match s {
                Sense::Le => Sense::Ge,
                Sense::Ge => Sense::Le,
                Sense::Eq => Sense::Eq,
            };
        }
        rows.push(r);
        rhs.push(b);
        senses.push(s);
    }

    // Count slack and artificial columns.
    let mut n_slack = 0;
    let mut n_art = 0;
    for s in &senses {
        match s {
            Sense::Le => n_slack += 1,
            Sense::Ge => {
                n_slack += 1;
                n_art += 1;
            }
            Sense::Eq => n_art += 1,
        }
    }
    let total = n_vars + n_slack + n_art;

    // Tableau: m rows x (total + 1) [last col = rhs].
    let mut tab = vec![vec![0.0; total + 1]; m];
    let mut basis = vec![0usize; m];
    let mut si = n_vars;
    let mut ai = n_vars + n_slack;
    let mut artificial_cols: Vec<usize> = Vec::new();
    for i in 0..m {
        tab[i][..n_vars].copy_from_slice(&rows[i]);
        tab[i][total] = rhs[i];
        match senses[i] {
            Sense::Le => {
                tab[i][si] = 1.0;
                basis[i] = si;
                si += 1;
            }
            Sense::Ge => {
                tab[i][si] = -1.0;
                si += 1;
                tab[i][ai] = 1.0;
                basis[i] = ai;
                artificial_cols.push(ai);
                ai += 1;
            }
            Sense::Eq => {
                tab[i][ai] = 1.0;
                basis[i] = ai;
                artificial_cols.push(ai);
                ai += 1;
            }
        }
    }

    // ---- Phase 1: minimize sum of artificials --------------------------
    if n_art > 0 {
        let mut obj = vec![0.0; total + 1];
        for &a in &artificial_cols {
            obj[a] = 1.0;
        }
        // Make the objective row consistent with the basis (price out).
        for i in 0..m {
            if artificial_cols.contains(&basis[i]) {
                for j in 0..=total {
                    obj[j] -= tab[i][j];
                }
            }
        }
        if !pivot_loop(&mut tab, &mut basis, &mut obj, total) {
            return LpResult::Unbounded; // cannot happen in phase 1
        }
        let phase1 = -obj[total];
        if phase1 > 1e-7 {
            return LpResult::Infeasible;
        }
        // Drive any artificial still in the basis out (degenerate rows).
        for i in 0..m {
            if artificial_cols.contains(&basis[i]) {
                // Find a non-artificial column with nonzero coefficient.
                let mut found = None;
                for j in 0..(n_vars + n_slack) {
                    if tab[i][j].abs() > EPS {
                        found = Some(j);
                        break;
                    }
                }
                if let Some(j) = found {
                    pivot(&mut tab, &mut basis, i, j, total);
                }
                // Otherwise the row is all-zero: redundant, leave it.
            }
        }
    }

    // ---- Phase 2: original objective ------------------------------------
    let mut obj = vec![0.0; total + 1];
    obj[..n_vars].copy_from_slice(c);
    // Forbid artificial columns from re-entering.
    for &a in &artificial_cols {
        for row in tab.iter_mut() {
            row[a] = 0.0;
        }
        obj[a] = 0.0;
    }
    // Price out basic variables.
    for i in 0..m {
        let b = basis[i];
        if obj[b].abs() > EPS {
            let f = obj[b];
            for j in 0..=total {
                obj[j] -= f * tab[i][j];
            }
        }
    }
    if !pivot_loop(&mut tab, &mut basis, &mut obj, total) {
        return LpResult::Unbounded;
    }

    let mut x = vec![0.0; n_vars];
    for i in 0..m {
        if basis[i] < n_vars {
            x[basis[i]] = tab[i][total];
        }
    }
    let objective = c.iter().zip(&x).map(|(ci, xi)| ci * xi).sum();
    LpResult::Optimal { x, objective }
}

/// Bland's-rule pivoting until optimal. Returns false on unboundedness.
fn pivot_loop(
    tab: &mut [Vec<f64>],
    basis: &mut [usize],
    obj: &mut [f64],
    total: usize,
) -> bool {
    let m = tab.len();
    loop {
        // Entering: smallest index with negative reduced cost (Bland).
        let mut enter = None;
        for j in 0..total {
            if obj[j] < -EPS {
                enter = Some(j);
                break;
            }
        }
        let Some(e) = enter else { return true };
        // Leaving: min ratio, ties by smallest basis index (Bland).
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for i in 0..m {
            if tab[i][e] > EPS {
                let ratio = tab[i][total] / tab[i][e];
                if ratio < best - EPS
                    || (ratio < best + EPS
                        && leave.map(|l| basis[i] < basis[l]).unwrap_or(false))
                {
                    best = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else { return false };
        pivot_with_obj(tab, basis, obj, l, e, total);
    }
}

fn pivot(tab: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let m = tab.len();
    let p = tab[row][col];
    for j in 0..=total {
        tab[row][j] /= p;
    }
    for i in 0..m {
        if i != row && tab[i][col].abs() > EPS {
            let f = tab[i][col];
            for j in 0..=total {
                tab[i][j] -= f * tab[row][j];
            }
        }
    }
    basis[row] = col;
}

fn pivot_with_obj(
    tab: &mut [Vec<f64>],
    basis: &mut [usize],
    obj: &mut [f64],
    row: usize,
    col: usize,
    total: usize,
) {
    pivot(tab, basis, row, col, total);
    if obj[col].abs() > EPS {
        let f = obj[col];
        for j in 0..=total {
            obj[j] -= f * tab[row][j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn con(coeffs: &[(usize, f64)], sense: Sense, rhs: f64) -> Constraint {
        Constraint {
            coeffs: coeffs.to_vec(),
            sense,
            rhs,
        }
    }

    #[test]
    fn textbook_maximization() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18  => min -3x -5y
        // optimum (2, 6), objective -36.
        let r = solve_lp(
            2,
            &[-3.0, -5.0],
            &[
                con(&[(0, 1.0)], Sense::Le, 4.0),
                con(&[(1, 2.0)], Sense::Le, 12.0),
                con(&[(0, 3.0), (1, 2.0)], Sense::Le, 18.0),
            ],
        );
        match r {
            LpResult::Optimal { x, objective } => {
                assert!((x[0] - 2.0).abs() < 1e-6, "{x:?}");
                assert!((x[1] - 6.0).abs() < 1e-6);
                assert!((objective + 36.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn equality_and_ge() {
        // min x + y s.t. x + y = 10, x >= 3  => (3?,7?) any on segment;
        // objective must be 10.
        let r = solve_lp(
            2,
            &[1.0, 1.0],
            &[
                con(&[(0, 1.0), (1, 1.0)], Sense::Eq, 10.0),
                con(&[(0, 1.0)], Sense::Ge, 3.0),
            ],
        );
        match r {
            LpResult::Optimal { x, objective } => {
                assert!((objective - 10.0).abs() < 1e-6);
                assert!(x[0] >= 3.0 - 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_detected() {
        let r = solve_lp(
            1,
            &[1.0],
            &[
                con(&[(0, 1.0)], Sense::Ge, 5.0),
                con(&[(0, 1.0)], Sense::Le, 2.0),
            ],
        );
        assert_eq!(r, LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x with only x >= 0.
        let r = solve_lp(1, &[-1.0], &[]);
        assert_eq!(r, LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // x - y <= -2  (i.e. y >= x + 2), min y => x=0, y=2.
        let r = solve_lp(
            2,
            &[0.0, 1.0],
            &[con(&[(0, 1.0), (1, -1.0)], Sense::Le, -2.0)],
        );
        match r {
            LpResult::Optimal { x, objective } => {
                assert!((objective - 2.0).abs() < 1e-6, "{x:?}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic degeneracy-prone instance; Bland's rule must terminate.
        let r = solve_lp(
            4,
            &[-0.75, 150.0, -0.02, 6.0],
            &[
                con(&[(0, 0.25), (1, -60.0), (2, -0.04), (3, 9.0)], Sense::Le, 0.0),
                con(&[(0, 0.5), (1, -90.0), (2, -0.02), (3, 3.0)], Sense::Le, 0.0),
                con(&[(2, 1.0)], Sense::Le, 1.0),
            ],
        );
        match r {
            LpResult::Optimal { objective, .. } => {
                assert!((objective + 0.05).abs() < 1e-6, "obj {objective}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn xor_relaxation_solves() {
        // The partitioner's XOR encoding: L2 = L1 XOR R2 with L1 = 0
        // and heavy incentive to set L2 = 1 drives R2 = 1.
        // Vars: L1, L2, R2 in [0,1].
        let bound = |i| con(&[(i, 1.0)], Sense::Le, 1.0);
        let r = solve_lp(
            3,
            &[0.0, -10.0, 1.0], // min -10*L2 + R2
            &[
                bound(0),
                bound(1),
                bound(2),
                con(&[(0, 1.0)], Sense::Eq, 0.0), // L1 = 0 (pinned)
                // L2 >= L1 - R2 ; L2 <= L1 + R2 ; L2 >= R2 - L1 ; L2 <= 2 - R2 - L1
                con(&[(1, 1.0), (0, -1.0), (2, 1.0)], Sense::Ge, 0.0),
                con(&[(1, 1.0), (0, -1.0), (2, -1.0)], Sense::Le, 0.0),
                con(&[(1, 1.0), (2, -1.0), (0, 1.0)], Sense::Ge, 0.0),
                con(&[(1, 1.0), (2, 1.0), (0, 1.0)], Sense::Le, 2.0),
            ],
        );
        match r {
            LpResult::Optimal { x, objective } => {
                assert!((x[1] - 1.0).abs() < 1e-6, "L2=1: {x:?}");
                assert!((x[2] - 1.0).abs() < 1e-6, "R2=1: {x:?}");
                assert!((objective + 9.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }
}
