//! The partition database (paper §3, §4).
//!
//! "The partitioning mechanism can be run multiple times for different
//! execution conditions, resulting in a database that maps partitioning
//! to conditions. At runtime, the distributed execution mechanism
//! implements the choice of partition for the current execution
//! conditions." Keys here are (app, network) pairs; entries name the
//! R(m)=1 methods plus the expected/local costs; JSON on disk.

use std::borrow::Borrow;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::appvm::class::Program;
use crate::error::{CloneCloudError, Result};
use crate::util::json::{self, Json};

use super::solver::Partition;

/// One stored partition.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionEntry {
    pub app: String,
    pub network: String,
    /// Qualified method names ("Class.method") with R(m) = 1.
    pub migrate: Vec<String>,
    pub expected_ms: f64,
    pub local_ms: f64,
    /// Per-invocation profiled local (phone) cost of each `migrate`
    /// span, ms — parallel to `migrate`. The runtime policy engine
    /// prices migrate-vs-local per invocation with these; empty in
    /// databases written before the policy layer existed.
    pub span_local_ms: Vec<f64>,
    /// Per-invocation clone-side cost of each `migrate` span, ms —
    /// parallel to `migrate`.
    pub span_clone_ms: Vec<f64>,
    /// Scatter width of each `migrate` span — parallel to `migrate`.
    /// 0 (or a missing array: pre-scatter databases) = monolithic;
    /// >= 2 = data-parallel under the `work(begin, end, shards)`
    /// convention, offloads may fan across that many clone lanes.
    pub span_shards: Vec<u16>,
}

impl PartitionEntry {
    pub fn from_partition(app: &str, network: &str, program: &Program, p: &Partition) -> Self {
        let refs: Vec<_> = p.migrate.iter().copied().collect();
        PartitionEntry {
            app: app.to_string(),
            network: network.to_string(),
            migrate: refs.iter().map(|&m| program.method_name(m)).collect(),
            expected_ms: p.expected_us / 1e3,
            local_ms: p.local_us / 1e3,
            span_local_ms: refs
                .iter()
                .map(|m| p.span_costs.get(m).map_or(0.0, |c| c.local_us / 1e3))
                .collect(),
            span_clone_ms: refs
                .iter()
                .map(|m| p.span_costs.get(m).map_or(0.0, |c| c.clone_us / 1e3))
                .collect(),
            span_shards: refs
                .iter()
                .map(|m| p.span_shards.get(m).copied().unwrap_or(0))
                .collect(),
        }
    }

    /// Re-resolve into a Partition against a program (locations are
    /// recomputed by the solver when needed; the R set is what the
    /// runtime requires to pick a binary).
    pub fn to_migrate_set(
        &self,
        program: &Program,
    ) -> Result<BTreeSet<crate::appvm::bytecode::MRef>> {
        let mut out = BTreeSet::new();
        for name in &self.migrate {
            let (c, m) = name.split_once('.').ok_or_else(|| {
                CloneCloudError::partitioner(format!("bad method name '{name}'"))
            })?;
            out.insert(program.resolve(c, m)?);
        }
        Ok(out)
    }

    pub fn label(&self) -> &'static str {
        if self.migrate.is_empty() {
            "Local"
        } else {
            "Offload"
        }
    }
}

/// Borrow shim for the `(String, String)`-keyed map: `lookup` queries
/// with `(&str, &str)` through a trait object instead of allocating two
/// owned `String`s per call (the old runtime hot path). The `Ord` here
/// MUST agree with the tuple `Ord` the map's owned keys sort by —
/// lexicographic on (app, network) — or lookups would miss entries.
trait DbKey {
    fn app(&self) -> &str;
    fn network(&self) -> &str;
}

impl DbKey for (String, String) {
    fn app(&self) -> &str {
        &self.0
    }
    fn network(&self) -> &str {
        &self.1
    }
}

impl DbKey for (&str, &str) {
    fn app(&self) -> &str {
        self.0
    }
    fn network(&self) -> &str {
        self.1
    }
}

impl<'a> Borrow<dyn DbKey + 'a> for (String, String) {
    fn borrow(&self) -> &(dyn DbKey + 'a) {
        self
    }
}

impl PartialEq for dyn DbKey + '_ {
    fn eq(&self, other: &Self) -> bool {
        self.app() == other.app() && self.network() == other.network()
    }
}

impl Eq for dyn DbKey + '_ {}

impl PartialOrd for dyn DbKey + '_ {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for dyn DbKey + '_ {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.app(), self.network()).cmp(&(other.app(), other.network()))
    }
}

/// The database: (app, network) -> entry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PartitionDb {
    entries: BTreeMap<(String, String), PartitionEntry>,
}

impl PartitionDb {
    pub fn new() -> PartitionDb {
        PartitionDb::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn put(&mut self, e: PartitionEntry) {
        self.entries.insert((e.app.clone(), e.network.clone()), e);
    }

    /// Runtime lookup for the current execution conditions. Allocation
    /// free: the borrowed pair is compared through the [`DbKey`] shim.
    pub fn lookup(&self, app: &str, network: &str) -> Option<&PartitionEntry> {
        let key: &dyn DbKey = &(app, network);
        self.entries.get(key)
    }

    pub fn entries(&self) -> impl Iterator<Item = &PartitionEntry> {
        self.entries.values()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .values()
                .map(|e| {
                    Json::obj(vec![
                        ("app", e.app.as_str().into()),
                        ("network", e.network.as_str().into()),
                        (
                            "migrate",
                            Json::Arr(
                                e.migrate.iter().map(|m| m.as_str().into()).collect(),
                            ),
                        ),
                        ("expected_ms", e.expected_ms.into()),
                        ("local_ms", e.local_ms.into()),
                        (
                            "span_local_ms",
                            Json::Arr(e.span_local_ms.iter().map(|&x| x.into()).collect()),
                        ),
                        (
                            "span_clone_ms",
                            Json::Arr(e.span_clone_ms.iter().map(|&x| x.into()).collect()),
                        ),
                        (
                            "span_shards",
                            Json::Arr(
                                e.span_shards.iter().map(|&x| f64::from(x).into()).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(v: &Json) -> Result<PartitionDb> {
        let arr = v
            .as_arr()
            .ok_or_else(|| CloneCloudError::partitioner("db must be an array"))?;
        let mut db = PartitionDb::new();
        for e in arr {
            let get = |k: &str| -> Result<String> {
                e.get(k)
                    .as_str()
                    .map(String::from)
                    .ok_or_else(|| CloneCloudError::partitioner(format!("db entry missing {k}")))
            };
            let migrate = e
                .get("migrate")
                .as_arr()
                .ok_or_else(|| CloneCloudError::partitioner("db entry missing migrate"))?
                .iter()
                .map(|m| {
                    m.as_str()
                        .map(String::from)
                        .ok_or_else(|| CloneCloudError::partitioner("bad migrate item"))
                })
                .collect::<Result<Vec<_>>>()?;
            // Span-cost arrays are absent in pre-policy databases:
            // missing means unpriced (empty), anything else must be a
            // numeric array.
            let get_span = |k: &str| -> Result<Vec<f64>> {
                match e.get(k) {
                    Json::Null => Ok(Vec::new()),
                    v => v
                        .as_arr()
                        .ok_or_else(|| {
                            CloneCloudError::partitioner(format!("{k} must be an array"))
                        })?
                        .iter()
                        .map(|x| {
                            x.as_f64().ok_or_else(|| {
                                CloneCloudError::partitioner(format!("bad {k} item"))
                            })
                        })
                        .collect(),
                }
            };
            db.put(PartitionEntry {
                app: get("app")?,
                network: get("network")?,
                migrate,
                expected_ms: e.get("expected_ms").as_f64().unwrap_or(0.0),
                local_ms: e.get("local_ms").as_f64().unwrap_or(0.0),
                span_local_ms: get_span("span_local_ms")?,
                span_clone_ms: get_span("span_clone_ms")?,
                span_shards: get_span("span_shards")?
                    .into_iter()
                    .map(|x| {
                        if x.fract() == 0.0 && (0.0..=f64::from(u16::MAX)).contains(&x) {
                            Ok(x as u16)
                        } else {
                            Err(CloneCloudError::partitioner("bad span_shards item"))
                        }
                    })
                    .collect::<Result<Vec<_>>>()?,
            });
        }
        Ok(db)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, json::emit(&self.to_json()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PartitionDb> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, ensure_eq, forall, PropConfig};
    use crate::util::rng::Rng;

    fn entry(app: &str, net: &str, migrate: &[&str]) -> PartitionEntry {
        PartitionEntry {
            app: app.into(),
            network: net.into(),
            migrate: migrate.iter().map(|s| s.to_string()).collect(),
            expected_ms: 123.0,
            local_ms: 456.0,
            span_local_ms: vec![10.5; migrate.len()],
            span_clone_ms: vec![0.5; migrate.len()],
            span_shards: vec![0; migrate.len()],
        }
    }

    fn random_entry(rng: &mut Rng) -> PartitionEntry {
        // Small alphabets so duplicate (app, network) keys actually
        // occur across a generated set.
        let apps = ["virus", "image", "behavior", "app-ü"];
        let nets = ["wifi", "3g", "edge"];
        let n_migrate = rng.index(4);
        let migrate: Vec<String> = (0..n_migrate)
            .map(|_| format!("C{}.m{}", rng.index(3), rng.index(5)))
            .collect();
        // Span vectors exercise both priced and legacy (empty) shapes.
        let priced = rng.chance(0.7);
        let spans = |rng: &mut Rng| -> Vec<f64> {
            if priced {
                (0..n_migrate)
                    .map(|_| rng.range_i64(0, 1_000_000) as f64 / 128.0)
                    .collect()
            } else {
                Vec::new()
            }
        };
        PartitionEntry {
            app: apps[rng.index(apps.len())].to_string(),
            network: nets[rng.index(nets.len())].to_string(),
            migrate,
            expected_ms: rng.range_i64(0, 1 << 40) as f64 / 64.0,
            local_ms: rng.range_i64(0, 1 << 40) as f64 / 64.0,
            span_local_ms: spans(rng),
            span_clone_ms: spans(rng),
            span_shards: (0..rng.index(4)).map(|_| rng.index(8) as u16).collect(),
        }
    }

    #[test]
    fn put_lookup_label() {
        let mut db = PartitionDb::new();
        db.put(entry("virus", "wifi", &["V.scan"]));
        db.put(entry("virus", "3g", &[]));
        assert_eq!(db.lookup("virus", "wifi").unwrap().label(), "Offload");
        assert_eq!(db.lookup("virus", "3g").unwrap().label(), "Local");
        assert!(db.lookup("virus", "bluetooth").is_none());
        assert_eq!(db.len(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let mut db = PartitionDb::new();
        db.put(entry("image", "wifi", &["I.search", "I.index"]));
        db.put(entry("image", "3g", &[]));
        let text = json::emit(&db.to_json());
        let back = PartitionDb::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(db, back);
    }

    #[test]
    fn file_roundtrip() {
        let mut db = PartitionDb::new();
        db.put(entry("b", "wifi", &["B.profile"]));
        let dir = std::env::temp_dir().join(format!("ccdb_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("partitions.json");
        db.save(&path).unwrap();
        assert_eq!(PartitionDb::load(&path).unwrap(), db);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Property: any generated database survives emit → parse exactly
    /// (floats use shortest-roundtrip formatting), matching the wire
    /// codec's roundtrip style.
    #[test]
    fn prop_json_roundtrip_random_dbs() {
        forall(
            PropConfig { seed: 0xDB01, cases: 60 },
            |rng: &mut Rng| {
                let n = rng.index(8);
                (0..n).map(|_| random_entry(rng)).collect::<Vec<_>>()
            },
            |entries| {
                let mut db = PartitionDb::new();
                for e in entries {
                    db.put(e.clone());
                }
                let text = json::emit(&db.to_json());
                let back = PartitionDb::from_json(
                    &json::parse(&text).map_err(|e| format!("parse: {e}"))?,
                )
                .map_err(|e| format!("from_json: {e}"))?;
                ensure_eq(db.len(), back.len(), "entry count")?;
                ensure(db == back, format!("roundtrip mismatch for {text}"))
            },
        );
    }

    /// Property: duplicate (app, network) keys resolve last-wins, both
    /// through `put` and through `from_json` array order.
    #[test]
    fn prop_duplicate_keys_last_wins() {
        forall(
            PropConfig { seed: 0xDB02, cases: 60 },
            |rng: &mut Rng| {
                let n = 2 + rng.index(10);
                (0..n).map(|_| random_entry(rng)).collect::<Vec<_>>()
            },
            |entries| {
                let mut arr = Vec::new();
                let mut db_put = PartitionDb::new();
                for e in entries {
                    db_put.put(e.clone());
                    let mut single = PartitionDb::new();
                    single.put(e.clone());
                    // Reuse the canonical encoder for one entry's JSON.
                    if let Json::Arr(v) = single.to_json() {
                        arr.extend(v);
                    }
                }
                let db_json = PartitionDb::from_json(&Json::Arr(arr))
                    .map_err(|e| format!("from_json: {e}"))?;
                ensure_eq(db_put.len(), db_json.len(), "dedup count")?;
                for e in entries {
                    let last = entries
                        .iter()
                        .rev()
                        .find(|x| x.app == e.app && x.network == e.network)
                        .unwrap();
                    let got = db_json
                        .lookup(&e.app, &e.network)
                        .ok_or_else(|| format!("missing ({}, {})", e.app, e.network))?;
                    ensure(got == last, "last occurrence wins")?;
                }
                Ok(())
            },
        );
    }

    /// Property: dropping any required field from a valid entry is a
    /// typed parse error, never a panic or a silent default.
    #[test]
    fn prop_missing_required_field_rejected() {
        let required = ["app", "network", "migrate"];
        forall(
            PropConfig { seed: 0xDB03, cases: 30 },
            |rng: &mut Rng| (random_entry(rng), rng.index(required.len())),
            |(e, drop_idx)| {
                let mut db = PartitionDb::new();
                db.put(e.clone());
                let Json::Arr(arr) = db.to_json() else {
                    return Err("db json is not an array".into());
                };
                let Json::Obj(mut obj) = arr[0].clone() else {
                    return Err("entry json is not an object".into());
                };
                obj.remove(required[*drop_idx]);
                let res = PartitionDb::from_json(&Json::Arr(vec![Json::Obj(obj)]));
                ensure(
                    res.is_err(),
                    format!("missing '{}' must be rejected", required[*drop_idx]),
                )
            },
        );
    }

    /// Property: garbage input never panics — random byte soup either
    /// fails to parse as JSON or is rejected by `from_json`; structured
    /// non-array JSON is always rejected.
    #[test]
    fn prop_garbage_never_panics() {
        forall(
            PropConfig { seed: 0xDB04, cases: 80 },
            |rng: &mut Rng| {
                let len = rng.index(64);
                let mut bytes = vec![0u8; len];
                rng.fill_bytes(&mut bytes);
                String::from_utf8_lossy(&bytes).into_owned()
            },
            |soup| {
                if let Ok(v) = json::parse(soup) {
                    // Whatever parsed must be handled gracefully.
                    let _ = PartitionDb::from_json(&v);
                }
                // Structured-but-wrong shapes are typed errors.
                ensure(
                    PartitionDb::from_json(&Json::Num(1.0)).is_err()
                        && PartitionDb::from_json(&Json::Arr(vec![Json::Num(1.0)])).is_err(),
                    "non-db JSON rejected",
                )
            },
        );
    }

    /// Pre-policy databases (no span-cost arrays) still load; the spans
    /// come back unpriced, and malformed span arrays are rejected.
    #[test]
    fn legacy_db_without_span_costs_loads() {
        let text = r#"[{"app":"virus","network":"wifi","migrate":["V.scan"],
                       "expected_ms":1.5,"local_ms":9.5}]"#;
        let db = PartitionDb::from_json(&json::parse(text).unwrap()).unwrap();
        let e = db.lookup("virus", "wifi").unwrap();
        assert!(e.span_local_ms.is_empty() && e.span_clone_ms.is_empty());
        assert!(e.span_shards.is_empty(), "pre-scatter db loads unannotated");

        let bad = r#"[{"app":"v","network":"w","migrate":[],"span_local_ms":"fast"}]"#;
        assert!(PartitionDb::from_json(&json::parse(bad).unwrap()).is_err());
    }

    /// The borrow-keyed lookup returns exactly what owned-key access
    /// would, including for unicode and empty-string keys.
    #[test]
    fn borrowed_lookup_matches_owned_semantics() {
        let mut db = PartitionDb::new();
        db.put(entry("app-ü", "wifi", &["A.m"]));
        db.put(entry("", "", &[]));
        db.put(entry("virus", "3g", &[]));
        assert_eq!(db.lookup("app-ü", "wifi").unwrap().migrate, vec!["A.m"]);
        assert!(db.lookup("", "").is_some(), "empty keys are valid keys");
        assert!(db.lookup("app-ü", "3g").is_none(), "no cross-pairing");
        assert!(db.lookup("virus", "wif").is_none(), "no prefix matches");
    }

    #[test]
    fn resolves_against_program() {
        let p = crate::appvm::assembler::assemble(
            "class V app\n  method main nargs=0 regs=1\n    retv\n  end\n  method scan nargs=0 regs=1\n    retv\n  end\nend\n",
        )
        .unwrap();
        let e = entry("virus", "wifi", &["V.scan"]);
        let set = e.to_migrate_set(&p).unwrap();
        assert_eq!(set.len(), 1);
        let bad = entry("virus", "wifi", &["V.nope"]);
        assert!(bad.to_migrate_set(&p).is_err());
    }
}
