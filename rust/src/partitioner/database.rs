//! The partition database (paper §3, §4).
//!
//! "The partitioning mechanism can be run multiple times for different
//! execution conditions, resulting in a database that maps partitioning
//! to conditions. At runtime, the distributed execution mechanism
//! implements the choice of partition for the current execution
//! conditions." Keys here are (app, network) pairs; entries name the
//! R(m)=1 methods plus the expected/local costs; JSON on disk.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::appvm::class::Program;
use crate::error::{CloneCloudError, Result};
use crate::util::json::{self, Json};

use super::solver::Partition;

/// One stored partition.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionEntry {
    pub app: String,
    pub network: String,
    /// Qualified method names ("Class.method") with R(m) = 1.
    pub migrate: Vec<String>,
    pub expected_ms: f64,
    pub local_ms: f64,
}

impl PartitionEntry {
    pub fn from_partition(app: &str, network: &str, program: &Program, p: &Partition) -> Self {
        PartitionEntry {
            app: app.to_string(),
            network: network.to_string(),
            migrate: p
                .migrate
                .iter()
                .map(|&m| program.method_name(m))
                .collect(),
            expected_ms: p.expected_us / 1e3,
            local_ms: p.local_us / 1e3,
        }
    }

    /// Re-resolve into a Partition against a program (locations are
    /// recomputed by the solver when needed; the R set is what the
    /// runtime requires to pick a binary).
    pub fn to_migrate_set(
        &self,
        program: &Program,
    ) -> Result<BTreeSet<crate::appvm::bytecode::MRef>> {
        let mut out = BTreeSet::new();
        for name in &self.migrate {
            let (c, m) = name.split_once('.').ok_or_else(|| {
                CloneCloudError::partitioner(format!("bad method name '{name}'"))
            })?;
            out.insert(program.resolve(c, m)?);
        }
        Ok(out)
    }

    pub fn label(&self) -> &'static str {
        if self.migrate.is_empty() {
            "Local"
        } else {
            "Offload"
        }
    }
}

/// The database: (app, network) -> entry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PartitionDb {
    entries: BTreeMap<(String, String), PartitionEntry>,
}

impl PartitionDb {
    pub fn new() -> PartitionDb {
        PartitionDb::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn put(&mut self, e: PartitionEntry) {
        self.entries.insert((e.app.clone(), e.network.clone()), e);
    }

    /// Runtime lookup for the current execution conditions.
    pub fn lookup(&self, app: &str, network: &str) -> Option<&PartitionEntry> {
        self.entries.get(&(app.to_string(), network.to_string()))
    }

    pub fn entries(&self) -> impl Iterator<Item = &PartitionEntry> {
        self.entries.values()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .values()
                .map(|e| {
                    Json::obj(vec![
                        ("app", e.app.as_str().into()),
                        ("network", e.network.as_str().into()),
                        (
                            "migrate",
                            Json::Arr(
                                e.migrate.iter().map(|m| m.as_str().into()).collect(),
                            ),
                        ),
                        ("expected_ms", e.expected_ms.into()),
                        ("local_ms", e.local_ms.into()),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(v: &Json) -> Result<PartitionDb> {
        let arr = v
            .as_arr()
            .ok_or_else(|| CloneCloudError::partitioner("db must be an array"))?;
        let mut db = PartitionDb::new();
        for e in arr {
            let get = |k: &str| -> Result<String> {
                e.get(k)
                    .as_str()
                    .map(String::from)
                    .ok_or_else(|| CloneCloudError::partitioner(format!("db entry missing {k}")))
            };
            let migrate = e
                .get("migrate")
                .as_arr()
                .ok_or_else(|| CloneCloudError::partitioner("db entry missing migrate"))?
                .iter()
                .map(|m| {
                    m.as_str()
                        .map(String::from)
                        .ok_or_else(|| CloneCloudError::partitioner("bad migrate item"))
                })
                .collect::<Result<Vec<_>>>()?;
            db.put(PartitionEntry {
                app: get("app")?,
                network: get("network")?,
                migrate,
                expected_ms: e.get("expected_ms").as_f64().unwrap_or(0.0),
                local_ms: e.get("local_ms").as_f64().unwrap_or(0.0),
            });
        }
        Ok(db)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, json::emit(&self.to_json()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PartitionDb> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(app: &str, net: &str, migrate: &[&str]) -> PartitionEntry {
        PartitionEntry {
            app: app.into(),
            network: net.into(),
            migrate: migrate.iter().map(|s| s.to_string()).collect(),
            expected_ms: 123.0,
            local_ms: 456.0,
        }
    }

    #[test]
    fn put_lookup_label() {
        let mut db = PartitionDb::new();
        db.put(entry("virus", "wifi", &["V.scan"]));
        db.put(entry("virus", "3g", &[]));
        assert_eq!(db.lookup("virus", "wifi").unwrap().label(), "Offload");
        assert_eq!(db.lookup("virus", "3g").unwrap().label(), "Local");
        assert!(db.lookup("virus", "bluetooth").is_none());
        assert_eq!(db.len(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let mut db = PartitionDb::new();
        db.put(entry("image", "wifi", &["I.search", "I.index"]));
        db.put(entry("image", "3g", &[]));
        let text = json::emit(&db.to_json());
        let back = PartitionDb::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(db, back);
    }

    #[test]
    fn file_roundtrip() {
        let mut db = PartitionDb::new();
        db.put(entry("b", "wifi", &["B.profile"]));
        let dir = std::env::temp_dir().join(format!("ccdb_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("partitions.json");
        db.save(&path).unwrap();
        assert_eq!(PartitionDb::load(&path).unwrap(), db);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resolves_against_program() {
        let p = crate::appvm::assembler::assemble(
            "class V app\n  method main nargs=0 regs=1\n    retv\n  end\n  method scan nargs=0 regs=1\n    retv\n  end\nend\n",
        )
        .unwrap();
        let e = entry("virus", "wifi", &["V.scan"]);
        let set = e.to_migrate_set(&p).unwrap();
        assert_eq!(set.len(), 1);
        let bad = entry("virus", "wifi", &["V.nope"]);
        assert!(bad.to_migrate_set(&p).is_err());
    }
}
