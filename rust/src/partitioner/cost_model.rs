//! Cost model assembly (paper §3.2-3.3).
//!
//! Collapses the profile-tree pair (T on the mobile device, T' on the
//! clone) of each profiling execution into per-method aggregates:
//!
//! * `mobile_us[m]` = Σ_i C_c(i, 0)   (residuals from T)
//! * `clone_us[m]`  = Σ_i C_c(i, 1)   (residuals from T')
//! * `migr_us[m]`   = Σ_i C_s(i)      (suspend/resume + per-byte transfer
//!   over the edge state sizes measured on T)
//!
//! All executions in the set S are treated as equiprobable (summed),
//! exactly as the paper does.

use std::collections::HashMap;

use crate::appvm::bytecode::MRef;
use crate::config::{CostParams, NetworkProfile};

use super::profile_tree::ProfileTree;

/// Per-method cost aggregates across the profiling execution set.
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    pub mobile_us: HashMap<MRef, f64>,
    pub clone_us: HashMap<MRef, f64>,
    pub migr_us: HashMap<MRef, f64>,
    pub invocations: HashMap<MRef, usize>,
}

impl CostModel {
    /// Build from (mobile tree, clone tree) pairs, one per execution.
    /// `net` prices the transfer cost; `costs` prices the full
    /// suspend/capture/serialize/transmit/deserialize/reinstantiate path
    /// of the paper's C_s — including the phone-side merge, which
    /// dominates WiFi migrations (§6). `phone_factor`/`clone_factor`
    /// scale the CPU-bound phases to each device.
    pub fn build_scaled(
        pairs: &[(&ProfileTree, &ProfileTree)],
        costs: &CostParams,
        net: &NetworkProfile,
        phone_factor: f64,
        clone_factor: f64,
    ) -> CostModel {
        let mut cm = CostModel::default();
        for (t_mobile, t_clone) in pairs {
            // Native call counts (inline code; used by the class-level
            // baseline's RPC pricing).
            for (&callee, &n) in &t_mobile.native_calls {
                *cm.invocations.entry(callee).or_insert(0) += n;
            }
            let mut methods: Vec<MRef> = t_mobile.nodes.iter().map(|n| n.method).collect();
            methods.extend(t_clone.nodes.iter().map(|n| n.method));
            methods.sort_unstable();
            methods.dedup();
            for m in methods {
                *cm.mobile_us.entry(m).or_insert(0.0) += t_mobile.method_residual_us(m);
                *cm.clone_us.entry(m).or_insert(0.0) += t_clone.method_residual_us(m);
                *cm.invocations.entry(m).or_insert(0) += t_mobile.invocation_count(m);
                // C_s(i): suspend/resume + the volume-dependent cost of
                // capturing, serializing, transmitting, deserializing,
                // and reinstantiating state. Edge annotation already
                // sums capture-at-entry + capture-at-return; half rides
                // the slow uplink, half comes back down.
                let n_inv = t_mobile.invocation_count(m) as f64;
                let bytes = t_mobile.method_state_bytes(m) as f64;
                let transfer_ms = net.transfer_ms((bytes / 2.0) as u64, true)
                    + net.transfer_ms((bytes / 2.0) as u64, false);
                // Phone side: capture/serialize out + merge back in.
                let phone_us =
                    (costs.per_byte_us + costs.merge_per_byte_us) * bytes * phone_factor;
                // Clone side: reinstantiate the forward half.
                let clone_us = costs.merge_per_byte_us * (bytes / 2.0) * clone_factor;
                *cm.migr_us.entry(m).or_insert(0.0) += n_inv
                    * costs.suspend_resume_us
                    * phone_factor
                    + transfer_ms * 1e3
                    + phone_us
                    + clone_us;
            }
        }
        cm
    }

    /// [`CostModel::build_scaled`] with the paper's G1/desktop factors.
    pub fn build(
        pairs: &[(&ProfileTree, &ProfileTree)],
        costs: &CostParams,
        net: &NetworkProfile,
    ) -> CostModel {
        let phone = crate::device::DeviceSpec::phone_g1().cpu_factor;
        Self::build_scaled(pairs, costs, net, phone, 1.0)
    }

    pub fn mobile(&self, m: MRef) -> f64 {
        self.mobile_us.get(&m).copied().unwrap_or(0.0)
    }
    pub fn clone_side(&self, m: MRef) -> f64 {
        self.clone_us.get(&m).copied().unwrap_or(0.0)
    }
    pub fn migration(&self, m: MRef) -> f64 {
        self.migr_us.get(&m).copied().unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::bytecode::{ClassId, MethodId};

    fn m(i: u16) -> MRef {
        MRef {
            class: ClassId(0),
            method: MethodId(i),
        }
    }

    fn tree(costs: &[(u16, f64, u64)]) -> ProfileTree {
        // Flat tree: root = method 0, children in order.
        let mut t = ProfileTree::default();
        let root = t.push(m(0), None);
        let mut total = 0.0;
        for &(mi, c, b) in costs {
            let n = t.push(m(mi), Some(root));
            t.nodes[n].cost_us = c;
            t.nodes[n].edge_state_bytes = b;
            total += c;
        }
        t.nodes[root].cost_us = total + 10.0; // root residual 10
        t
    }

    #[test]
    fn aggregates_and_prices() {
        let tm = tree(&[(1, 100.0, 1000), (1, 50.0, 500), (2, 40.0, 2000)]);
        let tc = tree(&[(1, 5.0, 0), (1, 2.5, 0), (2, 2.0, 0)]);
        let costs = CostParams::default();
        let net = NetworkProfile::wifi();
        let cm = CostModel::build(&[(&tm, &tc)], &costs, &net);
        assert!((cm.mobile(m(1)) - 150.0).abs() < 1e-9);
        assert!((cm.clone_side(m(1)) - 7.5).abs() < 1e-9);
        assert!((cm.mobile(m(0)) - 10.0).abs() < 1e-9, "root residual");
        assert_eq!(cm.invocations[&m(1)], 2);
        // Migration cost grows with state size: m(2) single call moves
        // 2000 bytes, m(1) two calls move 1500 total but pay 2x
        // suspend/resume.
        assert!(cm.migration(m(2)) > 0.0);
        let two_latencies_us = 2.0 * net.latency_ms * 1e3;
        assert!(
            cm.migration(m(1)) > 2.0 * costs.suspend_resume_us + two_latencies_us,
            "two invocations pay suspend twice and latency per direction"
        );
    }

    #[test]
    fn threeg_migration_pricier_than_wifi() {
        let tm = tree(&[(1, 100.0, 500_000)]);
        let tc = tree(&[(1, 5.0, 0)]);
        let costs = CostParams::default();
        let cm_w = CostModel::build(&[(&tm, &tc)], &costs, &NetworkProfile::wifi());
        let cm_g = CostModel::build(&[(&tm, &tc)], &costs, &NetworkProfile::threeg());
        // The network-unspecific merge cost is shared; the 3G transfer
        // component makes the total at least ~2x (paper §6: 10-15 s WiFi
        // vs ~60 s 3G).
        assert!(cm_g.migration(m(1)) > 2.0 * cm_w.migration(m(1)));
    }

    #[test]
    fn multiple_executions_sum() {
        let tm = tree(&[(1, 100.0, 0)]);
        let tc = tree(&[(1, 5.0, 0)]);
        let costs = CostParams::default();
        let net = NetworkProfile::wifi();
        let cm1 = CostModel::build(&[(&tm, &tc)], &costs, &net);
        let cm2 = CostModel::build(&[(&tm, &tc), (&tm, &tc)], &costs, &net);
        assert!((cm2.mobile(m(1)) - 2.0 * cm1.mobile(m(1))).abs() < 1e-9);
    }
}
