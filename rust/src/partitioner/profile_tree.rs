//! Profile trees (paper §3.2, Figure 6).
//!
//! One node per method *invocation*, rooted at the application entry;
//! each node annotated with its invocation cost; each edge annotated with
//! the thread state size at invocation plus at return (what a migration
//! at that edge would transfer). Every non-leaf node conceptually has a
//! *residual* child holding the cost of the method body excluding its
//! callees — exposed here as [`ProfileTree::residual_us`].

use crate::appvm::bytecode::MRef;

/// One invocation.
#[derive(Debug, Clone)]
pub struct ProfileNode {
    pub method: MRef,
    /// Total cost of this invocation (µs, virtual).
    pub cost_us: f64,
    /// Thread state size (bytes) at invocation + at return — the data a
    /// migration at this edge would move in both directions.
    pub edge_state_bytes: u64,
    /// Child invocations, in call order.
    pub children: Vec<usize>,
    pub parent: Option<usize>,
}

/// A profile tree from one execution on one platform.
#[derive(Debug, Clone, Default)]
pub struct ProfileTree {
    pub nodes: Vec<ProfileNode>,
    pub roots: Vec<usize>,
    /// Native calls observed during the run (callee -> count). Natives
    /// are inline code (§3.2) with no tree nodes, but their call-site
    /// traffic prices the class-granularity baseline's RPC boundary.
    pub native_calls: std::collections::HashMap<MRef, usize>,
}

impl ProfileTree {
    pub fn len(&self) -> usize {
        self.nodes.len()
    }
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// The residual node value for invocation `i`: its cost minus its
    /// children's costs (Figure 6: main' = (t4-t1) - ((t4-t3)+(t2-t1))).
    pub fn residual_us(&self, i: usize) -> f64 {
        let n = &self.nodes[i];
        let kids: f64 = n.children.iter().map(|&c| self.nodes[c].cost_us).sum();
        (n.cost_us - kids).max(0.0)
    }

    /// All invocations of a given method.
    pub fn invocations_of(&self, m: MRef) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&i| self.nodes[i].method == m)
            .collect()
    }

    /// Total residual cost attributed to a method across the execution —
    /// Σ_i C_c(i, l) for I(i, m).
    pub fn method_residual_us(&self, m: MRef) -> f64 {
        self.invocations_of(m)
            .into_iter()
            .map(|i| self.residual_us(i))
            .sum()
    }

    /// Total *inclusive* cost of a method across its invocations (body
    /// plus callees) — the span the runtime policy engine prices: when
    /// R(m)=1, the whole subtree under each invocation of `m` runs on
    /// the other side. Not meaningful for recursive methods (nested
    /// invocations double-count), which are never partition candidates.
    pub fn method_inclusive_us(&self, m: MRef) -> f64 {
        self.invocations_of(m)
            .into_iter()
            .map(|i| self.nodes[i].cost_us)
            .sum()
    }

    /// Total edge state bytes across invocations of a method.
    pub fn method_state_bytes(&self, m: MRef) -> u64 {
        self.invocations_of(m)
            .into_iter()
            .map(|i| self.nodes[i].edge_state_bytes)
            .sum()
    }

    /// Number of invocations of a method (the I(i, m) relation's size).
    pub fn invocation_count(&self, m: MRef) -> usize {
        self.invocations_of(m).len()
    }

    /// Total execution cost (sum of root costs).
    pub fn total_us(&self) -> f64 {
        self.roots.iter().map(|&r| self.nodes[r].cost_us).sum()
    }

    /// Internal: add a node.
    pub fn push(&mut self, method: MRef, parent: Option<usize>) -> usize {
        let id = self.nodes.len();
        self.nodes.push(ProfileNode {
            method,
            cost_us: 0.0,
            edge_state_bytes: 0,
            children: Vec::new(),
            parent,
        });
        match parent {
            Some(p) => self.nodes[p].children.push(id),
            None => self.roots.push(id),
        }
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::bytecode::{ClassId, MethodId};

    fn m(i: u16) -> MRef {
        MRef {
            class: ClassId(0),
            method: MethodId(i),
        }
    }

    /// Reconstruct Figure 6: main calls a (which calls b, c) then a again.
    #[test]
    fn figure6_residuals() {
        let mut t = ProfileTree::default();
        let main = t.push(m(0), None); // main
        let a1 = t.push(m(1), Some(main)); // a (first call)
        let b = t.push(m(2), Some(a1));
        let c = t.push(m(3), Some(a1));
        let a2 = t.push(m(1), Some(main)); // a (second call)
        t.nodes[main].cost_us = 100.0; // t4 - t1
        t.nodes[a1].cost_us = 40.0;
        t.nodes[b].cost_us = 10.0;
        t.nodes[c].cost_us = 25.0;
        t.nodes[a2].cost_us = 30.0;

        // main' = 100 - (40 + 30) = 30
        assert!((t.residual_us(main) - 30.0).abs() < 1e-9);
        // a' (first) = 40 - (10 + 25) = 5
        assert!((t.residual_us(a1) - 5.0).abs() < 1e-9);
        // leaves: residual == own cost
        assert_eq!(t.residual_us(b), 10.0);
        // two invocations of a, summed residual = 5 + 30
        assert_eq!(t.invocation_count(m(1)), 2);
        assert!((t.method_residual_us(m(1)) - 35.0).abs() < 1e-9);
        // inclusive spans: a = 40 + 30, b = 10 (leaf: inclusive ==
        // residual)
        assert!((t.method_inclusive_us(m(1)) - 70.0).abs() < 1e-9);
        assert!((t.method_inclusive_us(m(2)) - 10.0).abs() < 1e-9);
        assert_eq!(t.total_us(), 100.0);
    }

    #[test]
    fn residual_clamped_nonnegative() {
        let mut t = ProfileTree::default();
        let r = t.push(m(0), None);
        let k = t.push(m(1), Some(r));
        t.nodes[r].cost_us = 5.0;
        t.nodes[k].cost_us = 9.0; // timer skew
        assert_eq!(t.residual_us(r), 0.0);
    }

    #[test]
    fn state_bytes_aggregate() {
        let mut t = ProfileTree::default();
        let r = t.push(m(0), None);
        let k1 = t.push(m(1), Some(r));
        let k2 = t.push(m(1), Some(r));
        t.nodes[k1].edge_state_bytes = 100;
        t.nodes[k2].edge_state_bytes = 250;
        assert_eq!(t.method_state_bytes(m(1)), 350);
    }
}
