//! Binary rewriter (paper §3, §5 — the Javassist role).
//!
//! Takes the original executable and a set of migratory methods, and
//! produces the modified executable: each gets a `CcStart(pid)` at its
//! entry (the migration point) and a `CcStop(pid)` before every return
//! (the reintegration point). Branch targets are remapped, and the result
//! must re-verify.
//!
//! Two flows share the machinery:
//! * [`rewrite_with_partition`] — the paper's pick-a-binary-offline flow:
//!   only the solver's R(m)=1 methods get points.
//! * [`rewrite_with_candidates`] — the adaptive flow: ONE binary carries
//!   every candidate migration point, and the runtime policy engine
//!   (`exec::policy`) answers migrate/local per invocation. A `CcStart`
//!   the policy declines is a no-op continuation, so the conditional
//!   binary run all-local is semantically the monolithic binary.

use std::collections::{BTreeSet, HashMap};

use crate::appvm::bytecode::{Instr, MRef};
use crate::appvm::class::Program;
use crate::appvm::verifier::verify_program;
use crate::error::{CloneCloudError, Result};

use super::cfg::Cfg;
use super::solver::Partition;

/// Rewrite `program` with the partition's migration points. Point ids are
/// assigned in method order; the returned map gives pid -> method.
pub fn rewrite_with_partition(
    program: &Program,
    partition: &Partition,
) -> Result<(Program, HashMap<u32, MRef>)> {
    for (&m, &shards) in &partition.span_shards {
        if shards >= 2 && !shard_shaped(program, m) {
            return Err(CloneCloudError::partitioner(format!(
                "shard annotation on '{}', which is not shard-shaped: \
                 the scatter convention needs `work(begin, end, shards)` \
                 (nargs >= 3)",
                program.method_name(m)
            )));
        }
    }
    rewrite_with_candidates(program, &partition.migrate)
}

/// Whether a method matches the rewriter-visible scatter convention
/// `work(begin, end, shards)`: at least three arguments, so `regs[0..3]`
/// of a captured top frame are the patchable range. The value-level
/// checks (ints, non-empty range) happen on the capture itself
/// (`migration::shard_capsule`); this is the static half the rewriter
/// and DB loader can enforce.
pub fn shard_shaped(program: &Program, m: MRef) -> bool {
    program.method(m).nargs >= 3
}

/// Every method that can host a conditional migration point: bytecode
/// app methods that are not pinned (V_M), not recursive (Property 3
/// with m1 = m2), and not the entry — the same exclusions the solver
/// applies to its R variables. Nesting among candidates is fine: while
/// a span runs offloaded, inner `CcStart`s at the clone are no-ops, and
/// while it runs locally the driver decides each inner point on its own.
pub fn candidate_points(program: &Program, cfg: &Cfg) -> BTreeSet<MRef> {
    let entry = program.entry().ok();
    program
        .app_methods()
        .into_iter()
        .filter(|&m| {
            let def = program.method(m);
            !(def.pinned || def.is_native() || cfg.recursive(m) || Some(m) == entry)
        })
        .collect()
}

/// Rewrite `program` with a conditional migration point in every method
/// of `candidates`: the one-binary adaptive flow. Point ids are assigned
/// in method order; the returned map gives pid -> method.
pub fn rewrite_with_candidates(
    program: &Program,
    candidates: &BTreeSet<MRef>,
) -> Result<(Program, HashMap<u32, MRef>)> {
    let mut out = program.clone();
    let mut points = HashMap::new();
    let mut next_pid: u32 = 0;
    for &m in candidates {
        let pid = next_pid;
        next_pid += 1;
        points.insert(pid, m);
        let def = out.method_mut(m);
        def.code = insert_cc_points(&def.code, pid);
        def.migration_point = Some(pid);
    }
    verify_program(&out)?;
    Ok((out, points))
}

/// Insert CcStart at entry and CcStop before every Return, remapping
/// branch targets.
fn insert_cc_points(code: &[Instr], pid: u32) -> Vec<Instr> {
    // new_pc[i] = landing position of old instruction i in the new code.
    // CRITICAL: a branch that targets a Return must land on the CcStop
    // inserted in front of it — otherwise the reintegration point is
    // skipped and the migrated thread sails past its method exit.
    let mut new_pc = Vec::with_capacity(code.len());
    let mut pos = 1u32; // CcStart occupies slot 0
    for instr in code {
        new_pc.push(pos); // branches land here (the CcStop for returns)
        pos += if matches!(instr, Instr::Return(_)) { 2 } else { 1 };
    }

    let mut out = Vec::with_capacity(pos as usize);
    out.push(Instr::CcStart(pid));
    for instr in code {
        if matches!(instr, Instr::Return(_)) {
            out.push(Instr::CcStop(pid));
        }
        out.push(remap(instr, &new_pc));
    }
    out
}

fn remap(instr: &Instr, new_pc: &[u32]) -> Instr {
    let mut i = instr.clone();
    match &mut i {
        Instr::IfZ(_, t) | Instr::IfNZ(_, t) | Instr::IfCmp(_, _, _, t) | Instr::Goto(t) => {
            *t = new_pc[*t as usize];
        }
        _ => {}
    }
    i
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeSet;
    use std::sync::Arc;

    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::interp::{run_thread, NoHooks, RunExit};
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::process::Process;
    use crate::device::{DeviceSpec, Location};
    use crate::vfs::SimFs;

    const SRC: &str = r#"
class C app
  static out
  method main nargs=0 regs=4
    const r0 6
    invoke r1 C.work r0
    puts C.out r1
    retv
  end
  method work nargs=1 regs=6
    const r1 0
    const r2 0
  loop:
    ifge r2 r0 @done
    add r1 r1 r2
    const r3 1
    add r2 r2 r3
    goto @loop
  done:
    ifz r1 @zero
    ret r1
  zero:
    const r1 -1
    ret r1
  end
end
"#;

    fn partition_of(program: &Program, names: &[&str]) -> Partition {
        let mut migrate = BTreeSet::new();
        for n in names {
            migrate.insert(program.resolve("C", n).unwrap());
        }
        Partition {
            migrate,
            locations: HashMap::new(),
            expected_us: 0.0,
            local_us: 0.0,
            span_costs: HashMap::new(),
            span_shards: HashMap::new(),
        }
    }

    #[test]
    fn rewritten_binary_verifies_and_has_points() {
        let program = assemble(SRC).unwrap();
        let work = program.resolve("C", "work").unwrap();
        let (out, points) =
            rewrite_with_partition(&program, &partition_of(&program, &["work"])).unwrap();
        assert_eq!(points.len(), 1);
        let code = &out.method(work).code;
        assert!(matches!(code[0], Instr::CcStart(0)));
        let stops = code
            .iter()
            .filter(|i| matches!(i, Instr::CcStop(_)))
            .count();
        assert_eq!(stops, 2, "one CcStop per return");
        assert_eq!(out.method(work).migration_point, Some(0));
        // The original is untouched.
        assert!(!program
            .method(work)
            .code
            .iter()
            .any(|i| matches!(i, Instr::CcStart(_))));
    }

    #[test]
    fn shard_annotation_requires_the_convention() {
        let program = assemble(SRC).unwrap();
        let work = program.resolve("C", "work").unwrap(); // nargs=1
        let mut p = partition_of(&program, &["work"]);
        p.span_shards.insert(work, 4);
        let err = rewrite_with_partition(&program, &p)
            .unwrap_err()
            .to_string();
        assert!(err.contains("not shard-shaped"), "{err}");
        // Width < 2 never scatters, so it is not worth refusing.
        p.span_shards.insert(work, 1);
        rewrite_with_partition(&program, &p).unwrap();
    }

    #[test]
    fn rewritten_binary_runs_identically_when_local() {
        let program = Arc::new(assemble(SRC).unwrap());
        let (rewritten, _) =
            rewrite_with_partition(&program, &partition_of(&program, &["work"])).unwrap();
        let rewritten = Arc::new(rewritten);

        let run = |prog: Arc<Program>| -> i64 {
            let main = prog.entry().unwrap();
            let mut p = Process::new(
                prog.clone(),
                DeviceSpec::phone_g1(),
                Location::Mobile,
                NodeEnv::with_rust_compute(SimFs::new()),
            );
            let tid = p.spawn_thread(main, &[]).unwrap();
            loop {
                match run_thread(&mut p, tid, &mut NoHooks, 1_000_000).unwrap() {
                    RunExit::Completed(_) => break,
                    RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. } => {
                        continue // local policy: don't migrate
                    }
                    other => panic!("{other:?}"),
                }
            }
            p.statics[main.class.0 as usize][0].as_int().unwrap()
        };
        assert_eq!(run(program), run(rewritten), "0+1+..+5 = 15 both ways");
    }

    #[test]
    fn branch_to_return_lands_on_ccstop() {
        // Regression: `ifge ... @done` where done: is a `ret` must land
        // on the CcStop, not jump past it (otherwise a migrated thread
        // skips its reintegration point and keeps running at the clone).
        const JUMP_TO_RET: &str = r#"
class C app
  method main nargs=0 regs=2
    invoke r0 C.work r0
    retv
  end
  method work nargs=1 regs=4
    const r1 0
  loop:
    ifge r1 r0 @done
    const r2 1
    add r1 r1 r2
    goto @loop
  done:
    ret r1
  end
end
"#;
        let program = assemble(JUMP_TO_RET).unwrap();
        let work = program.resolve("C", "work").unwrap();
        let (out, _) =
            rewrite_with_partition(&program, &partition_of(&program, &["work"])).unwrap();
        let code = &out.method(work).code;
        for instr in code {
            if let Some(t) = instr.branch_target() {
                if let Instr::Return(_) = code[t as usize] {
                    panic!("branch target {t} lands on a Return, skipping CcStop");
                }
            }
        }
        // And at least one branch lands exactly on a CcStop.
        let lands_on_stop = code.iter().filter_map(|i| i.branch_target()).any(|t| {
            matches!(code[t as usize], Instr::CcStop(_))
        });
        assert!(lands_on_stop);
    }

    #[test]
    fn candidate_rewrite_points_every_eligible_method() {
        let program = assemble(SRC).unwrap();
        let cfg = crate::partitioner::Cfg::build(&program);
        let candidates = candidate_points(&program, &cfg);
        // `main` is excluded (entry), `work` is eligible.
        let work = program.resolve("C", "work").unwrap();
        let main = program.entry().unwrap();
        assert!(candidates.contains(&work));
        assert!(!candidates.contains(&main));

        let (out, points) = rewrite_with_candidates(&program, &candidates).unwrap();
        assert_eq!(points.len(), candidates.len());
        for (&pid, &m) in &points {
            assert_eq!(out.method(m).migration_point, Some(pid));
            assert!(matches!(out.method(m).code[0], Instr::CcStart(p) if p == pid));
        }
        // The conditional binary run all-local computes the same result
        // as the unrewritten one (the no-op continuation contract).
        let run = |prog: Arc<Program>| -> i64 {
            let main = prog.entry().unwrap();
            let mut p = Process::new(
                prog.clone(),
                DeviceSpec::phone_g1(),
                Location::Mobile,
                NodeEnv::with_rust_compute(SimFs::new()),
            );
            let tid = p.spawn_thread(main, &[]).unwrap();
            loop {
                match run_thread(&mut p, tid, &mut NoHooks, 1_000_000).unwrap() {
                    RunExit::Completed(_) => break,
                    RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. } => {
                        continue
                    }
                    other => panic!("{other:?}"),
                }
            }
            p.statics[main.class.0 as usize][0].as_int().unwrap()
        };
        assert_eq!(run(Arc::new(program)), run(Arc::new(out)));
    }

    #[test]
    fn branch_targets_remapped_correctly() {
        let program = assemble(SRC).unwrap();
        let work = program.resolve("C", "work").unwrap();
        let (out, _) =
            rewrite_with_partition(&program, &partition_of(&program, &["work"])).unwrap();
        // Every branch target must land on a real instruction and the
        // loop must still be reachable (verified structurally by the
        // verifier; here we additionally check targets moved).
        let orig_targets: Vec<u32> = program
            .method(work)
            .code
            .iter()
            .filter_map(|i| i.branch_target())
            .collect();
        let new_targets: Vec<u32> = out
            .method(work)
            .code
            .iter()
            .filter_map(|i| i.branch_target())
            .collect();
        assert_eq!(orig_targets.len(), new_targets.len());
        for (o, n) in orig_targets.iter().zip(&new_targets) {
            assert!(n > o, "targets shift forward: {o} -> {n}");
        }
    }
}
