//! Dynamic profiler (paper §3.2).
//!
//! Temporarily instruments app-method entry/exit during a profile run and
//! fills in a [`ProfileTree`]: node costs from the virtual clock, edge
//! state sizes by performing the migrator's suspend-and-capture at
//! invocation and return and measuring (then discarding) the capture —
//! exactly the paper's procedure. System/native methods are treated as
//! inline code of their caller (their time lands in the caller's
//! residual).
//!
//! Each profiling execution runs twice — once on a phone-device process,
//! once on a clone-device process — producing the T / T' tree pair.

use crate::appvm::bytecode::MRef;
use crate::appvm::interp::{run_thread, ExecHooks, RunExit};
use crate::appvm::process::Process;
use crate::appvm::value::Value;
use crate::error::{CloneCloudError, Result};
use crate::migration::{measure_state_size, CaptureOptions};

use super::profile_tree::ProfileTree;

/// Profiler hook state.
pub struct Profiler {
    tree: ProfileTree,
    /// Stack of (node id, entry clock µs).
    stack: Vec<(usize, f64)>,
    /// Measure capture sizes at entry/exit (done on the mobile-device
    /// run only; clone-tree edges keep cost 0 since migrations are not
    /// initiated there — §3.2).
    pub measure_state: bool,
    capture_opts: CaptureOptions,
    /// Wall-clock seconds spent inside state measurement (reported by
    /// the E2 bench as the paper's "profiling migration cost" time).
    pub measure_wall_s: f64,
}

impl Profiler {
    pub fn new(measure_state: bool) -> Profiler {
        Profiler {
            tree: ProfileTree::default(),
            stack: Vec::new(),
            measure_state,
            capture_opts: CaptureOptions::default(),
            measure_wall_s: 0.0,
        }
    }

    pub fn into_tree(self) -> ProfileTree {
        self.tree
    }

    fn is_app_method(&self, p: &Process, m: MRef) -> bool {
        !p.program.class(m.class).system && !p.program.method(m).is_native()
    }

    fn measure(&mut self, p: &Process, tid: u32) -> u64 {
        let t0 = std::time::Instant::now();
        let bytes = measure_state_size(p, tid, self.capture_opts).unwrap_or(0);
        self.measure_wall_s += t0.elapsed().as_secs_f64();
        bytes
    }
}

impl ExecHooks for Profiler {
    fn on_entry(&mut self, p: &mut Process, tid: u32, mref: MRef) {
        if !self.is_app_method(p, mref) {
            return;
        }
        let parent = self.stack.last().map(|&(n, _)| n);
        let node = self.tree.push(mref, parent);
        if self.measure_state {
            let bytes = self.measure(p, tid);
            self.tree.nodes[node].edge_state_bytes += bytes;
        }
        self.stack.push((node, p.clock.now_us()));
    }

    fn on_native(&mut self, p: &mut Process, _tid: u32, _caller: MRef, callee: MRef) {
        if !p.program.class(callee.class).system {
            *self.tree.native_calls.entry(callee).or_insert(0) += 1;
        }
    }

    fn on_exit(&mut self, p: &mut Process, tid: u32, mref: MRef) {
        if !self.is_app_method(p, mref) {
            return;
        }
        let Some((node, t0)) = self.stack.pop() else {
            return;
        };
        debug_assert_eq!(self.tree.nodes[node].method, mref);
        self.tree.nodes[node].cost_us = p.clock.now_us() - t0;
        if self.measure_state {
            let bytes = self.measure(p, tid);
            self.tree.nodes[node].edge_state_bytes += bytes;
        }
    }
}

/// Wall-clock + virtual timing of one profile run (feeds E2).
#[derive(Debug, Clone, Default)]
pub struct ProfileRunReport {
    pub wall_s: f64,
    pub virtual_ms: f64,
    pub state_measure_wall_s: f64,
    pub methods_profiled: usize,
}

/// Run `entry(args)` to completion on `p` under profiling. The root
/// method is entered manually (hooks only fire on `Invoke`).
pub fn profile_run(
    p: &mut Process,
    entry: MRef,
    args: &[Value],
    measure_state: bool,
) -> Result<(ProfileTree, ProfileRunReport)> {
    let wall0 = std::time::Instant::now();
    let tid = p.spawn_thread(entry, args)?;
    let mut prof = Profiler::new(measure_state);

    // Root node for the entry method itself.
    prof.on_entry(p, tid, entry);
    // Fix the root entry: on_entry consumed clock 0 reading; stack holds it.

    loop {
        match run_thread(p, tid, &mut prof, 4_000_000_000)? {
            RunExit::Completed(_) => break,
            // Profiling runs the ORIGINAL binary; if a partitioned binary
            // is profiled anyway, partition points are no-ops.
            RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. } => continue,
            RunExit::OutOfFuel => {
                return Err(CloneCloudError::partitioner("profile run out of fuel"))
            }
        }
    }
    prof.on_exit(p, tid, entry);

    let methods: std::collections::HashSet<MRef> =
        prof.tree.nodes.iter().map(|n| n.method).collect();
    let report = ProfileRunReport {
        wall_s: wall0.elapsed().as_secs_f64(),
        virtual_ms: p.clock.now_ms(),
        state_measure_wall_s: prof.measure_wall_s,
        methods_profiled: methods.len(),
    };
    Ok((prof.into_tree(), report))
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::Program;
    use crate::device::{DeviceSpec, Location};
    use crate::vfs::SimFs;

    const PROG: &str = r#"
class P app
  method main nargs=0 regs=4
    invokev P.a
    invokev P.a
    retv
  end
  method a nargs=0 regs=6
    const r0 0
    const r1 100
  loop:
    ifge r0 r1 @done
    const r2 1
    add r0 r0 r2
    goto @loop
  done:
    invokev P.b
    retv
  end
  method b nargs=0 regs=2
    const r0 1
    retv
  end
end
"#;

    fn proc(dev: DeviceSpec) -> (Process, MRef) {
        let program: Arc<Program> = Arc::new(assemble(PROG).unwrap());
        let main = program.entry().unwrap();
        (
            Process::new(
                program,
                dev,
                Location::Mobile,
                NodeEnv::with_rust_compute(SimFs::new()),
            ),
            main,
        )
    }

    #[test]
    fn tree_structure_matches_calls() {
        let (mut p, main) = proc(DeviceSpec::phone_g1());
        let (tree, report) = profile_run(&mut p, main, &[], false).unwrap();
        // main + 2x a + 2x b = 5 invocations.
        assert_eq!(tree.len(), 5);
        assert_eq!(tree.roots.len(), 1);
        let a = p.program.resolve("P", "a").unwrap();
        let b = p.program.resolve("P", "b").unwrap();
        assert_eq!(tree.invocation_count(a), 2);
        assert_eq!(tree.invocation_count(b), 2);
        assert_eq!(report.methods_profiled, 3);
        // a's residual dominates b's (the loop lives in a's body).
        assert!(tree.method_residual_us(a) > tree.method_residual_us(b) * 5.0);
        // Total equals the root cost and is positive.
        assert!(tree.total_us() > 0.0);
    }

    #[test]
    fn phone_tree_costs_scale_with_device() {
        let (mut phone, main) = proc(DeviceSpec::phone_g1());
        let (pt, _) = profile_run(&mut phone, main, &[], false).unwrap();
        let (mut clone, _) = proc(DeviceSpec::clone_desktop());
        let (ct, _) = profile_run(&mut clone, main, &[], false).unwrap();
        let ratio = pt.total_us() / ct.total_us();
        assert!(
            (ratio - DeviceSpec::phone_g1().cpu_factor).abs() < 0.5,
            "ratio {ratio}"
        );
        // Same tree shape on both platforms (deterministic program).
        assert_eq!(pt.len(), ct.len());
    }

    #[test]
    fn state_measurement_fills_edges() {
        let (mut p, main) = proc(DeviceSpec::phone_g1());
        let (tree, report) = profile_run(&mut p, main, &[], true).unwrap();
        let a = p.program.resolve("P", "a").unwrap();
        assert!(tree.method_state_bytes(a) > 0, "captures measured");
        assert!(report.state_measure_wall_s >= 0.0);
        // Virtual clock unaffected by measurement (capture discarded).
        let (mut q, main2) = proc(DeviceSpec::phone_g1());
        let (_t2, r2) = profile_run(&mut q, main2, &[], false).unwrap();
        assert!((report.virtual_ms - r2.virtual_ms).abs() < 1e-6);
    }
}
