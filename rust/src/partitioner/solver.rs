//! The optimization solver (paper §3.3).
//!
//! Chooses the binary migration decisions R(m) — and the induced
//! locations L(m) — minimizing Σ_E C(E) = Comp(E) + Migr(E) subject to
//! the paper's constraints (1)–(4):
//!
//! 1. soundness: a migrant method's body runs opposite its caller
//!    (encoded as L(callee) = L(caller) XOR R(callee) on every DC edge —
//!    the XOR also covers the "location only changes at migration
//!    points" execution semantics);
//! 2. V_M methods are pinned to the mobile device;
//! 3. V_Nat_C methods are collocated;
//! 4. no cyclic migration: R(m1) = 1 ⇒ R(m2) = 0 for TC(m1, m2).
//!
//! Solved as a 0-1 ILP with our branch-and-bound simplex (`lp`),
//! standing in for the paper's Mosek.

use std::collections::{BTreeSet, HashMap};

use crate::appvm::bytecode::MRef;
use crate::appvm::class::Program;
use crate::device::Location;
use crate::error::{CloneCloudError, Result};

use super::cfg::Cfg;
use super::cost_model::CostModel;
use super::lp::{solve_ilp, Constraint, IlpResult, Sense};

/// Per-invocation profiled cost of one migratory span (µs, virtual):
/// the span's inclusive time (body + callees) run on the phone vs at
/// the clone. The runtime policy engine prices migrate-vs-local per
/// invocation with these (`exec::policy`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpanCostUs {
    pub local_us: f64,
    pub clone_us: f64,
}

/// A partitioning: the R(m)=1 set plus induced locations and costs.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Methods with migration/reintegration points (R(m) = 1).
    pub migrate: BTreeSet<MRef>,
    /// Induced location of each app method's body.
    pub locations: HashMap<MRef, Location>,
    /// Expected cost of the partitioned execution (µs, model units).
    pub expected_us: f64,
    /// Cost of the all-local execution (µs) — the comparison baseline.
    pub local_us: f64,
    /// Per-invocation span costs for each R(m)=1 method, from the
    /// profile trees (filled by `pipeline::partition_from_trees`; empty
    /// when a partition is constructed without profiling).
    pub span_costs: HashMap<MRef, SpanCostUs>,
    /// Data-parallel R(m)=1 methods: scatter width under the
    /// `work(begin, end, shards)` convention (absent = monolithic).
    /// The rewriter refuses an annotation on a method that is not
    /// shard-shaped, so a stored width is always honorable.
    pub span_shards: HashMap<MRef, u16>,
}

impl Partition {
    /// "Offload" in Table 1's sense: at least one migration point chosen.
    pub fn is_offload(&self) -> bool {
        !self.migrate.is_empty()
    }

    pub fn label(&self) -> &'static str {
        if self.is_offload() {
            "Offload"
        } else {
            "Local"
        }
    }
}

/// Diagnostics from one solve (feeds the E2 bench).
#[derive(Debug, Clone, Default)]
pub struct SolveReport {
    pub n_vars: usize,
    pub n_constraints: usize,
    pub solve_wall_s: f64,
    pub candidates: usize,
}

/// Solve the partitioning problem for a program + cost model.
pub fn solve_partition(
    program: &Program,
    cfg: &Cfg,
    costs: &CostModel,
) -> Result<(Partition, SolveReport)> {
    let t0 = std::time::Instant::now();

    // Variables: app methods only (system classes are not partition
    // candidates, §3.1). x = [L_0..L_{n-1}, R_0..R_{n-1}].
    let methods: Vec<MRef> = program.app_methods();
    let n = methods.len();
    let idx: HashMap<MRef, usize> = methods.iter().enumerate().map(|(i, m)| (*m, i)).collect();
    let l = |i: usize| i;
    let r = |i: usize| n + i;

    let mut cons: Vec<Constraint> = Vec::new();
    let eq = |var: usize, v: f64| Constraint {
        coeffs: vec![(var, 1.0)],
        sense: Sense::Eq,
        rhs: v,
    };

    // R(m) = 0 for methods that cannot host partition points: pinned
    // (their body cannot move), native (no bytecode to rewrite),
    // recursive (Property 3 with m1 = m2), and main.
    let mut candidates = 0usize;
    for (i, &m) in methods.iter().enumerate() {
        let def = program.method(m);
        let fixed = def.pinned || def.is_native() || cfg.recursive(m);
        if fixed {
            cons.push(eq(r(i), 0.0));
        } else {
            candidates += 1;
        }
        // Constraint (2): V_M pinned to the mobile device.
        if def.pinned {
            cons.push(eq(l(i), 0.0));
        }
    }

    // Constraint (1) + execution semantics on every DC edge between app
    // methods: L(m2) = L(m1) XOR R(m2), linearized.
    for (ci, cj) in cfg.dc_edges() {
        let (m1, m2) = (cfg.methods[ci], cfg.methods[cj]);
        let (Some(&i1), Some(&i2)) = (idx.get(&m1), idx.get(&m2)) else {
            continue; // edge touching a system method
        };
        let (l1, l2, r2) = (l(i1), l(i2), r(i2));
        cons.push(Constraint {
            coeffs: vec![(l2, 1.0), (l1, -1.0), (r2, 1.0)],
            sense: Sense::Ge,
            rhs: 0.0,
        });
        cons.push(Constraint {
            coeffs: vec![(l2, 1.0), (l1, -1.0), (r2, -1.0)],
            sense: Sense::Le,
            rhs: 0.0,
        });
        cons.push(Constraint {
            coeffs: vec![(l2, 1.0), (r2, -1.0), (l1, 1.0)],
            sense: Sense::Ge,
            rhs: 0.0,
        });
        cons.push(Constraint {
            coeffs: vec![(l2, 1.0), (r2, 1.0), (l1, 1.0)],
            sense: Sense::Le,
            rhs: 2.0,
        });
    }

    // Constraint (3): V_Nat_C collocation — native-state methods of the
    // same class share a location.
    for class in &program.classes {
        if class.system {
            continue;
        }
        let group: Vec<usize> = class
            .methods
            .iter()
            .enumerate()
            .filter(|(_, m)| m.native_state)
            .filter_map(|(mi, _)| {
                let mref = program.resolve(&class.name, &class.methods[mi].name).ok()?;
                idx.get(&mref).copied()
            })
            .collect();
        for w in group.windows(2) {
            cons.push(Constraint {
                coeffs: vec![(l(w[0]), 1.0), (l(w[1]), -1.0)],
                sense: Sense::Eq,
                rhs: 0.0,
            });
        }
    }

    // Constraint (4): no cyclic migration — R(m1) + R(m2) <= 1 when
    // TC(m1, m2).
    for (ci, cj) in cfg.tc_pairs() {
        let (m1, m2) = (cfg.methods[ci], cfg.methods[cj]);
        let (Some(&i1), Some(&i2)) = (idx.get(&m1), idx.get(&m2)) else {
            continue;
        };
        if i1 == i2 {
            continue; // recursion handled by the R=0 fixing above
        }
        cons.push(Constraint {
            coeffs: vec![(r(i1), 1.0), (r(i2), 1.0)],
            sense: Sense::Le,
            rhs: 1.0,
        });
    }

    // Objective: Σ_m [A_m + (B_m - A_m) L_m + S_m R_m]; the constant
    // Σ A_m is added back afterwards.
    let mut c = vec![0.0; 2 * n];
    let mut local_us = 0.0;
    for (i, &m) in methods.iter().enumerate() {
        let a = costs.mobile(m);
        let b = costs.clone_side(m);
        let s = costs.migration(m);
        local_us += a;
        c[l(i)] = b - a;
        c[r(i)] = s;
    }

    let report_cons = cons.len();
    let result = solve_ilp(2 * n, &c, &cons);
    let (x, obj) = match result {
        IlpResult::Optimal { x, objective } => (x, objective),
        IlpResult::Infeasible => {
            return Err(CloneCloudError::Solver(
                "partitioning ILP infeasible (constraint bug?)".into(),
            ))
        }
    };

    let mut migrate = BTreeSet::new();
    let mut locations = HashMap::new();
    for (i, &m) in methods.iter().enumerate() {
        if x[r(i)] == 1 {
            migrate.insert(m);
        }
        locations.insert(m, Location::from_bit(x[l(i)]));
    }
    let partition = Partition {
        migrate,
        locations,
        expected_us: local_us + obj,
        local_us,
        span_costs: HashMap::new(),
        span_shards: HashMap::new(),
    };
    let report = SolveReport {
        n_vars: 2 * n,
        n_constraints: report_cons,
        solve_wall_s: t0.elapsed().as_secs_f64(),
        candidates,
    };
    Ok((partition, report))
}

/// Validate that a partition satisfies the paper's constraints against a
/// program + CFG (used by tests and after DB loads).
pub fn validate_partition(program: &Program, cfg: &Cfg, p: &Partition) -> Result<()> {
    for &m in &p.migrate {
        let def = program.method(m);
        if def.pinned {
            return Err(CloneCloudError::partitioner(format!(
                "migration point on pinned method {}",
                program.method_name(m)
            )));
        }
        if def.is_native() {
            return Err(CloneCloudError::partitioner("migration point on native"));
        }
        if cfg.recursive(m) {
            return Err(CloneCloudError::partitioner("migration point on recursion"));
        }
        for &m2 in &p.migrate {
            if m != m2 && cfg.tc(m, m2) {
                return Err(CloneCloudError::partitioner(format!(
                    "cyclic migration: {} transitively calls {}",
                    program.method_name(m),
                    program.method_name(m2)
                )));
            }
        }
    }
    // Pinned methods must be located at the mobile device.
    for m in program.app_methods() {
        if program.method(m).pinned {
            if let Some(loc) = p.locations.get(&m) {
                if *loc != Location::Mobile {
                    return Err(CloneCloudError::partitioner(format!(
                        "pinned {} located at clone",
                        program.method_name(m)
                    )));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::bytecode::{ClassId, MethodId};

    /// Figure 5's program with a cost model that makes c() expensive.
    const FIG5: &str = r#"
class C app
  method main nargs=0 regs=2
    invokev C.a
    retv
  end
  method a nargs=0 regs=2
    invokev C.b
    invokev C.c
    retv
  end
  method b nargs=0 regs=2
    retv
  end
  method c nargs=0 regs=2
    retv
  end
end
"#;

    fn model(program: &Program, entries: &[(&str, f64, f64, f64)]) -> CostModel {
        let mut cm = CostModel::default();
        for &(name, a, b, s) in entries {
            let m = program.resolve("C", name).unwrap();
            cm.mobile_us.insert(m, a);
            cm.clone_us.insert(m, b);
            cm.migr_us.insert(m, s);
            cm.invocations.insert(m, 1);
        }
        cm
    }

    #[test]
    fn figure5_offloads_expensive_c() {
        let program = assemble(FIG5).unwrap();
        let cfg = Cfg::build(&program);
        // c is heavy (1000 vs 50 at the clone), migration cheap (100).
        let cm = model(
            &program,
            &[
                ("main", 10.0, 0.5, 1e9),
                ("a", 20.0, 1.0, 200.0),
                ("b", 5.0, 0.25, 50.0),
                ("c", 1000.0, 50.0, 100.0),
            ],
        );
        let (p, report) = solve_partition(&program, &cfg, &cm).unwrap();
        validate_partition(&program, &cfg, &p).unwrap();
        let c = program.resolve("C", "c").unwrap();
        let b = program.resolve("C", "b").unwrap();
        let a = program.resolve("C", "a").unwrap();
        assert!(p.migrate.contains(&c), "paper Fig. 5c: c() offloaded");
        assert_eq!(p.locations[&c], Location::Clone);
        assert_eq!(p.locations[&program.resolve("C", "main").unwrap()], Location::Mobile);
        assert!(p.expected_us < p.local_us, "offload must beat local");
        assert!(report.n_vars >= 8);
        // b stays local (cheap to run, costs 50 to move).
        assert!(!p.migrate.contains(&b));
        let _ = a;
    }

    #[test]
    fn offloading_a_takes_b_and_c_with_it() {
        let program = assemble(FIG5).unwrap();
        let cfg = Cfg::build(&program);
        // Everything under a() is expensive; moving a() once is cheapest.
        let cm = model(
            &program,
            &[
                ("main", 10.0, 0.5, 1e9),
                ("a", 500.0, 25.0, 80.0),
                ("b", 400.0, 20.0, 500.0),
                ("c", 400.0, 20.0, 500.0),
            ],
        );
        let (p, _) = solve_partition(&program, &cfg, &cm).unwrap();
        validate_partition(&program, &cfg, &p).unwrap();
        let a = program.resolve("C", "a").unwrap();
        let b = program.resolve("C", "b").unwrap();
        let c = program.resolve("C", "c").unwrap();
        assert!(p.migrate.contains(&a));
        // Property 3: nothing under a() is also a migration point.
        assert!(!p.migrate.contains(&b) && !p.migrate.contains(&c));
        // But their bodies run at the clone (XOR propagation).
        assert_eq!(p.locations[&b], Location::Clone);
        assert_eq!(p.locations[&c], Location::Clone);
    }

    #[test]
    fn expensive_migration_keeps_everything_local() {
        let program = assemble(FIG5).unwrap();
        let cfg = Cfg::build(&program);
        let cm = model(
            &program,
            &[
                ("main", 10.0, 0.5, 1e9),
                ("a", 100.0, 5.0, 1e9),
                ("b", 50.0, 2.5, 1e9),
                ("c", 100.0, 5.0, 1e9),
            ],
        );
        let (p, _) = solve_partition(&program, &cfg, &cm).unwrap();
        assert!(!p.is_offload());
        assert_eq!(p.label(), "Local");
        assert!((p.expected_us - p.local_us).abs() < 1e-6);
    }

    #[test]
    fn pinned_subtree_cannot_move() {
        const SRC: &str = r#"
class C app
  method main nargs=0 regs=2
    invokev C.a
    retv
  end
  method a nargs=0 regs=2
    invokev C.show
    retv
  end
  method show nargs=1 regs=2 native=ui.show
end
"#;
        // a() calls a pinned UI native: offloading a() would require the
        // native's location to flip — infeasible, so a() stays local no
        // matter how expensive it is.
        let program = assemble(SRC).unwrap();
        let cfg = Cfg::build(&program);
        let mut cm = CostModel::default();
        let a = program.resolve("C", "a").unwrap();
        cm.mobile_us.insert(a, 1e6);
        cm.clone_us.insert(a, 1.0);
        cm.migr_us.insert(a, 1.0);
        let (p, _) = solve_partition(&program, &cfg, &cm).unwrap();
        assert!(!p.migrate.contains(&a), "Property 1 wins over cost");
    }

    #[test]
    fn native_state_collocation_forces_group_moves() {
        const SRC: &str = r#"
class C app
  method main nargs=0 regs=4
    invokev C.a
    invokev C.b
    retv
  end
  method a nargs=0 regs=4
    const r0 0
    invoke r1 C.size r0
    retv
  end
  method b nargs=0 regs=4
    const r0 0
    invoke r1 C.size2 r0
    retv
  end
  method size nargs=1 regs=1 native=fs.size natstate
  method size2 nargs=1 regs=1 native=fs.size natstate
end
"#;
        // a uses native-state method `size`, b uses `size2` of the same
        // class: Property 2 says size/size2 are collocated, so a and b
        // must land on the same side. Offloading only a (huge win) is
        // blocked unless b comes too — and b is cheap to move, so the
        // solver offloads both.
        let program = assemble(SRC).unwrap();
        let cfg = Cfg::build(&program);
        let mut cm = CostModel::default();
        let a = program.resolve("C", "a").unwrap();
        let b = program.resolve("C", "b").unwrap();
        cm.mobile_us.insert(a, 1e6);
        cm.clone_us.insert(a, 10.0);
        cm.migr_us.insert(a, 100.0);
        cm.mobile_us.insert(b, 100.0);
        cm.clone_us.insert(b, 5.0);
        cm.migr_us.insert(b, 100.0);
        let (p, _) = solve_partition(&program, &cfg, &cm).unwrap();
        validate_partition(&program, &cfg, &p).unwrap();
        let size = program.resolve("C", "size").unwrap();
        let size2 = program.resolve("C", "size2").unwrap();
        assert_eq!(
            p.locations[&size], p.locations[&size2],
            "V_Nat_C collocated"
        );
        assert!(p.migrate.contains(&a));
        assert!(p.migrate.contains(&b), "dragged along by collocation");
    }

    #[test]
    fn recursion_cannot_be_a_migration_point() {
        const SRC: &str = r#"
class C app
  method main nargs=0 regs=2
    const r0 5
    invoke r1 C.f r0
    retv
  end
  method f nargs=1 regs=4
    ifz r0 @base
    const r1 1
    sub r2 r0 r1
    invoke r3 C.f r2
    ret r3
  base:
    ret r0
  end
end
"#;
        let program = assemble(SRC).unwrap();
        let cfg = Cfg::build(&program);
        let f = program.resolve("C", "f").unwrap();
        let mut cm = CostModel::default();
        cm.mobile_us.insert(f, 1e6);
        cm.clone_us.insert(f, 1.0);
        cm.migr_us.insert(f, 1.0);
        let (p, _) = solve_partition(&program, &cfg, &cm).unwrap();
        assert!(!p.migrate.contains(&f), "Property 3: no nested suspends");
    }

    #[test]
    fn validate_rejects_bogus_partition() {
        let program = assemble(FIG5).unwrap();
        let cfg = Cfg::build(&program);
        let a = program.resolve("C", "a").unwrap();
        let c = program.resolve("C", "c").unwrap();
        let mut migrate = BTreeSet::new();
        migrate.insert(a);
        migrate.insert(c); // a transitively calls c: illegal
        let p = Partition {
            migrate,
            locations: HashMap::new(),
            expected_us: 0.0,
            local_us: 0.0,
            span_costs: HashMap::new(),
            span_shards: HashMap::new(),
        };
        assert!(validate_partition(&program, &cfg, &p).is_err());
        let _ = MRef {
            class: ClassId(0),
            method: MethodId(0),
        };
    }
}
