//! Static control-flow graph over methods (paper §3.1).
//!
//! Conservative caller/callee approximation from the bytecode: an edge
//! m1 -> m2 exists iff m1 contains an `Invoke` of m2 (every actual call
//! path exists in the graph; the converse need not hold). Exported as the
//! paper's two relations: DC (directly calls) and its transitive closure
//! TC.

use std::collections::HashMap;

use crate::appvm::bytecode::MRef;
use crate::appvm::class::Program;

/// The static method-level CFG.
#[derive(Debug, Clone)]
pub struct Cfg {
    /// All methods, deterministic order.
    pub methods: Vec<MRef>,
    index: HashMap<MRef, usize>,
    /// DC(i, j): methods[i] directly calls methods[j].
    dc: Vec<Vec<bool>>,
    /// TC(i, j): transitive closure of DC.
    tc: Vec<Vec<bool>>,
}

impl Cfg {
    /// Build the CFG for a program.
    pub fn build(program: &Program) -> Cfg {
        let methods = program.all_methods();
        let n = methods.len();
        let index: HashMap<MRef, usize> =
            methods.iter().enumerate().map(|(i, m)| (*m, i)).collect();
        let mut dc = vec![vec![false; n]; n];
        for (i, &m) in methods.iter().enumerate() {
            for instr in &program.method(m).code {
                if let Some(callee) = instr.callee() {
                    dc[i][index[&callee]] = true;
                }
            }
        }
        // Transitive closure (Floyd–Warshall over booleans).
        let mut tc = dc.clone();
        for k in 0..n {
            for i in 0..n {
                if tc[i][k] {
                    for j in 0..n {
                        if tc[k][j] {
                            tc[i][j] = true;
                        }
                    }
                }
            }
        }
        Cfg {
            methods,
            index,
            dc,
            tc,
        }
    }

    pub fn len(&self) -> usize {
        self.methods.len()
    }
    pub fn is_empty(&self) -> bool {
        self.methods.is_empty()
    }

    pub fn idx(&self, m: MRef) -> usize {
        self.index[&m]
    }

    /// "m1 Directly Calls m2".
    pub fn dc(&self, m1: MRef, m2: MRef) -> bool {
        self.dc[self.idx(m1)][self.idx(m2)]
    }

    /// "m1 Transitively Calls m2".
    pub fn tc(&self, m1: MRef, m2: MRef) -> bool {
        self.tc[self.idx(m1)][self.idx(m2)]
    }

    /// All DC edges as index pairs.
    pub fn dc_edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..self.len() {
            for j in 0..self.len() {
                if self.dc[i][j] {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// All TC pairs as index pairs.
    pub fn tc_pairs(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..self.len() {
            for j in 0..self.len() {
                if self.tc[i][j] {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Is method `m` recursive (calls itself transitively)?
    pub fn recursive(&self, m: MRef) -> bool {
        self.tc[self.idx(m)][self.idx(m)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::assembler::assemble;

    /// The paper's Figure 5 program: a() calls b() then c().
    const FIG5: &str = r#"
class C app
  method main nargs=0 regs=2
    invokev C.a
    retv
  end
  method a nargs=0 regs=2
    invokev C.b
    invokev C.c
    retv
  end
  method b nargs=0 regs=2
    retv
  end
  method c nargs=0 regs=2
    retv
  end
end
"#;

    #[test]
    fn figure5_dc_and_tc() {
        let p = assemble(FIG5).unwrap();
        let cfg = Cfg::build(&p);
        let m = |n: &str| p.resolve("C", n).unwrap();
        assert!(cfg.dc(m("main"), m("a")));
        assert!(cfg.dc(m("a"), m("b")));
        assert!(cfg.dc(m("a"), m("c")));
        assert!(!cfg.dc(m("main"), m("b")), "not a direct call");
        assert!(cfg.tc(m("main"), m("b")), "but a transitive one");
        assert!(cfg.tc(m("main"), m("c")));
        assert!(!cfg.tc(m("b"), m("a")), "no back edges");
        assert!(!cfg.recursive(m("a")));
    }

    #[test]
    fn recursion_detected() {
        let src = r#"
class R app
  method main nargs=0 regs=2
    invokev R.f
    retv
  end
  method f nargs=0 regs=2
    invokev R.g
    retv
  end
  method g nargs=0 regs=2
    invokev R.f
    retv
  end
end
"#;
        let p = assemble(src).unwrap();
        let cfg = Cfg::build(&p);
        let f = p.resolve("R", "f").unwrap();
        let g = p.resolve("R", "g").unwrap();
        assert!(cfg.recursive(f));
        assert!(cfg.recursive(g));
        assert!(cfg.tc(f, f));
    }

    #[test]
    fn edges_enumerate() {
        let p = assemble(FIG5).unwrap();
        let cfg = Cfg::build(&p);
        assert_eq!(cfg.dc_edges().len(), 3);
        assert_eq!(cfg.tc_pairs().len(), 5, "main->{{a,b,c}}, a->{{b,c}}");
    }
}
