//! The CloneCloud partitioner (paper §3): static analysis + dynamic
//! profiling + optimization solving + binary rewriting.

pub mod cfg;
pub mod cost_model;
pub mod database;
pub mod lp;
pub mod profile_tree;
pub mod profiler;
pub mod rewriter;
pub mod solver;

pub use cfg::Cfg;
pub use cost_model::CostModel;
pub use database::{PartitionDb, PartitionEntry};
pub use profile_tree::{ProfileNode, ProfileTree};
pub use profiler::{profile_run, ProfileRunReport, Profiler};
pub use rewriter::{
    candidate_points, rewrite_with_candidates, rewrite_with_partition, shard_shaped,
};
pub use solver::{solve_partition, validate_partition, Partition, SolveReport, SpanCostUs};
