//! Farm gateway: the serve-many network front door.
//!
//! Where `CloneServer` binds one transport to one dedicated clone
//! process, the gateway binds *each accepted connection* to a farm
//! session: the same `protocol::Msg` conversation (provision → fs sync →
//! migrate… → shutdown) but with execution multiplexed over the farm's
//! worker pool. A phone-side `NodeManager` cannot tell the difference —
//! the wire protocol is unchanged.
//!
//! Provisioning differs in one respect: the farm's Zygote template is
//! fixed at farm start, so a phone whose (objects, seed) parameters
//! disagree is rejected — §4.3's independently-booted-template trick
//! only works when both sides build the *same* template.

use std::time::Duration;

use crate::error::{CloneCloudError, Result};
use crate::farm::{FarmClone, FarmHandle};
use crate::vfs::SimFs;

use super::protocol::{
    codec_agreed, dict_agreed, open_frame, seal_frame, trace_agreed, Codec, Msg,
    CAP_SESSION_DICT, PROTO_VERSION, SUPPORTED_CAPS,
};
use super::transport::{TcpEndpoint, Transport};

/// The per-connection capability set a gateway arms from `Hello`.
/// Shared by the blocking and async serve paths so both negotiate —
/// and therefore execute — identically.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct SessionCaps {
    pub delta: bool,
    pub dict: bool,
    pub trace: bool,
    pub codec: Codec,
}

impl SessionCaps {
    /// Arm a farm session with the negotiated set.
    pub(crate) fn apply(&self, s: &mut FarmClone) {
        s.set_delta(self.delta);
        s.set_dict(self.dict);
        s.set_trace(self.trace);
    }
}

/// Negotiate one `Hello` against the farm: compute the capability set
/// this connection runs with and the `Hello` reply to send. Both
/// gateways call this — the dict-masking rule and the min-revision echo
/// live in exactly one place.
pub(crate) fn negotiate_hello(
    handle: &FarmHandle,
    proto: u16,
    want_delta: bool,
    caps: u32,
) -> (SessionCaps, Msg) {
    // Delta — and the session dictionary, whose replica also
    // lives in the slot — require placement that parks the
    // phone on one worker (affinity). The dictionary bit
    // must be masked out of the REPLY caps too: the phone
    // computes `dict_agreed` from what we advertise, and a
    // phone that believes dict while the slots decode
    // without it would fail every capsule.
    let local_caps = if handle.delta_friendly() {
        SUPPORTED_CAPS
    } else {
        SUPPORTED_CAPS & !CAP_SESSION_DICT
    };
    let negotiated = SessionCaps {
        delta: super::protocol::delta_agreed(proto, want_delta) && handle.delta_friendly(),
        dict: dict_agreed(PROTO_VERSION, local_caps, proto, caps),
        // Trace context is per-job stateless (no slot-resident
        // baseline), so it needs no affinity and no masking.
        trace: trace_agreed(PROTO_VERSION, local_caps, proto, caps),
        codec: codec_agreed(proto, caps),
    };
    // Log the negotiated capability set: mixed-version
    // fleets are debugged from exactly this line.
    eprintln!(
        "[farm] session caps: proto v{}, delta={}, dict={}, trace={}, codec={}",
        proto.min(PROTO_VERSION),
        negotiated.delta,
        negotiated.dict,
        negotiated.trace,
        negotiated.codec.name()
    );
    // Reply with the negotiated (min) revision so a v3
    // initiator gets a Hello its decoder accepts.
    let reply = Msg::Hello {
        proto: proto.min(PROTO_VERSION),
        delta: negotiated.delta,
        caps: local_caps,
    };
    (negotiated, reply)
}

/// Validate a `Provision` against the farm's fixed template; returns
/// the reply to send and whether the connection is now provisioned.
/// Shared by both gateways.
pub(crate) fn check_provision(
    handle: &FarmHandle,
    zygote_objects: u32,
    zygote_seed: u64,
    want_hash: u64,
) -> (bool, Msg) {
    let have = handle.program_hash();
    if have != want_hash {
        return (
            false,
            Msg::Error(format!(
                "program hash mismatch: farm={have:#x} phone={want_hash:#x} (resync executables)"
            )),
        );
    }
    let (zo, zs) = handle.zygote_params();
    if zygote_objects as usize != zo || zygote_seed != zs {
        return (
            false,
            Msg::Error(format!(
                "zygote parameter mismatch: farm=({zo}, {zs}) phone=({zygote_objects}, {zygote_seed})"
            )),
        );
    }
    (true, Msg::Ack)
}

/// Serve one phone connection against the farm. Returns the number of
/// migrations served. Exits cleanly on `Shutdown` (explicit, or a clean
/// TCP EOF which the transport reports as `Shutdown`).
pub fn serve_farm_session<T: Transport>(mut t: T, handle: &FarmHandle) -> Result<u64> {
    let mut session: Option<FarmClone> = None;
    let mut provisioned = false;
    let mut migrations = 0u64;
    // Armed by Hello; applied to the session whenever one exists.
    let mut caps = SessionCaps::default();
    loop {
        let (msg, _) = t.recv()?;
        match msg {
            Msg::Hello {
                proto,
                delta: want,
                caps: peer_caps,
            } => {
                let (negotiated, reply) = negotiate_hello(handle, proto, want, peer_caps);
                caps = negotiated;
                if let Some(s) = session.as_mut() {
                    caps.apply(s);
                }
                t.send(&reply)?;
            }
            Msg::Provision {
                zygote_objects,
                zygote_seed,
                program_hash: want,
            } => {
                let (ok, reply) = check_provision(handle, zygote_objects, zygote_seed, want);
                provisioned = provisioned || ok;
                t.send(&reply)?;
            }
            Msg::SyncFs(fs) => {
                match session.as_mut() {
                    Some(s) => s.set_fs(fs),
                    None => {
                        let mut s = handle.session_auto(fs);
                        caps.apply(&mut s);
                        session = Some(s);
                    }
                }
                t.send(&Msg::Ack)?;
            }
            Msg::Migrate(bytes) => {
                if !provisioned {
                    t.send(&Msg::Error("migrate before provision".into()))?;
                    continue;
                }
                if session.is_none() {
                    let mut s = handle.session_auto(SimFs::new());
                    caps.apply(&mut s);
                    session = Some(s);
                }
                let s = session.as_mut().unwrap();
                // Frame layer: open a (possibly compressed) payload for
                // the farm, seal the reply under the negotiated codec,
                // and feed the per-direction raw/wire byte counters.
                let wire_up = bytes.len() as u64;
                let raw = match open_frame(&bytes) {
                    Ok(raw) => raw.into_owned(),
                    Err(e) => {
                        t.send(&Msg::Error(e.to_string()))?;
                        continue;
                    }
                };
                let raw_up = raw.len() as u64;
                match s.roundtrip_bytes(raw) {
                    Ok((rbytes, _)) => {
                        migrations += 1;
                        let raw_down = rbytes.len() as u64;
                        let sealed = seal_frame(caps.codec, rbytes);
                        handle.record_wire(raw_up, wire_up, raw_down, sealed.len() as u64);
                        t.send(&Msg::Reintegrate(sealed))?;
                    }
                    Err(CloneCloudError::NeedFull(reason)) => {
                        t.send(&Msg::NeedFull(reason))?;
                    }
                    Err(e) => {
                        t.send(&Msg::Error(e.to_string()))?;
                    }
                }
            }
            Msg::Heartbeat {
                base_epoch: _,
                digest,
                assignments,
            } => {
                let res = match session.as_mut() {
                    Some(s) => s.heartbeat_probe(digest, &assignments),
                    None => Err(CloneCloudError::need_full("heartbeat before any session")),
                };
                match res {
                    Ok(()) => t.send(&Msg::Ack)?,
                    Err(e) if e.is_need_full() => t.send(&Msg::NeedFull(e.to_string()))?,
                    Err(e) => t.send(&Msg::Error(e.to_string()))?,
                };
            }
            Msg::Shutdown => return Ok(migrations),
            other => {
                t.send(&Msg::Error(format!("unexpected message {other:?}")))?;
            }
        }
    }
}

/// Accept loop: one gateway thread per connection, all sharing the farm.
/// `read_timeout` bounds how long an idle/hung connection may pin its
/// gateway thread. `max_sessions` stops accepting after that many
/// connections (used by tests and drains); `None` serves forever.
pub fn serve_farm(
    ep: &TcpEndpoint,
    handle: &FarmHandle,
    read_timeout: Option<Duration>,
    max_sessions: Option<usize>,
) -> Result<()> {
    let mut served = 0usize;
    loop {
        if let Some(max) = max_sessions {
            if served >= max {
                return Ok(());
            }
        }
        // Per-connection failures (ECONNABORTED races, EMFILE spikes,
        // setsockopt on an already-dead socket) must not take down the
        // gateway for every other phone.
        let mut t = match ep.accept() {
            Ok(t) => t,
            Err(e) => {
                eprintln!("[farm] accept error: {e}");
                continue;
            }
        };
        if let Some(d) = read_timeout {
            if let Err(e) = t.set_read_timeout(Some(d)) {
                eprintln!("[farm] session setup error: {e}");
                continue;
            }
        }
        let h = handle.clone();
        std::thread::spawn(move || match serve_farm_session(t, &h) {
            Ok(n) => eprintln!("[farm] session done: {n} migration(s)"),
            Err(e) => eprintln!("[farm] session error: {e}"),
        });
        served += 1;
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::manager::NodeManager;
    use super::super::transport::InProcTransport;
    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::process::Process;
    use crate::appvm::zygote::build_template;
    use crate::config::{CostParams, ExecTierKind};
    use crate::device::{DeviceSpec, Location};
    use crate::farm::{
        synthetic_expected, synthetic_offload_src, CloneFarm, FarmConfig, PlacementPolicy,
    };
    use crate::migration::{CapturePacket, Migrator};

    const ITERS: i64 = 2_000;
    const ZY: usize = 120;
    const SEED: u64 = 3;

    fn start_farm() -> (Arc<crate::appvm::Program>, CloneFarm) {
        let program = Arc::new(assemble(&synthetic_offload_src(ITERS)).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let farm = CloneFarm::start(
            program.clone(),
            FarmConfig {
                workers: 2,
                warm_per_worker: 1,
                queue_depth: 4,
                policy: PlacementPolicy::LeastLoaded,
                zygote_objects: ZY,
                zygote_seed: SEED,
                fuel: 100_000_000,
                slot_gc_interval: 8,
                exec_tier: ExecTierKind::Tier1,
            },
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        (program, farm)
    }

    /// Full wire path: a phone-side NodeManager speaks the unchanged Msg
    /// protocol to a gateway session backed by the farm.
    #[test]
    fn gateway_end_to_end_over_wire_protocol() {
        let (program, farm) = start_farm();
        let (phone_t, clone_t) = InProcTransport::pair();
        let handle = farm.handle();
        let gw = std::thread::spawn(move || serve_farm_session(clone_t, &handle).unwrap());

        let mut fs = crate::vfs::SimFs::new();
        fs.add("data.bin", (0u8..64).collect());
        let expected = synthetic_expected(&fs, ITERS);

        let mut nm = NodeManager::new(phone_t);
        nm.provision(&program, ZY, SEED).unwrap();
        nm.sync_fs(&fs).unwrap();

        let template = build_template(&program, ZY, SEED);
        let mut phone = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(fs),
        );
        let main = program.entry().unwrap();
        let tid = phone.spawn_thread(main, &[]).unwrap();
        use crate::appvm::interp::{run_thread, NoHooks, RunExit};
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000_000).unwrap();
        assert!(matches!(exit, RunExit::MigrationPoint { .. }));

        let migrator = Migrator::new(CostParams::default());
        let (packet, _) = migrator.migrate_out(&mut phone, tid).unwrap();
        let (rbytes, transfer) = nm.migrate(packet.encode().unwrap()).unwrap();
        assert!(transfer.up > 0 && transfer.down > 0);
        let rpacket = CapturePacket::decode(&rbytes).unwrap();
        migrator.merge_back(&mut phone, tid, &rpacket).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)), "{exit:?}");
        assert_eq!(
            phone.statics[main.class.0 as usize][0].as_int(),
            Some(expected)
        );

        nm.shutdown().unwrap();
        assert_eq!(gw.join().unwrap(), 1);
        let stats = farm.shutdown();
        assert_eq!(stats.migrations, 1);
        assert_eq!(stats.sessions_opened, 1);
        assert_eq!(stats.sessions_closed, 1, "gateway session retired");
    }

    /// Without affinity placement the gateway must not just disable the
    /// dictionary locally — it must mask `CAP_SESSION_DICT` out of the
    /// Hello REPLY, or the phone would negotiate dict against slots
    /// that decode without it and every capsule would fail.
    #[test]
    fn gateway_masks_dict_capability_without_affinity() {
        let (_program, farm) = start_farm(); // LeastLoaded placement
        let (phone_t, clone_t) = InProcTransport::pair();
        let handle = farm.handle();
        let gw = std::thread::spawn(move || serve_farm_session(clone_t, &handle).unwrap());

        let mut nm = NodeManager::new(phone_t);
        nm.negotiate().unwrap();
        assert!(!nm.delta_negotiated(), "delta needs affinity placement");
        assert!(
            !nm.dict_negotiated(),
            "dict bit masked out of the reply caps too"
        );
        assert_eq!(
            nm.negotiated_codec(),
            Codec::Lz,
            "the codec is placement-independent and survives the mask"
        );
        nm.shutdown().unwrap();
        gw.join().unwrap();
        farm.shutdown();
    }

    /// The gateway rejects a provision whose executable or Zygote
    /// parameters disagree with the farm's.
    #[test]
    fn gateway_rejects_mismatched_provision() {
        let (program, farm) = start_farm();
        let other = Arc::new(
            assemble("class B app\n  method main nargs=0 regs=1\n    retv\n  end\nend\n").unwrap(),
        );
        let (phone_t, clone_t) = InProcTransport::pair();
        let handle = farm.handle();
        let gw = std::thread::spawn(move || serve_farm_session(clone_t, &handle).unwrap());

        let mut nm = NodeManager::new(phone_t);
        let err = nm.provision(&other, ZY, SEED).unwrap_err().to_string();
        assert!(err.contains("hash mismatch"), "{err}");
        let err = nm.provision(&program, ZY, SEED + 1).unwrap_err().to_string();
        assert!(err.contains("zygote parameter mismatch"), "{err}");
        // The right program + parameters still go through afterwards.
        nm.provision(&program, ZY, SEED).unwrap();
        nm.shutdown().unwrap();
        assert_eq!(gw.join().unwrap(), 0);
        farm.shutdown();
    }
}
