//! Async sharded gateway: the C10k serve path for clone farms.
//!
//! The blocking gateway ([`super::gateway::serve_farm`]) spends one OS
//! thread per phone; at farm scale (thousands of mostly-idle phones)
//! that is thousands of stacks parked in `read()`. This module serves
//! the *same wire protocol* from a fixed thread count:
//!
//! * **One acceptor** polls the listener nonblocking and deals new
//!   connections round-robin to shards over bounded queues (a full
//!   shard queue blocks the acceptor — admission backpressure at the
//!   front door, not unbounded conn growth).
//! * **N shard threads** each own a private connection table — no
//!   global session lock, no cross-shard contention. A shard sweeps its
//!   connections with nonblocking reads/writes
//!   ([`crate::util::readiness`]), parsing frames incrementally through
//!   the same [`FrameDecoder`] the blocking transport uses.
//! * **Farm handoff never blocks a shard**: migrations are submitted
//!   through [`FarmClone::try_begin_roundtrip`] and polled to
//!   completion, so one slow capsule (or a full admission window) stalls
//!   only its own connection while the shard keeps sweeping the rest.
//!
//! Protocol semantics are shared with the blocking path — Hello
//! negotiation, dict masking, provision checks, and error strings come
//! from the same helpers in [`super::gateway`] — so a phone cannot tell
//! which gateway it reached, and results are bit-identical. The
//! blocking gateway remains selectable (`farm.gateway = "blocking"`) as
//! the ablation baseline.

use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{CloneCloudError, Result};
use crate::farm::{FarmClone, FarmHandle, PendingProbe, PendingRoundtrip, Submit};
use crate::util::readiness::{read_step, write_step, IdleBackoff, ReadStep, WriteStep};
use crate::util::stats::LogHistogram;
use crate::vfs::SimFs;

use super::gateway::{check_provision, negotiate_hello, SessionCaps};
use super::protocol::{open_frame, seal_frame, FrameDecoder, Msg};
use super::transport::TcpEndpoint;

/// Which serve loop fronts the farm.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GatewayKind {
    /// Thread-per-connection blocking gateway (the ablation baseline).
    Blocking,
    /// Sharded nonblocking readiness loop (the default).
    #[default]
    Async,
}

impl GatewayKind {
    /// Parse a config value (`"blocking"` / `"async"`).
    pub fn parse(s: &str) -> Option<GatewayKind> {
        match s {
            "blocking" => Some(GatewayKind::Blocking),
            "async" => Some(GatewayKind::Async),
            _ => None,
        }
    }

    /// The config-file spelling.
    pub fn name(&self) -> &'static str {
        match self {
            GatewayKind::Blocking => "blocking",
            GatewayKind::Async => "async",
        }
    }
}

/// Tuning for [`serve_farm_async`].
#[derive(Debug, Clone)]
pub struct AsyncGatewayConfig {
    /// Shard thread count; each shard owns a private connection table.
    pub shards: usize,
    /// Bounded accept→shard queue depth. A full queue blocks the
    /// acceptor (front-door backpressure).
    pub shard_queue_depth: usize,
    /// Retire a connection idle for longer than this (`None` = never).
    /// Mid-frame dribble and in-flight farm work both count as
    /// progress, so a slow phone is not retired mid-capsule.
    pub read_timeout: Option<Duration>,
    /// Stop accepting after this many connections and drain (`None` =
    /// serve forever). Used by tests and controlled shutdowns.
    pub max_sessions: Option<usize>,
}

impl Default for AsyncGatewayConfig {
    fn default() -> AsyncGatewayConfig {
        AsyncGatewayConfig {
            shards: 4,
            shard_queue_depth: 64,
            read_timeout: None,
            max_sessions: None,
        }
    }
}

/// Counters the async gateway reports when it drains.
#[derive(Debug, Clone, Default)]
pub struct GatewayStats {
    /// Connections accepted.
    pub accepts: u64,
    /// Accept/setup failures (the gateway keeps serving through them).
    pub accept_errors: u64,
    /// Peak simultaneously-open connections across all shards.
    pub conns_peak: u64,
    /// Migration roundtrips served to completion.
    pub migrations: u64,
    /// Sweeps that read bytes but could not yet complete a frame
    /// (partial-frame pressure: big capsules, slow phones).
    pub decode_stalls: u64,
    /// Writes the socket accepted only partially (send-buffer pressure).
    pub short_writes: u64,
    /// Times a connection had to hold work because the farm admission
    /// window was full, or paused reading on its own write backlog.
    pub backpressure_stalls: u64,
    /// Connections killed for protocol violations (undecodable frames,
    /// lying length prefixes, EOF mid-frame).
    pub protocol_errors: u64,
    /// Accept→shard-pickup handoff latency (milliseconds).
    pub handoff_ms: LogHistogram,
}

impl GatewayStats {
    fn absorb(&mut self, o: &GatewayStats) {
        self.accepts += o.accepts;
        self.accept_errors += o.accept_errors;
        self.conns_peak += o.conns_peak;
        self.migrations += o.migrations;
        self.decode_stalls += o.decode_stalls;
        self.short_writes += o.short_writes;
        self.backpressure_stalls += o.backpressure_stalls;
        self.protocol_errors += o.protocol_errors;
        self.handoff_ms.merge(&o.handoff_ms);
    }
}

/// Stop reading from a connection whose unflushed reply backlog exceeds
/// this (write-interest backpressure): the peer gets no new replies
/// buffered until it drains the ones in flight.
const WRITE_BACKLOG_CAP: usize = 256 * 1024;

/// Farm work a connection is waiting on. The protocol is strictly
/// request/response, so at most one of these exists per connection and
/// frame processing pauses while it is in flight.
enum Pending {
    /// A submitted migration awaiting its reverse capture.
    Migrate {
        ticket: PendingRoundtrip,
        raw_up: u64,
        wire_up: u64,
    },
    /// A migration refused at the admission window, held for retry on a
    /// later sweep (the opened frame rides along untouched).
    Admission { raw: Vec<u8>, wire_up: u64 },
    /// A heartbeat probe awaiting the placement worker's verdict.
    Heartbeat(PendingProbe),
}

/// One phone connection's incremental state machine.
struct Conn {
    stream: TcpStream,
    decoder: FrameDecoder,
    /// Encoded replies not yet accepted by the socket.
    out: Vec<u8>,
    out_pos: usize,
    session: Option<FarmClone>,
    provisioned: bool,
    caps: SessionCaps,
    pending: Option<Pending>,
    /// Clean shutdown requested: flush `out`, then retire.
    closing: bool,
    /// Hard failure: retire immediately.
    dead: bool,
    /// True while reads are paused on the write backlog (so the stall
    /// counter records transitions, not sweeps).
    write_blocked: bool,
    migrations: u64,
    last_progress: Instant,
}

impl Conn {
    fn adopt(stream: TcpStream) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true).ok();
        Ok(Conn {
            stream,
            decoder: FrameDecoder::new(),
            out: Vec::new(),
            out_pos: 0,
            session: None,
            provisioned: false,
            caps: SessionCaps::default(),
            pending: None,
            closing: false,
            dead: false,
            write_blocked: false,
            migrations: 0,
            last_progress: Instant::now(),
        })
    }

    fn finished(&self) -> bool {
        self.dead || (self.closing && self.out_pos >= self.out.len())
    }

    fn backlog(&self) -> usize {
        self.out.len() - self.out_pos
    }

    fn queue_msg(&mut self, msg: &Msg) {
        // Gateway replies carry no variable-count sections (Hello, Ack,
        // Error, NeedFull, Reintegrate — blobs and strings only), so
        // encoding cannot hit the u32 count limit.
        let payload = msg
            .encode()
            .expect("gateway replies contain no oversized collections");
        self.out
            .extend_from_slice(&(payload.len() as u32).to_be_bytes());
        self.out.extend_from_slice(&payload);
    }

    /// Push queued bytes at the socket; short writes keep a cursor.
    fn flush(&mut self, stats: &mut GatewayStats) -> std::io::Result<bool> {
        let mut progress = false;
        while self.out_pos < self.out.len() {
            match write_step(&mut self.stream, &self.out[self.out_pos..])? {
                WriteStep::Wrote(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ));
                }
                WriteStep::Wrote(n) => {
                    if n < self.backlog() {
                        stats.short_writes += 1;
                    }
                    self.out_pos += n;
                    self.last_progress = Instant::now();
                    progress = true;
                }
                WriteStep::Idle => break,
            }
        }
        if self.out_pos > 0 && self.out_pos == self.out.len() {
            self.out.clear();
            self.out_pos = 0;
        }
        Ok(progress)
    }

    /// Submit (or re-submit) an opened forward frame to the farm
    /// without blocking the shard.
    fn begin_roundtrip(
        &mut self,
        raw: Vec<u8>,
        wire_up: u64,
        stats: &mut GatewayStats,
        first_attempt: bool,
    ) {
        let raw_up = raw.len() as u64;
        let s = self
            .session
            .as_mut()
            .expect("begin_roundtrip without a session");
        match s.try_begin_roundtrip(raw) {
            Ok(Submit::Pending(ticket)) => {
                self.pending = Some(Pending::Migrate {
                    ticket,
                    raw_up,
                    wire_up,
                });
            }
            Ok(Submit::Backpressure(raw)) => {
                if first_attempt {
                    stats.backpressure_stalls += 1;
                }
                self.pending = Some(Pending::Admission { raw, wire_up });
            }
            Err(CloneCloudError::NeedFull(reason)) => self.queue_msg(&Msg::NeedFull(reason)),
            Err(e) => self.queue_msg(&Msg::Error(e.to_string())),
        }
    }

    /// Poll in-flight farm work; returns whether state advanced.
    fn poll_pending(&mut self, handle: &FarmHandle, stats: &mut GatewayStats) -> bool {
        let Some(p) = self.pending.take() else {
            return false;
        };
        match p {
            Pending::Admission { raw, wire_up } => {
                self.begin_roundtrip(raw, wire_up, stats, false);
                // Progress only if the retry escaped the admission arm.
                !matches!(self.pending, Some(Pending::Admission { .. }))
            }
            Pending::Migrate {
                mut ticket,
                raw_up,
                wire_up,
            } => {
                let s = self
                    .session
                    .as_mut()
                    .expect("pending roundtrip without a session");
                match s.poll_roundtrip(&mut ticket) {
                    None => {
                        self.pending = Some(Pending::Migrate {
                            ticket,
                            raw_up,
                            wire_up,
                        });
                        false
                    }
                    Some(Ok((rbytes, _))) => {
                        self.migrations += 1;
                        let raw_down = rbytes.len() as u64;
                        let sealed = seal_frame(self.caps.codec, rbytes);
                        handle.record_wire(raw_up, wire_up, raw_down, sealed.len() as u64);
                        self.queue_msg(&Msg::Reintegrate(sealed));
                        true
                    }
                    Some(Err(CloneCloudError::NeedFull(reason))) => {
                        self.queue_msg(&Msg::NeedFull(reason));
                        true
                    }
                    Some(Err(e)) => {
                        self.queue_msg(&Msg::Error(e.to_string()));
                        true
                    }
                }
            }
            Pending::Heartbeat(mut probe) => {
                let s = self
                    .session
                    .as_mut()
                    .expect("pending heartbeat without a session");
                match s.poll_heartbeat(&mut probe) {
                    None => {
                        self.pending = Some(Pending::Heartbeat(probe));
                        false
                    }
                    Some(Ok(())) => {
                        self.queue_msg(&Msg::Ack);
                        true
                    }
                    Some(Err(e)) if e.is_need_full() => {
                        self.queue_msg(&Msg::NeedFull(e.to_string()));
                        true
                    }
                    Some(Err(e)) => {
                        self.queue_msg(&Msg::Error(e.to_string()));
                        true
                    }
                }
            }
        }
    }

    /// One decoded message, with semantics identical to the blocking
    /// gateway (shared helpers for everything negotiation-shaped).
    fn handle_msg(&mut self, msg: Msg, handle: &FarmHandle, stats: &mut GatewayStats) {
        match msg {
            Msg::Hello {
                proto,
                delta: want,
                caps: peer_caps,
            } => {
                let (negotiated, reply) = negotiate_hello(handle, proto, want, peer_caps);
                self.caps = negotiated;
                if let Some(s) = self.session.as_mut() {
                    self.caps.apply(s);
                }
                self.queue_msg(&reply);
            }
            Msg::Provision {
                zygote_objects,
                zygote_seed,
                program_hash: want,
            } => {
                let (ok, reply) = check_provision(handle, zygote_objects, zygote_seed, want);
                self.provisioned = self.provisioned || ok;
                self.queue_msg(&reply);
            }
            Msg::SyncFs(fs) => {
                match self.session.as_mut() {
                    Some(s) => s.set_fs(fs),
                    None => {
                        let mut s = handle.session_auto(fs);
                        self.caps.apply(&mut s);
                        self.session = Some(s);
                    }
                }
                self.queue_msg(&Msg::Ack);
            }
            Msg::Migrate(bytes) => {
                if !self.provisioned {
                    self.queue_msg(&Msg::Error("migrate before provision".into()));
                    return;
                }
                if self.session.is_none() {
                    let mut s = handle.session_auto(SimFs::new());
                    self.caps.apply(&mut s);
                    self.session = Some(s);
                }
                let wire_up = bytes.len() as u64;
                let raw = match open_frame(&bytes) {
                    Ok(raw) => raw.into_owned(),
                    Err(e) => {
                        self.queue_msg(&Msg::Error(e.to_string()));
                        return;
                    }
                };
                self.begin_roundtrip(raw, wire_up, stats, true);
            }
            Msg::Heartbeat {
                base_epoch: _,
                digest,
                assignments,
            } => match self.session.as_mut() {
                Some(s) => match s.try_begin_heartbeat(digest, &assignments) {
                    Ok(probe) => self.pending = Some(Pending::Heartbeat(probe)),
                    Err(e) if e.is_need_full() => self.queue_msg(&Msg::NeedFull(e.to_string())),
                    Err(e) => self.queue_msg(&Msg::Error(e.to_string())),
                },
                None => {
                    let e = CloneCloudError::need_full("heartbeat before any session");
                    self.queue_msg(&Msg::NeedFull(e.to_string()));
                }
            },
            Msg::Shutdown => self.closing = true,
            other => {
                self.queue_msg(&Msg::Error(format!("unexpected message {other:?}")));
            }
        }
    }

    /// One readiness sweep: flush → poll farm → read → decode → flush.
    /// Returns whether anything moved (the shard's backoff signal).
    fn sweep(
        &mut self,
        handle: &FarmHandle,
        stats: &mut GatewayStats,
        read_timeout: Option<Duration>,
        scratch: &mut [u8],
    ) -> bool {
        if self.dead {
            return false;
        }
        let mut progress = match self.flush(stats) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("[farm] async conn write error: {e}");
                self.dead = true;
                return true;
            }
        };
        if self.poll_pending(handle, stats) {
            self.last_progress = Instant::now();
            progress = true;
        }

        // Read, unless the peer owes us a drain first (write-interest
        // backpressure) or a clean shutdown is already underway.
        let mut fed = false;
        if !self.closing {
            if self.backlog() > WRITE_BACKLOG_CAP {
                if !self.write_blocked {
                    self.write_blocked = true;
                    stats.backpressure_stalls += 1;
                }
            } else {
                self.write_blocked = false;
                match read_step(&mut self.stream, scratch) {
                    Ok(ReadStep::Data(n)) => {
                        self.decoder.feed(&scratch[..n]);
                        self.last_progress = Instant::now();
                        fed = true;
                        progress = true;
                    }
                    Ok(ReadStep::Eof) => {
                        progress = true;
                        if self.decoder.mid_frame() {
                            eprintln!(
                                "[farm] async conn eof mid-frame ({} bytes buffered)",
                                self.decoder.buffered()
                            );
                            stats.protocol_errors += 1;
                            self.dead = true;
                        } else if self.pending.is_some() {
                            // Peer hung up with a roundtrip in flight;
                            // dropping the ticket releases admission.
                            eprintln!("[farm] async conn eof with work in flight");
                            self.dead = true;
                        } else {
                            // EOF at a frame boundary is a clean close,
                            // exactly like the blocking transport.
                            self.closing = true;
                        }
                    }
                    Ok(ReadStep::Idle) => {}
                    Err(e) => {
                        eprintln!("[farm] async conn read error: {e}");
                        self.dead = true;
                        return true;
                    }
                }
            }
        }

        // Decode buffered frames. Strictly request/response: stop while
        // farm work is pending — later frames wait in the decoder.
        while !self.dead && !self.closing && self.pending.is_none() {
            match self.decoder.next_frame() {
                Ok(Some(frame)) => {
                    progress = true;
                    match Msg::decode(&frame) {
                        Ok(msg) => self.handle_msg(msg, handle, stats),
                        Err(e) => {
                            eprintln!("[farm] async conn protocol error: {e}");
                            stats.protocol_errors += 1;
                            self.dead = true;
                        }
                    }
                }
                Ok(None) => {
                    if fed && self.decoder.mid_frame() {
                        stats.decode_stalls += 1;
                    }
                    break;
                }
                Err(e) => {
                    eprintln!("[farm] async conn framing error: {e}");
                    stats.protocol_errors += 1;
                    self.dead = true;
                }
            }
        }

        if !self.dead {
            match self.flush(stats) {
                Ok(p) => progress |= p,
                Err(e) => {
                    eprintln!("[farm] async conn write error: {e}");
                    self.dead = true;
                    return true;
                }
            }
        }

        // Idle timeout. In-flight farm work suspends it (the phone is
        // waiting on us), and any read/write progress resets it — a
        // mid-frame dribble never retires a slow phone.
        if let Some(tmo) = read_timeout {
            if !self.dead
                && !self.closing
                && self.pending.is_none()
                && self.last_progress.elapsed() > tmo
            {
                eprintln!(
                    "[farm] async conn idle past {}ms, retiring{}",
                    tmo.as_millis(),
                    if self.decoder.mid_frame() {
                        " (stalled mid-frame)"
                    } else {
                        ""
                    }
                );
                self.dead = true;
                progress = true;
            }
        }
        progress
    }
}

/// One shard: a private connection table swept with nonblocking I/O.
fn shard_main(
    shard: usize,
    rx: Receiver<(TcpStream, Instant)>,
    handle: FarmHandle,
    read_timeout: Option<Duration>,
    open: Arc<AtomicU64>,
    peak: Arc<AtomicU64>,
) -> GatewayStats {
    let mut stats = GatewayStats::default();
    let mut conns: Vec<Conn> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let mut backoff = IdleBackoff::new(Duration::from_millis(2));
    let mut accepting = true;
    loop {
        let mut progress = false;
        // Adopt newly dealt connections.
        while accepting {
            match rx.try_recv() {
                Ok((stream, accepted_at)) => {
                    stats
                        .handoff_ms
                        .record(accepted_at.elapsed().as_secs_f64() * 1e3);
                    match Conn::adopt(stream) {
                        Ok(c) => {
                            let now_open = open.fetch_add(1, Ordering::Relaxed) + 1;
                            peak.fetch_max(now_open, Ordering::Relaxed);
                            conns.push(c);
                            progress = true;
                        }
                        Err(e) => {
                            stats.accept_errors += 1;
                            eprintln!("[farm] shard {shard} conn setup error: {e}");
                        }
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => accepting = false,
            }
        }
        // Sweep every connection; retire the finished ones.
        let mut i = 0;
        while i < conns.len() {
            progress |= conns[i].sweep(&handle, &mut stats, read_timeout, &mut scratch);
            if conns[i].finished() {
                let c = conns.swap_remove(i);
                stats.migrations += c.migrations;
                open.fetch_sub(1, Ordering::Relaxed);
                progress = true;
            } else {
                i += 1;
            }
        }
        if !accepting && conns.is_empty() {
            return stats;
        }
        if progress {
            backoff.progress();
        } else {
            backoff.idle();
        }
    }
}

/// Serve the farm with the sharded nonblocking gateway. Returns the
/// merged per-shard [`GatewayStats`] once `max_sessions` connections
/// have been accepted **and** drained (with `max_sessions: None` it
/// serves forever).
///
/// The phone-visible protocol — and every reply byte — is identical to
/// [`super::gateway::serve_farm`]; only the scheduling differs.
pub fn serve_farm_async(
    ep: &TcpEndpoint,
    handle: &FarmHandle,
    cfg: &AsyncGatewayConfig,
) -> Result<GatewayStats> {
    let shards = cfg.shards.max(1);
    let depth = cfg.shard_queue_depth.max(1);
    ep.set_nonblocking(true)?;
    let open = Arc::new(AtomicU64::new(0));
    let peak = Arc::new(AtomicU64::new(0));
    let mut senders = Vec::with_capacity(shards);
    let mut joins = Vec::with_capacity(shards);
    for shard in 0..shards {
        let (tx, rx) = mpsc::sync_channel::<(TcpStream, Instant)>(depth);
        senders.push(tx);
        let h = handle.clone();
        let (open, peak) = (open.clone(), peak.clone());
        let tmo = cfg.read_timeout;
        let join = std::thread::Builder::new()
            .name(format!("gw-shard-{shard}"))
            .spawn(move || shard_main(shard, rx, h, tmo, open, peak))
            .map_err(|e| CloneCloudError::Transport(format!("spawn gateway shard: {e}")))?;
        joins.push(join);
    }

    let mut accepts = 0u64;
    let mut accept_errors = 0u64;
    let mut backoff = IdleBackoff::new(Duration::from_millis(2));
    loop {
        if let Some(max) = cfg.max_sessions {
            if accepts as usize >= max {
                break;
            }
        }
        match ep.poll_accept() {
            Ok(Some(stream)) => {
                let shard = (accepts as usize) % shards;
                accepts += 1;
                // A full shard queue blocks right here: backpressure at
                // the front door instead of unbounded connection growth.
                if senders[shard].send((stream, Instant::now())).is_err() {
                    accept_errors += 1;
                }
                backoff.progress();
            }
            Ok(None) => backoff.idle(),
            Err(e) => {
                accept_errors += 1;
                eprintln!("[farm] accept error: {e}");
                backoff.idle();
            }
        }
    }

    drop(senders); // shards drain their tables, then exit
    let mut stats = GatewayStats::default();
    for join in joins {
        let shard_stats = join
            .join()
            .map_err(|_| CloneCloudError::Transport("gateway shard panicked".into()))?;
        stats.absorb(&shard_stats);
    }
    stats.accepts = accepts;
    stats.accept_errors += accept_errors;
    stats.conns_peak = peak.load(Ordering::Relaxed);
    ep.set_nonblocking(false)?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use std::io::{Read, Write};
    use std::sync::Arc;

    use super::super::manager::NodeManager;
    use super::super::protocol::{Codec, PROTO_VERSION, SUPPORTED_CAPS};
    use super::super::transport::TcpTransport;
    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::process::Process;
    use crate::appvm::zygote::build_template;
    use crate::config::{CostParams, ExecTierKind};
    use crate::device::{DeviceSpec, Location};
    use crate::farm::{
        synthetic_expected, synthetic_offload_src, CloneFarm, FarmConfig, PlacementPolicy,
    };
    use crate::migration::{CapturePacket, Migrator};

    const ITERS: i64 = 2_000;
    const ZY: usize = 120;
    const SEED: u64 = 3;

    fn start_farm(workers: usize, policy: PlacementPolicy) -> (Arc<crate::appvm::Program>, CloneFarm) {
        let program = Arc::new(assemble(&synthetic_offload_src(ITERS)).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let farm = CloneFarm::start(
            program.clone(),
            FarmConfig {
                workers,
                warm_per_worker: 1,
                queue_depth: 8,
                policy,
                zygote_objects: ZY,
                zygote_seed: SEED,
                fuel: 100_000_000,
                slot_gc_interval: 8,
                exec_tier: ExecTierKind::Tier1,
            },
            CostParams::default(),
            Arc::new(NodeEnv::with_rust_compute),
        )
        .unwrap();
        (program, farm)
    }

    fn drive_phone(addr: &str, program: &Arc<crate::appvm::Program>) -> i64 {
        let mut fs = crate::vfs::SimFs::new();
        fs.add("data.bin", (0u8..64).collect());

        let mut nm = NodeManager::new(TcpTransport::connect(addr).unwrap());
        nm.provision(program, ZY, SEED).unwrap();
        nm.sync_fs(&fs).unwrap();

        let template = build_template(program, ZY, SEED);
        let mut phone = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(fs),
        );
        let main = program.entry().unwrap();
        let tid = phone.spawn_thread(main, &[]).unwrap();
        use crate::appvm::interp::{run_thread, NoHooks, RunExit};
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000_000).unwrap();
        assert!(matches!(exit, RunExit::MigrationPoint { .. }));

        let migrator = Migrator::new(CostParams::default());
        let (packet, _) = migrator.migrate_out(&mut phone, tid).unwrap();
        let (rbytes, transfer) = nm.migrate(packet.encode().unwrap()).unwrap();
        assert!(transfer.up > 0 && transfer.down > 0);
        let rpacket = CapturePacket::decode(&rbytes).unwrap();
        migrator.merge_back(&mut phone, tid, &rpacket).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 100_000_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)), "{exit:?}");
        nm.shutdown().unwrap();
        phone.statics[main.class.0 as usize][0].as_int().unwrap()
    }

    /// Full wire path over real sockets: several phones, each running
    /// the complete provision → sync → migrate → merge conversation
    /// against the sharded gateway, all landing the right result.
    #[test]
    fn async_gateway_end_to_end_over_wire_protocol() {
        const PHONES: usize = 3;
        let (program, farm) = start_farm(2, PlacementPolicy::LeastLoaded);
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let handle = farm.handle();
        let gw = std::thread::spawn(move || {
            let cfg = AsyncGatewayConfig {
                shards: 2,
                max_sessions: Some(PHONES),
                ..AsyncGatewayConfig::default()
            };
            serve_farm_async(&ep, &handle, &cfg).unwrap()
        });

        let mut fs = crate::vfs::SimFs::new();
        fs.add("data.bin", (0u8..64).collect());
        let expected = synthetic_expected(&fs, ITERS);

        let phones: Vec<_> = (0..PHONES)
            .map(|_| {
                let addr = addr.clone();
                let program = program.clone();
                std::thread::spawn(move || drive_phone(&addr, &program))
            })
            .collect();
        for p in phones {
            assert_eq!(p.join().unwrap(), expected);
        }

        let stats = gw.join().unwrap();
        assert_eq!(stats.accepts, PHONES as u64);
        assert_eq!(stats.migrations, PHONES as u64);
        assert_eq!(stats.protocol_errors, 0);
        assert!(stats.conns_peak >= 1);
        assert_eq!(stats.handoff_ms.count(), PHONES as u64);

        let fstats = farm.shutdown();
        assert_eq!(fstats.migrations, PHONES as u64);
        assert_eq!(fstats.sessions_opened, PHONES as u64);
        assert_eq!(fstats.sessions_closed, PHONES as u64, "sessions retired");
    }

    /// The async gateway applies the same dict-masking rule as the
    /// blocking one: without affinity placement, `CAP_SESSION_DICT` is
    /// masked out of the Hello reply.
    #[test]
    fn async_gateway_masks_dict_capability_without_affinity() {
        let (_program, farm) = start_farm(2, PlacementPolicy::LeastLoaded);
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let handle = farm.handle();
        let gw = std::thread::spawn(move || {
            let cfg = AsyncGatewayConfig {
                shards: 1,
                max_sessions: Some(1),
                ..AsyncGatewayConfig::default()
            };
            serve_farm_async(&ep, &handle, &cfg).unwrap()
        });

        let mut nm = NodeManager::new(TcpTransport::connect(&addr).unwrap());
        nm.negotiate().unwrap();
        assert!(!nm.delta_negotiated(), "delta needs affinity placement");
        assert!(!nm.dict_negotiated(), "dict bit masked out of reply caps");
        assert_eq!(nm.negotiated_codec(), Codec::Lz, "codec survives the mask");
        nm.shutdown().unwrap();
        gw.join().unwrap();
        farm.shutdown();
    }

    /// A phone dribbling its frames a byte at a time (partial reads on
    /// every sweep) still completes the conversation: the decoder
    /// accumulates across sweeps and the idle timeout counts dribble as
    /// progress.
    #[test]
    fn async_gateway_survives_byte_dribble() {
        let (_program, farm) = start_farm(1, PlacementPolicy::Affinity);
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let handle = farm.handle();
        let gw = std::thread::spawn(move || {
            let cfg = AsyncGatewayConfig {
                shards: 1,
                read_timeout: Some(Duration::from_millis(100)),
                max_sessions: Some(1),
                ..AsyncGatewayConfig::default()
            };
            serve_farm_async(&ep, &handle, &cfg).unwrap()
        });

        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.set_nodelay(true).ok();
        let hello = Msg::Hello {
            proto: PROTO_VERSION,
            delta: true,
            caps: SUPPORTED_CAPS,
        };
        let payload = hello.encode().unwrap();
        let mut wire = (payload.len() as u32).to_be_bytes().to_vec();
        wire.extend_from_slice(&payload);
        for b in wire {
            s.write_all(&[b]).unwrap();
            s.flush().ok();
            std::thread::sleep(Duration::from_millis(2));
        }
        // Read the Hello reply frame off the raw socket.
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut reply = vec![0u8; u32::from_be_bytes(len) as usize];
        s.read_exact(&mut reply).unwrap();
        match Msg::decode(&reply).unwrap() {
            Msg::Hello { proto, delta, caps } => {
                assert_eq!(proto, PROTO_VERSION);
                assert!(delta, "affinity placement keeps delta on");
                assert_eq!(caps, SUPPORTED_CAPS);
            }
            other => panic!("expected Hello reply, got {other:?}"),
        }
        let bye = Msg::Shutdown.encode().unwrap();
        s.write_all(&(bye.len() as u32).to_be_bytes()).unwrap();
        s.write_all(&bye).unwrap();
        drop(s);

        let stats = gw.join().unwrap();
        assert_eq!(stats.protocol_errors, 0, "dribble is not a violation");
        assert!(stats.decode_stalls > 0, "partial frames were observed");
        farm.shutdown();
    }

    /// Dozens of concurrent connections multiplex over a small fixed
    /// shard count, and the per-shard tables retire them all cleanly.
    #[test]
    fn async_gateway_many_concurrent_connections() {
        const CONNS: usize = 32;
        let (_program, farm) = start_farm(2, PlacementPolicy::LeastLoaded);
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let handle = farm.handle();
        let gw = std::thread::spawn(move || {
            let cfg = AsyncGatewayConfig {
                shards: 2,
                shard_queue_depth: 4,
                max_sessions: Some(CONNS),
                ..AsyncGatewayConfig::default()
            };
            serve_farm_async(&ep, &handle, &cfg).unwrap()
        });

        let clients: Vec<_> = (0..CONNS)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut nm = NodeManager::new(TcpTransport::connect(&addr).unwrap());
                    nm.negotiate().unwrap();
                    nm.shutdown().unwrap();
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }

        let stats = gw.join().unwrap();
        assert_eq!(stats.accepts, CONNS as u64);
        assert_eq!(stats.protocol_errors, 0);
        assert_eq!(stats.handoff_ms.count(), CONNS as u64);
        farm.shutdown();
    }

    #[test]
    fn gateway_kind_parses_config_spellings() {
        assert_eq!(GatewayKind::parse("async"), Some(GatewayKind::Async));
        assert_eq!(GatewayKind::parse("blocking"), Some(GatewayKind::Blocking));
        assert_eq!(GatewayKind::parse("epoll"), None);
        assert_eq!(GatewayKind::default().name(), "async");
    }
}
