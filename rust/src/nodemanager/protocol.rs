//! Node-manager wire protocol.
//!
//! The per-node managers speak a small framed protocol over a single
//! channel (paper §4: "it amortizes the cost of communicating with the
//! cloud over a single ... transport channel"): provisioning, file-system
//! synchronization, thread migration, and reintegration.

use std::borrow::Cow;

use crate::error::{CloneCloudError, Result};
use crate::util::bytes::{WireReader, WireWriter};
use crate::util::compress;
use crate::vfs::SimFs;

/// Protocol revision spoken by this build. v3 added `Hello` capability
/// negotiation and the delta-migration frames; v4 adds the capability
/// **bitmap** to `Hello` (codec flags), the digest `Heartbeat` frame,
/// and folds statics into the session digest. `Migrate`/`Reintegrate`
/// payloads may carry delta capsules only after both peers `Hello` with
/// `delta = true`, and compressed frames only after both advertised a
/// common codec bit (older peers never send `Hello`, so they are
/// offered neither).
///
/// Skew rules: the caps bitmap rides `Hello` only when its `proto`
/// field is >= 4, and responders echo the *negotiated* (min) revision,
/// so a v4 responder interoperates with a v3 initiator byte-for-byte.
/// A v4 *initiator* against a frozen v3 responder drops at the first
/// Hello (the v3 decoder demands exact length) — the same
/// fatal-connection story already documented for pre-v3 peers.
pub const PROTO_VERSION: u16 = 4;

/// Lowest protocol revision that understands *this build's* delta
/// capsules. Both peers agree on `min(theirs, ours)`, so version skew
/// can never arm exactly one end. v4 (not v3) because the canonical
/// session digest now covers app statics: a v3 peer computes digests
/// over a different domain, so a mixed v3/v4 pairing would reject every
/// delta — negotiating full-captures-only is strictly better.
pub const DELTA_MIN_PROTO: u16 = 4;

/// Lowest protocol revision that understands compressed frames and the
/// digest heartbeat.
pub const COMPRESS_MIN_PROTO: u16 = 4;

/// The delta decision both Hello peers compute: the negotiated revision
/// is the minimum of the two, and it must know delta capsules.
pub fn delta_agreed(peer_proto: u16, peer_delta: bool) -> bool {
    delta_agreed_at(PROTO_VERSION, peer_proto, peer_delta)
}

/// [`delta_agreed`] with an explicit local revision — the interop matrix
/// (and any build pinned to an older revision for skew testing) passes
/// its own instead of `PROTO_VERSION`.
pub fn delta_agreed_at(local_proto: u16, peer_proto: u16, peer_delta: bool) -> bool {
    peer_delta && peer_proto.min(local_proto) >= DELTA_MIN_PROTO
}

/// The session-dictionary decision, symmetric like [`delta_agreed`]:
/// min-revision agreement plus the intersection of the capability
/// bitmaps. Unknown bits are ignored, never rejected.
pub fn dict_agreed(local_proto: u16, local_caps: u32, peer_proto: u16, peer_caps: u32) -> bool {
    peer_proto.min(local_proto) >= DICT_MIN_PROTO
        && (peer_caps & local_caps & CAP_SESSION_DICT) != 0
}

// ---------------------------------------------------------------------------
// Capability bitmap + negotiated frame codec
// ---------------------------------------------------------------------------

/// Capability bit: the peer accepts LZ-compressed frames
/// ([`crate::util::compress`]).
pub const CAP_CODEC_LZ: u32 = 1 << 0;

/// Capability bit: the peer keeps a session-lifetime string dictionary
/// ([`crate::migration::SessionDict`]) — capsules after the first ship
/// only dictionary additions plus indices. When unnegotiated, capsules
/// keep the pre-dict byte layout exactly.
pub const CAP_SESSION_DICT: u32 = 1 << 1;

/// Capability bit: the peer understands the trace-context envelope
/// ([`crate::trace::wire`]) riding in front of `Migrate` payloads and
/// may piggyback its own phase events on `Reintegrate` payloads. Pure
/// observability — negotiating it never changes execution results.
pub const CAP_TRACE_CTX: u32 = 1 << 2;

/// Capability bit: the peer understands scatter sub-job frames — a
/// `Migrate` payload wrapped in [`SubJobFrame`] ("CCSJ") whose reply
/// rides back wrapped in a sub-result frame ("CCSR"). Executors that
/// never see the wrapper behave exactly as before; the bit only says
/// the wrapper will be unwrapped rather than rejected as a bad capsule.
pub const CAP_SCATTER: u32 = 1 << 3;

/// Every capability bit this build advertises in its `Hello`.
pub const SUPPORTED_CAPS: u32 = CAP_CODEC_LZ | CAP_SESSION_DICT | CAP_TRACE_CTX | CAP_SCATTER;

/// Lowest protocol revision that understands the session dictionary
/// (the caps bitmap itself only exists from v4 on).
pub const DICT_MIN_PROTO: u16 = 4;

/// Lowest protocol revision that understands trace-context envelopes.
pub const TRACE_MIN_PROTO: u16 = 4;

/// The trace-context decision, symmetric like [`dict_agreed`]:
/// min-revision agreement plus the intersection of the capability
/// bitmaps. Unknown bits are ignored, never rejected.
pub fn trace_agreed(local_proto: u16, local_caps: u32, peer_proto: u16, peer_caps: u32) -> bool {
    peer_proto.min(local_proto) >= TRACE_MIN_PROTO
        && (peer_caps & local_caps & CAP_TRACE_CTX) != 0
}

/// Lowest protocol revision that understands scatter sub-job frames
/// (the caps bitmap itself only exists from v4 on).
pub const SCATTER_MIN_PROTO: u16 = 4;

/// The scatter decision, symmetric like [`dict_agreed`]: min-revision
/// agreement plus the intersection of the capability bitmaps. Unknown
/// bits are ignored, never rejected.
pub fn scatter_agreed(local_proto: u16, local_caps: u32, peer_proto: u16, peer_caps: u32) -> bool {
    peer_proto.min(local_proto) >= SCATTER_MIN_PROTO
        && (peer_caps & local_caps & CAP_SCATTER) != 0
}

/// The frame codec a session negotiated. `None` is always legal; `Lz`
/// flows only after both `Hello`s carried [`CAP_CODEC_LZ`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    /// Frames ride raw (always legal; the pre-v4 answer).
    #[default]
    None,
    /// Frames are sealed with the hand-rolled LZ77/RLE codec.
    Lz,
}

impl Codec {
    /// Short lowercase name for logs and metrics labels.
    pub fn name(&self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Lz => "lz",
        }
    }
}

/// The codec decision both Hello peers compute, symmetric like
/// [`delta_agreed`]: min-version agreement plus the intersection of the
/// two capability bitmaps. Invariant: **unknown flag bits are ignored,
/// never rejected** — masking with our own supported set is the entire
/// forward-compatibility story, so a future peer advertising bits we do
/// not know still lands on the common subset.
pub fn codec_agreed(peer_proto: u16, peer_caps: u32) -> Codec {
    codec_agreed_at(PROTO_VERSION, SUPPORTED_CAPS, peer_proto, peer_caps)
}

/// [`codec_agreed`] with an explicit local (revision, caps) pair for
/// version-skew testing and capability ablations.
pub fn codec_agreed_at(
    local_proto: u16,
    local_caps: u32,
    peer_proto: u16,
    peer_caps: u32,
) -> Codec {
    if peer_proto.min(local_proto) >= COMPRESS_MIN_PROTO
        && (peer_caps & local_caps & CAP_CODEC_LZ) != 0
    {
        Codec::Lz
    } else {
        Codec::None
    }
}

/// Outcome of a digest heartbeat, as seen by the mobile endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HeartbeatOutcome {
    /// The channel cannot carry heartbeats (no negotiation, no baseline).
    Unsupported,
    /// Both baselines describe the same state; deltas are safe.
    Coherent,
    /// The peer answered `NeedFull`: the baseline is gone/diverged and
    /// the sender's cache was dropped — the next capture is full.
    Divergent,
}

/// Shared mobile-side heartbeat driver: fetch the session baseline, run
/// the channel-specific probe (wire exchange, farm worker round, or
/// in-process check), and map its `Result` onto the session cache — a
/// coherent probe clears the delivered assignments and restarts the
/// idle clock; a `NeedFull` drops the baseline so the next capture goes
/// out full. Every `CloneChannel::heartbeat` impl goes through here, so
/// the cache protocol lives in exactly one place.
pub fn drive_heartbeat<F>(
    session: &mut crate::migration::MobileSession,
    probe: F,
) -> Result<HeartbeatOutcome>
where
    F: FnOnce(u64, u64, &[(u64, u64)]) -> Result<()>,
{
    let (base_epoch, digest) = match session.baseline_info() {
        Some(x) => x,
        None => return Ok(HeartbeatOutcome::Unsupported),
    };
    match probe(base_epoch, digest, session.pending_assignments()) {
        Ok(()) => {
            session.mark_coherent();
            Ok(HeartbeatOutcome::Coherent)
        }
        Err(e) if e.is_need_full() => {
            session.drop_baseline();
            // The peer reset its dictionary replica alongside the
            // NeedFull; mirror it so both re-seed from empty.
            session.reset_dict();
            Ok(HeartbeatOutcome::Divergent)
        }
        Err(e) => Err(e),
    }
}

// ---------------------------------------------------------------------------
// Frame layer: self-describing compressed payload container
// ---------------------------------------------------------------------------

/// The most any decoder may reserve on the strength of an *unvalidated*
/// length claim. Wire counts and raw-length fields are attacker
/// controlled until the bytes behind them have actually been consumed,
/// so every `Vec::with_capacity(claimed)` site clamps through this one
/// constant — [`open_frame`], [`crate::util::compress::decompress`], and
/// (indirectly, via `WireReader::checked_count`'s bytes-remaining bound)
/// every section decoder. Decoded output may still *grow* past the cap,
/// but only in proportion to input bytes actually received, never in
/// proportion to what a hostile prefix merely claims. The fuzz harness
/// (`tests/fuzz_wire.rs`) asserts this law with a counting allocator.
pub const MAX_PREVALIDATION_ALLOC: usize = 1 << 20;

/// Magic for a compressed `Migrate`/`Reintegrate` payload ("CCZF" =
/// CloneCloud Z-frame). Distinct from both capsule magics ("CCHP" full /
/// "CCDP" delta), so `open_frame` can always tell a sealed frame from a
/// raw capsule without out-of-band state.
pub(crate) const FRAME_MAGIC: u32 = 0x4343_5A46;

/// Codec id inside a sealed frame (only LZ exists; `Codec::None`
/// payloads are never sealed).
const FRAME_CODEC_LZ: u8 = 1;

/// Sealed-frame header size: magic (4) + codec id (1) + raw length (4)
/// + preserved-head length (2).
const FRAME_HEADER: usize = 11;

/// Seal a capsule payload for the wire under the negotiated codec.
/// Identity when the codec is `None` **or** when compression does not
/// shrink the payload (incompressible input rides raw) — the receiver
/// dispatches on the frame magic either way.
pub fn seal_frame(codec: Codec, raw: Vec<u8>) -> Vec<u8> {
    seal_frame_keep_head(codec, raw, 0)
}

/// Like [`seal_frame`], but the first `head` bytes of the payload ride
/// **uncompressed** inside the container, so a fixed-offset field in
/// that range (the capsule's clock stamp) can be patched into the
/// sealed frame afterwards via [`patch_frame_payload`] — without a
/// second compression pass.
pub fn seal_frame_keep_head(codec: Codec, raw: Vec<u8>, head: usize) -> Vec<u8> {
    if codec == Codec::None {
        return raw;
    }
    let head = head.min(raw.len());
    let body = compress::compress(&raw[head..]);
    if body.len() + head + FRAME_HEADER >= raw.len() {
        return raw; // incompressible: passthrough
    }
    let mut w = WireWriter::with_capacity(body.len() + head + FRAME_HEADER);
    w.put_u32(FRAME_MAGIC);
    w.put_u8(FRAME_CODEC_LZ);
    w.put_u32(raw.len() as u32);
    w.put_u16(head as u16);
    let mut out = w.into_vec();
    out.extend_from_slice(&raw[..head]);
    out.extend_from_slice(&body);
    out
}

/// Open a wire payload: decompress a sealed frame (preserved head +
/// compressed tail), pass a raw capsule through untouched. Strict once
/// the frame magic matches — a truncated header, unknown codec id, or
/// any decompression defect is an error.
pub fn open_frame(bytes: &[u8]) -> Result<Cow<'_, [u8]>> {
    if bytes.len() < 4 {
        return Ok(Cow::Borrowed(bytes));
    }
    let magic = u32::from_be_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    if magic != FRAME_MAGIC {
        return Ok(Cow::Borrowed(bytes));
    }
    let mut r = WireReader::new(&bytes[4..]);
    let codec = r.get_u8()?;
    if codec != FRAME_CODEC_LZ {
        return Err(CloneCloudError::Wire(format!(
            "unknown frame codec id {codec}"
        )));
    }
    let raw_len = r.get_u32()? as usize;
    let head_len = r.get_u16()? as usize;
    if head_len > raw_len || FRAME_HEADER + head_len > bytes.len() {
        return Err(CloneCloudError::Wire(format!(
            "sealed frame head {head_len} exceeds raw length {raw_len} or frame size"
        )));
    }
    let mut raw = Vec::with_capacity(raw_len.min(MAX_PREVALIDATION_ALLOC));
    raw.extend_from_slice(&bytes[FRAME_HEADER..FRAME_HEADER + head_len]);
    let tail = compress::decompress(&bytes[FRAME_HEADER + head_len..], raw_len - head_len)?;
    raw.extend_from_slice(&tail);
    Ok(Cow::Owned(raw))
}

/// Overwrite `patch` at `offset` of the frame's *payload* — through the
/// container header when the frame is sealed (the range must then fall
/// inside the preserved head), directly when it is raw. This is how the
/// driver stamps the post-transfer clock into an already-sealed frame.
pub fn patch_frame_payload(wire: &mut [u8], offset: usize, patch: &[u8]) -> Result<()> {
    let base = if wire.len() >= 4
        && u32::from_be_bytes([wire[0], wire[1], wire[2], wire[3]]) == FRAME_MAGIC
    {
        if wire.len() < FRAME_HEADER {
            return Err(CloneCloudError::Wire("truncated sealed frame header".into()));
        }
        let head_len = u16::from_be_bytes([wire[9], wire[10]]) as usize;
        if offset + patch.len() > head_len {
            return Err(CloneCloudError::Wire(format!(
                "patch at {offset}..{} outside the sealed frame's {head_len}-byte head",
                offset + patch.len()
            )));
        }
        FRAME_HEADER
    } else {
        0
    };
    let start = base + offset;
    if start + patch.len() > wire.len() {
        return Err(CloneCloudError::Wire("patch outside the frame".into()));
    }
    wire[start..start + patch.len()].copy_from_slice(patch);
    Ok(())
}

// ---------------------------------------------------------------------------
// Scatter sub-job framing (shared by both gateways and the farm workers)
// ---------------------------------------------------------------------------

/// Magic for a scatter sub-job frame ("CCSJ"): one shard of a
/// data-parallel span riding inside a `Migrate` payload. Distinct from
/// the capsule magics ("CCHP"/"CCDP") and the z-frame ("CCZF"), so an
/// executor can always tell a wrapped sub-job from a bare capsule.
pub const SUB_JOB_MAGIC: u32 = 0x4343_534A;

/// Magic for a scatter sub-result frame ("CCSR"): the reverse capsule of
/// one shard, tagged with its shard index so the gather side can match
/// replies to sub-jobs whatever order they complete in.
pub const SUB_RESULT_MAGIC: u32 = 0x4343_5352;

/// Wire revision of the sub-job/sub-result framing.
pub const SUB_FRAME_VERSION: u16 = 1;

/// Byte offset of the payload inside an encoded sub-job frame: magic
/// (4) + version (2) + shard (2) + shards (2) + payload length prefix
/// (4). The driver patches the capsule clock through this header, so
/// the offset is part of the wire contract.
pub const SUB_JOB_PAYLOAD_OFFSET: usize = 14;

/// One shard of a scattered span: which shard this is, how many the
/// span was split into, and the (possibly sealed) forward capsule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SubJobFrame {
    /// Shard index, `0 <= shard < shards`.
    pub shard: u16,
    /// Total shard count for the span (`>= 1`; a count of 1 is a legal
    /// degenerate scatter and must roundtrip like any other).
    pub shards: u16,
    /// The forward capsule bytes for this shard.
    pub payload: Vec<u8>,
}

impl SubJobFrame {
    /// Encode to the tagged wire form ([`decode_sub_job`] inverts it).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::with_capacity(14 + self.payload.len());
        w.put_u32(SUB_JOB_MAGIC);
        w.put_u16(SUB_FRAME_VERSION);
        w.put_u16(self.shard);
        w.put_u16(self.shards);
        w.put_bytes(&self.payload);
        w.into_vec()
    }
}

/// Whether a payload leads with the sub-job magic (cheap dispatch for
/// executors; a `true` here still needs the strict decode to succeed).
pub fn is_sub_job(bytes: &[u8]) -> bool {
    bytes.len() >= 4
        && u32::from_be_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) == SUB_JOB_MAGIC
}

/// Strictly decode a sub-job frame: wrong magic, unknown version, a
/// zero shard count, an out-of-range shard index, truncation, and
/// trailing bytes are all typed errors — never panics, never a silent
/// partial parse.
pub fn decode_sub_job(bytes: &[u8]) -> Result<SubJobFrame> {
    let mut r = WireReader::new(bytes);
    let magic = r.get_u32()?;
    if magic != SUB_JOB_MAGIC {
        return Err(CloneCloudError::Wire(format!(
            "sub-job frame magic {magic:#x} != {SUB_JOB_MAGIC:#x}"
        )));
    }
    let version = r.get_u16()?;
    if version != SUB_FRAME_VERSION {
        return Err(CloneCloudError::Wire(format!(
            "unknown sub-job frame version {version}"
        )));
    }
    let shard = r.get_u16()?;
    let shards = r.get_u16()?;
    if shards == 0 {
        return Err(CloneCloudError::Wire("sub-job shard count 0".into()));
    }
    if shard >= shards {
        return Err(CloneCloudError::Wire(format!(
            "sub-job shard {shard} out of range (count {shards})"
        )));
    }
    let payload = r.get_bytes()?;
    if !r.is_done() {
        return Err(CloneCloudError::Wire("trailing bytes in sub-job frame".into()));
    }
    Ok(SubJobFrame {
        shard,
        shards,
        payload,
    })
}

/// Wrap one shard's reverse capsule in a sub-result frame.
pub fn encode_sub_result(shard: u16, payload: &[u8]) -> Vec<u8> {
    let mut w = WireWriter::with_capacity(12 + payload.len());
    w.put_u32(SUB_RESULT_MAGIC);
    w.put_u16(SUB_FRAME_VERSION);
    w.put_u16(shard);
    w.put_bytes(payload);
    w.into_vec()
}

/// Strictly decode a sub-result frame into (shard index, reverse
/// capsule bytes). Same strictness contract as [`decode_sub_job`].
pub fn decode_sub_result(bytes: &[u8]) -> Result<(u16, Vec<u8>)> {
    let mut r = WireReader::new(bytes);
    let magic = r.get_u32()?;
    if magic != SUB_RESULT_MAGIC {
        return Err(CloneCloudError::Wire(format!(
            "sub-result frame magic {magic:#x} != {SUB_RESULT_MAGIC:#x}"
        )));
    }
    let version = r.get_u16()?;
    if version != SUB_FRAME_VERSION {
        return Err(CloneCloudError::Wire(format!(
            "unknown sub-result frame version {version}"
        )));
    }
    let shard = r.get_u16()?;
    let payload = r.get_bytes()?;
    if !r.is_done() {
        return Err(CloneCloudError::Wire(
            "trailing bytes in sub-result frame".into(),
        ));
    }
    Ok((shard, payload))
}

// ---------------------------------------------------------------------------
// Incremental frame decoder (shared by the blocking and async serve paths)
// ---------------------------------------------------------------------------

/// Hard ceiling on a single wire frame's payload (the 4-byte length
/// prefix may not claim more). A lying or hostile prefix is rejected at
/// [`FrameDecoder::next_frame`] *before* any payload-sized allocation,
/// so buffering stays bounded by real bytes received.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Incremental decoder for the TCP framing: 4-byte big-endian length
/// prefix followed by that many payload bytes, repeated.
///
/// Feed it whatever the socket produced — one byte or one megabyte —
/// and drain complete frames with [`FrameDecoder::next_frame`]. The
/// decoder never blocks, never looks at a transport, and never
/// preallocates what a prefix merely *claims*: memory grows only with
/// bytes actually fed. Both gateways share it: the blocking transport
/// drives it from timeout-interrupted reads, the async gateway from
/// readiness-loop reads.
#[derive(Debug)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Bytes of `buf` already consumed by returned frames; compacted
    /// away once past half the buffer so feeds stay amortized O(1).
    start: usize,
    max_frame: usize,
}

impl Default for FrameDecoder {
    fn default() -> Self {
        FrameDecoder::new()
    }
}

impl FrameDecoder {
    /// A decoder with the default [`MAX_FRAME_BYTES`] frame ceiling.
    pub fn new() -> FrameDecoder {
        FrameDecoder::with_max_frame(MAX_FRAME_BYTES)
    }

    /// A decoder with an explicit frame ceiling (tests use small ones
    /// to exercise the lying-prefix rejection cheaply).
    pub fn with_max_frame(max_frame: usize) -> FrameDecoder {
        FrameDecoder {
            buf: Vec::new(),
            start: 0,
            max_frame,
        }
    }

    /// Append raw socket bytes. Any split is fine — mid-prefix,
    /// mid-payload, several frames at once.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.start > 0 && self.start >= self.buf.len().max(4096) / 2 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame's payload, `Ok(None)` while more
    /// bytes are needed. A prefix claiming more than the ceiling is a
    /// wire error (the connection is poisoned — callers drop it).
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>> {
        let avail = &self.buf[self.start..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_be_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
        if len > self.max_frame {
            return Err(CloneCloudError::Wire(format!(
                "frame length prefix {len} exceeds the {}-byte ceiling",
                self.max_frame
            )));
        }
        if avail.len() < 4 + len {
            return Ok(None);
        }
        let frame = avail[4..4 + len].to_vec();
        self.start += 4 + len;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        }
        Ok(Some(frame))
    }

    /// Unconsumed bytes currently buffered (partial prefix + payload).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Whether the decoder sits mid-frame: it has seen at least one
    /// byte of the next frame but not all of it. This is the bit the
    /// blocking transport uses to tell "idle peer" (clean timeout /
    /// EOF) from "peer died mid-capsule" (hard error).
    pub fn mid_frame(&self) -> bool {
        self.buffered() > 0
    }
}

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Provision a clone process: Zygote size, template seed, program
    /// hash (the executable itself arrives via file sync — both sides
    /// load the same binary).
    Provision {
        /// Zygote template size (object count) to boot with.
        zygote_objects: u32,
        /// Deterministic template seed (same seed ⇒ same names).
        zygote_seed: u64,
        /// FNV-1a identity of the executable both sides must share.
        program_hash: u64,
    },
    /// Synchronize the phone file system to the clone.
    SyncFs(SimFs),
    /// A forward capture: migrate this thread to the clone.
    Migrate(Vec<u8>),
    /// A reverse capture: the thread coming home.
    Reintegrate(Vec<u8>),
    /// Positive acknowledgement (provision/sync).
    Ack,
    /// Remote failure.
    Error(String),
    /// Tear down the clone.
    Shutdown,
    /// Capability negotiation (v3, bitmap since v4). The phone sends its
    /// protocol version, whether it speaks delta capsules, and its
    /// capability bitmap (codec flags); the clone answers with its own
    /// `Hello` carrying the *negotiated* (min) revision. Deltas flow
    /// only when both said `delta = true`; compressed frames only when
    /// both bitmaps share a codec bit. Unknown bits MUST be ignored,
    /// never rejected. On the wire the bitmap is present only when
    /// `proto >= 4` (a v3-shaped `Hello` has no caps field; it decodes
    /// as `caps = 0`), so a v4 responder stays byte-compatible with v3
    /// initiators.
    Hello {
        /// Protocol revision the sender speaks (responders echo the
        /// negotiated minimum).
        proto: u16,
        /// Whether the sender offers delta capsules.
        delta: bool,
        /// Capability bitmap (`CAP_*`); rides the wire only when
        /// `proto >= 4`.
        caps: u32,
    },
    /// The clone rejected a delta capsule (no/incoherent baseline); the
    /// phone must resend the migration as a full capture.
    NeedFull(String),
    /// Digest-only liveness probe for the session baseline (v4): the
    /// mobile endpoint sends its baseline epoch + canonical digest after
    /// an idle interval, piggybacking any pending MID assignments. A
    /// coherent clone answers `Ack`; a divergent/slotless one answers
    /// `NeedFull`, pre-arming a full capture *before* a doomed delta is
    /// built and shipped.
    Heartbeat {
        /// The sender's baseline heap epoch.
        base_epoch: u64,
        /// Canonical session digest at that epoch.
        digest: u64,
        /// (clone id, assigned mobile id) pairs from the last reverse
        /// merge (same bookkeeping a forward delta would carry).
        assignments: Vec<(u64, u64)>,
    },
}

impl Msg {
    /// Encode to the tagged wire form ([`Msg::decode`] inverts it).
    /// Fails (typed `Wire` error) only when a collection count exceeds
    /// the u32 wire limit — the old `as u32` cast silently truncated the
    /// count and produced a frame the receiver misparses.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut w = WireWriter::new();
        match self {
            Msg::Provision {
                zygote_objects,
                zygote_seed,
                program_hash,
            } => {
                w.put_u8(0);
                w.put_u32(*zygote_objects);
                w.put_u64(*zygote_seed);
                w.put_u64(*program_hash);
            }
            Msg::SyncFs(fs) => {
                w.put_u8(1);
                w.put_count(fs.count())?;
                for i in 0..fs.count() {
                    let f = fs.file(i).unwrap();
                    w.put_str(&f.name);
                    w.put_bytes(&f.bytes);
                }
            }
            Msg::Migrate(b) => {
                w.put_u8(2);
                w.put_bytes(b);
            }
            Msg::Reintegrate(b) => {
                w.put_u8(3);
                w.put_bytes(b);
            }
            Msg::Ack => w.put_u8(4),
            Msg::Error(e) => {
                w.put_u8(5);
                w.put_str(e);
            }
            Msg::Shutdown => w.put_u8(6),
            Msg::Hello { proto, delta, caps } => {
                w.put_u8(7);
                w.put_u16(*proto);
                w.put_u8(u8::from(*delta));
                // The caps bitmap exists only from v4 on; a Hello
                // stamped with an older revision keeps the v3 shape.
                if *proto >= COMPRESS_MIN_PROTO {
                    w.put_u32(*caps);
                }
            }
            Msg::NeedFull(reason) => {
                w.put_u8(8);
                w.put_str(reason);
            }
            Msg::Heartbeat {
                base_epoch,
                digest,
                assignments,
            } => {
                w.put_u8(9);
                w.put_u64(*base_epoch);
                w.put_u64(*digest);
                w.put_count(assignments.len())?;
                for (cid, mid) in assignments {
                    w.put_u64(*cid);
                    w.put_u64(*mid);
                }
            }
        }
        Ok(w.into_vec())
    }

    /// Decode one tagged message. Strict: unknown tags and trailing
    /// bytes are errors, and hostile counts are clamped by
    /// [`WireReader::checked_count`] before any allocation.
    pub fn decode(buf: &[u8]) -> Result<Msg> {
        let mut r = WireReader::new(buf);
        let tag = r.get_u8()?;
        let msg = match tag {
            0 => Msg::Provision {
                zygote_objects: r.get_u32()?,
                zygote_seed: r.get_u64()?,
                program_hash: r.get_u64()?,
            },
            1 => {
                let n = r.get_u32()? as usize;
                // Each file needs at least its two length prefixes.
                let n = r.checked_count(n, 8)?;
                let mut fs = SimFs::new();
                for _ in 0..n {
                    let name = r.get_str()?;
                    let bytes = r.get_bytes()?;
                    fs.add(&name, bytes);
                }
                Msg::SyncFs(fs)
            }
            2 => Msg::Migrate(r.get_bytes()?),
            3 => Msg::Reintegrate(r.get_bytes()?),
            4 => Msg::Ack,
            5 => Msg::Error(r.get_str()?),
            6 => Msg::Shutdown,
            7 => {
                let proto = r.get_u16()?;
                let delta = r.get_u8()? != 0;
                let caps = if proto >= COMPRESS_MIN_PROTO {
                    r.get_u32()?
                } else {
                    0
                };
                Msg::Hello { proto, delta, caps }
            }
            8 => Msg::NeedFull(r.get_str()?),
            9 => {
                let base_epoch = r.get_u64()?;
                let digest = r.get_u64()?;
                let n = r.get_u32()? as usize;
                let n = r.checked_count(n, 16)?;
                let mut assignments = Vec::with_capacity(n);
                for _ in 0..n {
                    let cid = r.get_u64()?;
                    let mid = r.get_u64()?;
                    assignments.push((cid, mid));
                }
                Msg::Heartbeat {
                    base_epoch,
                    digest,
                    assignments,
                }
            }
            t => return Err(CloneCloudError::Transport(format!("bad message tag {t}"))),
        };
        if !r.is_done() {
            return Err(CloneCloudError::Transport("trailing bytes in message".into()));
        }
        Ok(msg)
    }
}

/// Deterministic FNV-1a hash of a program's assembly/bytecode identity —
/// used to confirm the synchronized executable matches before migrating.
pub fn program_hash(p: &crate::appvm::Program) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    };
    for c in &p.classes {
        eat(c.name.as_bytes());
        for m in &c.methods {
            eat(m.name.as_bytes());
            eat(&(m.code.len() as u32).to_be_bytes());
            for i in &m.code {
                eat(format!("{i:?}").as_bytes());
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_roundtrip() {
        let mut fs = SimFs::new();
        fs.add("a", vec![1, 2, 3]);
        let msgs = vec![
            Msg::Provision {
                zygote_objects: 40_000,
                zygote_seed: 7,
                program_hash: 0xDEAD,
            },
            Msg::SyncFs(fs),
            Msg::Migrate(vec![9, 9, 9]),
            Msg::Reintegrate(vec![1]),
            Msg::Ack,
            Msg::Error("boom".into()),
            Msg::Shutdown,
            Msg::Hello {
                proto: PROTO_VERSION,
                delta: true,
                caps: SUPPORTED_CAPS,
            },
            Msg::Hello {
                proto: 2,
                delta: false,
                caps: 0,
            },
            Msg::NeedFull("baseline digest mismatch".into()),
            Msg::Heartbeat {
                base_epoch: 12,
                digest: 0xFEED_FACE,
                assignments: vec![(100, 1), (101, 2)],
            },
        ];
        for m in msgs {
            assert_eq!(Msg::decode(&m.encode().unwrap()).unwrap(), m);
        }
    }

    /// Generate an arbitrary protocol message: random payload sizes
    /// (including empty frames), random file sets, random strings.
    fn gen_msg(rng: &mut crate::util::rng::Rng) -> Msg {
        match rng.index(10) {
            0 => Msg::Provision {
                zygote_objects: rng.next_u64() as u32,
                zygote_seed: rng.next_u64(),
                program_hash: rng.next_u64(),
            },
            1 => {
                let mut fs = SimFs::new();
                for i in 0..rng.index(4) {
                    let mut bytes = vec![0u8; rng.index(2048)];
                    rng.fill_bytes(&mut bytes);
                    fs.add(&format!("f{i}"), bytes);
                }
                Msg::SyncFs(fs)
            }
            2 => {
                let mut b = vec![0u8; rng.index(4096)]; // 0 = empty frame
                rng.fill_bytes(&mut b);
                Msg::Migrate(b)
            }
            3 => {
                let mut b = vec![0u8; rng.index(4096)];
                rng.fill_bytes(&mut b);
                Msg::Reintegrate(b)
            }
            4 => Msg::Ack,
            5 => {
                let n = rng.index(128);
                let s: String = (0..n).map(|_| (b'a' + rng.byte() % 26) as char).collect();
                Msg::Error(s)
            }
            6 => {
                let proto = rng.next_u64() as u16;
                Msg::Hello {
                    proto,
                    delta: rng.chance(0.5),
                    // Arbitrary bits, including ones this build does not
                    // know: the bitmap invariant says they must survive
                    // the codec untouched and be ignored by negotiation.
                    // Pre-v4 Hellos have no caps field on the wire, so
                    // only `caps = 0` round-trips for them.
                    caps: if proto >= COMPRESS_MIN_PROTO {
                        rng.next_u64() as u32
                    } else {
                        0
                    },
                }
            }
            7 => {
                let n = rng.index(64);
                let s: String = (0..n).map(|_| (b'a' + rng.byte() % 26) as char).collect();
                Msg::NeedFull(s)
            }
            8 => Msg::Heartbeat {
                base_epoch: rng.next_u64(),
                digest: rng.next_u64(),
                assignments: (0..rng.index(5))
                    .map(|_| (rng.next_u64(), rng.next_u64()))
                    .collect(),
            },
            _ => Msg::Shutdown,
        }
    }

    #[test]
    fn prop_messages_roundtrip() {
        use crate::util::prop::{ensure_eq, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xC10E_A11,
                cases: 200,
            },
            gen_msg,
            |m| {
                let bytes = m.encode().map_err(|e| format!("encode failed: {e}"))?;
                let decoded = Msg::decode(&bytes)
                    .map_err(|e| format!("decode failed: {e}"))?;
                ensure_eq(decoded, m.clone(), "decode(encode(m))")
            },
        );
    }

    #[test]
    fn prop_strict_prefixes_never_decode() {
        use crate::util::prop::{ensure, forall, PropConfig};
        // Every field is length-prefixed and decode demands exhaustion, so
        // any strict prefix of a valid encoding must be a clean error
        // (never a panic, never a silent partial parse).
        forall(
            PropConfig {
                seed: 0xC10E_A12,
                cases: 200,
            },
            |rng| {
                let bytes = gen_msg(rng).encode().unwrap();
                let cut = rng.index(bytes.len());
                (bytes, cut)
            },
            |(bytes, cut)| ensure(Msg::decode(&bytes[..*cut]).is_err(), "prefix decoded"),
        );
    }

    #[test]
    fn prop_garbage_never_panics() {
        use crate::util::prop::{forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xC10E_A13,
                cases: 300,
            },
            |rng| {
                let mut b = vec![0u8; rng.index(256)];
                rng.fill_bytes(&mut b);
                b
            },
            |bytes| {
                let _ = Msg::decode(bytes); // Ok or Err both fine; no panic.
                Ok(())
            },
        );
    }

    #[test]
    fn negotiation_is_symmetric_and_ignores_unknown_bits() {
        // Same-build peers agree on LZ.
        assert_eq!(codec_agreed(PROTO_VERSION, SUPPORTED_CAPS), Codec::Lz);
        // Unknown high bits are ignored, never rejected.
        assert_eq!(codec_agreed(PROTO_VERSION, 0xFFFF_FFFF), Codec::Lz);
        assert_eq!(codec_agreed(PROTO_VERSION, !SUPPORTED_CAPS), Codec::None);
        // A pre-v4 peer never gets compressed frames, whatever it waves.
        assert_eq!(codec_agreed(3, SUPPORTED_CAPS), Codec::None);
        // A future peer lands on our revision's answer.
        assert_eq!(codec_agreed(u16::MAX, SUPPORTED_CAPS | 0xF0), Codec::Lz);
        // Delta requires the v4 digest domain (statics included) on
        // both ends; a v3 peer negotiates full-captures-only.
        assert!(delta_agreed(PROTO_VERSION, true));
        assert!(!delta_agreed(3, true), "v3 digests are incomparable");
    }

    #[test]
    fn dict_negotiation_needs_bit_and_revision_on_both_ends() {
        let v = PROTO_VERSION;
        let all = SUPPORTED_CAPS;
        assert!(dict_agreed(v, all, v, all));
        // Unknown high bits are ignored, never rejected.
        assert!(dict_agreed(v, all, v, 0xFFFF_FFFF));
        // Either side withholding the bit lands on per-capsule tables.
        assert!(!dict_agreed(v, all, v, CAP_CODEC_LZ));
        assert!(!dict_agreed(v, CAP_CODEC_LZ, v, all));
        // A pre-v4 peer has no caps bitmap at all.
        assert!(!dict_agreed(v, all, 3, all));
        assert!(!dict_agreed(3, all, v, all));
        // A future peer lands on our revision's answer.
        assert!(dict_agreed(v, all, u16::MAX, all | 0xF0));
        // The locally-scoped codec negotiation masks the same way.
        assert_eq!(codec_agreed_at(v, CAP_SESSION_DICT, v, all), Codec::None);
        assert_eq!(codec_agreed_at(3, all, v, all), Codec::None);
    }

    #[test]
    fn trace_negotiation_needs_bit_and_revision_on_both_ends() {
        let v = PROTO_VERSION;
        let all = SUPPORTED_CAPS;
        assert!(trace_agreed(v, all, v, all));
        // Unknown high bits are ignored, never rejected.
        assert!(trace_agreed(v, all, v, 0xFFFF_FFFF));
        // Either side withholding the bit disables the envelope.
        assert!(!trace_agreed(v, all, v, all & !CAP_TRACE_CTX));
        assert!(!trace_agreed(v, all & !CAP_TRACE_CTX, v, all));
        // A pre-v4 peer has no caps bitmap at all.
        assert!(!trace_agreed(v, all, 3, all));
        assert!(!trace_agreed(3, all, v, all));
        // A future peer lands on our revision's answer.
        assert!(trace_agreed(v, all, u16::MAX, all | 0xF0));
        // Orthogonal to dict/codec: trace-only caps give trace only.
        assert!(trace_agreed(v, CAP_TRACE_CTX, v, CAP_TRACE_CTX));
        assert!(!dict_agreed(v, CAP_TRACE_CTX, v, CAP_TRACE_CTX));
        assert_eq!(codec_agreed_at(v, CAP_TRACE_CTX, v, CAP_TRACE_CTX), Codec::None);
    }

    /// A v3-shaped Hello (no caps field) decodes on a v4 build, and a
    /// min-revision reply to it re-encodes in the v3 shape — the wire
    /// compatibility the responder side promises.
    #[test]
    fn v3_shaped_hello_stays_wire_compatible() {
        let mut w = WireWriter::new();
        w.put_u8(7);
        w.put_u16(3);
        w.put_u8(1);
        let v3_bytes = w.into_vec();
        let decoded = Msg::decode(&v3_bytes).unwrap();
        assert_eq!(
            decoded,
            Msg::Hello {
                proto: 3,
                delta: true,
                caps: 0
            }
        );
        // The responder echoes the negotiated (min) revision: the
        // encoding must match what a v3 decoder expects, byte for byte.
        let reply = Msg::Hello {
            proto: 3,
            delta: false,
            caps: 0,
        };
        assert_eq!(reply.encode().unwrap().len(), v3_bytes.len());
    }

    // ---- frame layer (negotiated compression) ---------------------------

    /// A capsule-shaped payload: zero-heavy body behind a known magic.
    fn compressible_payload(rng: &mut crate::util::rng::Rng) -> Vec<u8> {
        let mut b = 0x4343_4850u32.to_be_bytes().to_vec(); // "CCHP"
        b.extend(std::iter::repeat(0u8).take(512 + rng.index(2048)));
        b.extend((0..rng.index(64)).map(|_| rng.byte()));
        b
    }

    #[test]
    fn prop_sealed_frames_roundtrip_and_shrink() {
        use crate::util::prop::{ensure, ensure_eq, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xF4A_3E01,
                cases: 100,
            },
            compressible_payload,
            |raw| {
                let sealed = seal_frame(Codec::Lz, raw.clone());
                ensure(sealed.len() < raw.len(), "compressible frame shrank")?;
                let opened = open_frame(&sealed).map_err(|e| format!("open: {e}"))?;
                ensure_eq(opened.into_owned(), raw.clone(), "open(seal(raw))")
            },
        );
    }

    #[test]
    fn prop_sealed_frame_strict_prefixes_never_open() {
        use crate::util::prop::{ensure, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xF4A_3E02,
                cases: 100,
            },
            |rng| {
                let sealed = seal_frame(Codec::Lz, compressible_payload(rng));
                // Cuts shorter than the magic read as a raw (unsealed)
                // payload by design; every cut that keeps the magic must
                // fail to open.
                let cut = 4 + rng.index(sealed.len() - 4);
                (sealed, cut)
            },
            |(sealed, cut)| ensure(open_frame(&sealed[..*cut]).is_err(), "prefix opened"),
        );
    }

    #[test]
    fn prop_garbage_frames_never_panic() {
        use crate::util::prop::{forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xF4A_3E03,
                cases: 300,
            },
            |rng| {
                // Half the cases start from the real frame magic so the
                // fuzz reaches the container parser, not just the
                // passthrough.
                let mut b = if rng.chance(0.5) {
                    FRAME_MAGIC.to_be_bytes().to_vec()
                } else {
                    Vec::new()
                };
                let mut tail = vec![0u8; rng.index(256)];
                rng.fill_bytes(&mut tail);
                b.extend_from_slice(&tail);
                b
            },
            |bytes| {
                let _ = open_frame(bytes); // Ok or Err; no panic
                Ok(())
            },
        );
    }

    #[test]
    fn prop_incompressible_frames_pass_through() {
        use crate::util::prop::{ensure, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xF4A_3E04,
                cases: 100,
            },
            |rng| {
                let mut b = vec![0u8; 16 + rng.index(1024)];
                rng.fill_bytes(&mut b);
                b
            },
            |raw| {
                // Random bytes do not compress: seal must fall back to
                // the identity so the wire never grows, and open must
                // hand the same bytes back untouched.
                let sealed = seal_frame(Codec::Lz, raw.clone());
                ensure(sealed == *raw, "incompressible input rode raw")?;
                let opened = open_frame(&sealed).map_err(|e| format!("open: {e}"))?;
                ensure(opened.as_ref() == &raw[..], "passthrough intact")
            },
        );
    }

    #[test]
    fn codec_none_is_identity() {
        let raw = vec![0u8; 4096];
        assert_eq!(seal_frame(Codec::None, raw.clone()), raw);
        assert_eq!(open_frame(&raw).unwrap().into_owned(), raw);
    }

    /// The preserved-head path: a sealed frame keeps its first bytes
    /// uncompressed, a patch lands inside them without resealing, and
    /// the opened payload shows exactly the patched bytes. Patches
    /// outside the preserved head are refused.
    #[test]
    fn sealed_frames_allow_head_patching() {
        let mut raw = vec![0u8; 2048];
        for (i, b) in raw.iter_mut().enumerate().take(32) {
            *b = i as u8; // a distinctive head
        }
        let mut wire = seal_frame_keep_head(Codec::Lz, raw.clone(), 19);
        assert!(wire.len() < raw.len(), "zero-heavy tail still compressed");

        let patch = [0xAA; 8];
        patch_frame_payload(&mut wire, 11, &patch).unwrap();
        let mut expect = raw.clone();
        expect[11..19].copy_from_slice(&patch);
        assert_eq!(open_frame(&wire).unwrap().into_owned(), expect);
        assert!(
            patch_frame_payload(&mut wire, 12, &patch).is_err(),
            "patch crossing out of the preserved head is refused"
        );

        // Raw (unsealed) frames patch directly at the payload offset.
        let mut plain = raw.clone();
        patch_frame_payload(&mut plain, 11, &patch).unwrap();
        assert_eq!(plain, expect);
    }

    // ---- scatter sub-job / sub-result framing ---------------------------

    /// Generate an arbitrary legal sub-job frame, covering the shard
    /// count 1 edge and empty payloads.
    fn gen_sub_job(rng: &mut crate::util::rng::Rng) -> SubJobFrame {
        let shards = 1 + rng.index(9) as u16; // 1..=9: count 1 is legal
        let shard = rng.index(shards as usize) as u16;
        let mut payload = vec![0u8; rng.index(2048)]; // 0 = empty capsule slot
        rng.fill_bytes(&mut payload);
        SubJobFrame {
            shard,
            shards,
            payload,
        }
    }

    #[test]
    fn prop_sub_frames_roundtrip() {
        use crate::util::prop::{ensure, ensure_eq, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0x5CA_77E1,
                cases: 200,
            },
            gen_sub_job,
            |j| {
                let bytes = j.encode();
                ensure(is_sub_job(&bytes), "magic recognized")?;
                let back = decode_sub_job(&bytes).map_err(|e| format!("decode: {e}"))?;
                ensure_eq(back, j.clone(), "decode(encode(j))")?;
                let reply = encode_sub_result(j.shard, &j.payload);
                ensure(!is_sub_job(&reply), "result magic is distinct")?;
                let (shard, payload) =
                    decode_sub_result(&reply).map_err(|e| format!("decode result: {e}"))?;
                ensure_eq(shard, j.shard, "result shard index")?;
                ensure_eq(payload, j.payload.clone(), "result payload")
            },
        );
    }

    #[test]
    fn prop_sub_frame_strict_prefixes_never_decode() {
        use crate::util::prop::{ensure, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0x5CA_77E2,
                cases: 200,
            },
            |rng| {
                let j = gen_sub_job(rng);
                let job_bytes = j.encode();
                let res_bytes = encode_sub_result(j.shard, &j.payload);
                let job_cut = rng.index(job_bytes.len());
                let res_cut = rng.index(res_bytes.len());
                (job_bytes, job_cut, res_bytes, res_cut)
            },
            |(job, job_cut, res, res_cut)| {
                ensure(
                    decode_sub_job(&job[..*job_cut]).is_err(),
                    "sub-job prefix decoded",
                )?;
                ensure(
                    decode_sub_result(&res[..*res_cut]).is_err(),
                    "sub-result prefix decoded",
                )
            },
        );
    }

    #[test]
    fn prop_sub_frame_garbage_never_panics() {
        use crate::util::prop::{forall, PropConfig};
        forall(
            PropConfig {
                seed: 0x5CA_77E3,
                cases: 300,
            },
            |rng| {
                // Half the cases start from a real magic so the fuzz
                // reaches the body parsers, not just the magic check.
                let mut b = match rng.index(3) {
                    0 => SUB_JOB_MAGIC.to_be_bytes().to_vec(),
                    1 => SUB_RESULT_MAGIC.to_be_bytes().to_vec(),
                    _ => Vec::new(),
                };
                let mut tail = vec![0u8; rng.index(256)];
                rng.fill_bytes(&mut tail);
                b.extend_from_slice(&tail);
                b
            },
            |bytes| {
                let _ = decode_sub_job(bytes); // Ok or Err both fine
                let _ = decode_sub_result(bytes); // no panic either way
                Ok(())
            },
        );
    }

    /// Shard-count edge cases: 0 is a typed error, 1 roundtrips, and an
    /// out-of-range shard index is rejected.
    #[test]
    fn sub_job_shard_count_edges() {
        let one = SubJobFrame {
            shard: 0,
            shards: 1,
            payload: vec![0xAB; 7],
        };
        assert_eq!(decode_sub_job(&one.encode()).unwrap(), one);

        // Hand-build a zero-count frame (encode of a legal frame can
        // never produce one).
        let mut w = WireWriter::new();
        w.put_u32(SUB_JOB_MAGIC);
        w.put_u16(SUB_FRAME_VERSION);
        w.put_u16(0);
        w.put_u16(0);
        w.put_bytes(&[]);
        let err = decode_sub_job(&w.into_vec()).unwrap_err().to_string();
        assert!(err.contains("shard count 0"), "{err}");

        let mut w = WireWriter::new();
        w.put_u32(SUB_JOB_MAGIC);
        w.put_u16(SUB_FRAME_VERSION);
        w.put_u16(3);
        w.put_u16(3);
        w.put_bytes(&[1, 2]);
        let err = decode_sub_job(&w.into_vec()).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");

        // Unknown framing version: typed error on both frame kinds.
        let mut w = WireWriter::new();
        w.put_u32(SUB_RESULT_MAGIC);
        w.put_u16(SUB_FRAME_VERSION + 1);
        w.put_u16(0);
        w.put_bytes(&[]);
        assert!(decode_sub_result(&w.into_vec()).is_err());
    }

    /// The scatter capability bit negotiates like every other bit:
    /// unknown high bits ignored, pre-v4 peers never see it.
    #[test]
    fn scatter_cap_is_advertised_and_maskable() {
        assert_ne!(SUPPORTED_CAPS & CAP_SCATTER, 0);
        assert_eq!(CAP_SCATTER & (CAP_CODEC_LZ | CAP_SESSION_DICT | CAP_TRACE_CTX), 0);
    }

    // ---- incremental frame decoder --------------------------------------

    /// Length-prefix a payload the way the TCP transport does.
    fn framed(payload: &[u8]) -> Vec<u8> {
        let mut v = (payload.len() as u32).to_be_bytes().to_vec();
        v.extend_from_slice(payload);
        v
    }

    /// Drain every complete frame currently buffered.
    fn drain(d: &mut FrameDecoder) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(f) = d.next_frame().unwrap() {
            out.push(f);
        }
        out
    }

    #[test]
    fn frame_decoder_byte_at_a_time() {
        let frames: Vec<Vec<u8>> = vec![vec![], vec![7], vec![1, 2, 3, 4, 5]];
        let stream: Vec<u8> = frames.iter().flat_map(|f| framed(f)).collect();
        let mut d = FrameDecoder::new();
        let mut got = Vec::new();
        for &b in &stream {
            d.feed(&[b]);
            got.extend(drain(&mut d));
        }
        assert_eq!(got, frames);
        assert_eq!(d.buffered(), 0);
        assert!(!d.mid_frame());
    }

    /// Split a two-frame stream at EVERY byte boundary: the decoder must
    /// hand back exactly the same frames regardless of where the socket
    /// happened to cut.
    #[test]
    fn frame_decoder_split_at_every_boundary() {
        let a = vec![0xAB; 37];
        let b = vec![0xCD; 5];
        let mut stream = framed(&a);
        stream.extend_from_slice(&framed(&b));
        for cut in 0..=stream.len() {
            let mut d = FrameDecoder::new();
            d.feed(&stream[..cut]);
            let mut got = drain(&mut d);
            // Mid-frame exactly when the cut left unconsumed bytes.
            assert_eq!(d.mid_frame(), d.buffered() > 0);
            d.feed(&stream[cut..]);
            got.extend(drain(&mut d));
            assert_eq!(got, vec![a.clone(), b.clone()], "cut at {cut}");
            assert_eq!(d.buffered(), 0);
        }
    }

    #[test]
    fn prop_frame_decoder_random_chunking_roundtrips() {
        use crate::util::prop::{ensure, ensure_eq, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xF4A_3E05,
                cases: 100,
            },
            |rng| {
                let frames: Vec<Vec<u8>> = (0..1 + rng.index(5))
                    .map(|_| {
                        let mut b = vec![0u8; rng.index(512)];
                        rng.fill_bytes(&mut b);
                        b
                    })
                    .collect();
                let stream: Vec<u8> = frames.iter().flat_map(|f| framed(f)).collect();
                // Random cut points, sorted, possibly duplicated.
                let mut cuts: Vec<usize> =
                    (0..rng.index(8)).map(|_| rng.index(stream.len() + 1)).collect();
                cuts.sort_unstable();
                (frames, stream, cuts)
            },
            |(frames, stream, cuts)| {
                let mut d = FrameDecoder::new();
                let mut got = Vec::new();
                let mut prev = 0usize;
                for &c in cuts.iter().chain(std::iter::once(&stream.len())) {
                    d.feed(&stream[prev..c]);
                    prev = c;
                    while let Some(f) =
                        d.next_frame().map_err(|e| format!("next_frame: {e}"))?
                    {
                        got.push(f);
                    }
                }
                ensure_eq(got, frames.clone(), "frames survive arbitrary chunking")?;
                ensure(d.buffered() == 0, "stream fully consumed")
            },
        );
    }

    #[test]
    fn prop_frame_decoder_garbage_never_panics() {
        use crate::util::prop::{ensure, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xF4A_3E06,
                cases: 300,
            },
            |rng| {
                let mut b = vec![0u8; rng.index(256)];
                rng.fill_bytes(&mut b);
                b
            },
            |bytes| {
                let mut d = FrameDecoder::with_max_frame(4096);
                let mut fed = 0usize;
                for chunk in bytes.chunks(7) {
                    d.feed(chunk);
                    fed += chunk.len();
                    loop {
                        match d.next_frame() {
                            Ok(Some(_)) => continue,
                            Ok(None) => break,
                            Err(_) => break, // lying prefix: clean error
                        }
                    }
                    // Bounded buffering: the decoder never holds more
                    // than what was actually fed, whatever the prefixes
                    // claim.
                    ensure(d.buffered() <= fed, "buffering bounded by bytes fed")?;
                }
                Ok(())
            },
        );
    }

    /// A lying length prefix is rejected as soon as the 4 prefix bytes
    /// arrive — no payload-sized allocation, no waiting for a payload
    /// that will never come.
    #[test]
    fn frame_decoder_rejects_lying_prefix_immediately() {
        let mut d = FrameDecoder::with_max_frame(1024);
        d.feed(&(1025u32).to_be_bytes());
        let err = d.next_frame().unwrap_err().to_string();
        assert!(err.contains("ceiling"), "got: {err}");
        // At the exact ceiling it is a legal (pending) frame.
        let mut d = FrameDecoder::with_max_frame(1024);
        d.feed(&(1024u32).to_be_bytes());
        assert!(d.next_frame().unwrap().is_none());
        assert!(d.mid_frame());
        d.feed(&[3u8; 1024]);
        assert_eq!(d.next_frame().unwrap().unwrap().len(), 1024);
    }

    /// Long sessions stay O(1): the consumed prefix is compacted away,
    /// so a million tiny frames never grow the buffer.
    #[test]
    fn frame_decoder_compacts_consumed_bytes() {
        let mut d = FrameDecoder::new();
        let one = framed(&[0x11; 16]);
        for _ in 0..10_000 {
            d.feed(&one);
            assert_eq!(d.next_frame().unwrap().unwrap(), vec![0x11; 16]);
        }
        assert_eq!(d.buffered(), 0);
        assert!(d.buf.len() < 8 * one.len(), "buffer stays compacted");
    }

    #[test]
    fn program_hash_distinguishes_programs() {
        let a = crate::appvm::assembler::assemble(
            "class A app\n  method main nargs=0 regs=1\n    retv\n  end\nend\n",
        )
        .unwrap();
        let b = crate::appvm::assembler::assemble(
            "class A app\n  method main nargs=0 regs=1\n    nop\n    retv\n  end\nend\n",
        )
        .unwrap();
        assert_ne!(program_hash(&a), program_hash(&b));
        assert_eq!(program_hash(&a), program_hash(&a));
    }
}
