//! Node-manager wire protocol.
//!
//! The per-node managers speak a small framed protocol over a single
//! channel (paper §4: "it amortizes the cost of communicating with the
//! cloud over a single ... transport channel"): provisioning, file-system
//! synchronization, thread migration, and reintegration.

use crate::error::{CloneCloudError, Result};
use crate::util::bytes::{WireReader, WireWriter};
use crate::vfs::SimFs;

/// Protocol revision spoken by this build. v3 adds `Hello` capability
/// negotiation and the delta-migration frames; `Migrate`/`Reintegrate`
/// payloads may carry delta capsules only after both peers `Hello` with
/// `delta = true` (older peers never send `Hello`, so they are never
/// offered deltas).
pub const PROTO_VERSION: u16 = 3;

/// Lowest protocol revision that understands delta capsules. Both peers
/// agree on `min(theirs, ours)`, so a future-version peer and a v3 peer
/// still land on the same answer (checking `proto >= PROTO_VERSION` on
/// each side would let version skew arm exactly one end).
pub const DELTA_MIN_PROTO: u16 = 3;

/// The delta decision both Hello peers compute: the negotiated revision
/// is the minimum of the two, and it must know delta capsules.
pub fn delta_agreed(peer_proto: u16, peer_delta: bool) -> bool {
    peer_delta && peer_proto.min(PROTO_VERSION) >= DELTA_MIN_PROTO
}

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Provision a clone process: Zygote size, template seed, program
    /// hash (the executable itself arrives via file sync — both sides
    /// load the same binary).
    Provision {
        zygote_objects: u32,
        zygote_seed: u64,
        program_hash: u64,
    },
    /// Synchronize the phone file system to the clone.
    SyncFs(SimFs),
    /// A forward capture: migrate this thread to the clone.
    Migrate(Vec<u8>),
    /// A reverse capture: the thread coming home.
    Reintegrate(Vec<u8>),
    /// Positive acknowledgement (provision/sync).
    Ack,
    /// Remote failure.
    Error(String),
    /// Tear down the clone.
    Shutdown,
    /// Capability negotiation (v3). The phone sends its protocol version
    /// and whether it speaks delta capsules; the clone answers with its
    /// own `Hello`. Deltas flow only when both said `delta = true`.
    Hello { proto: u16, delta: bool },
    /// The clone rejected a delta capsule (no/incoherent baseline); the
    /// phone must resend the migration as a full capture.
    NeedFull(String),
}

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        match self {
            Msg::Provision {
                zygote_objects,
                zygote_seed,
                program_hash,
            } => {
                w.put_u8(0);
                w.put_u32(*zygote_objects);
                w.put_u64(*zygote_seed);
                w.put_u64(*program_hash);
            }
            Msg::SyncFs(fs) => {
                w.put_u8(1);
                w.put_u32(fs.count() as u32);
                for i in 0..fs.count() {
                    let f = fs.file(i).unwrap();
                    w.put_str(&f.name);
                    w.put_bytes(&f.bytes);
                }
            }
            Msg::Migrate(b) => {
                w.put_u8(2);
                w.put_bytes(b);
            }
            Msg::Reintegrate(b) => {
                w.put_u8(3);
                w.put_bytes(b);
            }
            Msg::Ack => w.put_u8(4),
            Msg::Error(e) => {
                w.put_u8(5);
                w.put_str(e);
            }
            Msg::Shutdown => w.put_u8(6),
            Msg::Hello { proto, delta } => {
                w.put_u8(7);
                w.put_u16(*proto);
                w.put_u8(u8::from(*delta));
            }
            Msg::NeedFull(reason) => {
                w.put_u8(8);
                w.put_str(reason);
            }
        }
        w.into_vec()
    }

    pub fn decode(buf: &[u8]) -> Result<Msg> {
        let mut r = WireReader::new(buf);
        let tag = r.get_u8()?;
        let msg = match tag {
            0 => Msg::Provision {
                zygote_objects: r.get_u32()?,
                zygote_seed: r.get_u64()?,
                program_hash: r.get_u64()?,
            },
            1 => {
                let n = r.get_u32()? as usize;
                let mut fs = SimFs::new();
                for _ in 0..n {
                    let name = r.get_str()?;
                    let bytes = r.get_bytes()?;
                    fs.add(&name, bytes);
                }
                Msg::SyncFs(fs)
            }
            2 => Msg::Migrate(r.get_bytes()?),
            3 => Msg::Reintegrate(r.get_bytes()?),
            4 => Msg::Ack,
            5 => Msg::Error(r.get_str()?),
            6 => Msg::Shutdown,
            7 => Msg::Hello {
                proto: r.get_u16()?,
                delta: r.get_u8()? != 0,
            },
            8 => Msg::NeedFull(r.get_str()?),
            t => return Err(CloneCloudError::Transport(format!("bad message tag {t}"))),
        };
        if !r.is_done() {
            return Err(CloneCloudError::Transport("trailing bytes in message".into()));
        }
        Ok(msg)
    }
}

/// Deterministic FNV-1a hash of a program's assembly/bytecode identity —
/// used to confirm the synchronized executable matches before migrating.
pub fn program_hash(p: &crate::appvm::Program) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    };
    for c in &p.classes {
        eat(c.name.as_bytes());
        for m in &c.methods {
            eat(m.name.as_bytes());
            eat(&(m.code.len() as u32).to_be_bytes());
            for i in &m.code {
                eat(format!("{i:?}").as_bytes());
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_roundtrip() {
        let mut fs = SimFs::new();
        fs.add("a", vec![1, 2, 3]);
        let msgs = vec![
            Msg::Provision {
                zygote_objects: 40_000,
                zygote_seed: 7,
                program_hash: 0xDEAD,
            },
            Msg::SyncFs(fs),
            Msg::Migrate(vec![9, 9, 9]),
            Msg::Reintegrate(vec![1]),
            Msg::Ack,
            Msg::Error("boom".into()),
            Msg::Shutdown,
            Msg::Hello {
                proto: PROTO_VERSION,
                delta: true,
            },
            Msg::Hello {
                proto: 2,
                delta: false,
            },
            Msg::NeedFull("baseline digest mismatch".into()),
        ];
        for m in msgs {
            assert_eq!(Msg::decode(&m.encode()).unwrap(), m);
        }
    }

    /// Generate an arbitrary protocol message: random payload sizes
    /// (including empty frames), random file sets, random strings.
    fn gen_msg(rng: &mut crate::util::rng::Rng) -> Msg {
        match rng.index(9) {
            0 => Msg::Provision {
                zygote_objects: rng.next_u64() as u32,
                zygote_seed: rng.next_u64(),
                program_hash: rng.next_u64(),
            },
            1 => {
                let mut fs = SimFs::new();
                for i in 0..rng.index(4) {
                    let mut bytes = vec![0u8; rng.index(2048)];
                    rng.fill_bytes(&mut bytes);
                    fs.add(&format!("f{i}"), bytes);
                }
                Msg::SyncFs(fs)
            }
            2 => {
                let mut b = vec![0u8; rng.index(4096)]; // 0 = empty frame
                rng.fill_bytes(&mut b);
                Msg::Migrate(b)
            }
            3 => {
                let mut b = vec![0u8; rng.index(4096)];
                rng.fill_bytes(&mut b);
                Msg::Reintegrate(b)
            }
            4 => Msg::Ack,
            5 => {
                let n = rng.index(128);
                let s: String = (0..n).map(|_| (b'a' + rng.byte() % 26) as char).collect();
                Msg::Error(s)
            }
            6 => Msg::Hello {
                proto: rng.next_u64() as u16,
                delta: rng.chance(0.5),
            },
            7 => {
                let n = rng.index(64);
                let s: String = (0..n).map(|_| (b'a' + rng.byte() % 26) as char).collect();
                Msg::NeedFull(s)
            }
            _ => Msg::Shutdown,
        }
    }

    #[test]
    fn prop_messages_roundtrip() {
        use crate::util::prop::{ensure_eq, forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xC10E_A11,
                cases: 200,
            },
            gen_msg,
            |m| {
                let decoded = Msg::decode(&m.encode())
                    .map_err(|e| format!("decode failed: {e}"))?;
                ensure_eq(decoded, m.clone(), "decode(encode(m))")
            },
        );
    }

    #[test]
    fn prop_strict_prefixes_never_decode() {
        use crate::util::prop::{ensure, forall, PropConfig};
        // Every field is length-prefixed and decode demands exhaustion, so
        // any strict prefix of a valid encoding must be a clean error
        // (never a panic, never a silent partial parse).
        forall(
            PropConfig {
                seed: 0xC10E_A12,
                cases: 200,
            },
            |rng| {
                let bytes = gen_msg(rng).encode();
                let cut = rng.index(bytes.len());
                (bytes, cut)
            },
            |(bytes, cut)| ensure(Msg::decode(&bytes[..*cut]).is_err(), "prefix decoded"),
        );
    }

    #[test]
    fn prop_garbage_never_panics() {
        use crate::util::prop::{forall, PropConfig};
        forall(
            PropConfig {
                seed: 0xC10E_A13,
                cases: 300,
            },
            |rng| {
                let mut b = vec![0u8; rng.index(256)];
                rng.fill_bytes(&mut b);
                b
            },
            |bytes| {
                let _ = Msg::decode(bytes); // Ok or Err both fine; no panic.
                Ok(())
            },
        );
    }

    #[test]
    fn program_hash_distinguishes_programs() {
        let a = crate::appvm::assembler::assemble(
            "class A app\n  method main nargs=0 regs=1\n    retv\n  end\nend\n",
        )
        .unwrap();
        let b = crate::appvm::assembler::assemble(
            "class A app\n  method main nargs=0 regs=1\n    nop\n    retv\n  end\nend\n",
        )
        .unwrap();
        assert_ne!(program_hash(&a), program_hash(&b));
        assert_eq!(program_hash(&a), program_hash(&a));
    }
}
